"""R5 bad fixture: donated buffers read after the donating call."""
import jax


def _step_impl(buf, n):
    return buf * n


step = jax.jit(_step_impl, donate_argnums=(0,))


def run(buf, n):
    out = step(buf, n)                                      # EXPECT-R5
    return out + buf.sum()


def run_loop(buf, n):
    out = buf
    for _ in range(n):
        out = step(buf, 2)                                  # EXPECT-R5
    return out
