"""R4 bad fixture: host round-trips on traced values inside jitted code."""
import jax
import jax.numpy as jnp


@jax.jit
def step(x, y):
    if x > 0:                                               # EXPECT-R4
        y = y + 1
    n = int(jnp.sum(y))                                     # EXPECT-R4
    return x * n


def _cond(c):
    return c[0] < 8


def _body(c):
    i, s = c
    return (i + 1, s + float(s.sum()))                      # EXPECT-R4


def loop(x):
    return jax.lax.while_loop(_cond, _body, (0, x))
