"""R4 good twin: static args, shape tests, and on-device control flow."""
import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("scale",))
def step(x, y, scale=2):
    if scale > 1:                  # static_argnames: concrete at trace time
        y = y * scale
    if x.shape[0] > 4:             # shapes are static under trace
        y = y[:4]
    return jnp.where(x[:4] > 0, y, 0.0)


def _body(c):
    i, s = c
    return (i + 1, s + jnp.sum(s))


def loop(x):
    return jax.lax.while_loop(lambda c: c[0] < 8, _body, (0, x))
