"""R1 good twin: graph/ uses numpy and graph siblings only."""
import numpy as np

from good_r1.graph import adjacency


def order(g):
    return np.argsort(adjacency.degrees(g))
