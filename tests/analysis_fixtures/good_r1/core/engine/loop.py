"""R1 good twin: all set algebra through the ops dispatch layer."""
from good_r1.kernels.bitset_ops import ops as bitops


def expand(rows, mask):
    return bitops.and_popcount_rows(rows, mask)
