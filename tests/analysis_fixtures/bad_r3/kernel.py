"""R3 bad fixture: integer-axis reduction + misaligned literal BlockSpec.

Mosaic rejects integer-dtype axis reductions (`jnp.sum` on the int32
popcount output) and block shapes whose trailing dims are neither
(8, 128)-multiples nor equal to the array dims.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _degree_kernel(rows_ref, mask_ref, deg_ref):
    anded = rows_ref[...] & mask_ref[...]
    pc = jax.lax.population_count(anded)
    deg_ref[...] = jnp.sum(pc, axis=1, keepdims=True)       # EXPECT-R3


def degrees(rows, mask):
    k, w = rows.shape
    return pl.pallas_call(
        _degree_kernel,
        grid=(k // 8,),
        in_specs=[pl.BlockSpec((8, 120), lambda i: (i, 0)),  # EXPECT-R3
                  pl.BlockSpec((1, w), lambda i: (0, 0))],
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.int32),
        out_specs=pl.BlockSpec((8, 1), lambda i: (i, 0)),
    )(rows, mask)


def _windowed_kernel(rows_ref, out_ref, acc_ref, stats_ref):
    acc_ref[...] = rows_ref[...]
    out_ref[...] = acc_ref[...]


def _lanes_kernel(rows_ref, rsz_ref, out_ref):
    out_ref[0] = rows_ref[0] + rsz_ref[0, 0]


def lanes(rows, rsz):
    # per-lane scalar row WITHOUT memory_space=SMEM: the (1, 8) literal
    # block lands in VMEM where the 128-multiple tiling rule applies
    l, k, w = rows.shape
    return pl.pallas_call(
        _lanes_kernel,
        grid=(l,),
        in_specs=[pl.BlockSpec((1, k, w), lambda i: (i, 0, 0)),
                  pl.BlockSpec((1, 8), lambda i: (i, 0))],  # EXPECT-R3
        out_shape=jax.ShapeDtypeStruct((l, k, w), jnp.int32),
        out_specs=pl.BlockSpec((1, k, w), lambda i: (i, 0, 0)),
    )(rows, rsz)


def windowed(rows, t):
    k, w = rows.shape
    return pl.pallas_call(
        _windowed_kernel,
        in_specs=[pl.BlockSpec((k, w), lambda: (0, 0))],
        out_shape=jax.ShapeDtypeStruct((k, w), jnp.uint32),
        out_specs=pl.BlockSpec((k, w), lambda: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((8, 100), jnp.uint32),   # EXPECT-R3
            pltpu.VMEM((t, 128), jnp.uint32),   # EXPECT-R3
        ],
    )(rows)
