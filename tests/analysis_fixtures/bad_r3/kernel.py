"""R3 bad fixture: integer-axis reduction + misaligned literal BlockSpec.

Mosaic rejects integer-dtype axis reductions (`jnp.sum` on the int32
popcount output) and block shapes whose trailing dims are neither
(8, 128)-multiples nor equal to the array dims.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _degree_kernel(rows_ref, mask_ref, deg_ref):
    anded = rows_ref[...] & mask_ref[...]
    pc = jax.lax.population_count(anded)
    deg_ref[...] = jnp.sum(pc, axis=1, keepdims=True)       # EXPECT-R3


def degrees(rows, mask):
    k, w = rows.shape
    return pl.pallas_call(
        _degree_kernel,
        grid=(k // 8,),
        in_specs=[pl.BlockSpec((8, 120), lambda i: (i, 0)),  # EXPECT-R3
                  pl.BlockSpec((1, w), lambda i: (0, 0))],
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.int32),
        out_specs=pl.BlockSpec((8, 1), lambda i: (i, 0)),
    )(rows, mask)
