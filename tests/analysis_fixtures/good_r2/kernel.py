"""R2 good twin: the tile-local pivot kernel that replaced the PR-1 bug.

Each grid step writes only its own output block, exactly once, from its
own inputs — idempotent and batch-safe; the argmax over tile scores runs
in jnp outside the kernel (the current bitset_ops design).
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pivot_kernel(rows_ref, mask_ref, score_ref):
    anded = rows_ref[...] & mask_ref[...]
    pc = jax.lax.population_count(anded).astype(jnp.float32)
    score_ref[...] = jnp.sum(pc, axis=1, keepdims=True)


def pivot_scores(rows, mask):
    k, w = rows.shape
    return pl.pallas_call(
        _pivot_kernel,
        grid=(k // 8,),
        in_specs=[pl.BlockSpec((8, w), lambda i: (i, 0)),
                  pl.BlockSpec((1, w), lambda i: (0, 0))],
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.float32),
        out_specs=pl.BlockSpec((8, 1), lambda i: (i, 0)),
    )(rows, mask)
