"""R3 good twin: f32 accumulation (exact below 2^24), aligned blocks,
literal (8, 128)-aligned VMEM scratch (SMEM scalar scratch is exempt)."""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _degree_kernel(rows_ref, mask_ref, deg_ref):
    anded = rows_ref[...] & mask_ref[...]
    pc = jax.lax.population_count(anded).astype(jnp.float32)
    deg_ref[...] = jnp.sum(pc, axis=1, keepdims=True).astype(jnp.int32)


def degrees(rows, mask):
    k, w = rows.shape
    return pl.pallas_call(
        _degree_kernel,
        grid=(k // 8,),
        in_specs=[pl.BlockSpec((8, 128), lambda i: (i, 0)),
                  pl.BlockSpec((1, w), lambda i: (0, 0))],
        out_shape=jax.ShapeDtypeStruct((k, 1), jnp.int32),
        out_specs=pl.BlockSpec((8, 1), lambda i: (i, 0)),
    )(rows, mask)


def _windowed_kernel(rows_ref, out_ref, acc_ref, idx_ref):
    acc_ref[...] = rows_ref[...]
    out_ref[...] = acc_ref[...]


def _lanes_kernel(rows_ref, rsz_ref, out_ref, ctl_ref, acc_ref, loc_ref):
    acc_ref[...] = rows_ref[0]
    out_ref[0] = acc_ref[...]
    ctl_ref[0, 0, 0] = rsz_ref[0, 0, 0]


def lanes(rows, rsz):
    l, k, w = rows.shape
    return pl.pallas_call(
        _lanes_kernel,
        grid=(l,),
        in_specs=[pl.BlockSpec((1, k, w), lambda i: (i, 0, 0)),
                  # per-lane scalar row: Mosaic checks the LAST TWO block
                  # dims even in SMEM, so the lane axis is the mapped
                  # leading dim and the trailing (1, 8) block matches the
                  # (l, 1, 8) array's trailing dims exactly
                  pl.BlockSpec((1, 1, 8), lambda i: (i, 0, 0),
                               memory_space=pltpu.SMEM)],
        out_shape=(jax.ShapeDtypeStruct((l, k, w), jnp.uint32),
                   jax.ShapeDtypeStruct((l, 1, 8), jnp.int32)),
        out_specs=(pl.BlockSpec((1, k, w), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1, 1, 8), lambda i: (i, 0, 0),
                                memory_space=pltpu.SMEM)),
        scratch_shapes=[
            pltpu.VMEM((8, 128), jnp.uint32),   # per-lane resident window
            pltpu.SMEM((8,), jnp.int32),
        ],
    )(rows, rsz)


def windowed(rows):
    k, w = rows.shape
    return pl.pallas_call(
        _windowed_kernel,
        in_specs=[pl.BlockSpec((k, w), lambda: (0, 0))],
        out_shape=jax.ShapeDtypeStruct((k, w), jnp.uint32),
        out_specs=pl.BlockSpec((k, w), lambda: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((8, 128), jnp.uint32),   # resident window: literal
            pltpu.SMEM((8,), jnp.int32),        # scalar memory: exempt
        ],
    )(rows)
