"""R5 good twin: donate-and-rebind — the double-buffered driver idiom."""
import jax


def _step_impl(buf, n):
    return buf * n


step = jax.jit(_step_impl, donate_argnums=(0,))


def run(buf, n):
    buf = step(buf, n)             # rebind over the donated name: safe
    return buf.sum()


def run_loop(buf, n):
    for _ in range(n):
        buf = step(buf, 2)         # loop-carried rebind: safe
    return buf
