"""R2 bad fixture: minimized copy of the PR-1 cross-grid pivot kernel.

The original bug: pivot scores were accumulated into the output block
across grid steps, with a `program_id(0) == 0` init. Under `jax.vmap`
the batching rule prepends the batch axis to the grid, so program_id(0)
became the *batch* index — every batch member after the first skipped
the init and folded its scores into the previous member's accumulator.
Wrong pivots, wrong (but plausible) clique counts.
"""
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pivot_kernel(rows_ref, mask_ref, best_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        best_ref[...] = jnp.zeros_like(best_ref)            # EXPECT-R2

    anded = rows_ref[...] & mask_ref[...]
    pc = jax.lax.population_count(anded).astype(jnp.float32)
    score = jnp.sum(pc, axis=1, keepdims=True)
    best_ref[...] = jnp.maximum(best_ref[...], score)       # EXPECT-R2


def pivot_scores(rows, mask):
    k, w = rows.shape
    return pl.pallas_call(
        _pivot_kernel,
        grid=(k // 8,),
        in_specs=[pl.BlockSpec((8, w), lambda i: (i, 0)),
                  pl.BlockSpec((1, w), lambda i: (0, 0))],
        out_shape=jax.ShapeDtypeStruct((1, 128), jnp.float32),
        out_specs=pl.BlockSpec((1, 128), lambda i: (0, 0)),
    )(rows, mask)
