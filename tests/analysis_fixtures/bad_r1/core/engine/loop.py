"""R1 bad fixture: the PR-1 dead-kernel import plus an upward import.

These files are parsed by mce_lint, never imported by python — the
`bad_r1.*` modules do not exist at runtime.
"""
from bad_r1.kernels.bitset_ops import ref as bitref     # EXPECT-R1
from ...kernels.bitset_ops import kernel as _k          # EXPECT-R1
from bad_r1.core.driver import DistributedMCE           # EXPECT-R1


def expand(rows, mask):
    _ = DistributedMCE
    return _k.and_popcount_rows(rows, mask) + bitref.and_popcount_rows(
        rows, mask)
