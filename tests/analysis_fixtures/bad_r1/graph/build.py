"""R1 bad fixture: graph/ reaching upward into core/."""
import numpy as np

from bad_r1.core.driver import estimate_costs           # EXPECT-R1


def order(adj):
    return np.argsort(estimate_costs(adj))
