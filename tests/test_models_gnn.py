"""GNN stacks: per-arch smoke, invariance/equivariance properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.models import gnn as G
from repro.models.gnn_steps import (FORWARD, batch_from_graph,
                                    batch_molecules, make_gnn_train_step)
from repro.optim import adamw_init

GNN_ARCHS = [a for a in list_archs() if get_arch(a).family == "gnn"]


def _smoke_batch(arch, d_feat=8):
    return batch_molecules(4, 10, d_feat, seed=0,
                           with_triplets=(arch == "dimenet"))


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_smoke_forward(arch):
    cfg = get_arch(arch).build_smoke()
    _, init, fwd, _ = FORWARD[arch]
    b = {k: jnp.asarray(v) for k, v in _smoke_batch(arch).items()}
    params = init(cfg, jax.random.PRNGKey(0), 8)
    out = fwd(cfg, params, b)
    assert out.shape == (40,)
    assert not bool(jnp.any(jnp.isnan(out)))


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_arch(arch).build_smoke()
    _, init, _, _ = FORWARD[arch]
    b = {k: jnp.asarray(v) for k, v in _smoke_batch(arch).items()}
    params = init(cfg, jax.random.PRNGKey(0), 8)
    opt = adamw_init(params)
    step = jax.jit(make_gnn_train_step(arch, cfg, 4, lr=1e-3))
    losses = []
    for _ in range(5):
        params, opt, loss = step(params, opt, b)
        losses.append(float(loss))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]


def test_mgn_permutation_equivariance():
    """Relabeling nodes permutes MeshGraphNet outputs identically."""
    cfg = get_arch("meshgraphnet").build_smoke()
    _, init, fwd, _ = FORWARD["meshgraphnet"]
    b = _smoke_batch("meshgraphnet")
    params = init(cfg, jax.random.PRNGKey(0), 8)
    out = fwd(cfg, params, {k: jnp.asarray(v) for k, v in b.items()})

    rng = np.random.default_rng(0)
    perm = rng.permutation(40)
    inv = np.argsort(perm)
    b2 = dict(b)
    for k in ("node_feat", "positions", "node_mask", "graph_id", "targets"):
        b2[k] = b[k][perm]
    b2["src"] = inv[b["src"]].astype(np.int32)
    b2["dst"] = inv[b["dst"]].astype(np.int32)
    out2 = fwd(cfg, params, {k: jnp.asarray(v) for k, v in b2.items()})
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out)[perm],
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("arch", ["schnet", "mace"])
def test_rotation_invariance(arch):
    """E(3) invariance: rotating all positions leaves energies unchanged."""
    cfg = get_arch(arch).build_smoke()
    _, init, fwd, _ = FORWARD[arch]
    b = _smoke_batch(arch)
    params = init(cfg, jax.random.PRNGKey(0), 8)
    e1 = fwd(cfg, params, {k: jnp.asarray(v) for k, v in b.items()})
    # random rotation matrix via QR
    rng = np.random.default_rng(1)
    q, _ = np.linalg.qr(rng.normal(size=(3, 3)))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    b2 = dict(b, positions=(b["positions"] @ q.T).astype(np.float32))
    e2 = fwd(cfg, params, {k: jnp.asarray(v) for k, v in b2.items()})
    np.testing.assert_allclose(np.asarray(e2), np.asarray(e1),
                               rtol=1e-3, atol=1e-3)


def test_dimenet_angles_invariant():
    """DimeNet uses distances + angles only ⇒ rotation invariant too."""
    cfg = get_arch("dimenet").build_smoke()
    _, init, fwd, _ = FORWARD["dimenet"]
    b = _smoke_batch("dimenet")
    params = init(cfg, jax.random.PRNGKey(0), 8)
    e1 = fwd(cfg, params, {k: jnp.asarray(v) for k, v in b.items()})
    rng = np.random.default_rng(2)
    q, _ = np.linalg.qr(rng.normal(size=(3, 3)))
    b2 = dict(b, positions=(b["positions"] @ q.T).astype(np.float32))
    e2 = fwd(cfg, params, {k: jnp.asarray(v) for k, v in b2.items()})
    np.testing.assert_allclose(np.asarray(e2), np.asarray(e1),
                               rtol=1e-3, atol=1e-3)


def test_gaunt_tensor_properties():
    """Numerically derived Gaunt couplings: symmetry + l=0 normalisation."""
    from repro.models import equivariant as E3
    g = E3.gaunt_tensor()
    assert g.shape == (9, 9, 9)
    np.testing.assert_allclose(g, np.transpose(g, (1, 0, 2)), atol=1e-10)
    np.testing.assert_allclose(g, np.transpose(g, (2, 1, 0)), atol=1e-10)
    # ∫ Y_0 Y_i Y_j = δ_ij / sqrt(4π)
    c = 1.0 / np.sqrt(4 * np.pi)
    np.testing.assert_allclose(g[0], np.eye(9) * c, atol=1e-9)


def test_sph_harm_orthonormal():
    from repro.models import equivariant as E3
    n_t, n_p = 96, 192
    ct, wt = np.polynomial.legendre.leggauss(n_t)
    phi = (np.arange(n_p) + 0.5) * (2 * np.pi / n_p)
    st_ = np.sqrt(1 - ct**2)
    xyz = np.stack([st_[:, None] * np.cos(phi), st_[:, None] * np.sin(phi),
                    np.broadcast_to(ct[:, None], (n_t, n_p))], -1)
    ys = E3.real_sph_harm_l2(xyz, np_mod=np)
    w = wt[:, None] * (2 * np.pi / n_p)
    gram = np.einsum("tpi,tpj,tp->ij", ys, ys, w)
    np.testing.assert_allclose(gram, np.eye(9), atol=1e-9)


def test_mace_equivariance_of_tensor_product():
    """Gaunt tensor product commutes with rotations (Wigner-D action)."""
    from repro.models import equivariant as E3
    rng = np.random.default_rng(3)
    # random unit vectors -> Y(r) transforms exactly like the irrep basis
    v1 = rng.normal(size=(16, 3))
    v1 /= np.linalg.norm(v1, axis=-1, keepdims=True)
    q, _ = np.linalg.qr(rng.normal(size=(3, 3)))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    g = E3.gaunt_tensor()
    a = np.asarray(E3.real_sph_harm_l2(v1, np_mod=np))[..., None]   # (16,9,1)
    b = np.asarray(E3.real_sph_harm_l2(v1 @ q.T, np_mod=np))[..., None]
    prod_then_rot = np.asarray(E3.tensor_product(
        jnp.asarray(b), jnp.asarray(b), jnp.asarray(g)))
    # invariant (l=0) channel of the product must match un-rotated product
    prod = np.asarray(E3.tensor_product(
        jnp.asarray(a), jnp.asarray(a), jnp.asarray(g)))
    np.testing.assert_allclose(prod_then_rot[:, 0], prod[:, 0],
                               rtol=1e-4, atol=1e-5)
