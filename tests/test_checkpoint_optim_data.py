"""Checkpoint store (atomic/keep-k/async/elastic), optimizer, data pipeline."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data import Prefetcher, TokenStream
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_warmup, ef_int8_allreduce, ef_state_init)


def tree():
    return dict(a=jnp.arange(6.0).reshape(2, 3),
                b=dict(c=jnp.ones((4,), jnp.int32), d=jnp.float32(2.5)),
                e=[jnp.zeros((2,)), jnp.ones((3,))])


# --------------------------------------------------------------------------
# Checkpointing
# --------------------------------------------------------------------------

def test_roundtrip(tmp_path):
    t = tree()
    save_checkpoint(str(tmp_path), 3, t, meta=dict(cursor=7))
    t2, step, meta = load_checkpoint(str(tmp_path), t)
    assert step == 3 and meta["cursor"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), t, t2)


def test_keep_k_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree())
    names = sorted(os.listdir(tmp_path))
    assert names == ["step_00000003", "step_00000004"]
    assert mgr.latest_step() == 4


def test_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    mgr.save_async(5, tree(), meta=dict(x=1))
    mgr.wait()
    t2, step, meta = mgr.restore(tree())
    assert step == 5 and meta["x"] == 1


def test_torn_write_ignored(tmp_path):
    """A .tmp directory without manifest must not count as a checkpoint."""
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree())
    os.makedirs(tmp_path / "step_00000002.tmp")
    # un-committed (no manifest) directory
    os.makedirs(tmp_path / "step_00000003")
    assert mgr.latest_step() == 1


def test_elastic_reshard(tmp_path):
    """Restore with explicit shardings (1-device mesh ≅ re-shard path)."""
    t = tree()
    save_checkpoint(str(tmp_path), 1, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(
        lambda _: jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec()), t)
    t2, _, _ = load_checkpoint(str(tmp_path), t, shardings=sh)
    for leaf in jax.tree.leaves(t2):
        assert isinstance(leaf, jax.Array)


def test_train_restart_bitexact(tmp_path):
    """Kill at step k, resume from checkpoint ⇒ same final params as
    uninterrupted run (fault-tolerance contract)."""
    from repro.launch.train import train

    ck1 = str(tmp_path / "a")
    full = train("two-tower-retrieval", steps=8, ckpt_dir=ck1, ckpt_every=4)

    ck2 = str(tmp_path / "b")
    with pytest.raises(RuntimeError):
        train("two-tower-retrieval", steps=8, ckpt_dir=ck2, ckpt_every=4,
              fail_at_step=6)
    resumed = train("two-tower-retrieval", steps=8, ckpt_dir=ck2,
                    ckpt_every=4, resume=True)
    assert resumed["restored_from"] == 4
    jax.tree.map(
        lambda a, b: np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6),
        full["params"], resumed["params"])


# --------------------------------------------------------------------------
# Optimizer
# --------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    params = dict(x=jnp.asarray([5.0, -3.0]))
    opt = adamw_init(params)
    cfg = AdamWConfig(weight_decay=0.0, clip_norm=100.0)

    @jax.jit
    def step(p, o):
        loss, g = jax.value_and_grad(
            lambda q: jnp.sum(jnp.square(q["x"] - 1.0)))(p)
        p, o = adamw_update(p, g, o, jnp.float32(0.1), cfg)
        return p, o, loss

    for _ in range(300):
        params, opt, loss = step(params, opt)
    np.testing.assert_allclose(np.asarray(params["x"]), [1.0, 1.0], atol=1e-2)


def test_adamw_grad_clip():
    params = dict(x=jnp.asarray([0.0]))
    opt = adamw_init(params)
    cfg = AdamWConfig(clip_norm=1.0, weight_decay=0.0)
    g = dict(x=jnp.asarray([1e6]))
    p2, _ = adamw_update(params, g, opt, jnp.float32(0.1), cfg)
    assert abs(float(p2["x"][0])) < 0.2     # clipped step ≈ lr


def test_cosine_warmup_shape():
    lrs = [float(cosine_warmup(s, peak_lr=1.0, warmup=10, total=100))
           for s in range(100)]
    assert lrs[0] == 0.0
    assert abs(lrs[10] - 1.0) < 1e-6
    assert lrs[99] < 0.2
    assert all(b <= a + 1e-6 for a, b in zip(lrs[10:], lrs[11:]))


def test_ef_int8_allreduce_error_feedback():
    """Quantisation residual is carried: two steps of the same grad average
    to the true value much better than one-shot int8."""
    from functools import partial
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    g = dict(w=jnp.asarray(np.linspace(-1, 1, 256), jnp.float32) * 0.01)
    ef = ef_state_init(g)

    from repro.sharding.compat import shard_map

    @partial(shard_map, mesh=mesh, in_specs=(P(), P()),
             out_specs=(P(), P()), check_vma=False)
    def run(gg, ee):
        return ef_int8_allreduce(gg, ee, axis_name="data")

    out1, ef = run(g, ef)
    out2, ef = run(g, ef)
    avg = (np.asarray(out1["w"]) + np.asarray(out2["w"])) / 2
    np.testing.assert_allclose(avg, np.asarray(g["w"]), atol=2e-4)


# --------------------------------------------------------------------------
# Data pipeline
# --------------------------------------------------------------------------

def test_token_stream_determinism():
    s1 = TokenStream(vocab=1000, seq_len=16, global_batch=4, seed=7)
    s2 = TokenStream(vocab=1000, seq_len=16, global_batch=4, seed=7)
    a, ta = s1.batch(12)
    b, tb = s2.batch(12)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(ta, tb)
    assert a.shape == (4, 16) and a.max() < 1000 and a.min() >= 0
    # targets are the shifted stream
    c, _ = s1.batch(13)
    assert not np.array_equal(a, c)


def test_prefetcher_order_and_close():
    pf = Prefetcher(lambda step: step * step, depth=2, num_steps=5)
    got = [(s, v) for s, v in pf]
    assert got == [(i, i * i) for i in range(5)]
    pf.close()


def test_prefetcher_propagates_errors():
    def boom(step):
        if step == 2:
            raise ValueError("bad shard")
        return step

    pf = Prefetcher(boom, depth=1, num_steps=5)
    with pytest.raises(ValueError, match="bad shard"):
        list(pf)
