"""Mosaic lowering smoke tests: export the bitset kernels for a TPU target.

Every parity test in this suite runs the Pallas kernels in interpret mode,
and on this CPU container the compiled (interpret=False) path is otherwise
never exercised — so the first real TPU run would also be the first compile
attempt. `jax.export` runs the full Pallas→Mosaic lowering pipeline on any
host, which catches the failure classes Mosaic actually rejects without
needing hardware: integer-axis reductions (unimplemented), block shapes
whose last two dims are neither (8, 128)-divisible nor equal to the array
dims, and batching-rule breakage under vmap (the engine's real call
pattern). Numeric parity is covered by the interpret-mode tests; this file
only asserts the kernels *compile* for TPU, both plain and vmapped.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from jax import export
except ImportError:                           # pragma: no cover
    export = None

from repro.kernels.bitset_ops import kernel as bk

pytestmark = pytest.mark.skipif(export is None,
                                reason="jax.export not available")


def _rand(shape, seed):
    return jnp.asarray(np.random.default_rng(seed).integers(
        0, 2**32, shape, dtype=np.uint32))


def _lower_tpu(f, *args):
    exported = export.export(jax.jit(f), platforms=["tpu"])(*args)
    assert "tpu_custom_call" in exported.mlir_module()


# Default block sizes, K forcing both multi-tile grids and pad remainders.
K, W, M = 515, 8, 33


def test_lower_and_popcount_rows():
    _lower_tpu(lambda r, m: bk.and_popcount_rows(r, m, interpret=False),
               _rand((K, W), 0), _rand((W,), 1))


def test_lower_and_popcount_argmax():
    valid = jnp.asarray(np.random.default_rng(2).random(K) < 0.7)
    _lower_tpu(
        lambda r, m, v: bk.and_popcount_argmax(r, m, v, interpret=False),
        _rand((K, W), 3), _rand((W,), 4), valid)


def test_lower_and_popcount_many():
    _lower_tpu(lambda r, ms: bk.and_popcount_many(r, ms, interpret=False),
               _rand((K, W), 5), _rand((M, W), 6))


@pytest.mark.parametrize("k,m,w", [
    (100, 300, 32),               # shrinks bm with bk == K (single k tile)
    (600, 300, 32),               # shrinks bm with multiple k tiles
    (2000, 8, 512),               # bm floor reached, shrinks bk to 128
])
def test_lower_and_popcount_many_vmem_clamp(k, m, w):
    """Shapes that trip the VMEM tile clamp must still produce
    Mosaic-lowerable blocks (shrunk dims 8-/128-divisible or full-array)."""
    _lower_tpu(lambda r, ms: bk.and_popcount_many(r, ms, interpret=False),
               _rand((k, w), 14), _rand((m, w), 15))


def test_lower_clique_counts():
    flags = np.random.default_rng(24).random(K) < 0.5
    _lower_tpu(
        lambda r, m, p, x: bk.clique_counts(r, m, p, x, interpret=False),
        _rand((K, W), 25), _rand((W,), 26),
        jnp.asarray(flags), jnp.asarray(~flags))


def test_lower_frame_step():
    _lower_tpu(lambda r, p, x, wr: bk.frame_step(r, p, x, wr,
                                                 interpret=False),
               _rand((K, W), 16), _rand((W,), 17), _rand((W,), 18),
               _rand((W,), 19))


# Vmapped lowering: run_bucket vmaps run_root, so on TPU the pallas_calls
# compile with the batch axis prepended to the grid — lower exactly that.

B = 3


def test_lower_vmapped_and_popcount_rows():
    _lower_tpu(
        jax.vmap(lambda r, m: bk.and_popcount_rows(r, m, interpret=False)),
        _rand((B, K, W), 7), _rand((B, W), 8))


def test_lower_vmapped_and_popcount_argmax():
    valid = jnp.asarray(np.random.default_rng(9).random((B, K)) < 0.7)
    _lower_tpu(
        jax.vmap(lambda r, m, v: bk.and_popcount_argmax(
            r, m, v, interpret=False)),
        _rand((B, K, W), 10), _rand((B, W), 11), valid)


def test_lower_vmapped_and_popcount_many():
    _lower_tpu(
        jax.vmap(lambda r, ms: bk.and_popcount_many(r, ms, interpret=False)),
        _rand((B, K, W), 12), _rand((B, M, W), 13))


def test_lower_vmapped_clique_counts():
    flags = np.random.default_rng(27).random((B, K)) < 0.5
    _lower_tpu(
        jax.vmap(lambda r, m, p, x: bk.clique_counts(r, m, p, x,
                                                     interpret=False)),
        _rand((B, K, W), 28), _rand((B, W), 29),
        jnp.asarray(flags), jnp.asarray(~flags))


def test_lower_vmapped_frame_step():
    _lower_tpu(
        jax.vmap(lambda r, p, x, wr: bk.frame_step(r, p, x, wr,
                                                   interpret=False)),
        _rand((B, K, W), 20), _rand((B, W), 21), _rand((B, W), 22),
        _rand((B, W), 23))


# ---------------------------------------------------------------------------
# dfs_step_window: the fused VMEM stack-window kernel (plain + vmapped)
# ---------------------------------------------------------------------------

def _window_args(batch=None):
    """One plausible window invocation (U=64 vertices, 2 words, 8 frames)."""
    rng = np.random.default_rng(11)
    u, w, xc, t = 64, 2, 24, 8
    from repro.core.engine import frames as fr
    a = _rand((u, w), 11)
    xr = _rand((xc, w), 12)
    eye = fr.eye_bits(u, w)
    alive0 = jnp.asarray((rng.random(xc) < 0.5).astype(np.int32))
    winP = _rand((t, w), 13)
    zeros = jnp.zeros((t, w), jnp.uint32)
    winrsz = jnp.zeros((t,), jnp.int32)
    dloc = jnp.int32(0)
    args = (a, xr, eye, alive0, winP, zeros, zeros, zeros, winrsz, dloc)
    if batch is None:
        return args
    return tuple(x if i == 2 else jnp.stack([x] * batch)
                 for i, x in enumerate(args))


def test_lower_dfs_step_window():
    _lower_tpu(lambda *a: bk.dfs_step_window(*a, steps=16, interpret=False),
               *_window_args())


def test_lower_vmapped_dfs_step_window():
    # the engine vmaps run_root over a bucket; eye is shared (in_axes=None)
    f = lambda *a: bk.dfs_step_window(*a, steps=16, interpret=False)
    _lower_tpu(
        jax.vmap(f, in_axes=(0, 0, None, 0, 0, 0, 0, 0, 0, 0)),
        *_window_args(batch=2))


# ---------------------------------------------------------------------------
# dfs_step_window_lanes: the grid-over-lanes window kernel the persistent
# engine dispatches (plain + vmapped, eye shared)
# ---------------------------------------------------------------------------

def _lanes_args(nlanes=4, batch=None):
    args = _window_args()
    lanes = tuple(x if i == 2 else jnp.stack([x] * nlanes)
                  for i, x in enumerate(args))
    if batch is None:
        return lanes
    return tuple(x if i == 2 else jnp.stack([x] * batch)
                 for i, x in enumerate(lanes))


def test_lower_dfs_step_window_lanes():
    _lower_tpu(
        lambda *a: bk.dfs_step_window_lanes(*a, steps=16, interpret=False),
        *_lanes_args())


def test_lower_vmapped_dfs_step_window_lanes():
    # shard_map/vmap over device shards batches the lane axis; eye stays
    # shared (in_axes=None), same as the engine's call pattern
    f = lambda *a: bk.dfs_step_window_lanes(*a, steps=16, interpret=False)
    _lower_tpu(
        jax.vmap(f, in_axes=(0, 0, None, 0, 0, 0, 0, 0, 0, 0)),
        *_lanes_args(batch=2))
