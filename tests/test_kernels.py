"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs pure-jnp ref."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitset_ops import kernel as bk, ref as br
from repro.kernels.common_neighbor import kernel as ck, ref as cr
from repro.kernels.embedding_bag import kernel as ek, ref as er
from repro.kernels.segment_spmm import kernel as sk, ref as sr


# --------------------------------------------------------------------------
# bitset_ops: AND + popcount rows
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 7, 32, 100, 256, 515])
@pytest.mark.parametrize("w", [1, 4, 8, 32])
def test_and_popcount_rows(k, w):
    rng = np.random.default_rng(k * 1000 + w)
    rows = rng.integers(0, 2**32, (k, w), dtype=np.uint32)
    mask = rng.integers(0, 2**32, (w,), dtype=np.uint32)
    got = bk.and_popcount_rows(jnp.asarray(rows), jnp.asarray(mask),
                               interpret=True)
    want = br.and_popcount_rows(jnp.asarray(rows), jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # independent python-int cross-check of the ref itself
    m_int = int.from_bytes(mask.tobytes(), "little")
    want_np = np.array([bin(int.from_bytes(row.tobytes(), "little") & m_int
                            ).count("1") for row in rows])
    np.testing.assert_array_equal(np.asarray(want), want_np)


@pytest.mark.parametrize("block_k", [16, 64, 256])
def test_and_popcount_blocks(block_k):
    rng = np.random.default_rng(0)
    rows = rng.integers(0, 2**32, (200, 8), dtype=np.uint32)
    mask = rng.integers(0, 2**32, (8,), dtype=np.uint32)
    got = bk.and_popcount_rows(jnp.asarray(rows), jnp.asarray(mask),
                               block_k=block_k, interpret=True)
    want = br.and_popcount_rows(jnp.asarray(rows), jnp.asarray(mask))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# bitset_ops: fused is-P-a-clique / X-domination counts
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k", [1, 7, 100, 515])
@pytest.mark.parametrize("w", [1, 4, 8])
def test_clique_counts(k, w):
    rng = np.random.default_rng(k * 37 + w)
    rows = rng.integers(0, 2**32, (k, w), dtype=np.uint32)
    mask = rng.integers(0, 2**32, (w,), dtype=np.uint32)
    in_p = rng.random(k) < 0.5
    in_x = ~in_p & (rng.random(k) < 0.5)
    got = bk.clique_counts(jnp.asarray(rows), jnp.asarray(mask),
                           jnp.asarray(in_p), jnp.asarray(in_x),
                           interpret=True)
    want = br.clique_counts(jnp.asarray(rows), jnp.asarray(mask),
                            jnp.asarray(in_p), jnp.asarray(in_x))
    assert (int(got[0]), int(got[1])) == (int(want[0]), int(want[1]))
    # independent python-int cross-check of the ref itself
    m_int = int.from_bytes(mask.tobytes(), "little")
    msize = bin(m_int).count("1")
    full = dom = 0
    for ki in range(k):
        pc = bin(int.from_bytes(rows[ki].tobytes(), "little") & m_int
                 ).count("1")
        full += int(in_p[ki] and pc == msize - 1)
        dom += int(in_x[ki] and pc == msize)
    assert (int(want[0]), int(want[1])) == (full, dom)


def test_clique_counts_detects_clique():
    """A packed triangle: every P member adjacent to the other two."""
    # vertices 0,1,2 mutually adjacent -> rows[i] = P & ~bit(i)
    p = np.array([0b111], np.uint32)
    rows = np.array([[0b110], [0b101], [0b011],   # the triangle
                     [0b001]], np.uint32)         # an X row seeing only v0
    in_p = np.array([True, True, True, False])
    in_x = np.array([False, False, False, True])
    n_full, n_dom = br.clique_counts(jnp.asarray(rows), jnp.asarray(p),
                                     jnp.asarray(in_p), jnp.asarray(in_x))
    assert int(n_full) == 3          # == |P|: P is a clique
    assert int(n_dom) == 0           # the X row misses v1,v2: no domination
    # an X vertex adjacent to ALL of P dominates -> n_dom > 0
    rows[3] = 0b111
    _, n_dom = br.clique_counts(jnp.asarray(rows), jnp.asarray(p),
                                jnp.asarray(in_p), jnp.asarray(in_x))
    assert int(n_dom) == 1


# --------------------------------------------------------------------------
# common_neighbor: tiled existence check
# --------------------------------------------------------------------------

@pytest.mark.parametrize("e,d", [(1, 4), (10, 8), (130, 16), (257, 5)])
def test_common_neighbor(e, d):
    rng = np.random.default_rng(e * 31 + d)
    au = rng.integers(-1, 40, (e, d)).astype(np.int32)
    av = rng.integers(-1, 40, (e, d)).astype(np.int32)
    got = ck.has_common_neighbor(jnp.asarray(au), jnp.asarray(av),
                                 interpret=True)
    want = cr.has_common_neighbor(jnp.asarray(au), jnp.asarray(av))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# embedding_bag: one-hot GEMM vs take+mask reduce
# --------------------------------------------------------------------------

@pytest.mark.parametrize("v,d,b,l", [(64, 8, 16, 4), (512, 32, 100, 8),
                                     (1000, 16, 33, 12), (2048, 64, 256, 1)])
def test_embedding_bag(v, d, b, l):
    rng = np.random.default_rng(v + d + b + l)
    table = rng.normal(size=(v, d)).astype(np.float32)
    ids = np.where(rng.random((b, l)) < 0.8,
                   rng.integers(0, v, (b, l)), -1).astype(np.int32)
    got = ek.embedding_bag_sum(jnp.asarray(table), jnp.asarray(ids),
                               interpret=True)
    want = er.embedding_bag(jnp.asarray(table), jnp.asarray(ids), "sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("block_v", [64, 256])
def test_embedding_bag_vocab_tiles(block_v):
    rng = np.random.default_rng(9)
    table = rng.normal(size=(500, 16)).astype(np.float32)
    ids = rng.integers(-1, 500, (64, 6)).astype(np.int32)
    got = ek.embedding_bag_sum(jnp.asarray(table), jnp.asarray(ids),
                               block_v=block_v, interpret=True)
    want = er.embedding_bag(jnp.asarray(table), jnp.asarray(ids), "sum")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# segment_spmm: batched dense adjacency GEMM vs segment_sum
# --------------------------------------------------------------------------

@pytest.mark.parametrize("b,n,f", [(1, 8, 4), (8, 30, 16), (17, 12, 32)])
def test_dense_spmm(b, n, f):
    rng = np.random.default_rng(b * n + f)
    adj = (rng.random((b, n, n)) < 0.3).astype(np.float32)
    x = rng.normal(size=(b, n, f)).astype(np.float32)
    got = sk.dense_spmm(jnp.asarray(adj), jnp.asarray(x), interpret=True)
    want = sr.dense_spmm(jnp.asarray(adj), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_dense_spmm_matches_segment_sum():
    """The dense MXU path computes the same aggregation as the sparse path."""
    rng = np.random.default_rng(3)
    n, f = 20, 8
    adj = (rng.random((1, n, n)) < 0.3).astype(np.float32)
    x = rng.normal(size=(1, n, f)).astype(np.float32)
    src, dst = np.nonzero(adj[0].T)          # message j->i iff adj[i,j]
    agg = jax.ops.segment_sum(jnp.asarray(x[0][src]),
                              jnp.asarray(dst), num_segments=n)
    got = sk.dense_spmm(jnp.asarray(adj), jnp.asarray(x), interpret=True)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(agg),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# flash_attention: online-softmax tiles vs full-softmax ref
# --------------------------------------------------------------------------

from repro.kernels.flash_attention import kernel as fk, ref as fr


@pytest.mark.parametrize("bh,sq,sk,d,causal", [
    (2, 128, 128, 64, True), (3, 100, 100, 32, True),
    (1, 256, 256, 128, False), (4, 64, 192, 64, False),
    (2, 33, 70, 16, False),
])
def test_flash_attention(bh, sq, sk, d, causal):
    rng = np.random.default_rng(bh * sq + d)
    q = jnp.asarray(rng.normal(size=(bh, sq, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(bh, sk, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(bh, sk, d)).astype(np.float32))
    got = fk.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64,
                             interpret=True)
    want = fr.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("bq,bk", [(32, 32), (128, 64), (64, 256)])
def test_flash_attention_block_shapes(bq, bk):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 256, 64)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 256, 64)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 256, 64)).astype(np.float32))
    got = fk.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                             interpret=True)
    want = fr.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(size=(2, 128, 64))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 128, 64))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 128, 64))).astype(jnp.bfloat16)
    got = fk.flash_attention(q, k, v, causal=True, interpret=True)
    want = fr.flash_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_mha_layout():
    from repro.kernels.flash_attention.ops import mha
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(2, 64, 4, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 64, 4, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 64, 4, 32)).astype(np.float32))
    out = mha(q, k, v, causal=True)
    assert out.shape == (2, 64, 4, 32)
    # cross-check against the model's blockwise attention
    from repro.models.layers import blockwise_attention
    want = blockwise_attention(q, k, v, causal=True, kv_block=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
