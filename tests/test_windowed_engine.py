"""Windowed persistent lanes: VMEM-resident stack windows inside the
bucket-spanning megakernel (DESIGN.md §2.6 WINDOW).

Parity contract: windowing is pure scheduling. A windowed persistent run
must reproduce the unwindowed persistent AND per-root counters
bit-for-bit (cliques, calls, branches, sum_px) and the same enumerated
clique sets, with steals, staged refills, dynamic reduction, hybrid
early termination, and bounded-window spills all happening *inside* the
window trips.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import oracle
from repro.core.engine import (EngineConfig, choose_engine, run,
                               run_bucket, run_bucket_persistent)
from repro.launch.mce_service import MCEService
from repro.graph import generators as gen

from test_persistent_engine import (GRAPHS, _bucket_args, run_py,
                                    skewed_graph, _HUB_GRAPH_SRC)


def _counters(res):
    return (res.cliques, res.calls, res.branches, res.sum_px)


def _wtrips(stats):
    return stats["window_spills"] + stats["window_hits"]


# ---------------------------------------------------------------------------
# Windowed vs unwindowed vs perroot parity matrix (engine-step window path:
# dynamic reduction ON, so every backend runs the full dfs_step contract
# from inside the resident window)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["pivot", "rcd", "hybrid"])
@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_windowed_persistent_matches_perroot_counts(backend, gname):
    g = GRAPHS[gname]()
    ref = run(g, backend=backend, engine="perroot")
    plain = run(g, backend=backend, engine="persistent", lanes=7)
    win = run(g, backend=backend, engine="persistent", lanes=7,
              window_steps=8)
    assert _counters(win) == _counters(plain) == _counters(ref)
    assert win.cliques == len(oracle.bk_pivot(g))
    if ref.branches > 0:
        # caveman roots all complete inside their entry call (branches=0,
        # entry_terms=calls): no lane ever steps, so no trip is tallied
        assert _wtrips(win.stats) > 0
    assert _wtrips(plain.stats) == 0
    assert not win.iters_exhausted


@pytest.mark.parametrize("steps", [4, 32])
def test_windowed_step_count_is_pure_scheduling(steps):
    """Different K walk the same tree: only the trip boundaries move."""
    g = GRAPHS["ba"]()
    ref = run(g, engine="persistent", lanes=8)
    res = run(g, engine="persistent", lanes=8, window_steps=steps)
    assert _counters(res) == _counters(ref)
    assert _wtrips(res.stats) > 0


# ---------------------------------------------------------------------------
# Window contract beyond counting: enumeration buffers inside the window
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_windowed_enumerates_same_sets(gname):
    g = GRAPHS[gname]()
    ref = run(g, enumerate_cliques=True, engine="perroot")
    res = run(g, enumerate_cliques=True, engine="persistent", lanes=5,
              window_steps=8)
    assert not res.overflow and not ref.overflow
    assert set(res.enumerated) == set(ref.enumerated)
    assert set(res.enumerated) == set(oracle.bk_pivot(g))


# ---------------------------------------------------------------------------
# Refill during a window: the staged in-trip pool (counting mode) must
# hand dead lanes fresh roots mid-trip without perturbing any counter
# ---------------------------------------------------------------------------

def test_refill_during_window_regression():
    """Many more roots than lanes: every lane dies and restages from the
    in-trip pool dozens of times; the queue cursor must stay a prefix
    cursor and the counters must match the unwindowed queue exactly."""
    g = skewed_graph()
    ref = run(g, bucket_sizes=(64,), engine="persistent", lanes=8)
    res = run(g, bucket_sizes=(64,), engine="persistent", lanes=8,
              window_steps=16)
    assert _counters(res) == _counters(ref)
    assert res.cliques == len(oracle.bk_pivot(g))
    assert _wtrips(res.stats) > 0
    assert res.stats["entry_terms"] == ref.stats["entry_terms"]


def test_hybrid_entry_terms_inside_window():
    """Hybrid early termination fires for staged roots consumed mid-trip;
    the entry_terms tally must survive windowing bit-for-bit."""
    g = GRAPHS["caveman"]()
    ref = run(g, backend="hybrid", engine="persistent", lanes=8)
    res = run(g, backend="hybrid", engine="persistent", lanes=8,
              window_steps=8)
    assert _counters(res) == _counters(ref)
    assert res.stats["entry_terms"] == ref.stats["entry_terms"]
    assert res.stats["entry_terms"] > 0


# ---------------------------------------------------------------------------
# Steal during a window: the in-trip multi-way split must stay parity-exact
# ---------------------------------------------------------------------------

def test_steal_during_window_parity_and_counters():
    """Stealing from inside a window trip (multi-way rank partition of
    the victim's donation slot) is pure scheduling: counters identical
    windowed/unwindowed and with steals on/off, steal counter live."""
    g = skewed_graph(blob=40, p=0.6)
    on = run(g, bucket_sizes=(64,), engine="persistent", lanes=8,
             steal=True, window_steps=16)
    off = run(g, bucket_sizes=(64,), engine="persistent", lanes=8,
              steal=False, window_steps=16)
    plain = run(g, bucket_sizes=(64,), engine="persistent", lanes=8,
                steal=True)
    assert _counters(on) == _counters(off) == _counters(plain)
    assert on.cliques == len(oracle.bk_pivot(g))
    assert on.stats["steals"] > 0
    assert off.stats["steals"] == 0


def test_steal_during_window_enumerates_same_sets():
    g = skewed_graph(blob=40, p=0.6)
    on = run(g, enumerate_cliques=True, bucket_sizes=(64,),
             engine="persistent", lanes=8, steal=True, window_steps=8)
    off = run(g, enumerate_cliques=True, bucket_sizes=(64,),
              engine="persistent", lanes=8, steal=False, window_steps=8)
    assert not on.overflow and not off.overflow
    assert set(on.enumerated) == set(off.enumerated)
    assert set(on.enumerated) == set(oracle.bk_pivot(g))


# ---------------------------------------------------------------------------
# Steal victim policy knob (branchiest vs deepest): bit-identical either way
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("window_steps", [0, 8])
def test_steal_victim_policies_bit_identical(window_steps):
    g = skewed_graph(blob=40, p=0.6)
    br = run(g, bucket_sizes=(64,), engine="persistent", lanes=8,
             steal=True, steal_victim="branchiest",
             window_steps=window_steps)
    de = run(g, bucket_sizes=(64,), engine="persistent", lanes=8,
             steal=True, steal_victim="deepest",
             window_steps=window_steps)
    assert _counters(br) == _counters(de)
    assert br.cliques == len(oracle.bk_pivot(g))
    assert br.stats["steals"] > 0
    assert de.stats["steals"] > 0


# ---------------------------------------------------------------------------
# Bounded window_frames: spill/re-center traffic with a window shallower
# than the walk (the spill-slot regression fixture)
# ---------------------------------------------------------------------------

def test_bounded_window_frames_parity_and_spills():
    """window_frames=4 on a walk deeper than 4: every trip that tops out
    must flush, re-center on the live frame, and reload — counters still
    bit-identical, and the spill counter proves the boundary was hit."""
    g = gen.erdos_renyi(60, 0.3, seed=0)
    args = _bucket_args(g)
    ref = run_bucket(*args, EngineConfig())
    cfg = EngineConfig(window_steps=8, window_frames=4)
    out = run_bucket_persistent(*args, cfg, lanes=8)
    for k in ("cliques", "calls", "branches", "sum_px"):
        assert int(out[k].sum()) == int(ref[k].sum()), k
    assert int(out["window_spills"]) > 0
    # full-depth window on the same bucket: same counters again
    full = run_bucket_persistent(*args,
                                 dataclasses.replace(cfg, window_frames=0),
                                 lanes=8)
    for k in ("cliques", "calls", "branches", "sum_px"):
        assert int(full[k].sum()) == int(ref[k].sum()), k


def test_bounded_window_frames_with_steals():
    # engine-level reference (run() would fold in the host pre-reported
    # cliques the packed bucket never sees)
    g = skewed_graph(blob=40, p=0.6)
    args = _bucket_args(g)
    ref = run_bucket_persistent(*args, EngineConfig(), lanes=8)
    cfg = EngineConfig(window_steps=8, window_frames=6)
    out = run_bucket_persistent(*args, cfg, lanes=8)
    for k in ("cliques", "calls", "branches", "sum_px"):
        assert int(out[k].sum()) == int(ref[k].sum()), k
    assert int(out["steals"]) > 0


# ---------------------------------------------------------------------------
# choose_engine steal-policy boundary (the steal flag halves the skew
# threshold: stealing de-serializes moderate-skew buckets)
# ---------------------------------------------------------------------------

def test_choose_engine_steal_halves_skew_threshold():
    n = 64
    # moderate skew: between thr/2 and thr -> the flag decides
    mid = np.array([3.0] + [1.0] * (n - 1))
    skew = float(mid.max() / mid.mean())
    assert 2.0 < skew < 4.0
    assert choose_engine(mid)[0] == "perroot"
    assert choose_engine(mid, steal=True)[0] == "persistent"
    # below even the halved threshold: perroot either way
    low = np.array([1.8] + [1.0] * (n - 1))
    assert float(low.max() / low.mean()) < 2.0
    assert choose_engine(low)[0] == "perroot"
    assert choose_engine(low, steal=True)[0] == "perroot"
    # above the full threshold: persistent either way, same lane sizing
    high = np.array([1000.0] + [1.0] * (n - 1))
    assert choose_engine(high) == choose_engine(high, steal=True)
    assert choose_engine(high, steal=True)[0] == "persistent"
    # tiny buckets stay lock-step no matter how skewed or steal-capable
    tiny = np.array([99.0, 1.0, 1.0])
    assert choose_engine(tiny, steal=True)[0] == "perroot"
    # memoized-skew callers hit the same boundary
    assert choose_engine(skew=skew, n_roots=n, steal=True)[0] == "persistent"
    assert choose_engine(skew=skew, n_roots=n, steal=False)[0] == "perroot"


# ---------------------------------------------------------------------------
# Service surfacing: boundary_stall / stream_occupancy / window counters
# ---------------------------------------------------------------------------

def test_service_surfaces_window_stats():
    g = skewed_graph()
    svc = MCEService(g, chunk=64, stream_roots=128,
                     engine="persistent", lanes=8)
    ref = svc.query()                                 # unwindowed baseline
    assert _wtrips(ref.stats) == 0
    assert svc.boundary_stall() == 0.0
    res = svc.query(EngineConfig(window_steps=8))
    assert res.cliques == ref.cliques
    assert _wtrips(res.stats) > 0
    assert svc.stats["window_spills"] == res.stats["window_spills"]
    assert svc.stats["window_hits"] == res.stats["window_hits"]
    assert 0.0 <= svc.boundary_stall() <= 1.0
    assert 0.0 < svc.stream_occupancy() <= 1.0
    assert svc.stream_occupancy() == svc.occupancy()
    # a second unwindowed query must not move the window counters
    before = (svc.stats["window_spills"], svc.stats["window_hits"])
    svc.query()
    assert (svc.stats["window_spills"], svc.stats["window_hits"]) == before


# ---------------------------------------------------------------------------
# Mid-stream elastic restart (4 -> 2 shards) with a live window
# ---------------------------------------------------------------------------

def test_midstream_elastic_restart_with_live_window(tmp_path):
    """Preempt the windowed persistent driver mid-stream under 4 shards,
    resume under 2: window trips flush to the HBM stack at checkpoint
    boundaries, so the elastic cursor must land on exactly the remaining
    roots with zero count drift."""
    ck = str(tmp_path / "windowed.json")
    out4 = run_py(_HUB_GRAPH_SRC + f"""
        from repro.core.driver import DistributedMCE
        from repro.core.engine import EngineConfig
        drv = DistributedMCE(g, chunk=16, ckpt_path={ck!r},
                             bucket_sizes=(32, 64), stream_roots=64,
                             cfg=EngineConfig(window_steps=8),
                             engine="persistent", lanes=8)
        n = 0
        orig = drv._run_chunk
        def failing(*args):
            global n
            if n >= 3: raise RuntimeError("preempted")
            n += 1
            return orig(*args)
        drv._run_chunk = failing
        try:
            drv.run()
        except RuntimeError:
            pass
        print("PARTIAL_OK")
    """, devices=4)
    assert "PARTIAL_OK" in out4
    out2 = run_py(_HUB_GRAPH_SRC + f"""
        from repro.core.driver import DistributedMCE
        from repro.core import bitset_engine
        from repro.core.engine import EngineConfig
        ref = bitset_engine.run(g, bucket_sizes=(32, 64))
        drv = DistributedMCE(g, chunk=16, ckpt_path={ck!r},
                             bucket_sizes=(32, 64), stream_roots=64,
                             cfg=EngineConfig(window_steps=8),
                             engine="persistent", lanes=8)
        res = drv.run(resume=True)
        print("CLIQUES", res.cliques, ref.cliques)
        wt = (int(drv.last_counters.get("window_spills", 0))
              + int(drv.last_counters.get("window_hits", 0)))
        print("WTRIPS", wt)
        assert res.cliques == ref.cliques
        assert res.calls == ref.calls
        assert not res.iters_exhausted
        assert wt > 0
    """, devices=2)
    assert "CLIQUES" in out2
