"""System-level: arch registry completeness + per-arch smoke integration."""
import jax
import numpy as np
import pytest

from repro.configs import get_arch, list_archs

ASSIGNED = [
    "mixtral-8x7b", "phi3.5-moe-42b-a6.6b", "qwen3-14b", "chatglm3-6b",
    "command-r-plus-104b", "meshgraphnet", "schnet", "dimenet", "mace",
    "two-tower-retrieval",
]


def test_all_assigned_archs_registered():
    archs = list_archs()
    for a in ASSIGNED:
        assert a in archs, f"assigned arch {a} missing"
    assert "rmce" in archs, "the paper's own arch must be selectable"


def test_every_arch_has_four_cells():
    for a in ASSIGNED:
        spec = get_arch(a)
        cells = spec.shapes(spec.build())
        assert len(cells) == 4, f"{a} must expose 4 shape cells"


def test_assignment_matrix_is_40_cells():
    n = sum(len(get_arch(a).shapes(get_arch(a).build())) for a in ASSIGNED)
    assert n == 40


def test_exact_assigned_configs():
    """Configs carry the exact published numbers from the brief."""
    m = get_arch("mixtral-8x7b").build()
    assert (m.n_layers, m.d_model, m.n_heads, m.n_kv_heads, m.d_ff,
            m.vocab, m.n_experts, m.top_k) == (32, 4096, 32, 8, 14336,
                                               32000, 8, 2)
    p = get_arch("phi3.5-moe-42b-a6.6b").build()
    assert (p.n_layers, p.d_model, p.d_ff, p.vocab, p.n_experts) == \
        (32, 4096, 6400, 32064, 16)
    q = get_arch("qwen3-14b").build()
    assert (q.n_layers, q.d_model, q.n_heads, q.n_kv_heads, q.d_ff,
            q.vocab, q.qk_norm) == (40, 5120, 40, 8, 17408, 151936, True)
    c = get_arch("chatglm3-6b").build()
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (28, 4096, 32, 2, 13696, 65024)
    r = get_arch("command-r-plus-104b").build()
    assert (r.n_layers, r.d_model, r.n_heads, r.n_kv_heads, r.d_ff,
            r.vocab) == (64, 12288, 96, 8, 33792, 256000)
    g = get_arch("meshgraphnet").build()
    assert (g.n_layers, g.d_hidden, g.mlp_layers) == (15, 128, 2)
    s = get_arch("schnet").build()
    assert (s.n_interactions, s.d_hidden, s.n_rbf, s.cutoff) == \
        (3, 64, 300, 10.0)
    d = get_arch("dimenet").build()
    assert (d.n_blocks, d.d_hidden, d.n_bilinear, d.n_spherical,
            d.n_radial) == (6, 128, 8, 7, 6)
    ma = get_arch("mace").build()
    assert (ma.n_layers, ma.d_hidden, ma.l_max, ma.correlation,
            ma.n_rbf) == (2, 128, 2, 3, 8)
    t = get_arch("two-tower-retrieval").build()
    assert (t.embed_dim, t.tower_mlp, t.interaction) == \
        (256, (1024, 512, 256), "dot")


def test_long_context_skips_documented():
    """Full-attention archs skip long_500k with a reason; SWA mixtral runs."""
    for a in ("qwen3-14b", "chatglm3-6b", "command-r-plus-104b",
              "phi3.5-moe-42b-a6.6b"):
        spec = get_arch(a)
        cell = {c.name: c for c in spec.shapes(spec.build())}["long_500k"]
        assert cell.skip_reason
    mix = get_arch("mixtral-8x7b")
    cell = {c.name: c for c in mix.shapes(mix.build())}["long_500k"]
    assert cell.skip_reason is None


def test_end_to_end_small_train():
    """examples-grade integration: 10 steps of the e2e driver converge."""
    from repro.launch.train import train
    out = train("two-tower-retrieval", steps=10)
    assert np.isfinite(out["final_loss"])
    assert out["final_loss"] < out["losses"][0][1]
