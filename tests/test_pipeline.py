"""GPipe pipeline parallelism: exactness vs the plain forward + training."""
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 4, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_pipeline_matches_plain_forward():
    """4 stages × 4 microbatches reproduce the non-pipelined logits."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import transformer as T
        from repro.models.pipeline import pipeline_forward, stack_stages

        cfg = T.TransformerConfig(
            name="pp-test", n_layers=8, d_model=32, n_heads=4, n_kv_heads=2,
            d_head=8, d_ff=64, vocab=128, dtype="float32", remat="none")
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        toks = jnp.asarray(np.random.default_rng(0).integers(
            0, cfg.vocab, (8, 16)), jnp.int32)
        ref, _ = T.forward(cfg, params, toks)

        mesh = jax.make_mesh((4,), ("pp",))
        pp = dict(params, layers=stack_stages(params["layers"], 4))
        got = pipeline_forward(cfg, pp, toks, mesh=mesh, n_microbatches=4)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref),
                                   rtol=2e-4, atol=2e-4)
        print("PIPELINE_FWD_OK")
    """)
    assert "PIPELINE_FWD_OK" in out


def test_pipeline_training_converges():
    """GPipe backward (automatic ppermute transpose) trains the model."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import transformer as T
        from repro.models.pipeline import (make_pipeline_train_step,
                                           stack_stages)
        from repro.optim import adamw_init

        cfg = T.TransformerConfig(
            name="pp-train", n_layers=4, d_model=32, n_heads=4, n_kv_heads=2,
            d_head=8, d_ff=64, vocab=64, dtype="float32", remat="none")
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        mesh = jax.make_mesh((4,), ("pp",))
        pp = dict(params, layers=stack_stages(params["layers"], 4))
        opt = adamw_init(pp)
        rng = np.random.default_rng(1)
        toks = jnp.asarray(rng.integers(0, cfg.vocab, (8, 12)), jnp.int32)
        tgts = jnp.asarray(rng.integers(0, cfg.vocab, (8, 12)), jnp.int32)
        step = jax.jit(make_pipeline_train_step(cfg, mesh, 4, lr=2e-3))
        losses = []
        for _ in range(8):
            pp, opt, loss = step(pp, opt, toks, tgts)
            losses.append(float(loss))
        assert all(np.isfinite(l) for l in losses), losses
        assert losses[-1] < losses[0], losses
        print("PIPELINE_TRAIN_OK", losses[0], losses[-1])
    """)
    assert "PIPELINE_TRAIN_OK" in out


def test_pipeline_microbatch_count_invariance():
    """Logits identical for M=2 and M=8 (schedule-independent math)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.models import transformer as T
        from repro.models.pipeline import pipeline_forward, stack_stages
        cfg = T.TransformerConfig(
            name="pp-mb", n_layers=4, d_model=16, n_heads=2, n_kv_heads=1,
            d_head=8, d_ff=32, vocab=64, dtype="float32", remat="none")
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        mesh = jax.make_mesh((4,), ("pp",))
        pp = dict(params, layers=stack_stages(params["layers"], 4))
        toks = jnp.asarray(np.random.default_rng(2).integers(
            0, cfg.vocab, (8, 8)), jnp.int32)
        a = pipeline_forward(cfg, pp, toks, mesh=mesh, n_microbatches=2)
        b = pipeline_forward(cfg, pp, toks, mesh=mesh, n_microbatches=8)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)
        print("PIPELINE_MB_OK")
    """)
    assert "PIPELINE_MB_OK" in out
