"""Graph substrate: CSR invariants, orderings, generators, sampler."""
import numpy as np
import pytest
from _hyp import given, strategies as st  # optional-hypothesis shim

from repro.graph import (CSRGraph, NeighborSampler, barabasi_albert, caveman,
                         complete_graph, core_numbers, degeneracy_order,
                         erdos_renyi, from_edge_list, grid_road,
                         induced_subgraph, kcore_peel_jax, kronecker,
                         moon_moser, random_geometric)


def random_graph(n, p, seed):
    return erdos_renyi(n, p, seed=seed)


@given(st.integers(2, 40), st.floats(0.0, 1.0), st.integers(0, 10**6))
def test_csr_invariants(n, p, seed):
    g = random_graph(n, p, seed)
    g.validate()
    assert g.n == n
    degs = g.degrees()
    assert degs.sum() == 2 * g.m


@given(st.integers(2, 30), st.integers(0, 10**6))
def test_from_edge_list_dedup_selfloop(n, seed):
    rng = np.random.default_rng(seed)
    e = rng.integers(0, n, size=(50, 2))
    g = from_edge_list(n, e)
    g.validate()
    # symmetric adjacency
    for u in range(n):
        for v in g.neighbors(u):
            assert g.has_edge(int(v), u)


@given(st.integers(2, 40), st.floats(0.05, 0.6), st.integers(0, 10**6))
def test_degeneracy_order_invariant(n, p, seed):
    """Every vertex has ≤ λ later neighbors — the BKdegen bound."""
    g = random_graph(n, p, seed)
    order, rank, lam = degeneracy_order(g)
    assert sorted(order.tolist()) == list(range(n))
    max_later = 0
    for v in range(n):
        later = sum(1 for u in g.neighbors(v) if rank[u] > rank[v])
        max_later = max(max_later, later)
    assert max_later <= lam
    # degeneracy equals the max core number
    assert lam == int(core_numbers(g).max(initial=0))


@given(st.integers(2, 40), st.floats(0.05, 0.6), st.integers(0, 10**6))
def test_kcore_peel_jax_invariant(n, p, seed):
    """Parallel peel order preserves |N+(v)| ≤ λ (round-based argument)."""
    g = random_graph(n, p, seed)
    _, _, lam = degeneracy_order(g)
    rank = kcore_peel_jax(g)
    for v in range(n):
        later = sum(1 for u in g.neighbors(v) if rank[u] > rank[v])
        assert later <= lam


def test_generators_basic():
    assert complete_graph(6).m == 15
    assert moon_moser(3).n == 9
    g = grid_road(10, drop_frac=0.0)
    assert g.n == 100 and g.m == 180
    _, _, lam = degeneracy_order(g)
    assert lam == 2            # lattice degeneracy — fully globally reducible
    ba = barabasi_albert(200, 4, seed=1)
    assert ba.n == 200 and ba.m >= 4 * (200 - 4 - 1)
    rg = random_geometric(300, seed=2)
    assert rg.n == 300
    kv = kronecker(8, 4, seed=3)
    assert kv.n == 256
    cm = caveman(4, 5, rewire=0.0)
    assert cm.m >= 4 * 10  # 4 cliques of C(5,2)=10 edges


def test_induced_subgraph():
    g = erdos_renyi(30, 0.3, seed=7)
    keep = np.zeros(30, dtype=bool)
    keep[:15] = True
    sub, old = induced_subgraph(g, keep)
    assert sub.n == 15
    for u in range(15):
        for v in sub.neighbors(u):
            assert g.has_edge(int(old[u]), int(old[v]))


def test_neighbor_sampler_budgets():
    g = barabasi_albert(2000, 6, seed=0)
    s = NeighborSampler(g, fanouts=(5, 3), batch_nodes=32, seed=1)
    sub = s.sample(0)
    assert len(sub.node_ids) == s.node_budget
    assert len(sub.blocks) == 2
    assert len(sub.blocks[0].src) == 32 * 5
    assert len(sub.blocks[1].src) == 32 * 5 * 3
    # sampled edges are real edges
    for blk in sub.blocks:
        for src, dst, ok in zip(blk.src, blk.dst, blk.mask):
            if ok:
                assert g.has_edge(int(sub.node_ids[src]),
                                  int(sub.node_ids[dst]))
    # determinism
    sub2 = s.sample(0)
    assert np.array_equal(sub.node_ids, sub2.node_ids)
