"""Persistent device-resident BK engine: lane-refill work queue.

Parity contract: the persistent engine must reproduce the per-root
engine's counters bit-for-bit (cliques, calls, branches, sum_px) AND the
same enumerated clique sets — lanes interleave roots, so any masking bug
in the dead-lane/refill path shows up as a count or set diff here.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import oracle
from repro.core.driver import DistributedMCE
from repro.core.engine import (EngineConfig, PrepStream, choose_engine,
                               estimate_costs, prepare, run, run_bucket,
                               run_bucket_persistent,
                               run_stream_persistent)
from repro.launch.mce_service import MCEService
from repro.graph import generators as gen
from repro.graph.csr import from_edge_list

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

GRAPHS = {
    "er": lambda: gen.erdos_renyi(60, 0.3, seed=0),
    "ba": lambda: gen.barabasi_albert(80, 5, seed=1),
    "caveman": lambda: gen.caveman(8, 6, seed=2),
}


def skewed_graph(n=300, m=3, blob=24, p=0.7, seed=7):
    """Sparse BA graph with one planted dense blob: a single hub root's
    subtree dwarfs every other root — the lock-step worst case."""
    g = gen.barabasi_albert(n, m, seed=seed)
    rng = np.random.default_rng(seed)
    extra = [(i, j) for i in range(blob) for j in range(i + 1, blob)
             if rng.random() < p]
    e = np.concatenate([g.edges().astype(np.int64),
                        np.array(extra, np.int64)])
    key = e[:, 0] * n + e[:, 1]
    e = e[np.unique(key, return_index=True)[1]]
    return from_edge_list(n, e)


# ---------------------------------------------------------------------------
# Engine-level parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["pivot", "rcd", "revised"])
@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_persistent_matches_perroot_counts(backend, gname):
    g = GRAPHS[gname]()
    ref = run(g, backend=backend, engine="perroot")
    res = run(g, backend=backend, engine="persistent", lanes=7)
    assert (res.cliques, res.calls, res.branches, res.sum_px) == \
           (ref.cliques, ref.calls, ref.branches, ref.sum_px)
    assert res.cliques == len(oracle.bk_pivot(g))
    assert not res.iters_exhausted


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_persistent_enumerates_same_sets(gname):
    g = GRAPHS[gname]()
    ref = run(g, enumerate_cliques=True, engine="perroot")
    res = run(g, enumerate_cliques=True, engine="persistent", lanes=5)
    assert not res.overflow and not ref.overflow
    assert set(res.enumerated) == set(ref.enumerated)
    assert set(res.enumerated) == set(oracle.bk_pivot(g))


def test_skewed_root_regression():
    """One unsplit hub root + many tiny roots in ONE bucket: exhausted
    lanes must refill from the queue while the hub lane keeps walking."""
    g = skewed_graph()
    ref = run(g, bucket_sizes=(64,), engine="perroot")
    res = run(g, bucket_sizes=(64,), engine="persistent", lanes=8)
    assert (res.cliques, res.calls, res.branches, res.sum_px) == \
           (ref.cliques, ref.calls, ref.branches, ref.sum_px)
    assert res.cliques == len(oracle.bk_pivot(g))


def test_persistent_lanes_exceed_roots():
    """lanes > queue length: surplus lanes stay dead and contribute
    nothing (run() clamps, but the kernel must tolerate it directly)."""
    g = gen.erdos_renyi(40, 0.25, seed=3)
    prep = prepare(g, bucket_sizes=(64,))
    (b,) = prep.buckets
    cfg = EngineConfig()
    args = (jnp.asarray(b.a), jnp.asarray(b.p0), jnp.asarray(b.x_rows),
            jnp.asarray(b.x_alive0), jnp.asarray(b.rsz0))
    ref = run_bucket(*args, cfg)
    out = run_bucket_persistent(*args, cfg, lanes=b.num_roots + 13)
    for k in ("cliques", "calls", "branches", "sum_px"):
        assert int(out[k].sum()) == int(ref[k].sum()), k
    assert int(out["claimed"]) == b.num_roots
    assert int(out["truncated"]) == 0


# ---------------------------------------------------------------------------
# max_iters truncation flag (satellite: run_root used to truncate silently)
# ---------------------------------------------------------------------------

def _bucket_args(g, bucket_sizes=(64,)):
    prep = prepare(g, bucket_sizes=bucket_sizes)
    (b,) = prep.buckets
    return (jnp.asarray(b.a), jnp.asarray(b.p0), jnp.asarray(b.x_rows),
            jnp.asarray(b.x_alive0), jnp.asarray(b.rsz0))


@pytest.mark.parametrize("runner", ["perroot", "persistent"])
def test_truncation_flag_set_when_iters_exhausted(runner):
    g = gen.erdos_renyi(50, 0.3, seed=4)
    args = _bucket_args(g)
    full = run_bucket(*args, EngineConfig())
    assert int(full["truncated"].sum()) == 0
    need = int(full["iters"].max())
    cfg = EngineConfig(max_iters=max(need // 4, 2))
    if runner == "perroot":
        out = run_bucket(*args, cfg)
        assert int(out["truncated"].sum()) > 0
        assert int(out["cliques"].sum()) < int(full["cliques"].sum())
    else:
        out = run_bucket_persistent(*args, cfg, lanes=4)
        assert int(out["truncated"]) == 1


def test_run_surfaces_iters_exhausted_flag():
    g = gen.erdos_renyi(60, 0.3, seed=5)
    res = run(g)
    assert res.iters_exhausted is False


# ---------------------------------------------------------------------------
# Remainder-flush pow2 padding (compile-count hygiene)
# ---------------------------------------------------------------------------

def test_remainder_flush_pads_to_pow2_fraction():
    g = gen.barabasi_albert(500, 5, seed=6)
    sr = 64
    stream = PrepStream(g, bucket_sizes=(32, 64), stream_roots=sr)
    buckets = list(stream)
    assert buckets
    for b in buckets:
        assert b.num_roots <= sr
        assert sr % b.num_roots == 0, \
            f"flush of {b.num_roots} roots is not a pow2 fraction of {sr}"
        real = b.num_roots - b.n_pad
        if b.n_pad:
            # pads are empty no-op roots appended at the tail
            for r in range(real, b.num_roots):
                assert b.bases[r] == (-1,)
                assert len(b.universes[r]) == 0
        # padding is minimal: the next smaller pow2 would not fit
        if b.num_roots < sr:
            assert real > b.num_roots // 2

    # executable-count: every bucket of a size runs through ONE compile
    # per distinct (u_pad, root-count) pair — pow2 padding caps that at
    # O(log stream_roots) instead of one per ragged remainder
    jax.clear_caches()
    cfg = EngineConfig()
    for b in buckets:
        run_bucket(jnp.asarray(b.a), jnp.asarray(b.p0),
                   jnp.asarray(b.x_rows), jnp.asarray(b.x_alive0),
                   jnp.asarray(b.rsz0), cfg)
    distinct = {(b.u_pad, b.num_roots, b.x_rows.shape[1]) for b in buckets}
    assert run_bucket._cache_size() <= len(distinct)


def test_padded_stream_counts_match_unpadded():
    g = gen.barabasi_albert(500, 5, seed=6)
    ref = run(g, bucket_sizes=(32, 64))        # stream_roots=0: no padding
    cfgs = dict(bucket_sizes=(32, 64), stream_roots=64)
    drv = DistributedMCE(g, chunk=16, **cfgs)
    res = drv.run()
    assert res.cliques == ref.cliques
    assert res.calls == ref.calls


# ---------------------------------------------------------------------------
# Driver integration + mid-queue elastic restart
# ---------------------------------------------------------------------------

def test_driver_persistent_matches_perroot():
    g = gen.barabasi_albert(400, 5, seed=3)
    ref = DistributedMCE(g, chunk=64, stream_roots=128).run()
    res = DistributedMCE(g, chunk=64, stream_roots=128,
                         engine="persistent", lanes=16).run()
    assert (res.cliques, res.calls, res.branches, res.sum_px) == \
           (ref.cliques, ref.calls, ref.branches, ref.sum_px)


# ---------------------------------------------------------------------------
# engine="auto": per-bucket choice from root-cost skew
# ---------------------------------------------------------------------------

def test_choose_engine_policy():
    uniform = np.full(64, 10.0)
    assert choose_engine(uniform) == ("perroot", 64)
    skewed = np.array([1000.0] + [1.0] * 63)
    eng, lanes = choose_engine(skewed, lanes=64)
    assert eng == "persistent"
    assert lanes == 16          # largest pow2 <= 64/4, floor 8, cap 64
    assert lanes & (lanes - 1) == 0
    # tiny buckets stay lock-step regardless of skew
    assert choose_engine(np.array([99.0, 1.0, 1.0]))[0] == "perroot"
    # the memoized-skew path must agree with the costs path
    skew = float(skewed.max() / skewed.mean())
    assert choose_engine(skew=skew, n_roots=64, lanes=64) == (eng, lanes)
    # degenerate inputs fall back to lock-step
    assert choose_engine(np.zeros(0))[0] == "perroot"
    assert choose_engine(skew=None, n_roots=None)[0] == "perroot"


def test_auto_picks_persistent_on_skewed_bucket():
    g = skewed_graph()
    prep = prepare(g, bucket_sizes=(64,))
    for b in prep.buckets:
        costs = estimate_costs(b)[:b.num_roots - b.n_pad]
        if costs.size and float(costs.max() / costs.mean()) >= 4.0:
            break
    else:
        pytest.fail("skewed_graph produced no skewed bucket")
    assert choose_engine(costs)[0] == "persistent"


def test_auto_matches_explicit_engines_on_skewed_graph():
    """Parity: auto must reproduce the explicit engines' counters exactly
    on the skewed-root fixture — the choice only moves work between
    equivalent execution strategies."""
    g = skewed_graph()
    ref = run(g, bucket_sizes=(64,), engine="perroot")
    res = run(g, bucket_sizes=(64,), engine="auto", lanes=16)
    assert (res.cliques, res.calls, res.branches, res.sum_px) == \
           (ref.cliques, ref.calls, ref.branches, ref.sum_px)
    assert res.cliques == len(oracle.bk_pivot(g))


def test_driver_auto_matches_explicit_and_records_choices():
    g = skewed_graph()
    ref = DistributedMCE(g, chunk=64, stream_roots=128).run()
    drv = DistributedMCE(g, chunk=64, stream_roots=128,
                         engine="auto", lanes=16)
    res = drv.run()
    assert (res.cliques, res.calls, res.branches, res.sum_px) == \
           (ref.cliques, ref.calls, ref.branches, ref.sum_px)
    picks = drv.stats["engine_choices"]
    assert picks["perroot"] + picks["persistent"] > 0
    assert picks["persistent"] > 0     # the hub bucket must trip the queue


def test_explicit_engine_flag_overrides_auto_policy():
    """engine='perroot'/'persistent' are hard overrides: no auto choice
    is recorded and every chunk runs the requested engine."""
    g = skewed_graph()
    drv = DistributedMCE(g, chunk=64, stream_roots=128, engine="perroot")
    drv.run()
    assert drv.stats["engine_choices"] == {"perroot": 0, "persistent": 0}


# ---------------------------------------------------------------------------
# MCEService occupancy stats (satellite: lane occupancy + truncation
# counters accumulate across cached-bucket replays)
# ---------------------------------------------------------------------------

def test_service_stats_accumulate_across_cached_replays():
    g = gen.barabasi_albert(200, 4, seed=11)
    svc = MCEService(g, chunk=64, stream_roots=64)
    r1 = svc.query()
    after_one = {k: svc.stats[k]
                 for k in ("live_iters", "lane_iters", "truncated")}
    assert r1.stats["live_iters"] == after_one["live_iters"]
    assert after_one["live_iters"] > 0
    assert after_one["lane_iters"] >= after_one["live_iters"]
    assert after_one["truncated"] == 0
    r2 = svc.query()                       # replays the CACHED buckets
    assert r2.cliques == r1.cliques
    # identical packed buckets -> identical per-query counters, so the
    # service totals are exactly double after the cached replay
    for k, v in after_one.items():
        assert svc.stats[k] == 2 * v, k
    assert 0.0 < svc.occupancy() <= 1.0
    assert svc.queries == 2


def test_service_persistent_engine_occupancy_and_choice_counters():
    g = skewed_graph()
    svc = MCEService(g, chunk=64, stream_roots=128, engine="auto", lanes=16)
    res = svc.query()
    assert res.cliques == len(oracle.bk_pivot(g))
    assert svc.stats["engine_choices"]["persistent"] > 0
    assert 0.0 < svc.occupancy() <= 1.0
    # per-query override beats the service default
    res2 = svc.query(engine="perroot")
    assert res2.cliques == res.cliques
    assert res2.stats["engine_choices"] == {"perroot": 0, "persistent": 0}


def run_py(code: str, devices: int, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_midqueue_elastic_restart_persistent(tmp_path):
    """Preempt the persistent driver mid-queue under 4 shards, resume
    under 2: the canonical cost-descending cursor (= persistent queue
    order) must land the restart on exactly the remaining roots."""
    ck = str(tmp_path / "persistent.json")
    out4 = run_py(f"""
        from repro.core.driver import DistributedMCE
        from repro.graph import barabasi_albert
        g = barabasi_albert(400, 6, seed=9)
        drv = DistributedMCE(g, chunk=16, ckpt_path={ck!r},
                             bucket_sizes=(32, 64), stream_roots=64,
                             engine="persistent", lanes=8)
        n = 0
        orig = drv._run_chunk
        def failing(*args):
            global n
            if n >= 3: raise RuntimeError("preempted")
            n += 1
            return orig(*args)
        drv._run_chunk = failing
        try:
            drv.run()
        except RuntimeError:
            pass
        print("PARTIAL_OK")
    """, devices=4)
    assert "PARTIAL_OK" in out4
    out2 = run_py(f"""
        from repro.core.driver import DistributedMCE
        from repro.core import bitset_engine
        from repro.graph import barabasi_albert
        g = barabasi_albert(400, 6, seed=9)
        ref = bitset_engine.run(g, bucket_sizes=(32, 64))
        drv = DistributedMCE(g, chunk=16, ckpt_path={ck!r},
                             bucket_sizes=(32, 64), stream_roots=64,
                             engine="persistent", lanes=8)
        res = drv.run(resume=True)
        print("CLIQUES", res.cliques, ref.cliques)
        assert res.cliques == ref.cliques
        assert res.calls == ref.calls
        assert not res.iters_exhausted
    """, devices=2)
    assert "CLIQUES" in out2


# ---------------------------------------------------------------------------
# Bucket-spanning stream + lane work stealing (DESIGN.md §2.6 STEAL)
# ---------------------------------------------------------------------------

def plant_hub(g, blob=18, p=0.85, seed=17):
    """Densify the first `blob` vertices of an existing graph into a
    near-clique hub (same recipe as skewed_graph, applied in place)."""
    rng = np.random.default_rng(seed)
    extra = [(i, j) for i in range(blob) for j in range(i + 1, blob)
             if rng.random() < p]
    e = np.concatenate([g.edges().astype(np.int64),
                        np.array(extra, np.int64)])
    key = e[:, 0] * g.n + e[:, 1]
    e = e[np.unique(key, return_index=True)[1]]
    return from_edge_list(g.n, e)


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_stream_spanning_matches_perroot_on_hub_graphs(gname):
    """Multi-bucket stream with a planted hub: the spanning engine (lane
    state carried across same-shape bucket boundaries, steals on) must
    reproduce the per-root counters exactly."""
    g = plant_hub(GRAPHS[gname]())
    ref = run(g, bucket_sizes=(32, 64), engine="perroot")
    res = run(g, bucket_sizes=(32, 64), engine="persistent", lanes=8)
    assert (res.cliques, res.calls, res.branches, res.sum_px) == \
           (ref.cliques, ref.calls, ref.branches, ref.sum_px)
    assert res.cliques == len(oracle.bk_pivot(g))
    assert res.stats["spans"] >= 1
    assert not res.iters_exhausted


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_stream_spanning_enumerates_same_sets_on_hub_graphs(gname):
    """Enumerated-set parity through the stream-global out_root decode:
    lanes cross bucket boundaries mid-subtree and may adopt stolen branch
    sets, so each emitted clique's root index must still decode to the
    right (bucket, local root) universe."""
    g = plant_hub(GRAPHS[gname]())
    ref = run(g, enumerate_cliques=True, bucket_sizes=(32, 64),
              engine="perroot")
    res = run(g, enumerate_cliques=True, bucket_sizes=(32, 64),
              engine="persistent", lanes=6)
    assert not res.overflow and not ref.overflow
    assert set(res.enumerated) == set(ref.enumerated)
    assert set(res.enumerated) == set(oracle.bk_pivot(g))


def test_steal_on_off_parity_and_steal_counter():
    """Stealing is pure scheduling: identical counters either way, with
    the steal counter live on the hub fixture and pinned to zero off."""
    # blob=40/p=0.6: big enough that graph reduction does not collapse
    # the hub, so idle lanes really do adopt stolen branch sets
    g = skewed_graph(blob=40, p=0.6)
    on = run(g, bucket_sizes=(64,), engine="persistent", lanes=8,
             steal=True)
    off = run(g, bucket_sizes=(64,), engine="persistent", lanes=8,
              steal=False)
    assert (on.cliques, on.calls, on.branches, on.sum_px) == \
           (off.cliques, off.calls, off.branches, off.sum_px)
    assert on.cliques == len(oracle.bk_pivot(g))
    assert on.stats["steals"] > 0
    assert off.stats["steals"] == 0


def test_steal_enumerates_same_sets():
    g = skewed_graph(blob=40, p=0.6)
    on = run(g, enumerate_cliques=True, bucket_sizes=(64,),
             engine="persistent", lanes=8, steal=True)
    off = run(g, enumerate_cliques=True, bucket_sizes=(64,),
              engine="persistent", lanes=8, steal=False)
    assert not on.overflow and not off.overflow
    assert set(on.enumerated) == set(off.enumerated)
    assert set(on.enumerated) == set(oracle.bk_pivot(g))


def test_hybrid_entry_terms_counted_in_refill():
    """Hybrid early termination inside the persistent refill: dense-blob
    roots complete within their entry call and must be tallied."""
    g = GRAPHS["caveman"]()
    ref = run(g, backend="hybrid", engine="perroot")
    res = run(g, backend="hybrid", engine="persistent", lanes=8)
    assert (res.cliques, res.calls, res.branches, res.sum_px) == \
           (ref.cliques, ref.calls, ref.branches, ref.sum_px)
    assert res.stats["entry_terms"] > 0


# ---------------------------------------------------------------------------
# run_stream_persistent: span formation and the stream-global root index
# ---------------------------------------------------------------------------

def test_stream_persistent_single_span_across_same_shape_slabs():
    """Two same-shape slabs form ONE span: no drain at their boundary,
    and the merged counters match the single-bucket reference."""
    g = GRAPHS["er"]()
    args = _bucket_args(g)
    h = args[0].shape[0] // 2
    slab1 = tuple(x[:h] for x in args)
    slab2 = tuple(x[h:] for x in args)
    outs, spans = run_stream_persistent([slab1, slab2], EngineConfig(),
                                        lanes=4)
    assert spans == [(0, 2)]
    ref = run_bucket(*args, EngineConfig())
    for k in ("cliques", "calls", "branches", "sum_px"):
        assert int(outs[0][k].sum()) == int(ref[k].sum()), k
    assert int(outs[0]["truncated"]) == 0


def test_stream_persistent_shape_change_flushes_span():
    """A shape change must flush the open span (different frame shapes
    cannot share one compiled loop); the per-span outputs still sum to
    the per-slab reference."""
    g = gen.erdos_renyi(150, 0.4, seed=3)
    prep = prepare(g, bucket_sizes=(32, 64))
    slabs = [tuple(jnp.asarray(x) for x in
                   (b.a, b.p0, b.x_rows, b.x_alive0, b.rsz0))
             for b in prep.buckets]
    sigs = [(s[0].shape[1], s[0].shape[2], s[2].shape[1]) for s in slabs]
    assert len(set(sigs)) >= 2, "fixture must mix bucket shapes"
    outs, spans = run_stream_persistent(slabs, EngineConfig(), lanes=8)
    # spans tile [0, len(slabs)) contiguously, one per run of equal sigs
    assert spans[0][0] == 0 and spans[-1][1] == len(slabs)
    for (alo, ahi), (blo, bhi) in zip(spans, spans[1:]):
        assert ahi == blo
    for lo, hi in spans:
        assert len({sigs[i] for i in range(lo, hi)}) == 1
    want = 0
    for s in slabs:
        out = run_bucket_persistent(*s, EngineConfig(),
                                    lanes=min(8, s[0].shape[0]))
        want += int(out["cliques"].sum())
    assert sum(int(o["cliques"].sum()) for o in outs) == want


def test_stream_persistent_out_root_is_stream_global():
    """Enumeration across a span boundary: out_root must index into the
    whole stream (slab prefix sums), not restart at 0 per slab."""
    g = GRAPHS["ba"]()
    args = _bucket_args(g)
    r = args[0].shape[0]
    h = r // 2
    slab1 = tuple(x[:h] for x in args)
    slab2 = tuple(x[h:] for x in args)
    cfg = EngineConfig(out_cap=2048)
    outs, spans = run_stream_persistent([slab1, slab2], cfg, lanes=4)
    assert spans == [(0, 2)]
    out = jax.tree.map(np.asarray, outs[0])
    assert not out["overflow"].any()
    roots = {int(out["out_root"][l, k])
             for l in range(out["out_n"].shape[0])
             for k in range(int(out["out_n"][l]))}
    assert roots and all(0 <= x < r for x in roots)
    assert max(roots) >= h, "second slab's cliques must carry global ids"


# ---------------------------------------------------------------------------
# VMEM stack windowing: run_root_windowed parity through run()
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("gname", sorted(GRAPHS))
@pytest.mark.parametrize("steps", [4, 16])
def test_windowed_walk_matches_plain(gname, steps):
    """window_steps routes eligible per-root walks (pivot, dynamic_red
    off, counting only) through dfs_step_window; counters must be
    identical to the plain one-step-per-HBM-round-trip walk."""
    g = GRAPHS[gname]()
    ref = run(g, dynamic_red=False, engine="perroot")
    res = run(g, dynamic_red=False, engine="perroot", window_steps=steps)
    assert (res.cliques, res.calls, res.branches, res.sum_px) == \
           (ref.cliques, ref.calls, ref.branches, ref.sum_px)
    assert res.cliques == len(oracle.bk_pivot(g))


def test_window_gate_ignores_ineligible_configs():
    """window_steps with dynamic reduction on (outside the dfs_step_window
    contract) must silently take the plain walk — same counters."""
    g = GRAPHS["er"]()
    ref = run(g, engine="perroot")
    res = run(g, engine="perroot", window_steps=16)
    assert (res.cliques, res.calls, res.branches, res.sum_px) == \
           (ref.cliques, ref.calls, ref.branches, ref.sum_px)


# ---------------------------------------------------------------------------
# Mid-stream elastic restart (4 -> 2 shards) through a bucket boundary
# with steals in flight
# ---------------------------------------------------------------------------

# indented to match the f-string bodies below: run_py dedents the
# concatenation, so both halves must share one indentation level
_HUB_GRAPH_SRC = """
        import numpy as np
        from repro.graph import barabasi_albert
        from repro.graph.csr import from_edge_list
        _g = barabasi_albert(300, 3, seed=7)
        _rng = np.random.default_rng(7)
        _extra = [(i, j) for i in range(24) for j in range(i + 1, 24)
                  if _rng.random() < 0.7]
        _e = np.concatenate([_g.edges().astype(np.int64),
                             np.array(_extra, np.int64)])
        _key = _e[:, 0] * 300 + _e[:, 1]
        _e = _e[np.unique(_key, return_index=True)[1]]
        g = from_edge_list(300, _e)
"""


def test_midstream_elastic_restart_with_steals(tmp_path):
    """Preempt the persistent driver mid-stream under 4 shards — past a
    bucket-size boundary, on the hub fixture so steals are in flight —
    then resume under 2: the elastic cursor must land on exactly the
    remaining roots, and the settled steal counter must show the queue
    actually stole across the run."""
    ck = str(tmp_path / "spanning.json")
    out4 = run_py(_HUB_GRAPH_SRC + f"""
        from repro.core.driver import DistributedMCE
        drv = DistributedMCE(g, chunk=16, ckpt_path={ck!r},
                             bucket_sizes=(32, 64), stream_roots=64,
                             engine="persistent", lanes=8)
        n = 0
        orig = drv._run_chunk
        def failing(*args):
            global n
            if n >= 3: raise RuntimeError("preempted")
            n += 1
            return orig(*args)
        drv._run_chunk = failing
        try:
            drv.run()
        except RuntimeError:
            pass
        print("PARTIAL_OK")
    """, devices=4)
    assert "PARTIAL_OK" in out4
    out2 = run_py(_HUB_GRAPH_SRC + f"""
        from repro.core.driver import DistributedMCE
        from repro.core import bitset_engine
        ref = bitset_engine.run(g, bucket_sizes=(32, 64))
        drv = DistributedMCE(g, chunk=16, ckpt_path={ck!r},
                             bucket_sizes=(32, 64), stream_roots=64,
                             engine="persistent", lanes=8)
        res = drv.run(resume=True)
        print("CLIQUES", res.cliques, ref.cliques)
        print("STEALS", int(drv.last_counters.get("steals", 0)))
        assert res.cliques == ref.cliques
        assert res.calls == ref.calls
        assert not res.iters_exhausted
        assert int(drv.last_counters.get("steals", 0)) > 0
    """, devices=2)
    assert "CLIQUES" in out2
