"""Optional-hypothesis shim: property tests SKIP when hypothesis is absent.

Test modules import `given`/`settings`/`st` from here instead of from
hypothesis directly. When hypothesis is installed the real objects pass
through untouched; when it is missing, `given` turns the test into a skip
and `st` hands out inert stand-in strategies so module-level `@st.composite`
definitions still import cleanly. This keeps the whole suite collectable on
a bare container (the seed died at collection with ModuleNotFoundError).
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (property test)")(fn)
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategiesStub:
        """Any `st.<name>(...)` returns an inert callable, so composite
        strategies can be defined and invoked at collection time."""

        def __getattr__(self, _name):
            def strategy(*_args, **_kwargs):
                return lambda *_a, **_k: None
            return strategy

    st = _StrategiesStub()

strategies = st  # both `from _hyp import st` and `... strategies as st` work
