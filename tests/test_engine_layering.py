"""Architecture lint: the layered engine + single kernel-dispatch choke point.

Guards the refactor's contracts (DESIGN.md §2–§3, §6). The layering
rules themselves now live ONCE, declaratively, in
`repro.analysis.layering.LAYERS`; these tests invoke the R1 rule engine
(AST-resolved imports — no regex false positives on docstrings, no
misses on aliased imports) and keep the structural checks that are about
file layout rather than imports.
"""
import os
import textwrap

import pytest

from repro.analysis import layering
from repro.analysis.modindex import PackageIndex

SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")


def _findings(root=SRC, package=None):
    index = PackageIndex.build(root, package=package)
    return layering.check(index)


def test_no_direct_ref_or_kernel_imports():
    offenders = [f.format() for f in _findings()
                 if "kernel-privates" in f.message]
    assert not offenders, (
        f"modules importing bitset_ops ref/kernel directly (must go through "
        f"bitset_ops.ops): {offenders}")


def test_repo_tree_is_layer_clean():
    """The full declarative layer table holds on the real tree."""
    offenders = [f.format() for f in _findings()]
    assert not offenders, f"layering violations: {offenders}"


def test_lint_catches_the_original_bug(tmp_path):
    """The R1 AST rule must flag the exact import the dead-kernel bug used
    (PR 1: `from repro.kernels.bitset_ops import ref` in the engine made
    the Pallas TPU kernel dead code on the hot path)."""
    pkg = tmp_path / "repro"
    eng = pkg / "core" / "engine"
    eng.mkdir(parents=True)
    (eng / "loop.py").write_text(textwrap.dedent("""\
        from repro.kernels.bitset_ops import ref as bitref

        def expand(rows, mask):
            return bitref.and_popcount_rows(rows, mask)
        """))
    bad = _findings(str(pkg))
    assert len(bad) == 1 and bad[0].rule == "R1"
    assert bad[0].line == 1
    assert "repro.kernels.bitset_ops.ref" in bad[0].message

    # aliasing and relative form cannot hide the import from the AST walker
    (eng / "loop.py").write_text(
        "from ...kernels.bitset_ops import kernel as k\n")
    assert [f.line for f in _findings(str(pkg))] == [1]

    # the blessed dispatch import stays clean
    (eng / "loop.py").write_text(
        "from repro.kernels.bitset_ops import ops as bitops\n")
    assert _findings(str(pkg)) == []


def test_layer_table_covers_the_design_contracts():
    """DESIGN.md §3/§6 contracts each live in the declarative table."""
    names = {r.name for r in layering.LAYERS}
    assert {"kernel-privates", "graph-purity", "engine-no-upward",
            "driver-no-launch"} <= names
    by_name = {r.name: r for r in layering.LAYERS}
    assert "repro.launch" in by_name["driver-no-launch"].forbid
    assert "repro.core.driver" in by_name["engine-no-upward"].forbid
    assert by_name["graph-purity"].allow_only == ("repro.graph",)


def test_ingest_pipeline_layering():
    """Ingest layers import strictly downward (DESIGN.md §6): graph/ ->
    numpy + siblings only; core/engine/ -> never driver or launch;
    core/driver.py -> never launch. Enforced by the R1 engine."""
    offenders = [f.format() for f in _findings()
                 if any(k in f.message for k in
                        ("graph-purity", "engine-no-upward",
                         "driver-no-launch"))]
    assert not offenders, f"upward imports: {offenders}"


def test_engine_package_layout():
    pkg = os.path.join(SRC, "core", "engine")
    for mod in ("__init__.py", "prepare.py", "pipeline.py", "frames.py",
                "reductions.py", "pivot.py", "loop.py"):
        assert os.path.isfile(os.path.join(pkg, mod)), f"missing engine/{mod}"
    assert os.path.isfile(os.path.join(SRC, "graph", "pack.py")), \
        "vectorized packer must live in the graph layer"


def test_prepare_is_a_thin_wrapper_over_the_pipeline():
    """Staging/packing code belongs in pipeline.py + graph/pack.py."""
    with open(os.path.join(SRC, "core", "engine", "prepare.py")) as f:
        text = f.read()
    assert "PrepStream" in text, "prepare() must delegate to the pipeline"
    assert "np.isin" not in text, "per-row isin packing must stay dead"


def test_bitset_engine_is_a_thin_shim():
    path = os.path.join(SRC, "core", "bitset_engine.py")
    with open(path) as f:
        lines = f.readlines()
    assert len(lines) <= 50, (
        f"bitset_engine.py is {len(lines)} lines; it must stay a ≤50-line "
        f"re-export shim — put real code in core/engine/")


def test_shim_exports_match_engine_package():
    import repro.core.bitset_engine as shim
    import repro.core.engine as eng

    for name in ("EngineConfig", "MCEResult", "PreparedMCE", "RootBucket",
                 "prepare", "run", "run_bucket", "run_root"):
        assert getattr(shim, name) is getattr(eng, name), name
    # historical underscore aliases still resolve
    assert shim._run_root is eng.run_root


def test_ops_is_the_engine_entry_point():
    """The hot-loop modules must reference the ops dispatcher."""
    for mod in ("reductions.py", "pivot.py"):
        with open(os.path.join(SRC, "core", "engine", mod)) as f:
            text = f.read()
        assert "from repro.kernels.bitset_ops import ops" in text, mod
