"""Architecture lint: the layered engine + single kernel-dispatch choke point.

Guards the refactor's contracts (DESIGN.md §2–§3):
  * no module outside `kernels/bitset_ops` imports `ref`/`kernel` directly —
    all bitset set algebra dispatches through `ops` (the dead-kernel bug
    this rule prevents: the engine importing the jnp ref and silently never
    using the Pallas TPU path);
  * `core/engine/` holds the layered modules;
  * `core/bitset_engine.py` stays a thin re-export shim.
"""
import os
import re

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src", "repro")

_FORBIDDEN = [
    # from repro.kernels.bitset_ops import ref / kernel (any alias/combo)
    re.compile(r"from\s+repro\.kernels\.bitset_ops\s+import\s+"
               r"[^\n]*\b(ref|kernel)\b"),
    re.compile(r"from\s+repro\.kernels\.bitset_ops\.(ref|kernel)\s+import"),
    re.compile(r"import\s+repro\.kernels\.bitset_ops\.(ref|kernel)\b"),
]


def _py_files():
    for dirpath, _dirnames, filenames in os.walk(SRC):
        if os.path.join("kernels", "bitset_ops") in dirpath:
            continue          # the package itself may wire ref/kernel to ops
        for name in filenames:
            if name.endswith(".py"):
                yield os.path.join(dirpath, name)


def test_no_direct_ref_or_kernel_imports():
    offenders = []
    for path in _py_files():
        with open(path) as f:
            text = f.read()
        for pat in _FORBIDDEN:
            if pat.search(text):
                offenders.append(os.path.relpath(path, SRC))
                break
    assert not offenders, (
        f"modules importing bitset_ops ref/kernel directly (must go through "
        f"bitset_ops.ops): {offenders}")


def test_lint_catches_the_original_bug():
    """The regex must flag the exact import the dead-kernel bug used."""
    bad = "from repro.kernels.bitset_ops import ref as bitref\n"
    assert any(p.search(bad) for p in _FORBIDDEN)
    good = "from repro.kernels.bitset_ops import ops as bitops\n"
    assert not any(p.search(good) for p in _FORBIDDEN)


def test_engine_package_layout():
    pkg = os.path.join(SRC, "core", "engine")
    for mod in ("__init__.py", "prepare.py", "pipeline.py", "frames.py",
                "reductions.py", "pivot.py", "loop.py"):
        assert os.path.isfile(os.path.join(pkg, mod)), f"missing engine/{mod}"
    assert os.path.isfile(os.path.join(SRC, "graph", "pack.py")), \
        "vectorized packer must live in the graph layer"


def _imports_of(path):
    with open(path) as f:
        text = f.read()
    return re.findall(r"^\s*(?:from|import)\s+(repro\.[\w.]+)", text,
                      flags=re.M)


def test_ingest_pipeline_layering():
    """Ingest layers import strictly downward (DESIGN.md §6).

    graph/  -> numpy + graph siblings only (no core, kernels, launch);
    core/engine/ -> never the driver or launch (the driver consumes the
    stream, not the other way around);
    core/driver.py -> never launch.
    """
    graph_dir = os.path.join(SRC, "graph")
    for name in os.listdir(graph_dir):
        if not name.endswith(".py"):
            continue
        for imp in _imports_of(os.path.join(graph_dir, name)):
            assert imp.startswith("repro.graph"), \
                f"graph/{name} imports upward: {imp}"
    eng_dir = os.path.join(SRC, "core", "engine")
    for name in os.listdir(eng_dir):
        if not name.endswith(".py"):
            continue
        for imp in _imports_of(os.path.join(eng_dir, name)):
            assert not imp.startswith(("repro.core.driver", "repro.launch")), \
                f"engine/{name} imports upward: {imp}"
    for imp in _imports_of(os.path.join(SRC, "core", "driver.py")):
        assert not imp.startswith("repro.launch"), \
            f"driver imports upward: {imp}"


def test_prepare_is_a_thin_wrapper_over_the_pipeline():
    """Staging/packing code belongs in pipeline.py + graph/pack.py."""
    with open(os.path.join(SRC, "core", "engine", "prepare.py")) as f:
        text = f.read()
    assert "PrepStream" in text, "prepare() must delegate to the pipeline"
    assert "np.isin" not in text, "per-row isin packing must stay dead"


def test_bitset_engine_is_a_thin_shim():
    path = os.path.join(SRC, "core", "bitset_engine.py")
    with open(path) as f:
        lines = f.readlines()
    assert len(lines) <= 50, (
        f"bitset_engine.py is {len(lines)} lines; it must stay a ≤50-line "
        f"re-export shim — put real code in core/engine/")


def test_shim_exports_match_engine_package():
    import repro.core.bitset_engine as shim
    import repro.core.engine as eng

    for name in ("EngineConfig", "MCEResult", "PreparedMCE", "RootBucket",
                 "prepare", "run", "run_bucket", "run_root"):
        assert getattr(shim, name) is getattr(eng, name), name
    # historical underscore aliases still resolve
    assert shim._run_root is eng.run_root


def test_ops_is_the_engine_entry_point():
    """The hot-loop modules must reference the ops dispatcher."""
    for mod in ("reductions.py", "pivot.py"):
        with open(os.path.join(SRC, "core", "engine", mod)) as f:
            text = f.read()
        assert "from repro.kernels.bitset_ops import ops" in text, mod
