"""Two-tower recsys: embedding bag semantics, training, retrieval."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import recsys as R
from repro.optim import adamw_init


def cfg_smoke():
    return get_arch("two-tower-retrieval").build_smoke()


def test_embedding_bag_mean_semantics():
    table = jnp.asarray(np.arange(20, dtype=np.float32).reshape(10, 2))
    ids = jnp.asarray(np.array([[0, 1, -1], [5, -1, -1], [-1, -1, -1]],
                               np.int32))
    out = R.embedding_bag(table, ids, mode="mean")
    np.testing.assert_allclose(np.asarray(out),
                               [[1.0, 2.0], [10.0, 11.0], [0.0, 0.0]])
    out_sum = R.embedding_bag(table, ids, mode="sum")
    np.testing.assert_allclose(np.asarray(out_sum),
                               [[2.0, 4.0], [10.0, 11.0], [0.0, 0.0]])


def test_towers_normalised():
    cfg = cfg_smoke()
    params = R.init_params(cfg, jax.random.PRNGKey(0))
    b = {k: jnp.asarray(v) for k, v in R.synth_batch(cfg, 32, seed=0).items()}
    u = R.user_tower(cfg, params, b)
    v = R.item_tower(cfg, params, b)
    assert u.shape == (32, cfg.tower_mlp[-1])
    np.testing.assert_allclose(np.linalg.norm(np.asarray(u), axis=-1), 1.0,
                               rtol=1e-4)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(v), axis=-1), 1.0,
                               rtol=1e-4)


def test_train_decreases_loss():
    cfg = cfg_smoke()
    params = R.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(R.make_train_step(cfg, lr=1e-3))
    b = {k: jnp.asarray(v) for k, v in R.synth_batch(cfg, 64, seed=0).items()}
    losses = []
    for _ in range(6):
        params, opt, loss = step(params, opt, b)
        losses.append(float(loss))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(l) for l in losses)


def test_retrieval_finds_planted_item():
    """Plant the query user's history items in the corpus — after a few
    training steps the positive item scores above random ones."""
    cfg = cfg_smoke()
    params = R.init_params(cfg, jax.random.PRNGKey(0))
    step = jax.jit(R.make_train_step(cfg, lr=5e-3))
    opt = adamw_init(params)
    b = {k: jnp.asarray(v) for k, v in R.synth_batch(cfg, 128, seed=0).items()}
    for _ in range(30):
        params, opt, loss = step(params, opt, b)

    retrieval = jax.jit(R.make_retrieval_step(cfg, top_k=10))
    rng = np.random.default_rng(1)
    n_cand = 512
    q = {k: np.asarray(v[:1]) for k, v in b.items()
         if k.startswith("user")}
    cand_id = rng.integers(0, cfg.n_items, n_cand).astype(np.int32)
    cand_id[7] = int(np.asarray(b["item_id"])[0])     # plant the positive
    cand_tags = np.full((n_cand, cfg.tags_len), -1, np.int32)
    cand_tags[7] = np.asarray(b["item_tags"])[0]
    q["cand_id"] = cand_id
    q["cand_tags"] = cand_tags
    scores, idx = retrieval(params, {k: jnp.asarray(v) for k, v in q.items()})
    assert scores.shape == (10,) and idx.shape == (10,)
    assert 7 in np.asarray(idx), "trained positive should reach top-10"


def test_serve_and_bulk_shapes():
    cfg = cfg_smoke()
    params = R.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    b = R.synth_batch(cfg, 16, seed=3)
    b["cand_emb"] = rng.normal(size=(16, 256, cfg.tower_mlp[-1])
                               ).astype(np.float32)
    serve = jax.jit(R.make_serve_step(cfg))
    s = serve(params, {k: jnp.asarray(v) for k, v in b.items()})
    assert s.shape == (16, 256)
    bulk = jax.jit(R.make_bulk_score_step(cfg))
    out = bulk(params, {k: jnp.asarray(v) for k, v in b.items()})
    assert out.shape == (16,)
    assert np.all(np.abs(np.asarray(out)) <= 1.0 + 1e-5)  # cosine range
