"""The roofline cost walker is load-bearing — validate it against XLA's own
cost analysis (scan-free programs) and analytic counts (nested scans)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import (analyze, collective_link_bytes,
                                   shape_elems_bytes,
                                   xla_cost_analysis as xla_cost)


def compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_shape_parse():
    assert shape_elems_bytes("f32[4,8]{1,0}") == (32, 128)
    assert shape_elems_bytes("bf16[10]") == (10, 20)
    assert shape_elems_bytes("pred[3,3]") == (9, 9)
    assert shape_elems_bytes("(f32[2], s32[4])") == (6, 24)
    assert shape_elems_bytes("f32[]") == (1, 4)


def test_matches_cost_analysis_no_scan():
    def f(x, w1, w2):
        return jnp.tanh(x @ w1) @ w2
    args = [jax.ShapeDtypeStruct(s, jnp.float32)
            for s in ((256, 512), (512, 512), (512, 128))]
    c = jax.jit(f).lower(*args).compile()
    w = analyze(c.as_text())
    ca = xla_cost(c)
    assert abs(w["flops"] - ca["flops"]) / ca["flops"] < 0.01


def test_scan_trip_count_weighted():
    def f(x, ws):
        def body(c, wi):
            return c @ wi, None
        return jax.lax.scan(body, x, ws)[0]
    args = [jax.ShapeDtypeStruct((256, 256), jnp.float32),
            jax.ShapeDtypeStruct((12, 256, 256), jnp.float32)]
    c = jax.jit(f).lower(*args).compile()
    w = analyze(c.as_text())
    expected = 12 * 2 * 256 ** 3
    assert abs(w["flops"] - expected) / expected < 0.01
    # XLA's own analysis counts the body once — the bug this walker fixes
    assert xla_cost(c)["flops"] < expected / 4


def test_nested_scan():
    def f(x, ws):
        def outer(c, wi):
            def inner(cc, _):
                return jnp.tanh(cc @ wi), None
            return jax.lax.scan(inner, c, None, length=4)[0], None
        return jax.lax.scan(outer, x, ws)[0]
    args = [jax.ShapeDtypeStruct((128, 128), jnp.float32),
            jax.ShapeDtypeStruct((6, 128, 128), jnp.float32)]
    c = jax.jit(f).lower(*args).compile()
    w = analyze(c.as_text())
    expected = 6 * 4 * 2 * 128 ** 3
    assert abs(w["flops"] - expected) / expected < 0.02


def test_collective_link_formulas():
    assert collective_link_bytes("all-reduce", 100, 4) == pytest.approx(150)
    assert collective_link_bytes("all-gather", 100, 4) == pytest.approx(75)
    assert collective_link_bytes("reduce-scatter", 25, 4) == pytest.approx(75)
    assert collective_link_bytes("collective-permute", 100, 4) == 100
    assert collective_link_bytes("all-reduce", 100, 1) == 0


def test_sharded_collectives_counted():
    import subprocess, sys, os, textwrap
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = src
    out = subprocess.run([sys.executable, "-c", textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_cost import analyze
        mesh = jax.make_mesh((8,), ("data",))
        x = jax.ShapeDtypeStruct((1024, 512), jnp.float32,
                                 sharding=NamedSharding(mesh, P("data", None)))
        w = jax.ShapeDtypeStruct((512, 512), jnp.float32,
                                 sharding=NamedSharding(mesh, P(None, "data")))
        with mesh:
            c = jax.jit(lambda a, b: a @ b,
                        out_shardings=NamedSharding(mesh, P("data", None))
                        ).lower(x, w).compile()
        r = analyze(c.as_text())
        assert r["collectives"], "expected at least one collective"
        assert r["link"] > 0
        print("COLLECTIVES_OK")
    """)], capture_output=True, text=True, env=env, timeout=300)
    assert "COLLECTIVES_OK" in out.stdout, out.stderr[-2000:]
