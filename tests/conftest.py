import os
import sys

# Tests run on the single real CPU device. The 512-device override is ONLY
# for the dry-run (tests that need virtual devices spawn subprocesses).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# hypothesis is optional: property tests skip when it is absent (see _hyp.py)
from _hyp import HAVE_HYPOTHESIS  # noqa: E402

if HAVE_HYPOTHESIS:
    from hypothesis import settings

    settings.register_profile("ci", max_examples=25, deadline=None)
    settings.load_profile("ci")
