"""BENCH_*.json trajectory records: append/migrate/validate round-trip.

Regression for the snapshot-overwrite bug: perf_prep/perf_engine used to
`json.dump` one flat snapshot per run, so CI erased the history every
time. `append_run` must keep the latest metrics at top level (consumer
compat) while growing a "runs" history, migrate legacy snapshots in
place, and `validate` must flag any file that regressed to a snapshot.
"""
import json

import pytest

from benchmarks.bench_record import append_run, main, validate


def test_append_creates_and_accumulates(tmp_path):
    p = str(tmp_path / "BENCH_x.json")
    doc1 = append_run(p, {"speedup": 2.0, "graph": "ba"})
    assert doc1["speedup"] == 2.0            # top-level compat field
    assert len(doc1["runs"]) == 1
    rec = doc1["runs"][0]
    assert rec["speedup"] == 2.0
    assert isinstance(rec["commit"], str) and isinstance(rec["date"], str)
    doc2 = append_run(p, {"speedup": 3.0, "graph": "ba"})
    assert doc2["speedup"] == 3.0            # top level tracks the LAST run
    assert len(doc2["runs"]) == 2
    assert doc2["runs"][0]["speedup"] == 2.0  # history preserved
    with open(p) as f:
        assert json.load(f) == doc2
    assert validate(p) == []


def test_append_migrates_legacy_snapshot(tmp_path):
    """A pre-trajectory flat snapshot becomes the first history record."""
    p = str(tmp_path / "BENCH_legacy.json")
    with open(p, "w") as f:
        json.dump({"speedup": 1.5, "roots": 100}, f)
    doc = append_run(p, {"speedup": 1.8, "roots": 100})
    assert len(doc["runs"]) == 2
    assert doc["runs"][0] == {"speedup": 1.5, "roots": 100,
                              "commit": "unknown", "date": "unknown"}
    assert doc["speedup"] == 1.8
    assert validate(p) == []


def test_append_rejects_reserved_metric_names(tmp_path):
    p = str(tmp_path / "BENCH_r.json")
    for bad in ("runs", "commit", "date"):
        with pytest.raises(ValueError, match="reserved"):
            append_run(p, {bad: 1})


def test_validate_flags_snapshot_regression(tmp_path):
    p = str(tmp_path / "BENCH_snap.json")
    with open(p, "w") as f:
        json.dump({"speedup": 2.0}, f)       # no "runs": the old bug shape
    problems = validate(p)
    assert problems and "runs" in problems[0]


def test_validate_flags_stale_top_level(tmp_path):
    """Top-level metrics drifting from the last run record means some
    writer bypassed append_run — the mirror invariant is the tripwire."""
    p = str(tmp_path / "BENCH_stale.json")
    append_run(p, {"speedup": 2.0})
    with open(p) as f:
        doc = json.load(f)
    doc["speedup"] = 9.9                     # hand-edited / stale mirror
    with open(p, "w") as f:
        json.dump(doc, f)
    assert any("differs" in m for m in validate(p))


def test_validate_flags_malformed_records(tmp_path):
    p = str(tmp_path / "BENCH_bad.json")
    with open(p, "w") as f:
        json.dump({"speedup": 1.0,
                   "runs": [{"speedup": 1.0}]}, f)   # no commit/date
    problems = validate(p)
    assert any("commit" in m for m in problems)
    assert any("date" in m for m in problems)


def test_cli_exit_codes(tmp_path, capsys):
    good = str(tmp_path / "BENCH_good.json")
    append_run(good, {"v": 1})
    assert main(["--validate", good]) == 0
    bad = str(tmp_path / "BENCH_bad.json")
    with open(bad, "w") as f:
        json.dump({"v": 1}, f)
    assert main(["--validate", good, bad]) == 1
