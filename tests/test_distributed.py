"""Distributed MCE + dry-run integration over virtual devices.

These tests need >1 device, so they spawn subprocesses with
XLA_FLAGS=--xla_force_host_platform_device_count=N (the parent pytest
process keeps the real single CPU device).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import bitset_engine
from repro.core.bitset_engine import EngineConfig
from repro.core.driver import DistributedMCE, deal_roots, estimate_costs
from repro.graph import barabasi_albert, erdos_renyi

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_py(code: str, devices: int = 8, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_driver_single_device_matches_engine(tmp_path):
    g = barabasi_albert(300, 6, seed=0)
    ref = bitset_engine.run(g, bucket_sizes=(32, 64))
    drv = DistributedMCE(g, chunk=64, bucket_sizes=(32, 64))
    res = drv.run()
    assert res.cliques == ref.cliques
    assert res.calls == ref.calls


def test_driver_checkpoint_restart(tmp_path):
    g = barabasi_albert(300, 6, seed=1)
    ck = str(tmp_path / "mce.json")
    full = DistributedMCE(g, chunk=32, bucket_sizes=(32, 64)).run()

    drv = DistributedMCE(g, chunk=32, ckpt_path=ck, bucket_sizes=(32, 64))
    # simulate failure: run only the first chunks by capping, then resume
    n_before = 0
    orig = drv._run_chunk

    def failing(*args):
        nonlocal n_before
        if n_before >= 2:
            raise RuntimeError("simulated preemption")
        n_before += 1
        return orig(*args)

    drv._run_chunk = failing
    with pytest.raises(RuntimeError):
        drv.run()
    assert os.path.exists(ck)
    # fresh driver (new process semantics) resumes from the cursor
    drv2 = DistributedMCE(g, chunk=32, ckpt_path=ck, bucket_sizes=(32, 64))
    res = drv2.run(resume=True)
    assert res.cliques == full.cliques
    assert res.calls == full.calls


def test_cost_balanced_dealing():
    g = erdos_renyi(200, 0.15, seed=2)
    prep = bitset_engine.prepare(g, bucket_sizes=(64,))
    costs = estimate_costs(prep.buckets[0])
    shards = deal_roots(costs, 4)
    masses = [costs[s].sum() for s in shards]
    assert max(masses) <= min(masses) * 1.8 + 1e-9, \
        "LPT-style dealing should balance cost mass"
    # every root assigned exactly once
    allr = np.sort(np.concatenate(shards))
    assert np.array_equal(allr, np.arange(len(costs)))


def test_distributed_8dev_matches_single():
    """8 virtual devices, shard_map over 'data': counters must match the
    single-host engine bit-for-bit; elastic restart with 4 devices agrees."""
    out = run_py("""
        import numpy as np
        from repro.core.driver import DistributedMCE
        from repro.core import bitset_engine
        from repro.graph import barabasi_albert
        g = barabasi_albert(400, 6, seed=3)
        ref = bitset_engine.run(g, bucket_sizes=(32, 64))
        drv = DistributedMCE(g, chunk=16, bucket_sizes=(32, 64))
        assert drv.n_shards == 8, drv.n_shards
        res = drv.run()
        print("CLIQUES", res.cliques, ref.cliques)
        print("CALLS", res.calls, ref.calls)
        assert res.cliques == ref.cliques
        assert res.calls == ref.calls
    """, devices=8)
    assert "CLIQUES" in out


def test_elastic_restart_different_device_count(tmp_path):
    """Checkpoint written under 8 shards, resumed under 4 — same totals."""
    ck = str(tmp_path / "elastic.json")
    out8 = run_py(f"""
        from repro.core.driver import DistributedMCE
        from repro.graph import barabasi_albert
        g = barabasi_albert(400, 6, seed=4)
        drv = DistributedMCE(g, chunk=16, ckpt_path={ck!r},
                             bucket_sizes=(32, 64))
        n = 0
        orig = drv._run_chunk
        def failing(*args):
            global n
            if n >= 3: raise RuntimeError("preempted")
            n += 1
            return orig(*args)
        drv._run_chunk = failing
        try:
            drv.run()
        except RuntimeError:
            pass
        print("PARTIAL_OK")
    """, devices=8)
    assert "PARTIAL_OK" in out8
    out4 = run_py(f"""
        from repro.core.driver import DistributedMCE
        from repro.core import bitset_engine
        from repro.graph import barabasi_albert
        g = barabasi_albert(400, 6, seed=4)
        ref = bitset_engine.run(g, bucket_sizes=(32, 64))
        drv = DistributedMCE(g, chunk=16, ckpt_path={ck!r},
                             bucket_sizes=(32, 64))
        res = drv.run(resume=True)
        print("CLIQUES", res.cliques, ref.cliques)
        assert res.cliques == ref.cliques
    """, devices=4)
    assert "CLIQUES" in out4


@pytest.mark.slow
def test_dryrun_entrypoint_multipod():
    """The dry-run entry point itself: one cheap cell on the 512-device
    2×16×16 production mesh must lower + compile."""
    out = run_py("""
        import runpy, sys
        sys.argv = ["dryrun", "--arch", "schnet", "--shape", "full_graph_sm",
                    "--multi-pod", "on"]
        import repro.launch.dryrun as d
        rc = d.main()
        assert rc == 0
    """, devices=512)
