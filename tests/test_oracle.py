"""Oracle BK + RMCE reductions vs brute force (the semantics ground truth)."""
import numpy as np
import pytest
from _hyp import given, settings, strategies as st  # optional-hypothesis shim

from repro.core import oracle
from repro.graph import erdos_renyi, from_edge_list, moon_moser


@st.composite
def small_graph(draw):
    n = draw(st.integers(2, 12))
    p = draw(st.floats(0.1, 0.9))
    seed = draw(st.integers(0, 10**6))
    return erdos_renyi(n, p, seed=seed)


@given(small_graph())
def test_bk_pivot_matches_brute(g):
    ref = oracle.maximal_cliques_brute(g)
    assert set(oracle.bk_pivot(g)) == ref


@given(small_graph())
def test_bk_degen_matches_brute(g):
    ref = oracle.maximal_cliques_brute(g)
    assert set(oracle.bk_degen(g)) == ref


@pytest.mark.parametrize("backend", ["pivot", "rcd", "revised"])
@given(g=small_graph())
@settings(max_examples=20)
def test_rmce_full_matches_brute(backend, g):
    ref = oracle.maximal_cliques_brute(g)
    assert set(oracle.rmce(g, backend=backend)) == ref


@given(small_graph(),
       st.booleans(), st.booleans(), st.booleans())
def test_rmce_reduction_combinations(g, gr, dr, xr):
    """Every subset of the three reductions preserves the clique set
    (paper invariants: mc(G) = mc(G') + α; m̃c identities; Lemma 9)."""
    ref = oracle.maximal_cliques_brute(g)
    got = set(oracle.rmce(g, global_red=gr, dynamic_red=dr, x_red=xr))
    assert got == ref


def test_rmce_reduces_calls_on_sparse():
    """The paper's Fig 9 direction: RMCE needs fewer recursive calls."""
    g = erdos_renyi(120, 0.05, seed=3)
    s_base = oracle.MCEStats()
    oracle.bk_degen(g, stats=s_base, collect=False)
    s_rmce = oracle.MCEStats()
    oracle.rmce(g, stats=s_rmce, collect=False)
    assert s_rmce.cliques == s_base.cliques
    assert s_rmce.recursive_calls < s_base.recursive_calls


def test_moon_moser_counts():
    g = moon_moser(4)                       # 3^4 = 81 maximal cliques
    s = oracle.MCEStats()
    oracle.rmce(g, stats=s, collect=False)
    assert s.cliques == 81


def test_stats_vertex_visits_tracked():
    g = erdos_renyi(40, 0.2, seed=11)
    s = oracle.MCEStats()
    oracle.bk_degen(g, stats=s, collect=False)
    assert sum(s.vertex_visits.values()) > 0


def test_path_graph_edge_cliques():
    # path 0-1-2-3: maximal cliques are the edges
    g = from_edge_list(4, np.array([[0, 1], [1, 2], [2, 3]]))
    assert set(oracle.rmce(g)) == {frozenset((0, 1)), frozenset((1, 2)),
                                   frozenset((2, 3))}
