"""LM transformer: per-arch smoke, decode/prefill consistency, MoE invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.configs.base import get_arch
from repro.models import transformer as T
from repro.models.lm_steps import (make_decode_step, make_prefill_step,
                                   make_train_step)
from repro.optim import adamw_init

LM_ARCHS = [a for a in list_archs()
            if get_arch(a).family == "lm"]


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_forward(arch):
    """Reduced config of the same family: one forward, shapes + no NaN."""
    cfg = get_arch(arch).build_smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    toks = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 24)), jnp.int32)
    logits, aux = T.forward(cfg, params, toks)
    assert logits.shape == (2, 24, cfg.vocab)
    assert logits.dtype == jnp.float32
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_smoke_train_step(arch):
    cfg = get_arch(arch).build_smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=1e-3))
    rng = np.random.default_rng(1)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
    tgts = jnp.asarray(rng.integers(0, cfg.vocab, (4, 16)), jnp.int32)
    l0 = None
    for i in range(5):
        params, opt, loss = step(params, opt, toks, tgts)
        l0 = float(loss) if l0 is None else l0
        assert np.isfinite(float(loss))
    assert float(loss) < l0, "loss must decrease when memorising one batch"


@pytest.mark.parametrize("arch", ["qwen3-14b", "chatglm3-6b", "mixtral-8x7b"])
def test_decode_matches_forward(arch):
    """Teacher-forced step-by-step decode reproduces the parallel forward.

    MoE note: capacity_factor is raised so no token is ever dropped — with
    drops, decode (1-token groups) and prefill (full-batch queues) legally
    disagree, exactly as production MoE serving does."""
    cfg = get_arch(arch).build_smoke()
    if cfg.is_moe:
        cfg = T.TransformerConfig(**{**cfg.__dict__, "capacity_factor": 8.0})
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    s = 12
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, s)), jnp.int32)
    full_logits, _ = T.forward(cfg, params, toks)

    cache = T.init_cache(cfg, 2, s)
    decode = jax.jit(make_decode_step(cfg))
    outs = []
    for i in range(s):
        logits, cache = decode(params, cache, toks[:, i:i + 1])
        outs.append(logits[:, 0])
    got = jnp.stack(outs, axis=1)
    window = cfg.sliding_window
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ["qwen3-14b", "mixtral-8x7b"])
def test_prefill_matches_forward(arch):
    cfg = get_arch(arch).build_smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 10)), jnp.int32)
    full_logits, _ = T.forward(cfg, params, toks)
    prefill = jax.jit(make_prefill_step(cfg))
    last, cache = prefill(params, toks)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-2, atol=2e-2)
    assert int(cache["pos"]) == 10


def test_prefill_then_decode_continues():
    """Cache handoff: decode after prefill equals full forward on the prefix."""
    cfg = get_arch("qwen3-14b").build_smoke()
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    s, extra = 8, 4
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (1, s + extra)), jnp.int32)
    full_logits, _ = T.forward(cfg, params, toks)

    prefill = jax.jit(make_prefill_step(cfg))
    _, cache = prefill(params, toks[:, :s])
    # grow cache into a (s+extra) buffer
    buf = T.init_cache(cfg, 1, s + extra)
    buf["k"] = jax.lax.dynamic_update_slice(buf["k"], cache["k"],
                                            (0, 0, 0, 0, 0))
    buf["v"] = jax.lax.dynamic_update_slice(buf["v"], cache["v"],
                                            (0, 0, 0, 0, 0))
    cache = dict(k=buf["k"], v=buf["v"], pos=cache["pos"])
    decode = jax.jit(make_decode_step(cfg))
    for i in range(extra):
        logits, cache = decode(params, cache, toks[:, s + i:s + i + 1])
        np.testing.assert_allclose(np.asarray(logits[:, 0]),
                                   np.asarray(full_logits[:, s + i]),
                                   rtol=2e-2, atol=2e-2)


def test_sliding_window_masks_far_tokens():
    """SWA: logits at position t must not depend on tokens outside the
    receptive field (n_layers × window). Dense FFN — MoE capacity queues
    would leak cross-position dependence through drop ordering."""
    cfg = get_arch("mixtral-8x7b").build_smoke()   # window 32
    small = T.TransformerConfig(
        **{**cfg.__dict__, "name": "swa-test", "sliding_window": 4,
           "n_experts": None})
    params = T.init_params(small, jax.random.PRNGKey(0))
    rng = np.random.default_rng(5)
    toks = rng.integers(0, small.vocab, (1, 10)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, 0] = (toks2[0, 0] + 7) % small.vocab   # perturb a far token
    l1, _ = T.forward(small, params, jnp.asarray(toks))
    l2, _ = T.forward(small, params, jnp.asarray(toks2))
    # position 9 attends to (5..9] — token 0 is outside the window
    np.testing.assert_allclose(np.asarray(l1[0, 9]), np.asarray(l2[0, 9]),
                               rtol=1e-4, atol=1e-4)
    # position 1 does see token 0
    assert not np.allclose(np.asarray(l1[0, 1]), np.asarray(l2[0, 1]),
                           rtol=1e-4, atol=1e-4)


def test_moe_conservation():
    """MoE combine weights: gates renormalised over kept experts ⇒ output
    magnitude comparable to dense; aux loss near 1 for uniform router."""
    from repro.models.layers import moe_ffn
    rng = jax.random.PRNGKey(0)
    b, s, d, e, f = 2, 64, 16, 4, 32
    x = jax.random.normal(rng, (b, s, d))
    router = jnp.zeros((d, e))       # uniform routing
    w_in = jax.random.normal(rng, (e, d, f)) * 0.1
    w_gate = jax.random.normal(rng, (e, d, f)) * 0.1
    w_out = jax.random.normal(rng, (e, f, d)) * 0.1
    y, aux = moe_ffn(x, router, w_in, w_gate, w_out, top_k=2,
                     group_size=64)
    assert y.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(y)))
    # Switch-style aux loss equals top_k under perfectly uniform routing:
    # me = 1/e, ce = top_k/e  ⇒  e · Σ me·ce = top_k
    assert abs(float(aux) - 2.0) < 0.3


def test_param_count_sanity():
    cfg = get_arch("mixtral-8x7b").build()
    n = cfg.param_count()
    assert 45e9 < n < 50e9, f"mixtral-8x7b ~46.7B params, got {n/1e9:.1f}B"
    na = cfg.active_param_count()
    assert 12e9 < na < 14e9, f"active ~12.9B, got {na/1e9:.1f}B"
