"""Heavy-root over-decomposition (straggler mitigation, DESIGN.md §5)."""
import numpy as np
import pytest

from repro.core import bitset_engine, oracle
from repro.core.driver import DistributedMCE, estimate_costs
from repro.graph import caveman, erdos_renyi, moon_moser


@pytest.mark.parametrize("make,thr", [
    (lambda: erdos_renyi(120, 0.25, seed=2), 8),
    (lambda: caveman(6, 8, 0.1, seed=3), 4),
    (lambda: moon_moser(5), 6),
    (lambda: erdos_renyi(60, 0.5, seed=4), 4),
])
def test_split_preserves_cliques(make, thr):
    g = make()
    ref = set(oracle.bk_pivot(g))
    res = bitset_engine.run(g, enumerate_cliques=True, out_cap=1 << 15,
                            bucket_sizes=(32, 64, 128), split_threshold=thr)
    assert set(res.enumerated) == ref
    assert res.cliques == len(ref)


def test_split_actually_decomposes():
    g = erdos_renyi(120, 0.25, seed=2)
    p1 = bitset_engine.prepare(g, bucket_sizes=(32, 64, 128))
    p2 = bitset_engine.prepare(g, bucket_sizes=(32, 64, 128),
                               split_threshold=8)
    n1 = sum(b.num_roots for b in p1.buckets)
    n2 = sum(b.num_roots for b in p2.buckets)
    assert n2 > n1, "hub roots must split into per-branch subproblems"
    # split subproblems carry |R| = 2 bases
    assert any((b.rsz0 > 1).any() for b in p2.buckets)


def test_split_reduces_max_root_cost():
    """The point of over-decomposition: the heaviest shard unit shrinks."""
    g = erdos_renyi(120, 0.25, seed=2)
    p1 = bitset_engine.prepare(g, bucket_sizes=(32, 64, 128))
    p2 = bitset_engine.prepare(g, bucket_sizes=(32, 64, 128),
                               split_threshold=8)
    max1 = max(estimate_costs(b).max() for b in p1.buckets)
    max2 = max(estimate_costs(b).max() for b in p2.buckets)
    assert max2 < max1


def test_split_through_distributed_driver():
    g = erdos_renyi(100, 0.3, seed=5)
    ref = bitset_engine.run(g, bucket_sizes=(32, 64, 128))
    drv = DistributedMCE(g, chunk=16, bucket_sizes=(32, 64, 128),
                         split_threshold=8)
    res = drv.run()
    assert res.cliques == ref.cliques
