"""bitset_ops layer: fused-kernel parity edge cases + dispatcher routing.

Covers the shapes the Pallas path must survive — K not a multiple of
block_k, W at/over the 128-lane pad boundary, and jax.vmap over the kernel
(the engine's real call pattern: the batching rule prepends the batch axis
to the grid, which a kernel reading program_id or revisiting output blocks
gets silently wrong) — plus the dispatch contract: 2-D on TPU goes to the
kernel, explicit leading batch dims fall back to ref.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitset_ops import kernel as bk
from repro.kernels.bitset_ops import ops, ref


def _rand(shape, seed):
    return np.random.default_rng(seed).integers(0, 2**32, shape,
                                                dtype=np.uint32)


# --------------------------------------------------------------------------
# and_popcount_argmax: fused AND + popcount + argmax
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k,w,block_k", [
    (1, 1, 256), (7, 4, 4), (100, 8, 32), (515, 4, 256),  # K % block_k != 0
    (64, 128, 64),                                        # W at lane boundary
    (33, 160, 16),                                        # W over the boundary
])
def test_and_popcount_argmax_parity(k, w, block_k):
    rng = np.random.default_rng(k * 100 + w)
    rows = jnp.asarray(_rand((k, w), k + w))
    mask = jnp.asarray(_rand((w,), k * w + 1))
    valid = jnp.asarray(rng.random(k) < 0.7)
    gi, gb = bk.and_popcount_argmax(rows, mask, valid, block_k=block_k,
                                    interpret=True)
    wi, wb = ref.and_popcount_argmax(rows, mask, valid)
    assert int(gb) == int(wb)
    assert int(gi) == int(wi)


def test_and_popcount_argmax_all_invalid():
    rows = jnp.asarray(_rand((13, 2), 5))
    mask = jnp.asarray(_rand((2,), 6))
    valid = jnp.zeros(13, bool)
    gi, gb = bk.and_popcount_argmax(rows, mask, valid, block_k=4,
                                    interpret=True)
    assert int(gb) == -1          # all-invalid sentinel score


def test_and_popcount_argmax_tie_breaks_first():
    # identical rows -> identical scores; first valid index must win, same
    # as jnp.argmax in the ref (the engine's pivot choice depends on this)
    rows = jnp.asarray(np.tile(_rand((1, 4), 7), (20, 1)))
    mask = jnp.asarray(_rand((4,), 8))
    valid = jnp.ones(20, bool)
    gi, _ = bk.and_popcount_argmax(rows, mask, valid, block_k=8,
                                   interpret=True)
    wi, _ = ref.and_popcount_argmax(rows, mask, valid)
    assert int(gi) == int(wi) == 0


# --------------------------------------------------------------------------
# and_popcount_many: one row matrix vs a batch of masks
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k,m,w", [
    (1, 1, 1), (7, 5, 4), (100, 33, 8),
    (300, 17, 4),                 # K % block_k != 0 (block_k=256)
    (5, 300, 4),                  # M % block_m != 0
    (9, 9, 128), (3, 4, 136),     # W at / over the 128-lane boundary
    (600, 300, 32),               # trips the VMEM tile clamp (bm*bk*w cap)
])
def test_and_popcount_many_parity(k, m, w):
    rows = jnp.asarray(_rand((k, w), k * m))
    masks = jnp.asarray(_rand((m, w), k + m + w))
    got = bk.and_popcount_many(rows, masks, interpret=True)
    want = ref.and_popcount_many(rows, masks)
    assert got.shape == (m, k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_and_popcount_many_python_int_crosscheck():
    rows = _rand((6, 3), 1)
    masks = _rand((4, 3), 2)
    want = ref.and_popcount_many(jnp.asarray(rows), jnp.asarray(masks))
    for mi in range(4):
        m_int = int.from_bytes(masks[mi].tobytes(), "little")
        for ki in range(6):
            r_int = int.from_bytes(rows[ki].tobytes(), "little")
            assert int(want[mi, ki]) == bin(r_int & m_int).count("1")


# --------------------------------------------------------------------------
# and_popcount_rows: existing kernel, new edge shapes
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k,w,block_k", [
    (515, 128, 256),              # K % block_k != 0, W at lane boundary
    (40, 136, 16),                # W over the lane boundary
    (1, 256, 256),
])
def test_and_popcount_rows_pad_boundaries(k, w, block_k):
    rows = jnp.asarray(_rand((k, w), k))
    mask = jnp.asarray(_rand((w,), w))
    got = bk.and_popcount_rows(rows, mask, block_k=block_k, interpret=True)
    want = ref.and_popcount_rows(rows, mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# vmap parity: loop.run_bucket vmaps run_root, so on TPU the kernels run
# with a batched grid — inside vmap the per-example tracer is 2-D and the
# ops dispatcher takes the pallas path (the ndim guard cannot see vmap).
# These tests run the batching rule in interpret mode; they fail for any
# kernel that accumulates across grid steps keyed on program_id (the
# batch axis is prepended to the grid, so program_id(0) becomes the batch
# index and only batch element 0 would initialise its output).
# --------------------------------------------------------------------------

@pytest.mark.parametrize("b,k,w,block_k", [
    (3, 100, 8, 32),              # several tiles per example
    (4, 33, 4, 16),               # K % block_k != 0
    (2, 7, 128, 4),               # W at the lane boundary
])
def test_vmap_and_popcount_rows_parity(b, k, w, block_k):
    rows = jnp.asarray(_rand((b, k, w), b + k))
    mask = jnp.asarray(_rand((b, w), b * k))
    got = jax.vmap(lambda r, m: bk.and_popcount_rows(
        r, m, block_k=block_k, interpret=True))(rows, mask)
    want = ref.and_popcount_rows(rows, mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,k,w,block_k", [
    (3, 100, 8, 32),
    (4, 33, 4, 16),               # K % block_k != 0
    (5, 256, 2, 64),
])
def test_vmap_and_popcount_argmax_parity(b, k, w, block_k):
    rng = np.random.default_rng(b * k + w)
    rows = jnp.asarray(_rand((b, k, w), b + k + w))
    mask = jnp.asarray(_rand((b, w), b * k + 1))
    valid = jnp.asarray(rng.random((b, k)) < 0.7)
    gi, gb = jax.vmap(lambda r, m, v: bk.and_popcount_argmax(
        r, m, v, block_k=block_k, interpret=True))(rows, mask, valid)
    wi, wb = ref.and_popcount_argmax(rows, mask, valid)
    np.testing.assert_array_equal(np.asarray(gb), np.asarray(wb))
    np.testing.assert_array_equal(np.asarray(gi), np.asarray(wi))


def test_vmap_and_popcount_argmax_every_batch_element_initialised():
    """Regression: per-example answers must not depend on batch position.
    Identical examples stacked B times must all return batch element 0's
    answer (an accumulator keyed on program_id(0) under vmap initialises
    only batch 0 and offsets tile_arg by the batch index)."""
    rows1 = _rand((40, 4), 11)
    mask1 = _rand((4,), 12)
    valid1 = np.random.default_rng(13).random(40) < 0.7
    b = 4
    rows = jnp.asarray(np.broadcast_to(rows1, (b, 40, 4)))
    mask = jnp.asarray(np.broadcast_to(mask1, (b, 4)))
    valid = jnp.asarray(np.broadcast_to(valid1, (b, 40)))
    gi, gb = jax.vmap(lambda r, m, v: bk.and_popcount_argmax(
        r, m, v, block_k=8, interpret=True))(rows, mask, valid)
    wi, wb = ref.and_popcount_argmax(jnp.asarray(rows1), jnp.asarray(mask1),
                                     jnp.asarray(valid1))
    np.testing.assert_array_equal(np.asarray(gi), np.full(b, int(wi)))
    np.testing.assert_array_equal(np.asarray(gb), np.full(b, int(wb)))


@pytest.mark.parametrize("b,k,m,w", [
    (3, 100, 33, 8),
    (2, 300, 17, 4),              # K % block_k != 0
    (4, 5, 9, 128),               # W at the lane boundary
])
def test_vmap_and_popcount_many_parity(b, k, m, w):
    rows = jnp.asarray(_rand((b, k, w), k * m))
    masks = jnp.asarray(_rand((b, m, w), k + m + w))
    got = jax.vmap(lambda r, ms: bk.and_popcount_many(
        r, ms, interpret=True))(rows, masks)
    want = ref.and_popcount_many(rows, masks)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# clique_counts: fused is-P-a-clique / X-domination counts
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k,w,block_k", [
    (1, 1, 256), (7, 4, 4), (100, 8, 32), (515, 4, 256),  # K % block_k != 0
    (64, 128, 64),                # W at the lane boundary
    (33, 160, 16),                # W over the boundary
])
def test_clique_counts_parity(k, w, block_k):
    rng = np.random.default_rng(k * 100 + w + 7)
    rows = jnp.asarray(_rand((k, w), k + w + 7))
    mask = jnp.asarray(_rand((w,), k * w + 8))
    in_p = rng.random(k) < 0.5
    in_x = ~in_p & (rng.random(k) < 0.5)
    got = bk.clique_counts(rows, mask, jnp.asarray(in_p), jnp.asarray(in_x),
                           block_k=block_k, interpret=True)
    want = ref.clique_counts(rows, mask, jnp.asarray(in_p),
                             jnp.asarray(in_x))
    assert (int(got[0]), int(got[1])) == (int(want[0]), int(want[1]))


@pytest.mark.parametrize("b,k,w,block_k", [
    (3, 100, 8, 32),
    (4, 33, 4, 16),               # K % block_k != 0
    (2, 7, 128, 4),               # W at the lane boundary
])
def test_vmap_clique_counts_parity(b, k, w, block_k):
    rng = np.random.default_rng(b + k + w)
    rows = jnp.asarray(_rand((b, k, w), b * k + 9))
    mask = jnp.asarray(_rand((b, w), b + k + 10))
    in_p = rng.random((b, k)) < 0.5
    in_x = ~in_p & (rng.random((b, k)) < 0.5)
    gf, gd = jax.vmap(lambda r, m, p, x: bk.clique_counts(
        r, m, p, x, block_k=block_k, interpret=True))(
        rows, mask, jnp.asarray(in_p), jnp.asarray(in_x))
    wf, wd = ref.clique_counts(rows, mask, jnp.asarray(in_p),
                               jnp.asarray(in_x))
    np.testing.assert_array_equal(np.asarray(gf), np.asarray(wf))
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))


def test_vmap_clique_counts_every_batch_element_initialised():
    """Distinct stacked examples must each get their own counts (a kernel
    whose pad-handling or output blocks depended on program_id(0) would
    bleed counts across batch elements under vmap)."""
    b = 4
    rng = np.random.default_rng(51)
    rows = jnp.asarray(_rand((b, 40, 4), 52))
    mask = jnp.asarray(_rand((b, 4), 53))
    in_p = rng.random((b, 40)) < 0.5
    in_x = ~in_p & (rng.random((b, 40)) < 0.5)
    gf, gd = jax.vmap(lambda r, m, p, x: bk.clique_counts(
        r, m, p, x, block_k=8, interpret=True))(
        rows, mask, jnp.asarray(in_p), jnp.asarray(in_x))
    for bi in range(b):
        wf, wd = ref.clique_counts(rows[bi], mask[bi],
                                   jnp.asarray(in_p[bi]),
                                   jnp.asarray(in_x[bi]))
        assert int(gf[bi]) == int(wf)
        assert int(gd[bi]) == int(wd)


def test_dispatch_clique_counts(monkeypatch):
    """2-D on TPU routes to the kernel; batch dims fall back to ref."""
    monkeypatch.setattr(ops, "_on_tpu", lambda: True)
    calls = []

    def fake(rows, mask, in_p, in_x, interpret):
        calls.append(("clique", interpret))
        return ref.clique_counts(rows, mask, in_p, in_x)

    monkeypatch.setattr(ops.kernel, "clique_counts", fake)
    rows = jnp.asarray(_rand((6, 2), 61))
    mask = jnp.asarray(_rand((2,), 62))
    in_p = jnp.asarray(np.array([1, 0, 1, 0, 1, 0], bool))
    ops.clique_counts(rows, mask, in_p, ~in_p)
    assert calls == [("clique", False)]
    calls.clear()

    def boom(*a, **k):
        raise RuntimeError("pallas kernel must not be called for 3-D")

    monkeypatch.setattr(ops.kernel, "clique_counts", boom)
    rows3 = jnp.asarray(_rand((2, 6, 2), 63))
    mask2 = jnp.asarray(_rand((2, 2), 64))
    in_p3 = jnp.asarray(np.random.default_rng(65).random((2, 6)) < 0.5)
    gf, gd = ops.clique_counts(rows3, mask2, in_p3, ~in_p3)
    wf, wd = ref.clique_counts(rows3, mask2, in_p3, ~in_p3)
    np.testing.assert_array_equal(np.asarray(gf), np.asarray(wf))
    np.testing.assert_array_equal(np.asarray(gd), np.asarray(wd))


# --------------------------------------------------------------------------
# frame_step: fused child-set + degree + Lemma-7 partner step
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k,w,block_k", [
    (1, 1, 256), (7, 4, 4), (100, 8, 32), (515, 4, 256),  # K % block_k != 0
    (64, 128, 64),                # W at the lane boundary
    (33, 160, 16),                # W over the boundary
])
def test_frame_step_parity(k, w, block_k):
    rows = jnp.asarray(_rand((k, w), k + w))
    p = jnp.asarray(_rand((w,), k * w + 1))
    xp = jnp.asarray(_rand((w,), k * w + 2))
    wrow = jnp.asarray(_rand((w,), k * w + 3))
    got = bk.frame_step(rows, p, xp, wrow, block_k=block_k, interpret=True)
    want = ref.frame_step(rows, p, xp, wrow)
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def test_frame_step_python_int_crosscheck():
    """Independent oracle: deg vs python big-ints, partner exact at deg 1."""
    rows = _rand((40, 3), 21)
    p = _rand((3,), 22)
    xp = _rand((3,), 23)
    wrow = _rand((3,), 24)
    childp, childxp, deg, partner = ref.frame_step(
        jnp.asarray(rows), jnp.asarray(p), jnp.asarray(xp), jnp.asarray(wrow))
    p_int = int.from_bytes(p.tobytes(), "little")
    w_int = int.from_bytes(wrow.tobytes(), "little")
    cp_int = int.from_bytes(np.asarray(childp).tobytes(), "little")
    assert cp_int == p_int & w_int
    assert (int.from_bytes(np.asarray(childxp).tobytes(), "little")
            == int.from_bytes(xp.tobytes(), "little") & w_int)
    for ki in range(40):
        r_int = int.from_bytes(rows[ki].tobytes(), "little")
        anded = r_int & cp_int
        assert int(deg[ki]) == bin(anded).count("1")
        if int(deg[ki]) == 1:
            assert int(partner[ki]) == anded.bit_length() - 1


def test_vmap_frame_step_parity():
    b, k, w = 3, 100, 8
    rows = jnp.asarray(_rand((b, k, w), 31))
    p = jnp.asarray(_rand((b, w), 32))
    xp = jnp.asarray(_rand((b, w), 33))
    wrow = jnp.asarray(_rand((b, w), 34))
    got = jax.vmap(lambda r, pp, xx, ww: bk.frame_step(
        r, pp, xx, ww, block_k=32, interpret=True))(rows, p, xp, wrow)
    want = ref.frame_step(rows, p, xp, wrow)
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))


def test_vmap_frame_step_every_batch_element_initialised():
    """The (1, W) child-set output blocks are revisited by every grid step;
    under vmap each batch element must still get its own (idempotent)
    value — stacked distinct examples must match per-example refs."""
    b = 4
    rows = jnp.asarray(_rand((b, 40, 4), 41))
    p = jnp.asarray(_rand((b, 4), 42))
    xp = jnp.asarray(_rand((b, 4), 43))
    wrow = jnp.asarray(_rand((b, 4), 44))
    got = jax.vmap(lambda r, pp, xx, ww: bk.frame_step(
        r, pp, xx, ww, block_k=8, interpret=True))(rows, p, xp, wrow)
    for bi in range(b):
        want = ref.frame_step(rows[bi], p[bi], xp[bi], wrow[bi])
        for g, r in zip(got, want):
            np.testing.assert_array_equal(np.asarray(g[bi]), np.asarray(r))


# --------------------------------------------------------------------------
# dispatcher routing: TPU 2-D -> kernel, batch dims -> ref fallback
# --------------------------------------------------------------------------

def test_dispatch_batch_dims_fall_back_to_ref(monkeypatch):
    """Even when the backend claims TPU, an explicit >2-D array must take
    the ref path (the pallas wrappers are written for 2-D operands; vmap
    batching is a separate, tested path — see the vmap tests above)."""
    monkeypatch.setattr(ops, "_on_tpu", lambda: True)
    sentinel = RuntimeError("pallas kernel must not be called for 3-D")

    def boom(*a, **k):
        raise sentinel

    monkeypatch.setattr(ops.kernel, "and_popcount_rows", boom)
    monkeypatch.setattr(ops.kernel, "and_popcount_many", boom)
    rows3 = jnp.asarray(_rand((2, 9, 4), 3))
    mask2 = jnp.asarray(_rand((2, 4), 4))
    want = ref.and_popcount_rows(rows3, mask2)
    np.testing.assert_array_equal(
        np.asarray(ops.and_popcount_rows(rows3, mask2)), np.asarray(want))
    masks3 = jnp.asarray(_rand((2, 5, 4), 5))
    np.testing.assert_array_equal(
        np.asarray(ops.and_popcount_many(rows3, masks3)),
        np.asarray(ref.and_popcount_many(rows3, masks3)))


def test_dispatch_2d_routes_to_kernel_on_tpu(monkeypatch):
    monkeypatch.setattr(ops, "_on_tpu", lambda: True)
    calls = []

    def fake_rows(rows, mask, interpret):
        calls.append(("rows", interpret))
        return ref.and_popcount_rows(rows, mask)

    def fake_argmax(rows, mask, valid, interpret):
        calls.append(("argmax", interpret))
        return ref.and_popcount_argmax(rows, mask, valid)

    def fake_many(rows, masks, interpret):
        calls.append(("many", interpret))
        return ref.and_popcount_many(rows, masks)

    monkeypatch.setattr(ops.kernel, "and_popcount_rows", fake_rows)
    monkeypatch.setattr(ops.kernel, "and_popcount_argmax", fake_argmax)
    monkeypatch.setattr(ops.kernel, "and_popcount_many", fake_many)
    rows = jnp.asarray(_rand((6, 2), 1))
    mask = jnp.asarray(_rand((2,), 2))
    ops.and_popcount_rows(rows, mask)
    ops.and_popcount_argmax(rows, mask, jnp.ones(6, bool))
    ops.and_popcount_many(rows, jnp.asarray(_rand((3, 2), 3)))
    assert calls == [("rows", False), ("argmax", False), ("many", False)]


def test_dispatch_cpu_uses_ref():
    """On this container (CPU) the dispatcher must take the jnp ref path."""
    assert not ops._on_tpu()
    rows = jnp.asarray(_rand((6, 2), 1))
    mask = jnp.asarray(_rand((2,), 2))
    np.testing.assert_array_equal(
        np.asarray(ops.and_popcount_rows(rows, mask)),
        np.asarray(ref.and_popcount_rows(rows, mask)))


# --------------------------------------------------------------------------
# dfs_step_window: fused K-step frame window (VMEM-resident stack slice)
# --------------------------------------------------------------------------

def _window_case(seed, u=64, w=2, xc=24, t=8):
    """A plausible window invocation: symmetric adjacency, random X rows,
    root-ish frame at slot 0 (the wrapper always re-centers so the live
    frame sits mid-window; slot 0 with dloc=0 is the cold-start shape)."""
    from repro.core.engine import frames as fr
    r = np.random.default_rng(seed)
    m = r.random((u, u)) < 0.25
    m = np.triu(m, 1)
    m = m | m.T
    a = np.zeros((u, w), np.uint32)
    for i in range(u):
        for j in range(u):
            if m[i, j]:
                a[i, j // 32] |= np.uint32(1 << (j % 32))
    xr = r.integers(0, 2**32, (xc, w), dtype=np.uint32)
    alive0 = (r.random(xc) < 0.6).astype(np.int32)
    winp = np.zeros((t, w), np.uint32)
    winp[0] = r.integers(0, 2**32, w, dtype=np.uint32)
    winb = np.zeros((t, w), np.uint32)
    winb[0] = winp[0] & r.integers(0, 2**32, w, dtype=np.uint32)
    winrsz = np.zeros(t, np.int32)
    winrsz[0] = 1
    return (jnp.asarray(a), jnp.asarray(xr), fr.eye_bits(u, w),
            jnp.asarray(alive0), jnp.asarray(winp), jnp.asarray(winb),
            jnp.zeros((t, w), jnp.uint32), jnp.zeros((t, w), jnp.uint32),
            jnp.asarray(winrsz), jnp.int32(0))


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("steps", [1, 7, 32])
def test_dfs_step_window_parity(seed, steps):
    """Kernel vs jnp ref, bit-exact: every window plane, rsz, and the
    packed ctl row (dloc', calls, branches, sum_px, cliques, steps_done)."""
    args = _window_case(seed)
    want = ref.dfs_step_window(*args, steps)
    got = bk.dfs_step_window(*args, steps=steps, interpret=True)
    for i, (g, r) in enumerate(zip(got, want)):
        np.testing.assert_array_equal(
            np.asarray(g), np.asarray(r), err_msg=f"output {i}")


def test_dfs_step_window_underflow_stops():
    """A window with no branch work anywhere (B empty in every frame)
    must pop straight below the window — dloc' = -1 — without fabricating
    work: zero calls, branches, cliques."""
    args = list(_window_case(3))
    args[5] = jnp.zeros_like(args[5])          # winB: no branch bits
    got = bk.dfs_step_window(*args, steps=16, interpret=True)
    ctl = np.asarray(got[-1])
    assert ctl[0] == -1                        # dloc'
    assert ctl[1] == ctl[2] == ctl[4] == 0     # calls, branches, cliques


def test_vmap_dfs_step_window_parity():
    """The engine vmaps the window step over lanes (shared eye)."""
    b = 3
    cases = [_window_case(100 + i) for i in range(b)]
    eye = cases[0][2]
    stacked = [jnp.stack([c[i] for c in cases])
               for i in range(10) if i != 2]

    def f(a, xr, alive0, wp, wb, wxp, wrb, wrsz, dl):
        return bk.dfs_step_window(a, xr, eye, alive0, wp, wb, wxp, wrb,
                                  wrsz, dl, steps=9, interpret=True)

    got = jax.vmap(f)(*stacked)
    for bi, c in enumerate(cases):
        want = ref.dfs_step_window(*c, 9)
        for i, (g, r) in enumerate(zip(got, want)):
            np.testing.assert_array_equal(
                np.asarray(g[bi]), np.asarray(r),
                err_msg=f"batch {bi} output {i}")


def test_dispatch_dfs_step_window(monkeypatch):
    """On TPU an (8, <=128)-word window routes to the kernel; CPU (this
    container) and oversized operands take the ref path."""
    args = _window_case(7)
    want = ref.dfs_step_window(*args, 4)

    got = ops.dfs_step_window(*args, steps=4)  # CPU -> ref
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))

    monkeypatch.setattr(ops, "_on_tpu", lambda: True)
    calls = []

    def fake(*a, steps, interpret):
        calls.append((steps, interpret))
        return ref.dfs_step_window(*a, steps)

    monkeypatch.setattr(ops.kernel, "dfs_step_window", fake)
    got = ops.dfs_step_window(*args, steps=4)
    assert calls == [(4, False)]
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))

    calls.clear()                              # too many X rows -> ref
    big = list(args)
    big[1] = jnp.zeros((ops.WINDOW_MAX_XROWS + 1, 2), jnp.uint32)
    big[3] = jnp.zeros(ops.WINDOW_MAX_XROWS + 1, jnp.int32)
    ops.dfs_step_window(*big, steps=4)
    assert calls == []


# --------------------------------------------------------------------------
# dfs_step_window_lanes: grid-over-lanes window walk (the persistent
# engine's batched form — one Pallas grid step per lane)
# --------------------------------------------------------------------------

def _lanes_case(nlanes=4, seed=200):
    """Stack independent single-lane window cases; lane 2 starts dead
    (dloc = -1), the engine's idle-lane shape the kernel must no-op."""
    cases = [_window_case(seed + i) for i in range(nlanes)]
    eye = cases[0][2]
    stacked = [jnp.stack([c[i] for c in cases])
               for i in range(10) if i != 2]
    a, xr, alive0, wp, wb, wxp, wrb, wrsz, dl = stacked
    dl = dl.at[2].set(-1)
    return (a, xr, eye, alive0, wp, wb, wxp, wrb, wrsz, dl)


@pytest.mark.parametrize("steps", [1, 9, 32])
def test_dfs_step_window_lanes_parity(steps):
    """Kernel vs vmapped ref, bit-exact per lane — including the dead
    lane, which must return unchanged with zero counter deltas."""
    args = _lanes_case()
    want = ref.dfs_step_window_lanes(*args, steps)
    got = bk.dfs_step_window_lanes(*args, steps=steps, interpret=True)
    for i, (g, r) in enumerate(zip(got, want)):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r),
                                      err_msg=f"output {i}")
    ctl = np.asarray(got[-1])
    assert ctl[2, 0] == -1                       # dead lane stays dead
    assert (ctl[2, 1:6] == 0).all()              # ...with zero deltas


def test_dispatch_dfs_step_window_lanes(monkeypatch):
    """On TPU a lane-batched (L, 8, <=128)-word window routes to the grid
    kernel; CPU and oversized operands take the vmapped ref path."""
    args = _lanes_case(nlanes=3, seed=300)
    want = ref.dfs_step_window_lanes(*args, 4)

    got = ops.dfs_step_window_lanes(*args, steps=4)   # CPU -> ref
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))

    monkeypatch.setattr(ops, "_on_tpu", lambda: True)
    calls = []

    def fake(*a, steps, interpret):
        calls.append((steps, interpret))
        return ref.dfs_step_window_lanes(*a, steps)

    monkeypatch.setattr(ops.kernel, "dfs_step_window_lanes", fake)
    got = ops.dfs_step_window_lanes(*args, steps=4)
    assert calls == [(4, False)]
    for g, r in zip(got, want):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(r))

    calls.clear()                              # too many X rows -> ref
    big = list(args)
    big[1] = jnp.zeros((3, ops.WINDOW_MAX_XROWS + 1, 2), jnp.uint32)
    big[3] = jnp.zeros((3, ops.WINDOW_MAX_XROWS + 1), jnp.int32)
    ops.dfs_step_window_lanes(*big, steps=4)
    assert calls == []
