"""bitset_ops layer: fused-kernel parity edge cases + dispatcher routing.

Covers the shapes the Pallas path must survive — K not a multiple of
block_k, W at/over the 128-lane pad boundary — plus the dispatch contract:
2-D on TPU goes to the kernel, leading batch dims always fall back to ref.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.bitset_ops import kernel as bk
from repro.kernels.bitset_ops import ops, ref


def _rand(shape, seed):
    return np.random.default_rng(seed).integers(0, 2**32, shape,
                                                dtype=np.uint32)


# --------------------------------------------------------------------------
# and_popcount_argmax: fused AND + popcount + argmax
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k,w,block_k", [
    (1, 1, 256), (7, 4, 4), (100, 8, 32), (515, 4, 256),  # K % block_k != 0
    (64, 128, 64),                                        # W at lane boundary
    (33, 160, 16),                                        # W over the boundary
])
def test_and_popcount_argmax_parity(k, w, block_k):
    rng = np.random.default_rng(k * 100 + w)
    rows = jnp.asarray(_rand((k, w), k + w))
    mask = jnp.asarray(_rand((w,), k * w + 1))
    valid = jnp.asarray(rng.random(k) < 0.7)
    gi, gb = bk.and_popcount_argmax(rows, mask, valid, block_k=block_k,
                                    interpret=True)
    wi, wb = ref.and_popcount_argmax(rows, mask, valid)
    assert int(gb) == int(wb)
    assert int(gi) == int(wi)


def test_and_popcount_argmax_all_invalid():
    rows = jnp.asarray(_rand((13, 2), 5))
    mask = jnp.asarray(_rand((2,), 6))
    valid = jnp.zeros(13, bool)
    gi, gb = bk.and_popcount_argmax(rows, mask, valid, block_k=4,
                                    interpret=True)
    assert int(gb) == -1          # all-invalid sentinel score


def test_and_popcount_argmax_tie_breaks_first():
    # identical rows -> identical scores; first valid index must win, same
    # as jnp.argmax in the ref (the engine's pivot choice depends on this)
    rows = jnp.asarray(np.tile(_rand((1, 4), 7), (20, 1)))
    mask = jnp.asarray(_rand((4,), 8))
    valid = jnp.ones(20, bool)
    gi, _ = bk.and_popcount_argmax(rows, mask, valid, block_k=8,
                                   interpret=True)
    wi, _ = ref.and_popcount_argmax(rows, mask, valid)
    assert int(gi) == int(wi) == 0


# --------------------------------------------------------------------------
# and_popcount_many: one row matrix vs a batch of masks
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k,m,w", [
    (1, 1, 1), (7, 5, 4), (100, 33, 8),
    (300, 17, 4),                 # K % block_k != 0 (block_k=256)
    (5, 300, 4),                  # M % block_m != 0
    (9, 9, 128), (3, 4, 136),     # W at / over the 128-lane boundary
    (600, 300, 32),               # trips the VMEM tile clamp (bm*bk*w cap)
])
def test_and_popcount_many_parity(k, m, w):
    rows = jnp.asarray(_rand((k, w), k * m))
    masks = jnp.asarray(_rand((m, w), k + m + w))
    got = bk.and_popcount_many(rows, masks, interpret=True)
    want = ref.and_popcount_many(rows, masks)
    assert got.shape == (m, k)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_and_popcount_many_python_int_crosscheck():
    rows = _rand((6, 3), 1)
    masks = _rand((4, 3), 2)
    want = ref.and_popcount_many(jnp.asarray(rows), jnp.asarray(masks))
    for mi in range(4):
        m_int = int.from_bytes(masks[mi].tobytes(), "little")
        for ki in range(6):
            r_int = int.from_bytes(rows[ki].tobytes(), "little")
            assert int(want[mi, ki]) == bin(r_int & m_int).count("1")


# --------------------------------------------------------------------------
# and_popcount_rows: existing kernel, new edge shapes
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k,w,block_k", [
    (515, 128, 256),              # K % block_k != 0, W at lane boundary
    (40, 136, 16),                # W over the lane boundary
    (1, 256, 256),
])
def test_and_popcount_rows_pad_boundaries(k, w, block_k):
    rows = jnp.asarray(_rand((k, w), k))
    mask = jnp.asarray(_rand((w,), w))
    got = bk.and_popcount_rows(rows, mask, block_k=block_k, interpret=True)
    want = ref.and_popcount_rows(rows, mask)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# dispatcher routing: TPU 2-D -> kernel, batch dims -> ref fallback
# --------------------------------------------------------------------------

def test_dispatch_batch_dims_fall_back_to_ref(monkeypatch):
    """Even when the backend claims TPU, >2-D input must take the ref path
    (the pallas kernels are 2-D only)."""
    monkeypatch.setattr(ops, "_on_tpu", lambda: True)
    sentinel = RuntimeError("pallas kernel must not be called for 3-D")

    def boom(*a, **k):
        raise sentinel

    monkeypatch.setattr(ops.kernel, "and_popcount_rows", boom)
    monkeypatch.setattr(ops.kernel, "and_popcount_many", boom)
    rows3 = jnp.asarray(_rand((2, 9, 4), 3))
    mask2 = jnp.asarray(_rand((2, 4), 4))
    want = ref.and_popcount_rows(rows3, mask2)
    np.testing.assert_array_equal(
        np.asarray(ops.and_popcount_rows(rows3, mask2)), np.asarray(want))
    masks3 = jnp.asarray(_rand((2, 5, 4), 5))
    np.testing.assert_array_equal(
        np.asarray(ops.and_popcount_many(rows3, masks3)),
        np.asarray(ref.and_popcount_many(rows3, masks3)))


def test_dispatch_2d_routes_to_kernel_on_tpu(monkeypatch):
    monkeypatch.setattr(ops, "_on_tpu", lambda: True)
    calls = []

    def fake_rows(rows, mask, interpret):
        calls.append(("rows", interpret))
        return ref.and_popcount_rows(rows, mask)

    def fake_argmax(rows, mask, valid, interpret):
        calls.append(("argmax", interpret))
        return ref.and_popcount_argmax(rows, mask, valid)

    def fake_many(rows, masks, interpret):
        calls.append(("many", interpret))
        return ref.and_popcount_many(rows, masks)

    monkeypatch.setattr(ops.kernel, "and_popcount_rows", fake_rows)
    monkeypatch.setattr(ops.kernel, "and_popcount_argmax", fake_argmax)
    monkeypatch.setattr(ops.kernel, "and_popcount_many", fake_many)
    rows = jnp.asarray(_rand((6, 2), 1))
    mask = jnp.asarray(_rand((2,), 2))
    ops.and_popcount_rows(rows, mask)
    ops.and_popcount_argmax(rows, mask, jnp.ones(6, bool))
    ops.and_popcount_many(rows, jnp.asarray(_rand((3, 2), 3)))
    assert calls == [("rows", False), ("argmax", False), ("many", False)]


def test_dispatch_cpu_uses_ref():
    """On this container (CPU) the dispatcher must take the jnp ref path."""
    assert not ops._on_tpu()
    rows = jnp.asarray(_rand((6, 2), 1))
    mask = jnp.asarray(_rand((2,), 2))
    np.testing.assert_array_equal(
        np.asarray(ops.and_popcount_rows(rows, mask)),
        np.asarray(ref.and_popcount_rows(rows, mask)))
