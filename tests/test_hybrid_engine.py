"""Hybrid branching + early termination: oracle parity and edge hardening.

The hybrid backend adds two checks on top of pivot branching — emit P∪R
without recursing when P∪X is already a clique (unless an X vertex
dominates P), and switch to vertex branching on dense subproblems — so
parity must hold on cliques AND enumerated sets across every dispatch
path: the lock-step per-root vmap, the persistent lane-refill queue
(side-effects gated on the live mask), and the auto policy. Also covers
the ISSUE-8 bugfix sweep: `choose_engine` degenerate cost vectors,
`root_cost_skew` clamping, and `MCEService.query` falsy-override
rejection.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import oracle
from repro.core.driver import DistributedMCE
from repro.core.engine import (BACKENDS, EngineConfig, choose_engine,
                               prepare, root_cost_skew, run, run_bucket,
                               run_bucket_persistent)
from repro.launch.mce_service import MCEService
from repro.graph import generators as gen

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

GRAPHS = {
    "er": lambda: gen.erdos_renyi(60, 0.3, seed=0),
    "ba": lambda: gen.barabasi_albert(80, 5, seed=1),
    "caveman": lambda: gen.caveman(8, 6, seed=2),
}


# ---------------------------------------------------------------------------
# Oracle parity across graphs × engines × dynamic reduction
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("engine,lanes", [("perroot", 64),
                                          ("persistent", 7),
                                          ("auto", 16)])
@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_hybrid_matches_oracle_counts(gname, engine, lanes):
    g = GRAPHS[gname]()
    res = run(g, backend="hybrid", engine=engine, lanes=lanes)
    assert res.cliques == len(oracle.bk_pivot(g))
    assert not res.iters_exhausted


@pytest.mark.parametrize("dyn", [True, False])
@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_hybrid_enumerates_same_sets(gname, dyn):
    """Early termination emits cliques from a different code path (the
    fused clique test, not the leaf report) — the SETS must still match
    the oracle exactly, both with Lemma 8 on and off."""
    g = GRAPHS[gname]()
    res = run(g, backend="hybrid", enumerate_cliques=True, dynamic_red=dyn)
    assert not res.overflow
    assert set(res.enumerated) == set(oracle.bk_pivot(g))


@pytest.mark.parametrize("gname", sorted(GRAPHS))
def test_hybrid_persistent_matches_perroot_counters(gname):
    """Lane interleaving must not change what the ET check reports: the
    persistent queue reproduces the per-root counters bit-for-bit."""
    g = GRAPHS[gname]()
    ref = run(g, backend="hybrid", engine="perroot")
    res = run(g, backend="hybrid", engine="persistent", lanes=5)
    assert (res.cliques, res.calls, res.branches, res.sum_px) == \
           (ref.cliques, ref.calls, ref.branches, ref.sum_px)


def test_hybrid_prunes_calls_on_community_graph():
    """The tentpole's win condition: with Lemma 8 off, a pivot walk strips
    each caveman community clique one vertex per call; the ET check emits
    it in one. ≥20% fewer calls at exact clique parity."""
    g = GRAPHS["caveman"]()
    rp = run(g, backend="pivot", dynamic_red=False)
    rh = run(g, backend="hybrid", dynamic_red=False)
    assert rh.cliques == rp.cliques == len(oracle.bk_pivot(g))
    assert rh.calls <= 0.8 * rp.calls


# ---------------------------------------------------------------------------
# max_iters truncation surfaces under hybrid too
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("runner", ["perroot", "persistent"])
def test_hybrid_truncation_flag(runner):
    import jax.numpy as jnp
    g = gen.erdos_renyi(50, 0.3, seed=4)
    prep = prepare(g, bucket_sizes=(64,))
    (b,) = prep.buckets
    args = (jnp.asarray(b.a), jnp.asarray(b.p0), jnp.asarray(b.x_rows),
            jnp.asarray(b.x_alive0), jnp.asarray(b.rsz0))
    full = run_bucket(*args, EngineConfig(backend="hybrid"))
    assert int(full["truncated"].sum()) == 0
    need = int(full["iters"].max())
    cfg = EngineConfig(backend="hybrid", max_iters=max(need // 4, 2))
    if runner == "perroot":
        out = run_bucket(*args, cfg)
        assert int(out["truncated"].sum()) > 0
        assert int(out["cliques"].sum()) < int(full["cliques"].sum())
    else:
        out = run_bucket_persistent(*args, cfg, lanes=4)
        assert int(out["truncated"]) == 1


def test_hybrid_run_surfaces_iters_exhausted_flag():
    res = run(gen.erdos_renyi(60, 0.3, seed=5), backend="hybrid")
    assert res.iters_exhausted is False


# ---------------------------------------------------------------------------
# Backend validation (satellite: bogus backends used to run as pivot)
# ---------------------------------------------------------------------------

def test_run_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        run(GRAPHS["er"](), backend="bogus")


def test_driver_rejects_unknown_backend():
    with pytest.raises(ValueError, match="backend"):
        DistributedMCE(GRAPHS["er"](), cfg=EngineConfig(backend="bogus"))


def test_hybrid_in_backends_registry():
    assert "hybrid" in BACKENDS


# ---------------------------------------------------------------------------
# choose_engine / root_cost_skew edge hardening (satellite bugfix)
# ---------------------------------------------------------------------------

def test_root_cost_skew_degenerate_inputs():
    assert root_cost_skew(np.zeros(0)) == 1.0          # empty bucket
    assert root_cost_skew(np.zeros(17)) == 1.0         # all-pad / all-zero
    assert root_cost_skew(np.full(5, np.nan)) == 1.0
    assert root_cost_skew(np.array([np.inf, 1.0])) == 1.0
    assert root_cost_skew(np.array([-3.0, -1.0])) == 1.0
    # near-zero mean must clamp to n, not explode to max/eps
    tiny = np.array([1.0] + [1e-300] * 7)
    assert root_cost_skew(tiny) == 8.0
    uniform = np.full(12, 3.5)
    assert root_cost_skew(uniform) == pytest.approx(1.0)


def test_choose_engine_degenerate_cost_vectors_route_perroot():
    """Empty/all-pad buckets used to crash on a length-0 max or misroute
    via skew = max/1e-12; they must answer perroot without raising."""
    assert choose_engine(np.zeros(0))[0] == "perroot"
    assert choose_engine(np.zeros(64))[0] == "perroot"
    assert choose_engine(np.full(64, np.nan))[0] == "perroot"
    # all-but-one-zero: skew clamps to n_roots, still a real skew -> the
    # policy may pick persistent, but it must not crash and lanes stay pow2
    eng, lanes = choose_engine(np.array([5.0] + [0.0] * 63))
    assert eng in ("perroot", "persistent")
    assert lanes & (lanes - 1) == 0


def test_choose_engine_memoized_skew_clamped_and_nan_safe():
    assert choose_engine(skew=float("nan"), n_roots=64)[0] == "perroot"
    # a memoized skew beyond n_roots is float noise: clamped, not trusted
    big = choose_engine(skew=1e9, n_roots=64, lanes=64)
    legit = choose_engine(skew=64.0, n_roots=64, lanes=64)
    assert big == legit


def test_driver_cost_skew_memo_matches_choose_engine():
    """The driver memoizes root_cost_skew per bucket for cached replays;
    a replay (skew= path) must route exactly like the fresh run
    (costs= path) on a degenerate all-zero bucket."""
    costs = np.zeros(64)
    fresh = choose_engine(costs)
    replay = choose_engine(skew=root_cost_skew(costs), n_roots=64)
    assert fresh == replay == ("perroot", 64)


# ---------------------------------------------------------------------------
# MCEService falsy-override rejection (satellite bugfix)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def service():
    return MCEService(gen.barabasi_albert(150, 4, seed=11),
                      chunk=64, stream_roots=64)


def test_service_explicit_engine_override_still_works(service):
    res = service.query(engine="perroot", lanes=8)
    assert res.cliques == len(oracle.bk_pivot(
        gen.barabasi_albert(150, 4, seed=11)))


def test_service_rejects_falsy_engine_override(service):
    """engine='' used to silently fall back to the service default via
    `engine or self.engine`; now it's a loud caller error."""
    with pytest.raises(ValueError, match="engine override"):
        service.query(engine="")
    with pytest.raises(ValueError, match="engine override"):
        service.query(engine="bogus")


def test_service_rejects_bad_lanes_override(service):
    with pytest.raises(ValueError, match="lanes override"):
        service.query(lanes=0)          # used to fall back silently
    with pytest.raises(ValueError, match="lanes override"):
        service.query(lanes=-4)
    with pytest.raises(ValueError, match="lanes override"):
        service.query(lanes=True)       # bool is not a lane count
    with pytest.raises(ValueError, match="lanes override"):
        service.query(lanes="16")


# ---------------------------------------------------------------------------
# Mid-queue elastic restart with the hybrid backend
# ---------------------------------------------------------------------------

def run_py(code: str, devices: int, timeout: int = 560) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


def test_midqueue_elastic_restart_hybrid(tmp_path):
    """Preempt the hybrid driver mid-queue under 4 shards, resume under 2:
    the checkpoint cursor replays exactly the remaining roots, and the ET
    check must not double-report cliques across the restart boundary."""
    ck = str(tmp_path / "hybrid.json")
    out4 = run_py(f"""
        from repro.core.driver import DistributedMCE
        from repro.core.engine import EngineConfig
        from repro.graph import barabasi_albert
        g = barabasi_albert(400, 6, seed=9)
        drv = DistributedMCE(g, chunk=16, ckpt_path={ck!r},
                             cfg=EngineConfig(backend="hybrid"),
                             bucket_sizes=(32, 64), stream_roots=64,
                             engine="persistent", lanes=8)
        n = 0
        orig = drv._run_chunk
        def failing(*args):
            global n
            if n >= 3: raise RuntimeError("preempted")
            n += 1
            return orig(*args)
        drv._run_chunk = failing
        try:
            drv.run()
        except RuntimeError:
            pass
        print("PARTIAL_OK")
    """, devices=4)
    assert "PARTIAL_OK" in out4
    out2 = run_py(f"""
        from repro.core.driver import DistributedMCE
        from repro.core import bitset_engine, oracle
        from repro.core.engine import EngineConfig
        from repro.graph import barabasi_albert
        g = barabasi_albert(400, 6, seed=9)
        ref = bitset_engine.run(g, bucket_sizes=(32, 64))
        drv = DistributedMCE(g, chunk=16, ckpt_path={ck!r},
                             cfg=EngineConfig(backend="hybrid"),
                             bucket_sizes=(32, 64), stream_roots=64,
                             engine="persistent", lanes=8)
        res = drv.run(resume=True)
        print("CLIQUES", res.cliques, ref.cliques)
        assert res.cliques == ref.cliques
        assert not res.iters_exhausted
    """, devices=2)
    assert "CLIQUES" in out2
