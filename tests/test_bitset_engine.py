"""The TPU bitset BK engine vs the python oracle: exact clique-set equality."""
import numpy as np
import pytest

from repro.core import bitset_engine, oracle
from repro.core.bitset_engine import EngineConfig
from repro.graph import (barabasi_albert, caveman, complete_graph,
                         erdos_renyi, grid_road, moon_moser,
                         random_geometric)

GRAPHS = [
    ("er_sparse", lambda: erdos_renyi(50, 0.08, seed=1)),
    ("er_mid", lambda: erdos_renyi(40, 0.25, seed=2)),
    ("er_dense", lambda: erdos_renyi(25, 0.6, seed=3)),
    ("ba", lambda: barabasi_albert(60, 5, seed=4)),
    ("rgg", lambda: random_geometric(80, seed=5)),
    ("road", lambda: grid_road(7, 0.1, seed=6)),
    ("caveman", lambda: caveman(4, 6, 0.15, seed=7)),
    ("moon_moser", lambda: moon_moser(4)),
    ("k8", lambda: complete_graph(8)),
    ("empty", lambda: erdos_renyi(10, 0.0, seed=8)),
]


@pytest.mark.parametrize("name,make", GRAPHS, ids=[g[0] for g in GRAPHS])
@pytest.mark.parametrize("backend", ["pivot", "rcd", "revised"])
def test_engine_matches_oracle(name, make, backend):
    g = make()
    ref = set(oracle.bk_pivot(g))
    res = bitset_engine.run(g, backend=backend, enumerate_cliques=True,
                            out_cap=16384, bucket_sizes=(32, 64))
    assert res.cliques == len(ref)
    assert set(res.enumerated) == ref
    assert not res.overflow


@pytest.mark.parametrize("gr", [False, True])
@pytest.mark.parametrize("dr", [False, True])
@pytest.mark.parametrize("xr", [False, True])
def test_engine_reduction_flags(gr, dr, xr):
    g = erdos_renyi(45, 0.2, seed=9)
    ref = set(oracle.bk_pivot(g))
    res = bitset_engine.run(g, global_red=gr, dynamic_red=dr, x_red=xr,
                            enumerate_cliques=True, out_cap=16384,
                            bucket_sizes=(32, 64))
    assert set(res.enumerated) == ref


def test_engine_dynamic_reduction_reduces_calls():
    g = random_geometric(150, seed=10)
    base = bitset_engine.run(g, dynamic_red=False, bucket_sizes=(32, 64))
    red = bitset_engine.run(g, dynamic_red=True, bucket_sizes=(32, 64))
    assert red.cliques == base.cliques
    assert red.calls <= base.calls


def test_engine_overflow_flag():
    g = moon_moser(4)  # 81 cliques
    res = bitset_engine.run(g, enumerate_cliques=True, out_cap=4,
                            bucket_sizes=(32,))
    assert res.overflow
    assert res.cliques == 81          # counting is exact even on overflow


def test_engine_counts_match_oracle_large():
    g = barabasi_albert(400, 8, seed=11)
    s = oracle.MCEStats()
    oracle.rmce(g, stats=s, collect=False)
    res = bitset_engine.run(g, bucket_sizes=(32, 64, 128))
    assert res.cliques == s.cliques


def test_prepare_buckets_shapes():
    g = erdos_renyi(60, 0.3, seed=12)
    prep = bitset_engine.prepare(g, bucket_sizes=(32, 64))
    for b in prep.buckets:
        r = b.num_roots
        w = b.u_pad // 32
        assert b.a.shape == (r, b.u_pad, w)
        assert b.p0.shape == (r, w)
        assert b.x_rows.shape[0] == r and b.x_rows.shape[2] == w
        assert (b.x_pad & (b.x_pad - 1)) == 0       # pow2 padding
