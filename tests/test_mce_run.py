"""Launcher arg parsing: graph descriptors, including scientific notation."""
import pytest

from repro.launch.mce_run import _num, parse_graph


def test_num_int_float_and_scientific():
    assert _num("300") == 300 and isinstance(_num("300"), int)
    assert _num("0.25") == 0.25
    assert _num("1e-3") == pytest.approx(1e-3)   # no '.' but still a float
    assert _num("2E2") == pytest.approx(200.0)


def test_parse_graph_scientific_notation_p():
    g = parse_graph("er:n=300,p=1e-3,seed=1")    # crashed pre-fix: int('1e-3')
    assert g.n == 300


def test_parse_graph_families():
    assert parse_graph("er:n=50,p=0.2").n == 50
    assert parse_graph("ba:n=60,m=3").n == 60
    assert parse_graph("road:side=5").n == 25
    assert parse_graph("caveman:c=3,k=4").n == 12


def test_parse_graph_unknown_family():
    with pytest.raises(ValueError, match="unknown graph family"):
        parse_graph("nope:n=10")
