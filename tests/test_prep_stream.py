"""Streaming ingest pipeline (DESIGN.md §6): parity, auto-split, elasticity.

The contract under test: a streamed run — buckets packed incrementally,
chunks double-buffered — produces bit-identical clique/call/branch
counters to the materialized path, survives elastic restarts mid-stream
with a different shard count, and auto-splits roots the legacy prepare()
rejected.
"""
import os

import numpy as np
import pytest

from repro.core import bitset_engine, oracle
from repro.core.driver import DistributedMCE, estimate_costs
from repro.core.engine import PrepStream
from repro.core.global_reduction import (_peel_rounds_np, global_reduce_jnp,
                                         peel_low_degree)
from repro.graph import barabasi_albert, caveman, erdos_renyi
from repro.graph.pack import pack_bucket, popcount_sum
from test_distributed import run_py

STREAM_GRAPHS = [
    ("er", lambda: erdos_renyi(150, 0.12, seed=1)),
    ("ba", lambda: barabasi_albert(300, 6, seed=2)),
    ("caveman", lambda: caveman(20, 6, 0.15, seed=3)),
]


# ---------------------------------------------------------------------------
# Streamed vs materialized parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,make", STREAM_GRAPHS,
                         ids=[g[0] for g in STREAM_GRAPHS])
def test_streamed_counters_match_materialized(name, make):
    """Bit-identical counters: streamed driver vs single-host engine."""
    g = make()
    ref = bitset_engine.run(g, bucket_sizes=(32, 64))
    drv = DistributedMCE(g, chunk=16, bucket_sizes=(32, 64),
                         streaming=True, stream_roots=24)
    res = drv.run()
    assert res.cliques == ref.cliques
    assert res.calls == ref.calls
    assert res.branches == ref.branches


def test_streaming_vs_materialized_driver_modes():
    g = barabasi_albert(250, 5, seed=4)
    a = DistributedMCE(g, chunk=32, bucket_sizes=(32, 64),
                       streaming=True, stream_roots=16).run()
    b = DistributedMCE(g, chunk=32, bucket_sizes=(32, 64),
                       streaming=False).run()
    assert (a.cliques, a.calls, a.branches) == (b.cliques, b.calls, b.branches)


def test_stream_flush_composition_is_shard_count_free():
    """Bucket sequence depends on stream_roots, never on devices/chunks."""
    g = erdos_renyi(120, 0.1, seed=5)
    seqs = []
    for chunk in (8, 64):
        s = PrepStream(g, bucket_sizes=(32, 64), stream_roots=16)
        DistributedMCE(g=None, prep=s, chunk=chunk).run()
        seqs.append([(b.u_pad, b.num_roots) for b in s._cached])
    assert seqs[0] == seqs[1]


# ---------------------------------------------------------------------------
# Vectorized packer vs a naive reference
# ---------------------------------------------------------------------------

def test_pack_bucket_matches_naive_reference():
    g = erdos_renyi(60, 0.3, seed=12)
    prep = bitset_engine.prepare(g, bucket_sizes=(32, 64))
    adj = [set(g.neighbors(v).tolist()) for v in range(g.n)]
    for bk in prep.buckets:
        words = bk.u_pad // 32
        for r in range(bk.num_roots):
            uni = bk.universes[r]
            for j, u in enumerate(uni):
                row = np.zeros(words, np.uint32)
                for k, w in enumerate(uni):
                    if int(w) in adj[int(u)]:
                        row[k // 32] |= np.uint32(1) << np.uint32(k % 32)
                assert np.array_equal(bk.a[r, j], row), (bk.u_pad, r, j)
            # p0 = first |P| bits
            expect_p0 = np.zeros(words, np.uint32)
            for k in range(len(uni)):
                expect_p0[k // 32] |= np.uint32(1) << np.uint32(k % 32)
            assert np.array_equal(bk.p0[r], expect_p0)
            # X rows: alive rows are nonzero, dead rows zero
            alive = bk.x_alive0[r]
            assert bk.x_rows[r][alive].any(axis=1).all()
            assert not bk.x_rows[r][~alive].any()


def test_pack_bucket_empty_x_and_shapes():
    indptr = np.array([0, 1, 2], np.int64)
    indices = np.array([1, 0], np.int32)
    a, p0, xr, xa = pack_bucket(indptr, indices, 2,
                                [np.array([1], np.int64)], [np.array([], np.int64)], 32)
    assert a.shape == (1, 32, 1) and p0.shape == (1, 1)
    assert xr.shape == (1, 1, 1) and not xa.any()
    assert p0[0, 0] == 1


# ---------------------------------------------------------------------------
# estimate_costs LUT regression (satellite)
# ---------------------------------------------------------------------------

def test_estimate_costs_lut_matches_unpackbits():
    g = erdos_renyi(200, 0.15, seed=2)
    prep = bitset_engine.prepare(g, bucket_sizes=(64,))
    bucket = prep.buckets[0]
    p_sizes = np.array([len(u) for u in bucket.universes], dtype=np.float64)
    pc_ref = np.unpackbits(bucket.a.view(np.uint8), axis=-1).sum(axis=(1, 2))
    ref = p_sizes * (1.0 + pc_ref / np.maximum(p_sizes, 1)) ** 2
    got = estimate_costs(bucket)
    assert np.allclose(got, ref)
    assert np.array_equal(np.argsort(-got, kind="stable"),
                          np.argsort(-ref, kind="stable"))


def test_popcount_sum_lut():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 1 << 32, size=(5, 7, 3), dtype=np.uint64).astype(np.uint32)
    ref = np.unpackbits(a.view(np.uint8), axis=-1).sum(axis=(1, 2))
    assert np.array_equal(popcount_sum(a, axis=(1, 2)), ref)
    assert popcount_sum(a) == ref.sum()


# ---------------------------------------------------------------------------
# Auto-split (satellite): oversized roots and X caps never raise
# ---------------------------------------------------------------------------

def test_auto_split_root_larger_than_biggest_bucket():
    """caveman cliques of 40 > bucket 32: legacy prepare() raised here."""
    g = caveman(3, 40, 0.05, seed=1)
    ref = set(oracle.bk_pivot(g))
    res = bitset_engine.run(g, enumerate_cliques=True, out_cap=1 << 15,
                            bucket_sizes=(32,))
    assert res.cliques == len(ref)
    assert set(res.enumerated) == ref


def test_auto_split_x_rows_cap():
    g = erdos_renyi(70, 0.3, seed=6)
    ref = set(oracle.bk_pivot(g))
    res = bitset_engine.run(g, enumerate_cliques=True, out_cap=1 << 15,
                            bucket_sizes=(32, 64), max_x_rows=2)
    assert res.cliques == len(ref)
    assert set(res.enumerated) == ref


def test_auto_split_through_streamed_driver():
    g = caveman(3, 40, 0.05, seed=2)
    ref = bitset_engine.run(g, bucket_sizes=(32, 64))
    drv = DistributedMCE(g, chunk=8, bucket_sizes=(32,), stream_roots=4)
    res = drv.run()
    assert res.cliques == ref.cliques


# ---------------------------------------------------------------------------
# Device peel pre-pass (satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_peel_np_matches_jnp(seed):
    import jax.numpy as jnp

    g = erdos_renyi(80, 0.035, seed=seed)   # sparse: real deg-0/1 fringe
    if g.m == 0:
        return
    ei = g.edge_index()
    av_dev, _ = global_reduce_jnp(jnp.asarray(ei[0]), jnp.asarray(ei[1]), g.n)
    assert np.array_equal(_peel_rounds_np(g), np.asarray(av_dev))


@pytest.mark.parametrize("seed", range(3))
def test_peel_low_degree_device_host_agree(seed):
    g = erdos_renyi(90, 0.03, seed=seed)
    r_host, rep_host = peel_low_degree(g, use_device=False)
    r_dev, rep_dev = peel_low_degree(g, use_device=True)
    assert r_host.m == r_dev.m
    assert set(rep_host) == set(rep_dev)
    assert len(rep_host) == len(set(rep_host)), "peel must not double-report"


# ---------------------------------------------------------------------------
# Elastic restart mid-stream with a different shard count
# ---------------------------------------------------------------------------

def test_elastic_restart_mid_stream_different_shard_count(tmp_path):
    """Checkpoint written mid-stream under 8 shards, resumed under 4."""
    ck = str(tmp_path / "elastic_stream.json")
    out8 = run_py(f"""
        from repro.core.driver import DistributedMCE
        from repro.graph import barabasi_albert
        g = barabasi_albert(400, 6, seed=9)
        drv = DistributedMCE(g, chunk=8, ckpt_path={ck!r},
                             bucket_sizes=(32, 64), stream_roots=32)
        n = 0
        orig = drv._run_chunk
        def failing(*args):
            global n
            if n >= 3: raise RuntimeError("preempted")
            n += 1
            return orig(*args)
        drv._run_chunk = failing
        try:
            drv.run()
        except RuntimeError:
            pass
        import os
        assert os.path.exists({ck!r})
        print("PARTIAL_OK")
    """, devices=8)
    assert "PARTIAL_OK" in out8
    out4 = run_py(f"""
        from repro.core.driver import DistributedMCE
        from repro.core import bitset_engine
        from repro.graph import barabasi_albert
        g = barabasi_albert(400, 6, seed=9)
        ref = bitset_engine.run(g, bucket_sizes=(32, 64))
        drv = DistributedMCE(g, chunk=8, ckpt_path={ck!r},
                             bucket_sizes=(32, 64), stream_roots=32)
        res = drv.run(resume=True)
        print("CLIQUES", res.cliques, ref.cliques)
        assert res.cliques == ref.cliques
        assert res.calls == ref.calls
    """, devices=4)
    assert "CLIQUES" in out4


# ---------------------------------------------------------------------------
# Prepared-stream reuse (launch.mce_service)
# ---------------------------------------------------------------------------

def test_stream_cache_reuse_across_queries():
    from repro.core.engine import EngineConfig
    from repro.launch.mce_service import MCEService

    g = barabasi_albert(200, 5, seed=7)
    ref = bitset_engine.run(g)
    svc = MCEService(g, chunk=64, stream_roots=16)
    r1 = svc.query(EngineConfig())
    assert svc.stream._cached is not None, "first pass must populate cache"
    n_buckets = svc.stream.num_buckets
    r2 = svc.query(EngineConfig())
    assert (r1.cliques, r1.calls) == (r2.cliques, r2.calls)
    assert r1.cliques == ref.cliques
    assert svc.stream.num_buckets == n_buckets
    # warm queries must reuse the memoized canonical order, not rescan
    assert all(b.cost_order is not None for b in svc.stream._cached)


def test_resume_refuses_schedule_mismatch(tmp_path):
    """The cursor is only meaningful against the same bucket sequence."""
    g = barabasi_albert(200, 5, seed=11)
    ck = str(tmp_path / "sched.json")
    DistributedMCE(g, chunk=32, bucket_sizes=(32, 64), stream_roots=16,
                   ckpt_path=ck).run()
    with pytest.raises(ValueError, match="schedule mismatch"):
        DistributedMCE(g, chunk=32, bucket_sizes=(32, 64), stream_roots=8,
                       ckpt_path=ck).run(resume=True)
    with pytest.raises(ValueError, match="schedule mismatch"):
        DistributedMCE(g, chunk=32, bucket_sizes=(32, 64), streaming=False,
                       ckpt_path=ck).run(resume=True)
    # same parameters but a DIFFERENT graph: the cursor is meaningless
    g2 = barabasi_albert(210, 5, seed=12)
    with pytest.raises(ValueError, match="schedule mismatch"):
        DistributedMCE(g2, chunk=32, bucket_sizes=(32, 64), stream_roots=16,
                       ckpt_path=ck).run(resume=True)
    # same schedule, different chunking: fine (elastic dimension)
    res = DistributedMCE(g, chunk=8, bucket_sizes=(32, 64), stream_roots=16,
                         ckpt_path=ck).run(resume=True)
    assert res.cliques == bitset_engine.run(g, bucket_sizes=(32, 64)).cliques


def test_prep_and_graph_conflict_rejected():
    g = erdos_renyi(50, 0.1, seed=1)
    s = PrepStream(g, bucket_sizes=(32, 64))
    with pytest.raises(ValueError, match="not both"):
        DistributedMCE(g, prep=s)


def test_driver_owned_stream_does_not_cache():
    g = erdos_renyi(120, 0.1, seed=10)
    drv = DistributedMCE(g, chunk=32, bucket_sizes=(32, 64), stream_roots=8)
    drv.run()
    assert drv.stream._cached is None, \
        "one-shot streaming must not retain every packed bucket"


def test_clique_reports_sequence_contract():
    from repro.core.global_reduction import CliqueReports

    r = CliqueReports([np.array([[0, 1], [2, 3]], np.int64),
                       [frozenset((4, 5))]])
    assert len(r) == 3
    assert list(r) == [frozenset((0, 1)), frozenset((2, 3)),
                       frozenset((4, 5))]
    assert r[-1] == frozenset((4, 5)) and r[0] == frozenset((0, 1))
    for bad in (3, -4):
        with pytest.raises(IndexError):
            r[bad]
    assert ([frozenset((9, 9))] + r)[0] == frozenset((9, 9))
    assert len(r + r) == 6


def test_stream_timings_populated():
    g = erdos_renyi(100, 0.1, seed=8)
    s = PrepStream(g, bucket_sizes=(32, 64), stream_roots=8)
    list(s)
    assert set(s.timings) == {"reduce", "order", "stage", "pack"}
    assert all(v >= 0 for v in s.timings.values())
    assert s.timings["pack"] > 0
