"""Global reduction (§4), dynamic reduction (§5), X-reduction (§6) unit tests."""
import numpy as np
import pytest
from _hyp import given, strategies as st  # optional-hypothesis shim

import jax.numpy as jnp

from repro.core import oracle
from repro.core.global_reduction import (_batch_lemma3, global_reduce_host,
                                         global_reduce_jnp, reduce_prepass)
from repro.core.xreduction import x_prune_roots
from repro.graph import (complete_graph, degeneracy_order, erdos_renyi,
                         from_edge_list, grid_road, random_geometric)


@st.composite
def any_graph(draw):
    n = draw(st.integers(2, 14))
    p = draw(st.floats(0.05, 0.9))
    seed = draw(st.integers(0, 10**6))
    return erdos_renyi(n, p, seed=seed)


@given(any_graph())
def test_global_reduction_completeness(g):
    """mc(G) = mc(G') + α(ΔV, ΔE) with exact multiset equality."""
    ref = oracle.maximal_cliques_brute(g)
    red = global_reduce_host(g)
    rest = set(oracle.bk_pivot(red.graph))
    reported = set(red.reported)
    assert reported | rest == ref
    assert not (reported & rest), "advance-reported cliques re-enumerated"
    assert len(reported) + len(rest) == len(ref)


def test_road_graph_fully_reduced():
    """Paper Fig 8: degeneracy-2 road networks vanish under global reduction."""
    g = grid_road(20, drop_frac=0.1, seed=0)
    red = global_reduce_host(g)
    assert red.graph.m == 0
    assert set(red.reported) == oracle_set(g)


def oracle_set(g):
    return set(oracle.bk_pivot(g))


def test_dense_graph_untouched():
    """Paper Fig 8 (sc-delaunay): min-degree>2 triangle-rich graphs survive."""
    g = complete_graph(8)
    red = global_reduce_host(g)
    assert red.graph.m == g.m and not red.reported


def test_nontriangle_edge_rule():
    # two triangles joined by a bridge edge: the bridge is non-triangle
    edges = [(0, 1), (1, 2), (0, 2), (3, 4), (4, 5), (3, 5), (2, 3)]
    g = from_edge_list(6, np.array(edges))
    red = global_reduce_host(g)
    assert frozenset((2, 3)) in red.reported


def test_degree2_cases():
    # case 1: deg-2, neighbors non-adjacent -> two 2-cliques
    g = from_edge_list(5, np.array([(0, 1), (0, 2), (1, 3), (2, 4),
                                    (3, 4), (1, 4), (3, 2)]))
    ref = oracle.maximal_cliques_brute(g)
    red = global_reduce_host(g)
    assert set(red.reported) | set(oracle.bk_pivot(red.graph)) == ref


@given(any_graph())
def test_global_reduce_jnp_masks(g):
    """Device-path deg≤1 peel: masks kill exactly the 1-core complement."""
    if g.m == 0:
        return
    ei = g.edge_index()
    av, ae = global_reduce_jnp(jnp.asarray(ei[0]), jnp.asarray(ei[1]), g.n)
    av, ae = np.asarray(av), np.asarray(ae)
    # surviving vertices have >= 2 surviving neighbors (2-core condition)
    deg = np.zeros(g.n, int)
    np.add.at(deg, ei[0][ae], 1)
    assert np.all(deg[av] >= 2)
    assert not np.any(deg[~av] > 0) or True  # dead vertices keep no edges
    assert np.all(~ae | (av[ei[0]] & av[ei[1]]))


@given(any_graph())
def test_batch_lemma3_preserves_cliques(g):
    """One conflict-free deg-2 batch = some sequential Lemma-3 order:
    reported ∪ mc(G') must equal mc(G) exactly, with no overlap."""
    ref = oracle.maximal_cliques_brute(g)
    g2, segs, _changed = _batch_lemma3(g)
    reported = {frozenset(int(x) for x in row)
                for s in segs for row in s.tolist()}
    rest = set(oracle.bk_pivot(g2))
    assert reported | rest == ref
    assert not (reported & rest)
    assert len(reported) + len(rest) == len(ref)


@given(any_graph())
def test_batch_lemma3_selection_is_conflict_free(g):
    """Selected vertices (first column of every report row) must have
    pairwise-disjoint CLOSED neighborhoods — the property that makes the
    batch order-independent."""
    _g2, segs, _ = _batch_lemma3(g)
    owned = {}
    for s in segs:
        for row in s.tolist():
            v = int(row[0])
            for t in row:
                assert owned.setdefault(int(t), v) == v, \
                    f"vertex {t} touched by two selected deg-2 vertices"


@given(any_graph())
def test_reduce_prepass_with_lemma3_completeness(g):
    """Full vectorized prepass (peel + batch Lemma 3 + edge sweep) then
    the host cascade: exact multiset equality against brute force."""
    ref = oracle.maximal_cliques_brute(g)
    residual, reports = reduce_prepass(g)
    red = global_reduce_host(residual)
    rest = set(oracle.bk_pivot(red.graph))
    pre = set(reports) | set(red.reported)
    assert pre | rest == ref
    assert not (pre & rest)
    assert len(reports) + len(red.reported) + len(rest) == len(ref)


@pytest.mark.parametrize("seed", range(8))
def test_batch_lemma3_parity_seeded(seed):
    """Deterministic pin of the batch Lemma-3 invariants (the @given
    variants above only run where hypothesis is installed)."""
    rng = np.random.default_rng(seed)
    n = int(rng.integers(8, 60))
    g = erdos_renyi(n, float(rng.uniform(0.03, 0.3)), seed=seed)
    ref = set(oracle.bk_pivot(g))
    g2, segs, _ = _batch_lemma3(g)
    reported = {frozenset(int(x) for x in row)
                for s in segs for row in s.tolist()}
    rest = set(oracle.bk_pivot(g2))
    assert reported | rest == ref
    assert not (reported & rest)
    residual, reports = reduce_prepass(g)
    red = global_reduce_host(residual)
    assert (set(reports) | set(red.reported)
            | set(oracle.bk_pivot(red.graph))) == ref


def test_batch_lemma3_triangle_edge_cases():
    # v=0 deg-2 with adjacent neighbors (1,2); 1-2 also in a second
    # triangle with 3 -> edge (1,2) must SURVIVE
    g = from_edge_list(4, np.array([(0, 1), (0, 2), (1, 2), (1, 3), (2, 3)]))
    g2, segs, ch = _batch_lemma3(g)
    assert ch
    rep = {frozenset(int(x) for x in r) for s in segs for r in s.tolist()}
    assert frozenset((0, 1, 2)) in rep
    e2 = {frozenset(e) for e in g2.edges().tolist()}
    assert frozenset((1, 2)) in e2
    # lone triangle: edge (u, w) has no other common neighbor -> deleted
    g = from_edge_list(5, np.array([(0, 1), (0, 2), (1, 2), (1, 3), (2, 4)]))
    g2, segs, ch = _batch_lemma3(g)
    rep = {frozenset(int(x) for x in r) for s in segs for r in s.tolist()}
    assert frozenset((0, 1, 2)) in rep
    e2 = {frozenset(e) for e in g2.edges().tolist()}
    assert frozenset((1, 2)) not in e2


@given(any_graph())
def test_x_reduction_preserves_cliques(g):
    """Lemma 9 via Algorithm 8 + witness chains: same clique set."""
    ref = set(oracle.rmce(g, global_red=False, dynamic_red=False, x_red=False))
    got = set(oracle.rmce(g, global_red=False, dynamic_red=False, x_red=True))
    assert got == ref


@given(any_graph())
def test_x_reduction_only_shrinks(g):
    adj = [set(g.neighbors(v).tolist()) for v in range(g.n)]
    order, rank, _ = degeneracy_order(g)
    kept = x_prune_roots(adj, order, rank)
    for i in range(g.n):
        v = int(order[i])
        x_full = {u for u in adj[v] if rank[u] < i}
        assert kept[i] <= x_full


def test_x_reduction_actually_prunes():
    """On clustered graphs the forbidden set shrinks (paper Fig 10)."""
    g = random_geometric(400, seed=5)
    s = oracle.MCEStats()
    oracle.rmce(g, stats=s, collect=False)
    assert s.sum_x_after < s.sum_x_before


@given(any_graph())
def test_dynamic_reduction_only(g):
    ref = oracle.maximal_cliques_brute(g)
    got = set(oracle.rmce(g, global_red=False, dynamic_red=True, x_red=False))
    assert got == ref
