"""mce_lint test suite: fixture corpus + suppression mechanics + CLI.

Every bad fixture under tests/analysis_fixtures/ carries `# EXPECT-Rn`
sentinels on the exact lines the rule must flag; the parametrized test
asserts the analyzer reports precisely those (rule, line) pairs — no
misses, no extras. Good twins (the patterns the repo actually ships)
must pass clean. A final test runs the strict analyzer over the real
`src/repro` tree, which is the same gate CI enforces.
"""
import json
import os
import re
import subprocess
import sys
import textwrap

import pytest

from repro.analysis.cli import analyze, main
from repro.analysis.findings import Suppressions
from repro.analysis.modindex import PackageIndex

HERE = os.path.dirname(__file__)
FIXTURES = os.path.join(HERE, "analysis_fixtures")
SRC = os.path.join(HERE, "..", "src", "repro")

_EXPECT_RE = re.compile(r"#\s*EXPECT-(R\d)\b")


def _expected(fixture_dir):
    """All (rule, path, line) sentinels in a fixture package."""
    out = set()
    for dirpath, _dirs, files in os.walk(fixture_dir):
        for name in files:
            if not name.endswith(".py"):
                continue
            path = os.path.join(dirpath, name)
            with open(path) as f:
                for i, line in enumerate(f, start=1):
                    for m in _EXPECT_RE.finditer(line):
                        out.add((m.group(1), path, i))
    return out


BAD = ["bad_r1", "bad_r2", "bad_r3", "bad_r4", "bad_r5"]
GOOD = ["good_r1", "good_r2", "good_r3", "good_r4", "good_r5"]


@pytest.mark.parametrize("fixture", BAD)
def test_bad_fixture_flagged_at_the_right_lines(fixture):
    root = os.path.join(FIXTURES, fixture)
    active, suppressed, _s1, n = analyze(root)
    assert n > 0
    assert not suppressed
    got = {(f.rule, f.path, f.line) for f in active}
    want = _expected(root)
    assert want, f"{fixture} has no EXPECT sentinels"
    missing = want - got
    extra = got - want
    assert not missing, f"expected findings not raised: {sorted(missing)}"
    assert not extra, f"unexpected findings: {sorted(extra)}"


def test_bad_r2_is_the_pr1_kernel_flagged_at_its_accumulation_site():
    """The reproduced PR-1 vmap-accumulator kernel must be flagged on the
    `best_ref[...] = jnp.maximum(best_ref[...], score)` accumulation line
    itself (and its program_id-gated init)."""
    root = os.path.join(FIXTURES, "bad_r2")
    active, *_ = analyze(root)
    path = os.path.join(root, "kernel.py")
    with open(path) as f:
        lines = f.read().splitlines()
    acc_line = next(i for i, l in enumerate(lines, start=1)
                    if "jnp.maximum(best_ref" in l)
    hits = {f.line: f.message for f in active if f.rule == "R2"}
    assert acc_line in hits
    assert "vmap" in hits[acc_line]


@pytest.mark.parametrize("fixture", GOOD)
def test_good_twin_passes_clean(fixture):
    root = os.path.join(FIXTURES, fixture)
    active, suppressed, s1, n = analyze(root)
    assert n > 0
    assert active == [], [f.format() for f in active]
    assert s1 == []


def test_every_rule_family_fires_in_the_corpus():
    got = set()
    for fixture in BAD:
        active, *_ = analyze(os.path.join(FIXTURES, fixture))
        got |= {f.rule for f in active}
    assert got == {"R1", "R2", "R3", "R4", "R5"}


# ---------------------------------------------------------------------------
# suppression mechanics
# ---------------------------------------------------------------------------

def test_suppression_inline_and_next_line(tmp_path):
    pkg = tmp_path / "suppkg"
    pkg.mkdir()
    (pkg / "steps.py").write_text(textwrap.dedent("""\
        import jax
        import jax.numpy as jnp


        @jax.jit
        def step(x):
            a = int(jnp.sum(x))  # mce-lint: disable=R4 -- test: inline form
            # mce-lint: disable=R4 -- test: next-line form
            b = int(jnp.sum(x))
            c = int(jnp.sum(x))
            return a + b + c
        """))
    active, suppressed, s1, _ = analyze(str(pkg))
    assert len(suppressed) == 2
    assert [f.line for f in active] == [10]        # the unsuppressed int()
    assert s1 == []


def test_suppression_file_level_and_s1(tmp_path):
    pkg = tmp_path / "suppkg"
    pkg.mkdir()
    (pkg / "steps.py").write_text(textwrap.dedent("""\
        # mce-lint: disable-file=R4
        import jax
        import jax.numpy as jnp


        @jax.jit
        def step(x):
            return int(jnp.sum(x))
        """))
    active, suppressed, s1, _ = analyze(str(pkg))
    assert active == [] and len(suppressed) == 1
    # no justification on the disable-file comment -> S1 under --strict
    assert len(s1) == 1 and s1[0].rule == "S1" and s1[0].line == 1


def test_suppression_requires_matching_rule(tmp_path):
    pkg = tmp_path / "suppkg"
    pkg.mkdir()
    (pkg / "steps.py").write_text(textwrap.dedent("""\
        import jax
        import jax.numpy as jnp


        @jax.jit
        def step(x):
            return int(jnp.sum(x))  # mce-lint: disable=R2 -- wrong rule
        """))
    active, suppressed, _s1, _ = analyze(str(pkg))
    assert len(active) == 1 and active[0].rule == "R4"
    assert suppressed == []


def test_suppression_parser_grammar():
    table = Suppressions(
        "x = 1  # mce-lint: disable=R1,R4 -- two rules, one comment\n")
    sup = table.match("R4", 1)
    assert sup is not None and sup.rules == ("R1", "R4")
    assert sup.justification == "two rules, one comment"
    assert table.match("R2", 1) is None


# ---------------------------------------------------------------------------
# the real tree + CLI
# ---------------------------------------------------------------------------

def test_src_repro_is_lint_clean_in_strict_mode():
    """The same gate CI enforces: zero active findings, every suppression
    justified. The suppressed count is >0 — the analyzer did find the
    real grid-gated kernel epilogues and they are documented, not ignored."""
    active, suppressed, s1, n = analyze(SRC)
    assert n >= 90                                  # the whole package
    assert active == [], "\n".join(f.format() for f in active)
    assert s1 == [], "\n".join(f.format() for f in s1)
    assert len(suppressed) >= 3                     # real R2 findings exist


def test_cli_exit_codes_and_report(tmp_path):
    report = tmp_path / "lint_report.json"
    rc = main([os.path.join(FIXTURES, "bad_r2"), "--report", str(report),
               "--format", "json"])
    assert rc == 1
    data = json.loads(report.read_text())
    assert data["counts"]["active"] == 2
    assert {f["rule"] for f in data["findings"]} == {"R2"}

    rc = main([os.path.join(FIXTURES, "good_r2"), "--strict"])
    assert rc == 0

    rc = main([os.path.join(FIXTURES, "does_not_exist")])
    assert rc == 2


def test_cli_rules_subset():
    rc = main([os.path.join(FIXTURES, "bad_r3"), "--rules", "R2"])
    assert rc == 0                                  # R3 findings filtered out
    rc = main([os.path.join(FIXTURES, "bad_r3"), "--rules", "R3"])
    assert rc == 1


def test_module_entry_point_runs_without_jax_imported():
    """`python -m repro.analysis` must work in a jax-less environment:
    the CI lint job runs it bare. Guard: the analysis package never
    imports jax (directly or transitively)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(HERE, "..", "src")
    code = ("import sys; import repro.analysis; "
            "sys.exit(1 if any(m == 'jax' or m.startswith('jax.') "
            "for m in sys.modules) else 0)")
    proc = subprocess.run([sys.executable, "-c", code], env=env,
                          capture_output=True, text=True)
    assert proc.returncode == 0, proc.stderr


def test_package_index_resolves_reexports():
    index = PackageIndex.build(SRC)
    resolved = index.resolve_symbol("repro.core.engine.run_root")
    assert resolved is not None
    mod, node = resolved
    assert mod.name == "repro.core.engine.loop"
    assert getattr(node, "name", None) == "run_root"
