"""Data pipeline: deterministic synthetic streams for every arch family.

Production shape: host-side prefetch workers produce fixed-shape numpy
batches; the training loop device_puts them with the step's input sharding.
Everything is deterministic in (seed, step) so elastic restarts replay the
exact stream from the checkpoint cursor.
"""
from repro.data.tokens import TokenStream, synth_tokens  # noqa: F401
from repro.data.prefetch import Prefetcher  # noqa: F401
