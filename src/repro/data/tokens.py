"""Synthetic LM token stream: deterministic, Zipf-distributed, seekable.

A production data pipeline is a seekable shard reader; here the "shards" are
PRNG streams. Determinism contract: batch(step) depends only on
(seed, step, global_batch, seq_len) — restart/elastic-resume replays
identically regardless of worker count.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.2

    def batch(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (tokens, targets): (B, S) int32 each; targets shift by 1."""
        rng = np.random.default_rng((self.seed, step))
        # Zipf body + uniform tail mixture, clipped into vocab
        z = rng.zipf(self.zipf_a, size=(self.global_batch, self.seq_len + 1))
        u = rng.integers(0, self.vocab, size=z.shape)
        pick = rng.random(z.shape) < 0.9
        toks = np.where(pick, np.minimum(z - 1, self.vocab - 1), u)
        return (toks[:, :-1].astype(np.int32), toks[:, 1:].astype(np.int32))

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def synth_tokens(vocab: int, batch: int, seq: int, seed: int = 0) -> np.ndarray:
    return TokenStream(vocab, seq, batch, seed).batch(0)[0]
