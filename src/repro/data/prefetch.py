"""Background-thread prefetcher: overlap host batch synthesis / sampling with
device compute (the CPU-side analogue of tf.data prefetch)."""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional


class Prefetcher:
    """Pulls `make_batch(step)` on a worker thread, `depth` batches ahead."""

    def __init__(self, make_batch: Callable[[int], object], depth: int = 2,
                 start_step: int = 0, num_steps: Optional[int] = None):
        self._make = make_batch
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._start = start_step
        self._num = num_steps
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self) -> None:
        step = self._start
        while not self._stop.is_set():
            if self._num is not None and step >= self._start + self._num:
                self._q.put(None)
                return
            try:
                item = (step, self._make(step))
            except Exception as e:  # surface worker errors at the consumer
                self._q.put(e)
                return
            while not self._stop.is_set():
                try:
                    self._q.put(item, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        while True:
            item = self._q.get()
            if item is None:
                return
            if isinstance(item, Exception):
                raise item
            yield item

    def close(self) -> None:
        self._stop.set()
