"""R4 — tracer leaks / host syncs inside traced code.

Builds a call graph rooted at every traced region in the package:

* functions decorated with (or wrapped by) `jax.jit` — parameters are
  traced except those named by `static_argnames`/`static_argnums`;
* kernel functions passed to `pl.pallas_call` (positional params traced,
  keyword-only params are `functools.partial`-bound statics);
* bodies handed to `lax.while_loop` / `lax.fori_loop` / `lax.scan` /
  `lax.cond` / `lax.switch` / `jax.vmap` / `shard_map`.

Within each root a taint analysis tracks which names hold traced values
and flags the host round-trips that the persistent engine exists to
eliminate (DESIGN.md §5): `int()`/`float()`/`bool()` coercions,
`.item()`, `np.asarray`/`np.array` materialization, and python
`if`/`while`/`for` control flow on a traced value (a silent
concretization -> device sync, or a TracerBoolConversionError at trace
time).

Deliberately *not* tainted (each is a static quantity under trace):
`.shape`/`.ndim`/`.size`/`.dtype`, `len()`, `x is None` tests, string
membership tests against dict-of-tracer carries, and parameters listed
as static. Calls into the package are followed (memoized, depth-capped);
calls that cannot be resolved propagate taint conservatively but emit
nothing.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding
from repro.analysis.modindex import (Module, PackageIndex, call_name,
                                     dotted_name, name_endswith)

RULE = "R4"

_STATIC_ATTRS = {"shape", "ndim", "size", "dtype", "weak_type", "sharding"}
_UNTAINTED_CALLS = {"len", "range", "zip", "enumerate", "isinstance",
                    "hasattr", "getattr", "type", "id", "repr", "str",
                    "tuple", "list", "dict", "set", "frozenset", "sorted",
                    "min", "max", "print"}
_COERCIONS = {"int", "float", "bool", "complex"}
_NUMPY_SINKS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array",
                "onp.asarray", "onp.array"}
# callee suffix -> indices of positional args that are traced callables
_COMBINATORS = {
    "while_loop": (0, 1),
    "fori_loop": (2,),
    "scan": (0,),
    "cond": (1, 2),
    "switch": None,          # switch(index, [branches], *ops) — handled inline
    "map": (0,),             # lax.map only — jax.tree.map is a host walk
}
# dotted prefixes whose calls produce tracers even from constant args
_PRODUCER_PREFIXES = ("jnp.", "lax.", "jax.lax.", "jax.numpy.",
                      "jax.random.", "jax.nn.", "jax.scipy.", "jsp.")
_WRAPPERS = ("vmap", "pmap", "shard_map", "checkpoint", "remat", "grad",
             "value_and_grad")
_MAX_DEPTH = 10


def _is_jit_expr(node: ast.AST) -> Optional[ast.Call]:
    """Return the jit Call carrying static_* kwargs, if `node` is a jit
    wrapper expression: jax.jit, jax.jit(**kw), partial(jax.jit, **kw)."""
    if isinstance(node, ast.Call):
        if name_endswith(node, "jit"):
            return node
        if name_endswith(node, "partial") and node.args and \
                isinstance(node.args[0], (ast.Name, ast.Attribute)):
            inner = dotted_name(node.args[0]) or ""
            if inner.rpartition(".")[2] == "jit":
                return node
    if isinstance(node, (ast.Name, ast.Attribute)):
        if (dotted_name(node) or "").rpartition(".")[2] == "jit":
            return ast.Call(func=node, args=[], keywords=[])
    return None


def _static_names(jit_call: ast.Call, fn: ast.FunctionDef) -> Set[str]:
    """Param names excluded from tracing by static_argnames/static_argnums."""
    params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
    out: Set[str] = set()
    for kw in jit_call.keywords:
        if kw.arg == "static_argnames":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    out.add(n.value)
        elif kw.arg == "static_argnums":
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int) \
                        and 0 <= n.value < len(params):
                    out.add(params[n.value])
    return out


def _local_defs(scope: ast.AST) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in ast.walk(scope)
            if isinstance(n, ast.FunctionDef)}


class TracerTaint:
    """Taint analysis over one package: roots -> findings."""

    def __init__(self, index: PackageIndex):
        self.index = index
        self.findings: List[Finding] = []
        self._memo: Dict[Tuple[int, frozenset], bool] = {}

    # ---- root discovery --------------------------------------------------

    def run(self) -> List[Finding]:
        for mod in self.index:
            self._roots_in_module(mod)
        return self.findings

    def _roots_in_module(self, mod: Module) -> None:
        # (a) decorated defs
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.FunctionDef):
                continue
            for dec in node.decorator_list:
                jit = _is_jit_expr(dec)
                if jit is not None:
                    statics = _static_names(jit, node)
                    self._analyze(mod, node, self._param_taint(node, statics))
        # (b) name = jax.jit(f, ...) / partial(jit, ...)(f)
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call) and node.args):
                continue
            jit = _is_jit_expr(node.func)
            if jit is None and isinstance(node.func, ast.Call):
                jit = _is_jit_expr(node.func)
            if jit is None:
                continue
            target = node.args[0]
            if not isinstance(target, (ast.Name, ast.Attribute)):
                continue
            resolved = self.index.resolve_call_target(mod, target)
            if resolved and isinstance(resolved[1], ast.FunctionDef):
                tmod, fn = resolved[0], resolved[1]
                statics = _static_names(node, fn)
                # statics may also sit on the partial(jit, ...) wrapper
                if isinstance(node.func, ast.Call):
                    statics |= _static_names(node.func, fn)
                self._analyze(tmod, fn, self._param_taint(fn, statics))
        # (c) pallas_call kernels: positional params are refs (traced),
        #     kw-only params are partial-bound statics
        for node in ast.walk(mod.tree):
            if not (isinstance(node, ast.Call) and
                    name_endswith(node, "pallas_call") and node.args):
                continue
            kfn = node.args[0]
            if isinstance(kfn, ast.Call) and name_endswith(kfn, "partial") \
                    and kfn.args:
                kfn = kfn.args[0]
            resolved = self.index.resolve_call_target(
                mod, kfn, _local_defs(mod.tree))
            if resolved and isinstance(resolved[1], ast.FunctionDef):
                fn = resolved[1]
                env = {a.arg: True
                       for a in fn.args.posonlyargs + fn.args.args}
                env.update({a.arg: False for a in fn.args.kwonlyargs})
                self._analyze(resolved[0], fn, env)
        # (d) bare combinator callsites (bodies whose enclosing function is
        #     not itself a root still run traced)
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Call):
                self._combinator_bodies(mod, node, _local_defs(mod.tree),
                                        env=None)

    @staticmethod
    def _param_taint(fn: ast.FunctionDef, statics: Set[str]
                     ) -> Dict[str, bool]:
        env = {}
        for a in (fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs):
            env[a.arg] = a.arg not in statics
        if fn.args.vararg:
            env[fn.args.vararg.arg] = True
        if fn.args.kwarg:
            env[fn.args.kwarg.arg] = True
        return env

    # ---- per-function analysis -------------------------------------------

    def _analyze(self, mod: Module, fn: ast.AST, env: Dict[str, bool],
                 depth: int = 0) -> bool:
        """Walk one function with `env` as the initial taint map.

        Returns the taint of the function's return value (conservative).
        """
        if depth > _MAX_DEPTH:
            return True
        key = (id(fn), frozenset(k for k, v in env.items() if v))
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = True                      # conservative for cycles
        if isinstance(fn, ast.Lambda):
            ret = self._expr(mod, fn.body, env, _local_defs(fn), depth)
            self._memo[key] = ret
            return ret
        if not isinstance(fn, ast.FunctionDef):
            return True
        local = _local_defs(fn)
        ret_taint = [False]
        self._stmts(mod, fn.body, env, local, depth, ret_taint)
        self._memo[key] = ret_taint[0]
        return ret_taint[0]

    def _stmts(self, mod: Module, stmts: Sequence[ast.stmt],
               env: Dict[str, bool], local: Dict[str, ast.FunctionDef],
               depth: int, ret_taint: List[bool]) -> None:
        for st in stmts:
            if isinstance(st, ast.FunctionDef):
                continue                       # analyzed only when invoked
            if isinstance(st, (ast.Assign, ast.AnnAssign, ast.AugAssign)):
                value = getattr(st, "value", None)
                t = self._expr(mod, value, env, local, depth) \
                    if value is not None else False
                targets = st.targets if isinstance(st, ast.Assign) \
                    else [st.target]
                for tgt in targets:
                    self._bind(tgt, t or (isinstance(st, ast.AugAssign) and
                                          self._expr(mod, st.target, env,
                                                     local, depth)), env)
            elif isinstance(st, (ast.If, ast.While)):
                if self._branch_taint(mod, st.test, env, local, depth):
                    self.findings.append(Finding(
                        rule=RULE, path=mod.path, line=st.test.lineno,
                        col=st.test.col_offset,
                        message=("python `if`/`while` on a traced value — "
                                 "forces a host sync (or a trace-time "
                                 "TracerBoolConversionError); use lax.cond/"
                                 "jnp.where or mark the argument static "
                                 "(DESIGN.md §5)")))
                self._stmts(mod, st.body, env, local, depth, ret_taint)
                self._stmts(mod, st.orelse, env, local, depth, ret_taint)
            elif isinstance(st, ast.For):
                if self._expr(mod, st.iter, env, local, depth):
                    self.findings.append(Finding(
                        rule=RULE, path=mod.path, line=st.iter.lineno,
                        col=st.iter.col_offset,
                        message=("python loop over a traced value — iterates "
                                 "on device contents at trace time; use "
                                 "lax.fori_loop/scan (DESIGN.md §5)")))
                    self._bind(st.target, True, env)
                else:
                    self._bind(st.target, False, env)
                # twice: propagate loop-carried taint
                self._stmts(mod, st.body, env, local, depth, ret_taint)
                self._stmts(mod, st.body, env, local, depth, ret_taint)
                self._stmts(mod, st.orelse, env, local, depth, ret_taint)
            elif isinstance(st, ast.Return):
                if st.value is not None:
                    ret_taint[0] |= bool(self._expr(mod, st.value, env,
                                                    local, depth))
            elif isinstance(st, ast.Expr):
                self._expr(mod, st.value, env, local, depth)
            elif isinstance(st, ast.With):
                for item in st.items:
                    self._expr(mod, item.context_expr, env, local, depth)
                self._stmts(mod, st.body, env, local, depth, ret_taint)
            elif isinstance(st, ast.Try):
                self._stmts(mod, st.body, env, local, depth, ret_taint)
                for h in st.handlers:
                    self._stmts(mod, h.body, env, local, depth, ret_taint)
                self._stmts(mod, st.finalbody, env, local, depth, ret_taint)
            # Assert/Raise/Pass/Import/...: no taint flow worth tracking

    def _bind(self, tgt: ast.AST, taint: bool, env: Dict[str, bool]) -> None:
        if isinstance(tgt, ast.Name):
            env[tgt.id] = bool(taint)
        elif isinstance(tgt, (ast.Tuple, ast.List)):
            for el in tgt.elts:
                self._bind(el, taint, env)
        elif isinstance(tgt, ast.Starred):
            self._bind(tgt.value, taint, env)
        # Subscript/Attribute stores: container taint unchanged

    def _branch_taint(self, mod: Module, test: ast.AST, env: Dict[str, bool],
                      local: Dict[str, ast.FunctionDef], depth: int) -> bool:
        """Taint of an if/while test, with the static-test exemptions."""
        if isinstance(test, ast.Compare):
            # `x is None` / `x is not None`: identity on the python object
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in test.ops):
                return False
            # `"key" in carry`: membership over dict keys, not tracer data
            if all(isinstance(op, (ast.In, ast.NotIn)) for op in test.ops) \
                    and isinstance(test.left, ast.Constant):
                return False
        if isinstance(test, ast.BoolOp):
            return any([self._branch_taint(mod, v, env, local, depth)
                        for v in test.values])
        if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not):
            return self._branch_taint(mod, test.operand, env, local, depth)
        return bool(self._expr(mod, test, env, local, depth))

    # ---- expression taint (and sink detection) ---------------------------

    def _expr(self, mod: Module, node: Optional[ast.AST],
              env: Dict[str, bool], local: Dict[str, ast.FunctionDef],
              depth: int) -> bool:
        if node is None or isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Name):
            return env.get(node.id, False)
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                self._expr(mod, node.value, env, local, depth)
                return False
            return self._expr(mod, node.value, env, local, depth)
        if isinstance(node, ast.Subscript):
            return (self._expr(mod, node.value, env, local, depth) |
                    self._expr(mod, node.slice, env, local, depth))
        # NB: sub-expressions are evaluated eagerly (no short-circuit `any`
        # over a generator) — sinks must be visited even after taint is known
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any([self._expr(mod, el, env, local, depth)
                        for el in node.elts])
        if isinstance(node, ast.Dict):
            return any([self._expr(mod, v, env, local, depth)
                        for v in list(node.keys) + list(node.values)
                        if v is not None])
        if isinstance(node, ast.BinOp):
            return (self._expr(mod, node.left, env, local, depth) |
                    self._expr(mod, node.right, env, local, depth))
        if isinstance(node, ast.UnaryOp):
            return self._expr(mod, node.operand, env, local, depth)
        if isinstance(node, ast.BoolOp):
            return any([self._expr(mod, v, env, local, depth)
                        for v in node.values])
        if isinstance(node, ast.Compare):
            vals = [node.left] + list(node.comparators)
            return any([self._expr(mod, v, env, local, depth) for v in vals])
        if isinstance(node, ast.IfExp):
            if self._branch_taint(mod, node.test, env, local, depth):
                self.findings.append(Finding(
                    rule=RULE, path=mod.path, line=node.test.lineno,
                    col=node.test.col_offset,
                    message=("conditional expression on a traced value — "
                             "boolean coercion of a tracer; use jnp.where/"
                             "lax.cond (DESIGN.md §5)")))
            return (self._expr(mod, node.body, env, local, depth) |
                    self._expr(mod, node.orelse, env, local, depth))
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            cenv = dict(env)
            for gen in node.generators:
                t = self._expr(mod, gen.iter, env, local, depth)
                self._bind(gen.target, t, cenv)
                for cond in gen.ifs:
                    self._expr(mod, cond, cenv, local, depth)
            if isinstance(node, ast.DictComp):
                return (self._expr(mod, node.key, cenv, local, depth) |
                        self._expr(mod, node.value, cenv, local, depth))
            return self._expr(mod, node.elt, cenv, local, depth)
        if isinstance(node, ast.Starred):
            return self._expr(mod, node.value, env, local, depth)
        if isinstance(node, ast.JoinedStr):
            return False          # f-string repr of a tracer is legal
        if isinstance(node, ast.Lambda):
            return False          # analyzed when invoked via a combinator
        if isinstance(node, ast.Call):
            return self._call(mod, node, env, local, depth)
        return False

    def _call(self, mod: Module, node: ast.Call, env: Dict[str, bool],
              local: Dict[str, ast.FunctionDef], depth: int) -> bool:
        name = call_name(node) or ""
        last = name.rpartition(".")[2]
        arg_taints = [self._expr(mod, a, env, local, depth)
                      for a in node.args]
        kw_taints = {kw.arg: self._expr(mod, kw.value, env, local, depth)
                     for kw in node.keywords}
        any_taint = any(arg_taints) or any(kw_taints.values())

        # ---- sinks ----
        if isinstance(node.func, ast.Name) and node.func.id in _COERCIONS \
                and any_taint:
            self.findings.append(Finding(
                rule=RULE, path=mod.path, line=node.lineno,
                col=node.col_offset,
                message=(f"`{node.func.id}()` on a traced value — host "
                         f"round-trip inside traced code (DESIGN.md §5)")))
            return False
        if last == "item" and isinstance(node.func, ast.Attribute) and \
                self._expr(mod, node.func.value, env, local, depth):
            self.findings.append(Finding(
                rule=RULE, path=mod.path, line=node.lineno,
                col=node.col_offset,
                message=("`.item()` on a traced value — device sync inside "
                         "traced code (DESIGN.md §5)")))
            return False
        if name in _NUMPY_SINKS and any_taint:
            self.findings.append(Finding(
                rule=RULE, path=mod.path, line=node.lineno,
                col=node.col_offset,
                message=(f"`{name}()` materializes a traced value on host "
                         f"inside traced code (DESIGN.md §5)")))
            return False

        # ---- traced-region extension ----
        self._combinator_bodies(mod, node, local, env)

        # ---- interprocedural propagation ----
        if isinstance(node.func, ast.Name) and \
                node.func.id in _UNTAINTED_CALLS:
            return node.func.id in ("tuple", "list", "dict", "sorted",
                                    "min", "max", "getattr") and any_taint
        resolved = self.index.resolve_call_target(mod, node.func, local)
        if resolved and isinstance(resolved[1], ast.FunctionDef):
            tmod, fn = resolved
            cenv = self._map_args(fn, arg_taints, kw_taints)
            if cenv is not None:
                return self._analyze(tmod, fn, cenv, depth + 1)
        # jnp./lax. producers return tracers even from constant args; the
        # broader jax.* namespace (default_backend, devices, tree.map) is
        # host-side and stays on the conservative fallthrough below
        if name.startswith(_PRODUCER_PREFIXES):
            return True
        # unresolved: propagate conservatively, flag nothing
        base = self._expr(mod, node.func, env, local, depth) \
            if isinstance(node.func, ast.Attribute) else False
        return any_taint or base

    @staticmethod
    def _map_args(fn: ast.FunctionDef, arg_taints: List[bool],
                  kw_taints: Dict[str, bool]) -> Optional[Dict[str, bool]]:
        params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        env: Dict[str, bool] = {p: False for p in params}
        env.update({a.arg: False for a in fn.args.kwonlyargs})
        for i, t in enumerate(arg_taints):
            if i < len(params):
                env[params[i]] = t
            elif fn.args.vararg:
                env[fn.args.vararg.arg] = env.get(fn.args.vararg.arg,
                                                  False) or t
        for k, t in kw_taints.items():
            if k in env:
                env[k] = t
            elif k is None or fn.args.kwarg:
                pass                               # **kwargs: ignore
        return env

    def _combinator_bodies(self, mod: Module, node: ast.Call,
                           local: Dict[str, ast.FunctionDef],
                           env: Optional[Dict[str, bool]]) -> None:
        """Analyze function args of lax.while_loop/cond/scan/... and
        jax.vmap(f)(...) with all params traced. `env` (when inside an
        analyzed root) supplies closure-variable taint context; from the
        module-level sweep it is None and closures read untainted."""
        name = call_name(node) or ""
        last = name.rpartition(".")[2]
        closure = dict(env) if env else {}

        def run_body(fn_expr: ast.AST) -> None:
            if isinstance(fn_expr, ast.Lambda):
                cenv = dict(closure)
                for a in fn_expr.args.args:
                    cenv[a.arg] = True
                self._expr(mod, fn_expr.body, cenv, local, 1)
                return
            resolved = self.index.resolve_call_target(mod, fn_expr, local)
            if resolved and isinstance(resolved[1], ast.FunctionDef):
                tmod, fn = resolved
                cenv = dict(closure)
                cenv.update({a.arg: True for a in
                             fn.args.posonlyargs + fn.args.args})
                self._analyze(tmod, fn, cenv, depth=1)

        if last == "map" and "lax" not in name.split("."):
            return                                 # jax.tree.map / builtin map
        if last in _COMBINATORS:
            idxs = _COMBINATORS[last]
            if last == "switch":
                branches = node.args[1] if len(node.args) > 1 else None
                if isinstance(branches, (ast.List, ast.Tuple)):
                    for b in branches.elts:
                        run_body(b)
            elif idxs:
                for i in idxs:
                    if i < len(node.args):
                        run_body(node.args[i])
        elif last in _WRAPPERS and node.args:
            run_body(node.args[0])
        elif isinstance(node.func, ast.Call):
            # jax.vmap(f)(xs) / shard_map(f, ...)(xs) call-through
            inner = node.func
            iname = (call_name(inner) or "").rpartition(".")[2]
            if iname in _WRAPPERS and inner.args:
                run_body(inner.args[0])


def check(index: PackageIndex) -> List[Finding]:
    return TracerTaint(index).run()
