"""Package index: parsed modules, resolved imports, cross-module symbols.

Builds the shared substrate every rule walks:

* one `ast` tree + suppression table per module;
* every `import`/`from ... import` resolved to absolute dotted names
  (relative imports resolved against the module's package), with the
  source location — the R1 layer walker consumes these;
* a per-module symbol table (top-level functions, assignments, import
  bindings) plus transitive re-export following, so the R4 call-graph
  can resolve `from repro.core.engine import run_root` through the
  package `__init__` down to the defining `FunctionDef`.

Stdlib-only on purpose (see findings.py).
"""
from __future__ import annotations

import ast
import dataclasses
import os
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.findings import Suppressions


@dataclasses.dataclass(frozen=True)
class ImportRecord:
    """One imported dotted target with its source location.

    `module` is the imported module path; `symbol` the name taken from it
    (None for plain `import x.y`). `candidates` lists the dotted names a
    layer rule should test: for `from a.b import c` both `a.b` and
    `a.b.c` — `c` may be a submodule (the dead-kernel bug's exact form)
    or a function, and the walker cannot always tell, so both are
    checked.
    """
    module: str
    symbol: Optional[str]
    lineno: int
    col: int

    @property
    def candidates(self) -> Tuple[str, ...]:
        if self.symbol is None:
            return (self.module,)
        return (self.module, f"{self.module}.{self.symbol}")


@dataclasses.dataclass
class Module:
    name: str                 # dotted: repro.core.engine.loop
    path: str                 # filesystem path as given to the CLI
    relpath: str              # posix path relative to the package root
    tree: ast.Module
    source: str
    suppressions: Suppressions
    imports: List[ImportRecord] = dataclasses.field(default_factory=list)
    # top-level bindings: name -> ("func", FunctionDef) | ("assign", Assign)
    #                          | ("module", dotted) | ("ref", dotted)
    symbols: Dict[str, Tuple[str, object]] = dataclasses.field(
        default_factory=dict)

    @property
    def package(self) -> str:
        """Dotted package the module's relative imports resolve against."""
        if self.name.endswith("__init__") or self.is_package:
            return self.name
        return self.name.rpartition(".")[0]

    @property
    def is_package(self) -> bool:
        return os.path.basename(self.path) == "__init__.py"


def _module_name(relpath: str, package: str) -> str:
    parts = relpath.replace(os.sep, "/").split("/")
    parts[-1] = parts[-1][:-3]                      # strip .py
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join([package] + [p for p in parts if p])


def _collect_imports(mod: Module) -> None:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                mod.imports.append(ImportRecord(
                    module=alias.name, symbol=None,
                    lineno=node.lineno, col=node.col_offset))
        elif isinstance(node, ast.ImportFrom):
            base = node.module or ""
            if node.level:                          # relative import
                pkg_parts = mod.package.split(".")
                up = node.level - 1
                if up:
                    pkg_parts = pkg_parts[:-up] if up < len(pkg_parts) else []
                base = ".".join(pkg_parts + ([node.module]
                                             if node.module else []))
            for alias in node.names:
                if alias.name == "*":
                    mod.imports.append(ImportRecord(
                        module=base, symbol=None,
                        lineno=node.lineno, col=node.col_offset))
                else:
                    mod.imports.append(ImportRecord(
                        module=base, symbol=alias.name,
                        lineno=node.lineno, col=node.col_offset))


def _collect_symbols(mod: Module) -> None:
    for node in mod.tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            mod.symbols[node.name] = ("func", node)
        elif isinstance(node, ast.ClassDef):
            mod.symbols[node.name] = ("class", node)
        elif isinstance(node, ast.Assign):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    mod.symbols[tgt.id] = ("assign", node)
        elif isinstance(node, ast.Import):
            for alias in node.names:
                bound = alias.asname or alias.name.partition(".")[0]
                target = alias.name if alias.asname else \
                    alias.name.partition(".")[0]
                mod.symbols[bound] = ("module", target)
        elif isinstance(node, ast.ImportFrom):
            base = node.module or ""
            if node.level:
                pkg_parts = mod.package.split(".")
                up = node.level - 1
                if up:
                    pkg_parts = pkg_parts[:-up] if up < len(pkg_parts) else []
                base = ".".join(pkg_parts + ([node.module]
                                             if node.module else []))
            for alias in node.names:
                if alias.name == "*":
                    continue
                mod.symbols[alias.asname or alias.name] = (
                    "ref", f"{base}.{alias.name}")


class PackageIndex:
    """All modules of one package, with cross-module resolution."""

    def __init__(self, package: str):
        self.package = package
        self.modules: Dict[str, Module] = {}      # dotted name -> Module

    @staticmethod
    def build(root: str, package: Optional[str] = None) -> "PackageIndex":
        """Parse every .py under `root` (the package directory itself)."""
        root = os.path.normpath(root)
        if package is None:
            package = os.path.basename(root)
        index = PackageIndex(package)
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames.sort()
            for name in sorted(filenames):
                if not name.endswith(".py"):
                    continue
                path = os.path.join(dirpath, name)
                relpath = os.path.relpath(path, root).replace(os.sep, "/")
                with open(path, encoding="utf-8") as f:
                    source = f.read()
                try:
                    tree = ast.parse(source, filename=path)
                except SyntaxError:
                    continue                       # not this tool's job
                mod = Module(name=_module_name(relpath, package), path=path,
                             relpath=relpath, tree=tree, source=source,
                             suppressions=Suppressions(source))
                _collect_imports(mod)
                _collect_symbols(mod)
                index.modules[mod.name] = mod
        return index

    def __iter__(self) -> Iterator[Module]:
        return iter(self.modules.values())

    # ---- cross-module function resolution (R4 call graph) ----------------

    def resolve_symbol(self, dotted: str, _depth: int = 0
                       ) -> Optional[Tuple[Module, ast.AST]]:
        """Resolve `repro.a.b.sym` to (defining module, FunctionDef).

        Follows `from x import y` re-export chains (package __init__
        indirection) up to a small depth; returns None for anything it
        cannot pin to a function/class definition.
        """
        if _depth > 8:
            return None
        if dotted in self.modules:
            return None                            # a module, not a symbol
        mod_name, _, sym = dotted.rpartition(".")
        mod = self.modules.get(mod_name)
        if mod is None:
            return None
        entry = mod.symbols.get(sym)
        if entry is None:
            return None
        kind, val = entry
        if kind in ("func", "class", "assign"):
            return mod, val                        # type: ignore[return-value]
        if kind == "ref":
            return self.resolve_symbol(val, _depth + 1)  # type: ignore[arg-type]
        if kind == "module":
            return None
        return None

    def resolve_call_target(self, mod: Module, func: ast.AST,
                            local: Optional[Dict[str, ast.AST]] = None
                            ) -> Optional[Tuple[Module, ast.AST]]:
        """Resolve a Call.func expression to a FunctionDef if possible.

        `local` maps names in the current scope to nested FunctionDefs
        (inner helpers passed to while_loop etc.).
        """
        if isinstance(func, ast.Name):
            if local and func.id in local:
                return mod, local[func.id]
            entry = mod.symbols.get(func.id)
            if entry is None:
                return None
            kind, val = entry
            if kind in ("func", "class", "assign"):
                return mod, val                    # type: ignore[return-value]
            if kind == "ref":
                return self.resolve_symbol(val)    # type: ignore[arg-type]
            return None
        if isinstance(func, ast.Attribute):
            base = dotted_name(func)
            if base is None:
                return None
            head, _, rest = base.partition(".")
            entry = mod.symbols.get(head)
            if entry and entry[0] == "module":
                return self.resolve_symbol(f"{entry[1]}.{rest}")
            if entry and entry[0] == "ref" and rest:
                return self.resolve_symbol(f"{entry[1]}.{rest}")
            return None
        return None


def dotted_name(node: ast.AST) -> Optional[str]:
    """`a.b.c` attribute chain -> 'a.b.c'; None if not a pure name chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    """Dotted name of a call's callee ('jax.jit', 'pl.pallas_call', ...)."""
    return dotted_name(node.func)


def name_endswith(node: ast.Call, *suffixes: str) -> bool:
    """True if the callee's dotted name ends with any suffix (module-alias
    agnostic: matches `pl.pallas_call`, `pallas.pallas_call`, bare
    `pallas_call`)."""
    name = call_name(node)
    if name is None:
        return False
    last = name.rpartition(".")[2]
    return last in suffixes
