"""R2/R3 — Pallas kernel-contract rules.

R2 (vmap-unsafe accumulators): inside any function passed to
`pl.pallas_call`, flag

* read-modify-write accumulation into an *output* block
  (`out_ref[...] += x`, `out_ref[...] = out_ref[...] * a + b`) — under
  `jax.vmap` the batching rule prepends the batch axis to the grid and
  cross-step output state is silently wrong (the exact PR-1 pivot-kernel
  corruption; see DESIGN.md §3);
* output writes gated on grid position (`@pl.when(program_id(...) == 0)`
  init / last-step epilogues) — the same hazard's control-flow form:
  under vmap `program_id(0)` becomes the batch index.

VMEM *scratch* operands (classified from `scratch_shapes`) are exempt:
a scratch accumulator over a sequential grid axis is the by-design
flash-attention pattern, and scratch is re-zeroed per batch member.
Writes that are pure functions of grid-invariant inputs (the idempotent
revisited-block pattern frame_step uses) carry no cross-step state and
pass clean.

R3 (Mosaic compilability): flag

* integer/bool-dtype axis reductions (`jnp.sum/cumsum/prod/mean`, or the
  `.sum(axis=...)` method forms) inside a kernel body — Mosaic rejects
  integer-axis reductions; accumulate in f32 (exact below 2^24) and cast
  back (the PR-1 review fix);
* `pl.BlockSpec` shapes built from literals whose trailing dims are
  neither (8, 128)-multiples nor 1 (1 ~ "equals the array dim", which
  is legal; non-literal dims are shape-dependent and skipped; specs
  whose `memory_space=` names SMEM are skipped — Mosaic applies the
  last-two-dims rule to SMEM blocks too, but their legality there
  hinges on "equals the array dims", which this static pass cannot see.
  The lane-batched kernels' per-lane scalar rows satisfy it by carrying
  a middle singleton: (1, 1, K) blocks of (L, 1, K) arrays);
* `pltpu.VMEM` scratch entries in `scratch_shapes` whose trailing dims
  are not (8, 128)-aligned *literals*. Scratch has no backing array to
  borrow dims from, so the BlockSpec "equals the array dim" escape does
  not exist: Mosaic allocates the scratch tile at compile time and a
  traced/derived dim either fails to lower or pads to a tile silently.
  The dfs_step_window kernel's resident stack window is the contract's
  poster child (literal (8, 128) frames); SMEM scratch is scalar memory
  and exempt.

Both rules are static approximations: dtypes are inferred by a local
forward dataflow over the kernel body (population_count/bitwise -> int,
`.astype(jnp.float32)` -> float, unknown stays unknown and is never
flagged).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Tuple

from repro.analysis.findings import Finding
from repro.analysis.modindex import (Module, PackageIndex, call_name,
                                     name_endswith)

RULE_VMAP = "R2"
RULE_MOSAIC = "R3"

_FLOAT_NAMES = {"float32", "float64", "float16", "bfloat16", "float_", "float"}
_INT_NAMES = {"int8", "int16", "int32", "int64", "uint8", "uint16", "uint32",
              "uint64", "int_", "int"}
_REDUCERS = {"sum", "cumsum", "prod", "mean"}
_FLOAT_FNS = {"exp", "log", "sqrt", "rsqrt", "sigmoid", "softmax", "tanh",
              "logaddexp", "erf"}

INT, FLOAT, BOOL, UNKNOWN = "int", "float", "bool", "unknown"


# ---------------------------------------------------------------------------
# pallas_call discovery + kernel operand classification
# ---------------------------------------------------------------------------

def _literal_len(node: Optional[ast.AST]) -> Optional[int]:
    if node is None:
        return None
    if isinstance(node, (ast.Tuple, ast.List)):
        return len(node.elts)
    if isinstance(node, ast.Call):
        return 1                                   # one ShapeDtypeStruct
    return None


def _kw(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _kernel_fn_name(arg: ast.AST) -> Optional[str]:
    """First pallas_call arg -> kernel function name (through partial)."""
    if isinstance(arg, ast.Name):
        return arg.id
    if isinstance(arg, ast.Call) and name_endswith(arg, "partial") and arg.args:
        inner = arg.args[0]
        if isinstance(inner, ast.Name):
            return inner.id
    return None


def find_kernels(mod: Module) -> List[Tuple[ast.FunctionDef, Dict[str, str]]]:
    """All (kernel FunctionDef, param-name -> 'in'|'out'|'scratch') pairs
    for kernels this module passes to pl.pallas_call."""
    local_defs: Dict[str, ast.FunctionDef] = {
        n.name: n for n in ast.walk(mod.tree)
        if isinstance(n, ast.FunctionDef)}
    out = []
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and
                name_endswith(node, "pallas_call")):
            continue
        if not node.args:
            continue
        fname = _kernel_fn_name(node.args[0])
        fn = local_defs.get(fname) if fname else None
        if fn is None:
            continue
        params = [a.arg for a in fn.args.posonlyargs + fn.args.args]
        n_in = _literal_len(_kw(node, "in_specs"))
        n_out = _literal_len(_kw(node, "out_shape"))
        n_scr = _literal_len(_kw(node, "scratch_shapes")) or 0
        kinds: Dict[str, str] = {}
        if (n_in is not None and n_out is not None and
                n_in + n_out + n_scr == len(params)):
            for i, p in enumerate(params):
                kinds[p] = ("in" if i < n_in else
                            "out" if i < n_in + n_out else "scratch")
        else:
            # cannot classify -> conservatively treat every ref as output
            kinds = {p: "out" for p in params}
        out.append((fn, kinds))
    return out


# ---------------------------------------------------------------------------
# R2: cross-grid accumulators / grid-position-gated output writes
# ---------------------------------------------------------------------------

def _progid_derived_names(fn: ast.FunctionDef) -> set:
    derived = set()
    for node in ast.walk(fn):
        if (isinstance(node, ast.Assign) and isinstance(node.value, ast.Call)
                and name_endswith(node.value, "program_id", "num_programs")):
            for tgt in node.targets:
                if isinstance(tgt, ast.Name):
                    derived.add(tgt.id)
    # fixpoint over straight-line derivations (run = ki * bk <= qmax)
    changed = True
    while changed:
        changed = False
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign) and _uses_progid(node.value,
                                                            derived):
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name) and tgt.id not in derived:
                        derived.add(tgt.id)
                        changed = True
    return derived


def _uses_progid(expr: ast.AST, derived: set) -> bool:
    for node in ast.walk(expr):
        if isinstance(node, ast.Name) and node.id in derived:
            return True
        if isinstance(node, ast.Call) and name_endswith(node, "program_id",
                                                        "num_programs"):
            return True
    return False


def _sub_base(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Subscript) and isinstance(node.value, ast.Name):
        return node.value.id
    return None


def _reads_ref(expr: ast.AST, ref: str) -> bool:
    for node in ast.walk(expr):
        if (isinstance(node, ast.Subscript) and
                isinstance(node.value, ast.Name) and node.value.id == ref and
                isinstance(node.ctx, ast.Load)):
            return True
    return False


def check_kernel_vmap_safety(mod: Module, fn: ast.FunctionDef,
                             kinds: Dict[str, str]) -> List[Finding]:
    findings: List[Finding] = []
    derived = _progid_derived_names(fn)

    def visit(stmts: Sequence[ast.stmt], gated_on_grid: bool) -> None:
        for st in stmts:
            if isinstance(st, ast.FunctionDef):
                gate = gated_on_grid
                for dec in st.decorator_list:
                    if (isinstance(dec, ast.Call) and
                            name_endswith(dec, "when") and dec.args and
                            _uses_progid(dec.args[0], derived)):
                        gate = True
                visit(st.body, gate)
                continue
            if isinstance(st, ast.AugAssign):
                ref = _sub_base(st.target)
                if ref in kinds and kinds[ref] == "out":
                    findings.append(Finding(
                        rule=RULE_VMAP, path=mod.path, line=st.lineno,
                        col=st.col_offset,
                        message=(f"cross-grid accumulation into output block "
                                 f"`{ref}` — under jax.vmap the batched grid "
                                 f"revisits this block and the accumulator "
                                 f"is silently corrupted (PR-1 pivot-kernel "
                                 f"bug class; DESIGN.md §3)")))
                continue
            if isinstance(st, ast.Assign):
                for tgt in st.targets:
                    ref = _sub_base(tgt)
                    if ref is None or kinds.get(ref) != "out":
                        continue
                    if _reads_ref(st.value, ref):
                        findings.append(Finding(
                            rule=RULE_VMAP, path=mod.path, line=st.lineno,
                            col=st.col_offset,
                            message=(f"read-modify-write of output block "
                                     f"`{ref}` across grid steps — "
                                     f"non-idempotent revisited output "
                                     f"blocks break under jax.vmap (PR-1 "
                                     f"bug class; DESIGN.md §3)")))
                    elif gated_on_grid:
                        findings.append(Finding(
                            rule=RULE_VMAP, path=mod.path, line=st.lineno,
                            col=st.col_offset,
                            message=(f"write to output block `{ref}` gated "
                                     f"on grid position (program_id) — "
                                     f"init/epilogue accumulator pattern; "
                                     f"under vmap program_id(0) becomes the "
                                     f"batch index (DESIGN.md §3)")))
                continue
            if isinstance(st, (ast.If, ast.For, ast.While, ast.With)):
                visit(st.body, gated_on_grid)
                visit(getattr(st, "orelse", []), gated_on_grid)

    visit(fn.body, False)
    return findings


# ---------------------------------------------------------------------------
# R3: integer-axis reductions + misaligned literal BlockSpecs
# ---------------------------------------------------------------------------

def _dtype_kind(node: Optional[ast.AST]) -> str:
    if node is None:
        return UNKNOWN
    name = None
    if isinstance(node, ast.Attribute):
        name = node.attr
    elif isinstance(node, ast.Name):
        name = node.id
    elif isinstance(node, ast.Constant) and isinstance(node.value, str):
        name = node.value
    if name in _FLOAT_NAMES:
        return FLOAT
    if name in _INT_NAMES:
        return INT
    if name in ("bool", "bool_"):
        return BOOL
    return UNKNOWN


def _join(a: str, b: str) -> str:
    if UNKNOWN in (a, b):
        return UNKNOWN
    if FLOAT in (a, b):
        return FLOAT
    if a == b:
        return a
    return INT                                      # int ∨ bool -> int


class _DtypeFlow:
    """Forward dataflow over a kernel body: name -> INT/FLOAT/BOOL/UNKNOWN."""

    def __init__(self):
        self.env: Dict[str, str] = {}

    def run(self, fn: ast.FunctionDef) -> None:
        for node in ast.walk(fn):
            if isinstance(node, ast.Assign):
                kind = self.infer(node.value)
                for tgt in node.targets:
                    if isinstance(tgt, ast.Name):
                        self.env[tgt.id] = kind

    def infer(self, node: ast.AST) -> str:
        if isinstance(node, ast.Constant):
            if isinstance(node.value, bool):
                return BOOL
            if isinstance(node.value, int):
                return INT
            if isinstance(node.value, float):
                return FLOAT
            return UNKNOWN
        if isinstance(node, ast.Name):
            return self.env.get(node.id, UNKNOWN)
        if isinstance(node, ast.Subscript):
            return self.infer(node.value)
        if isinstance(node, ast.UnaryOp):
            return self.infer(node.operand)
        if isinstance(node, ast.BinOp):
            if isinstance(node.op, ast.Div):
                return FLOAT
            return _join(self.infer(node.left), self.infer(node.right))
        if isinstance(node, (ast.Compare, ast.BoolOp)):
            return BOOL
        if isinstance(node, ast.IfExp):
            return _join(self.infer(node.body), self.infer(node.orelse))
        if isinstance(node, ast.Call):
            return self._infer_call(node)
        return UNKNOWN

    def _infer_call(self, node: ast.Call) -> str:
        name = call_name(node) or ""
        last = name.rpartition(".")[2]
        if last == "astype":
            return _dtype_kind(node.args[0] if node.args else None)
        if last == "population_count":
            return INT
        if last.startswith("bitwise") or last in ("left_shift",
                                                  "right_shift", "invert"):
            return INT
        if last in _FLOAT_FNS:
            return FLOAT
        if last == "where" and len(node.args) == 3:
            return _join(self.infer(node.args[1]), self.infer(node.args[2]))
        if last in ("broadcasted_iota", "iota"):
            return _dtype_kind(node.args[0] if node.args else None)
        if last in ("zeros", "ones", "full", "arange", "zeros_like",
                    "ones_like", "full_like"):
            dt = _kw(node, "dtype")
            if dt is None and last in ("zeros", "ones", "full", "arange"):
                return INT if last == "arange" and not node.args[1:] else \
                    _dtype_kind(dt)
            return _dtype_kind(dt)
        if last in ("dot", "dot_general", "matmul"):
            return _dtype_kind(_kw(node, "preferred_element_type"))
        if last in ("maximum", "minimum", "abs", "clip", "remainder", "mod"):
            kinds = [self.infer(a) for a in node.args]
            out = kinds[0] if kinds else UNKNOWN
            for k in kinds[1:]:
                out = _join(out, k)
            return out
        if last in _REDUCERS or last in ("max", "min", "amax", "amin"):
            base = (node.func.value if isinstance(node.func, ast.Attribute)
                    and not (call_name(node) or "").startswith(("jnp.", "np.",
                                                                "jax."))
                    else (node.args[0] if node.args else None))
            return self.infer(base) if base is not None else UNKNOWN
        return UNKNOWN


def _reduction_operand(node: ast.Call) -> Optional[ast.AST]:
    """Operand of jnp.sum(x, axis=...) or x.sum(axis=...); None if the
    call has no axis argument (full reductions lower fine)."""
    has_axis = _kw(node, "axis") is not None
    name = call_name(node) or ""
    if isinstance(node.func, ast.Attribute) and not name.startswith(
            ("jnp.", "np.", "jax.", "lax.", "numpy.")):
        # method form: x.sum(axis=1) / x.sum(1)
        if not (has_axis or node.args):
            return None
        return node.func.value
    if not (has_axis or len(node.args) >= 2):
        return None
    return node.args[0] if node.args else None


def check_kernel_mosaic(mod: Module, fn: ast.FunctionDef) -> List[Finding]:
    flow = _DtypeFlow()
    flow.run(fn)
    findings: List[Finding] = []
    for node in ast.walk(fn):
        if not isinstance(node, ast.Call):
            continue
        name = call_name(node) or ""
        if name.rpartition(".")[2] not in _REDUCERS:
            continue
        operand = _reduction_operand(node)
        if operand is None:
            continue
        kind = flow.infer(operand)
        if kind in (INT, BOOL):
            findings.append(Finding(
                rule=RULE_MOSAIC, path=mod.path, line=node.lineno,
                col=node.col_offset,
                message=(f"{kind}-dtype axis reduction inside a Pallas "
                         f"kernel body — Mosaic rejects integer-axis "
                         f"reductions; accumulate in f32 (exact below 2^24) "
                         f"and cast back (DESIGN.md §3)")))
    return findings


def check_blockspecs(mod: Module) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and
                name_endswith(node, "BlockSpec") and node.args):
            continue
        mem = _kw(node, "memory_space")
        if mem is not None and "SMEM" in ast.unparse(mem):
            continue                  # SMEM is scalar memory: no tiling
        shape = node.args[0]
        if not isinstance(shape, (ast.Tuple, ast.List)) or len(shape.elts) < 2:
            continue
        dims = shape.elts[-2:]
        if not all(isinstance(d, ast.Constant) and isinstance(d.value, int)
                   for d in dims):
            continue                  # shape-derived dims: caller's contract
        minor2, minor = dims[0].value, dims[1].value
        bad = []
        if minor != 1 and minor % 128 != 0:
            bad.append(f"last dim {minor} is not a multiple of 128")
        if minor2 != 1 and minor2 % 8 != 0:
            bad.append(f"second-minor dim {minor2} is not a multiple of 8")
        if bad:
            findings.append(Finding(
                rule=RULE_MOSAIC, path=mod.path, line=node.lineno,
                col=node.col_offset,
                message=(f"literal BlockSpec shape ({minor2}, {minor}): "
                         f"{'; '.join(bad)} — Mosaic requires (8, 128)-"
                         f"divisible trailing block dims (or dims equal to "
                         f"the array dims; DESIGN.md §3)")))
    return findings


def check_scratch_shapes(mod: Module) -> List[Finding]:
    """VMEM scratch_shapes entries: trailing dims must be (8, 128)-aligned
    literals (no array to inherit dims from — see module docstring)."""
    findings: List[Finding] = []
    for node in ast.walk(mod.tree):
        if not (isinstance(node, ast.Call) and
                name_endswith(node, "pallas_call")):
            continue
        scr = _kw(node, "scratch_shapes")
        if scr is None or not isinstance(scr, (ast.Tuple, ast.List)):
            continue
        for entry in scr.elts:
            if not (isinstance(entry, ast.Call) and
                    name_endswith(entry, "VMEM")):
                continue              # SMEM is scalar memory: no tiling
            shape = entry.args[0] if entry.args else None
            if not isinstance(shape, (ast.Tuple, ast.List)) or not shape.elts:
                findings.append(Finding(
                    rule=RULE_MOSAIC, path=mod.path, line=entry.lineno,
                    col=entry.col_offset,
                    message=("VMEM scratch shape is not a literal tuple — "
                             "Mosaic sizes scratch at compile time; spell "
                             "the dims as (8, 128)-aligned int literals "
                             "(DESIGN.md §3)")))
                continue
            dims = shape.elts[-2:]
            mults = (128,) if len(shape.elts) == 1 else (8, 128)
            bad = []
            for d, mult in zip(dims, mults):
                if not (isinstance(d, ast.Constant) and
                        isinstance(d.value, int)):
                    bad.append(f"dim {ast.unparse(d)} is not an int literal")
                elif d.value % mult != 0:
                    bad.append(f"dim {d.value} is not a multiple of {mult}")
            if bad:
                findings.append(Finding(
                    rule=RULE_MOSAIC, path=mod.path, line=entry.lineno,
                    col=entry.col_offset,
                    message=(f"VMEM scratch trailing dims must be (8, 128)-"
                             f"aligned literals: {'; '.join(bad)} — scratch "
                             f"has no backing array dim to equal, so the "
                             f"BlockSpec escape hatch does not apply "
                             f"(DESIGN.md §3)")))
    return findings


def check(index: PackageIndex) -> List[Finding]:
    findings: List[Finding] = []
    for mod in index:
        seen = set()
        for fn, kinds in find_kernels(mod):
            if id(fn) in seen:
                continue
            seen.add(id(fn))
            findings.extend(check_kernel_vmap_safety(mod, fn, kinds))
            findings.extend(check_kernel_mosaic(mod, fn))
        findings.extend(check_blockspecs(mod))
        findings.extend(check_scratch_shapes(mod))
    return findings
