"""`python -m repro.analysis` -> mce_lint CLI."""
import sys

from repro.analysis.cli import main

sys.exit(main())
