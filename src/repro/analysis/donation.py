"""R5 — donation safety: no reads of a donated buffer after the call.

`jax.jit(..., donate_argnums=/donate_argnames=)` lets XLA alias the
argument's device buffer into the output — after the donating call the
python name still points at an invalidated buffer, and touching it
raises (or worse, on some backends silently reads garbage). Both
double-buffered drivers in this repo donate (`core/driver.py` chunk
buffers, `launch/serve.py` KV cache), so the safe idiom is pinned down
here:

    params, opt, loss = jit_step(params, opt, batch)   # rebind: OK
    logits, cache = decode(params, cache, tok)         # loop rebind: OK

    out = step(buf)
    x = buf.sum()                                      # R5: read-after-donate

    for _ in range(n):
        out = step(buf)                                # R5: next iteration
                                                       # re-reads donated buf

Detection: donors are names bound to a jit expression carrying donate
kwargs (directly, through `functools.partial(jax.jit, ...)`, through an
alias/IfExp choosing between donor variants, or a decorated def). At
every donor callsite the donated positional/keyword args that are plain
names are traced forward: a Load before any re-Store — including the
implicit repeat of an enclosing loop body — is flagged. Rebinding in the
donating statement itself is the blessed pattern and never flagged.
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.findings import Finding
from repro.analysis.modindex import Module, PackageIndex, dotted_name

RULE = "R5"


@dataclasses.dataclass(frozen=True)
class Donor:
    argnums: Tuple[int, ...]
    argnames: Tuple[str, ...]


def _donation_kwargs(call: ast.Call) -> Optional[Donor]:
    nums: List[int] = []
    names: List[str] = []
    found = False
    for kw in call.keywords:
        if kw.arg == "donate_argnums":
            found = True
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, int):
                    nums.append(n.value)
        elif kw.arg == "donate_argnames":
            found = True
            for n in ast.walk(kw.value):
                if isinstance(n, ast.Constant) and isinstance(n.value, str):
                    names.append(n.value)
    return Donor(tuple(nums), tuple(names)) if found else None


def _donor_from_expr(node: ast.AST) -> Optional[Donor]:
    """Donor spec if `node` is a donating jit expression."""
    if not isinstance(node, ast.Call):
        return None
    name = dotted_name(node.func) or ""
    last = name.rpartition(".")[2]
    if last == "jit":
        return _donation_kwargs(node)
    if last == "partial" and node.args:
        inner = dotted_name(node.args[0]) or ""
        if inner.rpartition(".")[2] == "jit":
            return _donation_kwargs(node)
    # partial(jit, **kw)(f) / jit(**kw)(f): donation lives on the inner call
    if isinstance(node.func, ast.Call):
        return _donor_from_expr(node.func)
    return None


def _collect_donors(scope_body: Sequence[ast.stmt],
                    inherited: Dict[str, Donor]) -> Dict[str, Donor]:
    donors = dict(inherited)
    for st in scope_body:
        if isinstance(st, ast.FunctionDef):
            for dec in st.decorator_list:
                d = _donor_from_expr(dec) if isinstance(dec, ast.Call) \
                    else None
                if d:
                    donors[st.name] = d
        if not isinstance(st, ast.Assign):
            continue
        d = _donor_from_expr(st.value)
        if d is None and isinstance(st.value, ast.Name):
            d = donors.get(st.value.id)            # alias of a donor
        if d is None and isinstance(st.value, ast.IfExp):
            # fn = plain if cpu else donated  (driver.py lazy variant pick)
            for branch in (st.value.body, st.value.orelse):
                if isinstance(branch, ast.Name) and branch.id in donors:
                    d = donors[branch.id]
                    break
        if d is not None:
            for tgt in st.targets:
                if isinstance(tgt, ast.Name):
                    donors[tgt.id] = d
    return donors


def _donated_vars(call: ast.Call, donor: Donor) -> List[Tuple[str, int, int]]:
    out = []
    for i in donor.argnums:
        if i < len(call.args) and isinstance(call.args[i], ast.Name):
            a = call.args[i]
            out.append((a.id, call.lineno, call.col_offset))
    for kw in call.keywords:
        if kw.arg in donor.argnames and isinstance(kw.value, ast.Name):
            out.append((kw.value.id, call.lineno, call.col_offset))
    return out


def _stores(stmt: ast.stmt) -> Set[str]:
    return {n.id for n in ast.walk(stmt)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Store)}


def _loads(stmt: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(stmt)
            if isinstance(n, ast.Name) and isinstance(n.ctx, ast.Load)}


def _first_use_is_load(after: Sequence[ast.stmt], var: str) -> Optional[int]:
    """Line of the first read of `var` before any re-store, else None."""
    for st in after:
        if isinstance(st, ast.FunctionDef):
            continue
        if var in _loads(st):
            return st.lineno
        if var in _stores(st):
            return None
    return None


class _ScopeChecker:
    def __init__(self, mod: Module, donors: Dict[str, Donor]):
        self.mod = mod
        self.donors = donors
        self.findings: List[Finding] = []

    def scan(self, body: Sequence[ast.stmt], after_outer: Sequence[ast.stmt],
             loop_body: Optional[Sequence[ast.stmt]] = None) -> None:
        for i, st in enumerate(body):
            after = list(body[i + 1:]) + list(after_outer)
            if isinstance(st, ast.FunctionDef):
                inner_donors = _collect_donors(st.body, self.donors)
                checker = _ScopeChecker(self.mod, inner_donors)
                checker.scan(st.body, [])
                self.findings.extend(checker.findings)
                continue
            if isinstance(st, (ast.For, ast.While)):
                self.scan(st.body, after, loop_body=st.body)
                self.scan(st.orelse, after, loop_body=loop_body)
                continue
            if isinstance(st, ast.If):
                self.scan(st.body, after, loop_body=loop_body)
                self.scan(st.orelse, after, loop_body=loop_body)
                self._check_stmt(st.test, st, after, loop_body)
                continue
            if isinstance(st, (ast.With, ast.Try)):
                self.scan(st.body, after, loop_body=loop_body)
                for h in getattr(st, "handlers", []):
                    self.scan(h.body, after, loop_body=loop_body)
                self.scan(getattr(st, "finalbody", []), after,
                          loop_body=loop_body)
                continue
            self._check_stmt(st, st, after, loop_body)

    def _check_stmt(self, expr_root: ast.AST, stmt: ast.stmt,
                    after: Sequence[ast.stmt],
                    loop_body: Optional[Sequence[ast.stmt]]) -> None:
        for node in ast.walk(expr_root):
            if not (isinstance(node, ast.Call) and
                    isinstance(node.func, ast.Name) and
                    node.func.id in self.donors):
                continue
            donor = self.donors[node.func.id]
            rebound = _stores(stmt)
            for var, line, col in _donated_vars(node, donor):
                if var in rebound:
                    continue                       # donate-and-rebind: safe
                read_line = _first_use_is_load(after, var)
                if read_line is None and loop_body is not None:
                    # loop repeats: a donated var never re-stored in the
                    # loop body is consumed again next iteration
                    if not any(var in _stores(s) for s in loop_body):
                        read_line = line           # the call itself re-reads
                if read_line is not None:
                    self.findings.append(Finding(
                        rule=RULE, path=self.mod.path, line=line, col=col,
                        message=(f"`{var}` is donated to "
                                 f"`{node.func.id}()` (donate_argnums/"
                                 f"argnames) but read again at line "
                                 f"{read_line} — its device buffer is "
                                 f"invalidated by XLA aliasing; rebind the "
                                 f"result over `{var}` or drop the read")))


def check_module(mod: Module) -> List[Finding]:
    donors = _collect_donors(mod.tree.body, {})
    checker = _ScopeChecker(mod, donors)
    checker.scan(mod.tree.body, [])
    return checker.findings


def check(index: PackageIndex) -> List[Finding]:
    out: List[Finding] = []
    for mod in index:
        out.extend(check_module(mod))
    return out
