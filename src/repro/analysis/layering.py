"""R1 — dispatch purity / layering: declarative import-graph contracts.

Replaces the regex lint in tests/test_engine_layering.py with an
AST-backed walker: imports are resolved (including relative forms and
aliasing) before matching, so a mention of `ref` in a docstring no
longer matters and `from repro.kernels.bitset_ops import ref as r`
cannot hide behind formatting.

The layer contracts live ONCE, here, as data (`LAYERS`); the test suite
and the CLI both consume this table. Each rule descends from DESIGN.md
§3 (kernel dispatch choke point) and §6 (ingest layering):

* `kernel-privates` — the dead-Pallas-kernel bug (PR 1): the engine
  imported the jnp `ref` directly and the TPU kernel was dead code on
  the hot path. Only a kernel package may touch its own `ref`/`kernel`.
* `graph-purity` — `graph/` is the bottom layer: numpy + siblings only.
* `engine-no-upward` — the driver consumes the engine's stream, never
  the other way around.
* `driver-no-launch` — `core/driver.py` must stay launchable headless.
"""
from __future__ import annotations

import dataclasses
import fnmatch
from typing import List, Sequence, Tuple

from repro.analysis.findings import Finding
from repro.analysis.modindex import Module, PackageIndex

RULE = "R1"


@dataclasses.dataclass(frozen=True)
class LayerRule:
    """One declarative layer contract.

    scope/exclude are fnmatch globs over the module path relative to the
    package root (posix, e.g. 'core/engine/loop.py'). `forbid` patterns
    match resolved dotted import names with prefix semantics ('a.b' also
    bans 'a.b.c'). `allow_only` restricts every same-package import to
    the listed prefixes instead.
    """
    name: str
    description: str
    scope: Tuple[str, ...]
    exclude: Tuple[str, ...] = ()
    forbid: Tuple[str, ...] = ()
    allow_only: Tuple[str, ...] = ()


# The single source of truth for the repo's layer contracts
# (tests/test_engine_layering.py asserts this table's coverage).
LAYERS: Tuple[LayerRule, ...] = (
    LayerRule(
        name="kernel-privates",
        description=("`ref`/`kernel` modules are private to their kernel "
                     "package — all set algebra dispatches through `ops` "
                     "(DESIGN.md §3; the PR-1 dead-kernel bug)"),
        scope=("**",),
        exclude=("kernels/*/*.py",),
        forbid=("repro.kernels.*.ref", "repro.kernels.*.kernel"),
    ),
    LayerRule(
        name="graph-purity",
        description=("graph/ is the bottom layer: numpy + graph siblings "
                     "only, never core/kernels/launch (DESIGN.md §6)"),
        scope=("graph/*.py",),
        allow_only=("repro.graph",),
    ),
    LayerRule(
        name="engine-no-upward",
        description=("core/engine/ never imports the driver or launch — "
                     "the driver consumes the stream, not the reverse "
                     "(DESIGN.md §6)"),
        scope=("core/engine/*.py",),
        forbid=("repro.core.driver", "repro.launch"),
    ),
    LayerRule(
        name="driver-no-launch",
        description="core/driver.py never imports launch/ (DESIGN.md §6)",
        scope=("core/driver.py",),
        forbid=("repro.launch",),
    ),
)


def _matches_any(path: str, globs: Sequence[str]) -> bool:
    return any(fnmatch.fnmatch(path, g) for g in globs)


def _dotted_match(imp: str, pattern: str) -> bool:
    """Prefix-aware dotted match: 'a.b' bans 'a.b' and 'a.b.c'."""
    return fnmatch.fnmatch(imp, pattern) or fnmatch.fnmatch(imp, pattern + ".*")


def _rewrite(pattern: str, package: str) -> str:
    """Layer patterns are written against the canonical package name
    'repro'; retarget them when linting a differently-named tree (the
    fixture corpus uses throwaway package names)."""
    if package == "repro" or not pattern.startswith("repro"):
        return pattern
    return package + pattern[len("repro"):]


def check_module(mod: Module, package: str,
                 layers: Sequence[LayerRule] = LAYERS) -> List[Finding]:
    out: List[Finding] = []
    for rule in layers:
        if not _matches_any(mod.relpath, rule.scope):
            continue
        if _matches_any(mod.relpath, rule.exclude):
            continue
        forbid = [_rewrite(p, package) for p in rule.forbid]
        allow = [_rewrite(p, package) for p in rule.allow_only]
        for rec in mod.imports:
            for cand in rec.candidates:
                hit = None
                for pat in forbid:
                    if _dotted_match(cand, pat):
                        hit = (f"imports `{cand}` (forbidden by layer rule "
                               f"'{rule.name}': {rule.description})")
                        break
                if hit is None and allow and cand.startswith(package + "."):
                    if not any(_dotted_match(cand, pat) or
                               cand == pat for pat in allow):
                        hit = (f"imports `{cand}` outside its layer "
                               f"(rule '{rule.name}' allows only "
                               f"{list(rule.allow_only)}: {rule.description})")
                if hit:
                    out.append(Finding(rule=RULE, path=mod.path,
                                       line=rec.lineno, col=rec.col,
                                       message=hit))
                    break                          # one finding per import
    return out


def check(index: PackageIndex,
          layers: Sequence[LayerRule] = LAYERS) -> List[Finding]:
    out: List[Finding] = []
    for mod in index:
        out.extend(check_module(mod, index.package, layers))
    return out
