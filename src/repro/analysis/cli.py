"""mce_lint command-line driver.

    python -m repro.analysis src/repro --strict
    mce_lint src/repro --rules R1,R4 --format json --report lint_report.json

Exit status: 0 when no active (unsuppressed) finding remains, 1
otherwise. `--strict` additionally fails on suppressions that carry no
justification (S1) — the CI lint job runs in this mode so every silenced
rule documents *why* (DESIGN.md §7).

Stdlib-only end to end: the lint job needs no jax install.
"""
from __future__ import annotations

import argparse
import os
import sys
from typing import Dict, List, Optional, Sequence

from repro.analysis import donation, kernel_rules, layering, tracer_rules
from repro.analysis.findings import (Finding, Suppressions, dedupe,
                                     render_json, render_text,
                                     split_suppressed,
                                     unjustified_suppressions)
from repro.analysis.modindex import PackageIndex

RULE_FAMILIES = {
    "R1": ("dispatch purity / layering", layering.check),
    "R2": ("vmap-unsafe kernel accumulators", kernel_rules.check),
    "R3": ("Mosaic compilability", None),          # runs with R2's walker
    "R4": ("tracer leaks / host syncs", tracer_rules.check),
    "R5": ("donation safety", donation.check),
}


def analyze(root: str, package: Optional[str] = None,
            rules: Optional[Sequence[str]] = None):
    """Run all (or the selected) rule families over one package tree.

    Returns (active, suppressed, s1, n_modules). R2/R3 share one kernel
    walker, so selecting either runs it and the other family's findings
    are filtered out afterwards.
    """
    index = PackageIndex.build(root, package=package)
    selected = set(rules) if rules else set(RULE_FAMILIES)
    findings: List[Finding] = []
    if "R1" in selected:
        findings.extend(layering.check(index))
    if selected & {"R2", "R3"}:
        findings.extend(f for f in kernel_rules.check(index)
                        if f.rule in selected)
    if "R4" in selected:
        findings.extend(tracer_rules.check(index))
    if "R5" in selected:
        findings.extend(donation.check(index))
    findings = dedupe(findings)
    tables: Dict[str, Suppressions] = {m.path: m.suppressions for m in index}
    active, suppressed = split_suppressed(findings, tables)
    s1 = unjustified_suppressions(tables)
    return active, suppressed, s1, len(index.modules)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="mce_lint",
        description="AST-based kernel-contract and tracer-safety analyzer "
                    "for the repro package (rule families R1-R5; see "
                    "DESIGN.md §7).")
    ap.add_argument("paths", nargs="+",
                    help="package directories to analyze (e.g. src/repro)")
    ap.add_argument("--strict", action="store_true",
                    help="also fail on suppressions without a justification")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule subset (default: all)")
    ap.add_argument("--package", default=None,
                    help="override the dotted package name (default: "
                         "basename of each path)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--report", default=None, metavar="FILE",
                    help="also write a JSON findings report to FILE")
    args = ap.parse_args(argv)

    rules = [r.strip() for r in args.rules.split(",")] if args.rules else None
    active: List[Finding] = []
    suppressed: List[Finding] = []
    checked = 0
    for path in args.paths:
        if not os.path.isdir(path):
            print(f"mce_lint: {path} is not a directory", file=sys.stderr)
            return 2
        a, s, s1, n = analyze(path, package=args.package, rules=rules)
        active.extend(a)
        suppressed.extend(s)
        if args.strict:
            active.extend(s1)
        checked += n

    if args.report:
        with open(args.report, "w", encoding="utf-8") as f:
            f.write(render_json(active, suppressed, checked) + "\n")
    if args.format == "json":
        print(render_json(active, suppressed, checked))
    else:
        print(render_text(active, suppressed, checked))
    return 1 if active else 0


if __name__ == "__main__":
    sys.exit(main())
