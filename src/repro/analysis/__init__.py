"""mce_lint: AST-based static analysis for the repro package.

Five rule families, each descended from a bug this repo shipped and
fixed (DESIGN.md §7 documents the lineage and the suppression syntax):

* R1 dispatch purity / layering  (layering.py — declarative LAYERS)
* R2 vmap-unsafe kernel accumulators (kernel_rules.py)
* R3 Mosaic compilability        (kernel_rules.py)
* R4 tracer leaks / host syncs   (tracer_rules.py)
* R5 donation safety             (donation.py)

The package is stdlib-only (no jax import) so `python -m repro.analysis`
and the CI lint job run without the accelerator stack.
"""
from repro.analysis.cli import RULE_FAMILIES, analyze, main
from repro.analysis.findings import Finding, Suppressions
from repro.analysis.layering import LAYERS, LayerRule
from repro.analysis.modindex import PackageIndex

__all__ = [
    "RULE_FAMILIES", "analyze", "main", "Finding", "Suppressions",
    "LAYERS", "LayerRule", "PackageIndex",
]
