"""Findings, suppressions, and report formatting for mce_lint.

A `Finding` is one (rule, file, line) diagnostic. Suppressions are
in-source comments:

    x = something()            # mce-lint: disable=R4 -- host boundary: y is concrete here
    # mce-lint: disable=R2 -- sequential kv-axis accumulator, never vmapped
    out_ref[...] += part

    # mce-lint: disable-file=R3 -- whole-module opt-out (use sparingly)

A suppression on line L covers findings on L; a suppression on a
standalone comment line covers the next line. `disable-file` covers the
whole module. The text after `--` (or an em dash) is the justification;
`--strict` turns every justification-less suppression into an `S1`
finding, so a silenced rule always says *why* (DESIGN.md §7).

This module is stdlib-only: the analyzer must import without jax so the
CI lint job stays dependency-free.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Sequence, Tuple

_SUPPRESS_RE = re.compile(
    r"#\s*mce-lint:\s*disable(?P<file>-file)?\s*=\s*"
    r"(?P<rules>[A-Za-z0-9_]+(?:\s*,\s*[A-Za-z0-9_]+)*)"
    r"(?:\s*(?:--|—)\s*(?P<why>\S.*?))?\s*$")

_COMMENT_ONLY_RE = re.compile(r"^\s*#")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class Suppression:
    rules: Tuple[str, ...]
    line: int               # line the comment sits on (1-based)
    covers: Tuple[int, ...]  # source lines this suppression applies to
    file_level: bool
    justification: Optional[str]


class Suppressions:
    """Per-module suppression table parsed from raw source lines."""

    def __init__(self, source: str):
        self.entries: List[Suppression] = []
        self._by_line: Dict[int, List[Suppression]] = {}
        self._file_level: List[Suppression] = []
        for i, raw in enumerate(source.splitlines(), start=1):
            m = _SUPPRESS_RE.search(raw)
            if not m:
                continue
            rules = tuple(r.strip() for r in m.group("rules").split(","))
            file_level = m.group("file") is not None
            # a comment-only line shields the NEXT line; an inline trailing
            # comment shields its own line
            covers = () if file_level else (
                (i + 1,) if _COMMENT_ONLY_RE.match(raw) else (i,))
            sup = Suppression(rules=rules, line=i, covers=covers,
                              file_level=file_level,
                              justification=m.group("why"))
            self.entries.append(sup)
            if file_level:
                self._file_level.append(sup)
            for ln in covers:
                self._by_line.setdefault(ln, []).append(sup)

    def match(self, rule: str, line: int) -> Optional[Suppression]:
        for sup in self._by_line.get(line, ()):
            if rule in sup.rules:
                return sup
        for sup in self._file_level:
            if rule in sup.rules:
                return sup
        return None


def split_suppressed(findings: Sequence[Finding],
                     tables: Dict[str, Suppressions]
                     ) -> Tuple[List[Finding], List[Finding]]:
    """Partition findings into (active, suppressed) using per-path tables."""
    active: List[Finding] = []
    suppressed: List[Finding] = []
    for f in findings:
        table = tables.get(f.path)
        if table is not None and table.match(f.rule, f.line):
            suppressed.append(f)
        else:
            active.append(f)
    return active, suppressed


def unjustified_suppressions(tables: Dict[str, Suppressions]) -> List[Finding]:
    """S1: every suppression must carry a one-line justification."""
    out = []
    for path, table in sorted(tables.items()):
        for sup in table.entries:
            if not sup.justification:
                out.append(Finding(
                    rule="S1", path=path, line=sup.line, col=0,
                    message=(f"suppression of {','.join(sup.rules)} has no "
                             f"justification — append `-- <why>` to the "
                             f"mce-lint comment")))
    return out


def dedupe(findings: Sequence[Finding]) -> List[Finding]:
    seen = set()
    out = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.col, f.rule)):
        key = (f.rule, f.path, f.line, f.message)
        if key not in seen:
            seen.add(key)
            out.append(f)
    return out


def render_text(active: Sequence[Finding], suppressed: Sequence[Finding],
                checked: int) -> str:
    lines = [f.format() for f in active]
    lines.append(f"mce_lint: {checked} modules checked, "
                 f"{len(active)} finding(s), {len(suppressed)} suppressed")
    return "\n".join(lines)


def render_json(active: Sequence[Finding], suppressed: Sequence[Finding],
                checked: int) -> str:
    return json.dumps({
        "modules_checked": checked,
        "findings": [f.as_dict() for f in active],
        "suppressed": [f.as_dict() for f in suppressed],
        "counts": {"active": len(active), "suppressed": len(suppressed)},
    }, indent=2)
