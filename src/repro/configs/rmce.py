"""rmce [mce]: the paper's own architecture — the reduction-based maximal
clique enumeration engine as a first-class selectable arch (--arch rmce).

Shape cells mirror the paper's dataset regimes (Table 2) at production scale:
each cell fixes the padded bitset bucket tensor shapes that one device step
processes; the dry-run lowers the shard_map'ed counting kernel over the mesh
exactly as `repro.core.driver.DistributedMCE` runs it.

  roots_chunk  — roots per shard per device step,
  u_pad        — padded universe size (≥ graph degeneracy λ, multiple of 32),
  x_pad        — padded forbidden-set row count.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchSpec, ShapeCell, register


@dataclasses.dataclass(frozen=True)
class MCEArchConfig:
    name: str = "rmce"
    backend: str = "pivot"            # 'pivot' | 'rcd' | 'revised'
    dynamic_red: bool = True
    global_red: bool = True
    x_red: bool = True
    bucket_sizes: tuple = (32, 64, 128, 256, 512, 1024)
    chunk: int = 1024


def build() -> MCEArchConfig:
    return MCEArchConfig()


def build_smoke() -> MCEArchConfig:
    return MCEArchConfig(name="rmce-smoke", bucket_sizes=(32, 64), chunk=8)


def mce_shapes(cfg) -> list:
    # (regime, roots per shard-step, U pad, X rows pad) — λ from paper Tab. 2:
    # social/web graphs λ≈51-131 → U=128/256; flickr-like λ=573 → U=1024.
    return [
        ShapeCell("web_sparse", "mce", dict(roots_chunk=1024, u_pad=64,
                                            x_pad=64)),
        ShapeCell("social_mid", "mce", dict(roots_chunk=512, u_pad=256,
                                            x_pad=256)),
        ShapeCell("dense_core", "mce", dict(roots_chunk=128, u_pad=1024,
                                            x_pad=1024)),
        ShapeCell("orkut_scale", "mce", dict(roots_chunk=256, u_pad=512,
                                             x_pad=2048)),
    ]


ARCH = register(ArchSpec(
    name="rmce", family="mce", build=build, build_smoke=build_smoke,
    shapes=mce_shapes, source="this paper (Deng, Zheng, Cheng; PVLDB'24)"))
