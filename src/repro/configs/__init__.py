"""Architecture registry: every assigned arch is selectable via --arch <id>.

Importing this package registers all architectures. `get_arch(name)` returns
the ArchSpec; `list_archs()` enumerates them.
"""
from repro.configs.base import ArchSpec, ShapeCell, get_arch, list_archs, register

# assigned architectures (importing registers them)
from repro.configs import mixtral_8x7b         # noqa: F401
from repro.configs import phi35_moe            # noqa: F401
from repro.configs import qwen3_14b            # noqa: F401
from repro.configs import chatglm3_6b          # noqa: F401
from repro.configs import command_r_plus_104b  # noqa: F401
from repro.configs import meshgraphnet         # noqa: F401
from repro.configs import schnet               # noqa: F401
from repro.configs import dimenet              # noqa: F401
from repro.configs import mace                 # noqa: F401
from repro.configs import two_tower_retrieval  # noqa: F401
# the paper's own architecture: distributed RMCE
from repro.configs import rmce                 # noqa: F401

__all__ = ["ArchSpec", "ShapeCell", "get_arch", "list_archs", "register"]
