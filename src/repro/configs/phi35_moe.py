"""phi3.5-moe-42b-a6.6b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=6400
vocab=32064, MoE 16 experts top-2.  [hf:microsoft/Phi-3.5-MoE-instruct; hf]"""
from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig


def build() -> TransformerConfig:
    return TransformerConfig(
        name="phi3.5-moe-42b-a6.6b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_head=128, d_ff=6400, vocab=32064,
        n_experts=16, top_k=2, rope_theta=10000.0)


def build_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="phi3.5-moe-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=96, vocab=256,
        n_experts=8, top_k=2, moe_group_size=64)


ARCH = register(ArchSpec(
    name="phi3.5-moe-42b-a6.6b", family="lm", build=build,
    build_smoke=build_smoke, shapes=lm_shapes,
    source="hf:microsoft/Phi-3.5-MoE-instruct; hf"))
