"""mace [gnn]: n_layers=2 d_hidden=128 l_max=2 correlation_order=3 n_rbf=8,
E(3)-equivariant higher-order message passing.  [arXiv:2206.07697; paper]"""
from repro.configs.base import ArchSpec, register
from repro.configs.gnn_shapes import gnn_shapes
from repro.models.gnn import MACEConfig


def build() -> MACEConfig:
    return MACEConfig(n_layers=2, d_hidden=128, l_max=2, correlation=3, n_rbf=8)


def build_smoke() -> MACEConfig:
    return MACEConfig(n_layers=2, d_hidden=16, l_max=2, correlation=3, n_rbf=8)


ARCH = register(ArchSpec(
    name="mace", family="gnn", build=build, build_smoke=build_smoke,
    shapes=gnn_shapes, source="arXiv:2206.07697; paper"))
