"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias.  [hf:CohereForAI/c4ai-command-r-v01;
unverified]"""
from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig


def build() -> TransformerConfig:
    return TransformerConfig(
        name="command-r-plus-104b", n_layers=64, d_model=12288, n_heads=96,
        n_kv_heads=8, d_head=128, d_ff=33792, vocab=256000,
        rope_theta=75e6, tie_embeddings=True)


def build_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="command-r-plus-smoke", n_layers=2, d_model=64, n_heads=6,
        n_kv_heads=2, d_head=16, d_ff=160, vocab=256, tie_embeddings=True)


ARCH = register(ArchSpec(
    name="command-r-plus-104b", family="lm", build=build,
    build_smoke=build_smoke, shapes=lm_shapes,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified"))
