"""qwen3-14b [dense]: 40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B; hf]"""
from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig


def build() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-14b", n_layers=40, d_model=5120, n_heads=40,
        n_kv_heads=8, d_head=128, d_ff=17408, vocab=151936,
        qk_norm=True, rope_theta=1e6)


def build_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="qwen3-14b-smoke", n_layers=2, d_model=64, n_heads=5,
        n_kv_heads=1, d_head=16, d_ff=128, vocab=256, qk_norm=True)


ARCH = register(ArchSpec(
    name="qwen3-14b", family="lm", build=build, build_smoke=build_smoke,
    shapes=lm_shapes, source="hf:Qwen/Qwen3-8B; hf"))
