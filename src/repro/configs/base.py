"""Arch registry plumbing + the LM arch family adapter."""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional

_REGISTRY: Dict[str, "ArchSpec"] = {}


@dataclasses.dataclass
class ShapeCell:
    """One (architecture × input shape) dry-run cell."""
    name: str
    kind: str                      # 'train' | 'prefill' | 'decode' | 'serve' | ...
    meta: dict
    skip_reason: Optional[str] = None


@dataclasses.dataclass
class ArchSpec:
    name: str
    family: str                                   # 'lm' | 'gnn' | 'recsys' | 'mce'
    build: Callable[[], object]                   # full-size model config
    build_smoke: Callable[[], object]             # reduced config, same family
    shapes: Callable[[object], List[ShapeCell]]   # cells for a model config
    source: str = ""                              # citation tag from the brief


def register(spec: ArchSpec) -> ArchSpec:
    _REGISTRY[spec.name] = spec
    return spec


def get_arch(name: str) -> ArchSpec:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> List[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# LM family: the four assigned shape cells
# ---------------------------------------------------------------------------

def lm_shapes(cfg) -> List[ShapeCell]:
    cells = [
        ShapeCell("train_4k", "train", dict(seq_len=4096, global_batch=256)),
        ShapeCell("prefill_32k", "prefill", dict(seq_len=32768, global_batch=32)),
        ShapeCell("decode_32k", "decode", dict(seq_len=32768, global_batch=128)),
    ]
    if cfg.sliding_window is not None:
        cells.append(ShapeCell("long_500k", "decode",
                               dict(seq_len=524288, global_batch=1)))
    else:
        cells.append(ShapeCell(
            "long_500k", "decode", dict(seq_len=524288, global_batch=1),
            skip_reason="pure full-attention arch: 512k decode needs "
                        "sub-quadratic attention (see DESIGN.md)"))
    return cells
