"""The four assigned GNN shape cells (shared by all gnn archs).

Counts are padded to multiples of 2048 (fixed-shape pipeline with masks);
`raw_*` keeps the assigned numbers. minibatch_lg carries the real sampler's
padded budgets (batch 1024, fanout 15-10 over a 233k-node graph).
"""
from __future__ import annotations

from repro.configs.base import ShapeCell


def _pad(x: int, m: int = 2048) -> int:
    return -(-x // m) * m


def gnn_shapes(cfg) -> list:
    needs_triplets = cfg.name == "dimenet"
    cap = 16

    def cell(name, kind, n, e, d_feat, n_graphs=1, note=None, **extra):
        meta = dict(
            n_nodes=_pad(n), n_edges=_pad(e), d_feat=d_feat,
            raw_nodes=n, raw_edges=e, n_graphs=n_graphs,
            n_triplets=_pad(e * cap) if needs_triplets else 0, **extra)
        return ShapeCell(name, kind, meta, skip_reason=note)

    return [
        # Cora-scale full batch [n=2708 e=10556 d=1433]
        cell("full_graph_sm", "train", 2708, 10556, 1433),
        # Reddit-scale sampled training: budgets of the fanout-15-10 sampler
        cell("minibatch_lg", "train",
             1024 * (1 + 15 + 150), 1024 * 15 + 1024 * 150, 602,
             batch_nodes=1024, fanout=(15, 10),
             full_graph=dict(n_nodes=232965, n_edges=114615892)),
        # ogbn-products full batch [n=2449029 e=61859140 d=100]
        cell("ogb_products", "train", 2449029, 61859140, 100),
        # batched small molecules [30 nodes, 64 edges, batch 128]
        cell("molecule", "train", 30 * 128, 64 * 2 * 128, 32, n_graphs=128),
    ]
