"""schnet [gnn]: n_interactions=3 d_hidden=64 rbf=300 cutoff=10.
[arXiv:1706.08566; paper]"""
from repro.configs.base import ArchSpec, register
from repro.configs.gnn_shapes import gnn_shapes
from repro.models.gnn import SchNetConfig


def build() -> SchNetConfig:
    return SchNetConfig(n_interactions=3, d_hidden=64, n_rbf=300, cutoff=10.0)


def build_smoke() -> SchNetConfig:
    return SchNetConfig(n_interactions=2, d_hidden=32, n_rbf=16, cutoff=5.0)


ARCH = register(ArchSpec(
    name="schnet", family="gnn", build=build, build_smoke=build_smoke,
    shapes=gnn_shapes, source="arXiv:1706.08566; paper"))
