"""two-tower-retrieval [recsys]: embed_dim=256 tower_mlp=1024-512-256
interaction=dot — sampled-softmax retrieval.  [RecSys'19 (YouTube); unverified]
"""
from repro.configs.base import ArchSpec, ShapeCell, register
from repro.models.recsys import TwoTowerConfig


def build() -> TwoTowerConfig:
    return TwoTowerConfig()


def build_smoke() -> TwoTowerConfig:
    return TwoTowerConfig(
        name="two-tower-smoke", embed_dim=32, tower_mlp=(64, 32),
        n_users=1024, n_items=2048, n_geo=64, n_tags=64,
        d_id=16, d_small=8, d_dense=4, hist_len=8, tags_len=4)


def recsys_shapes(cfg) -> list:
    return [
        ShapeCell("train_batch", "train", dict(batch=65536)),
        ShapeCell("serve_p99", "serve", dict(batch=512)),
        ShapeCell("serve_bulk", "bulk", dict(batch=262144)),
        ShapeCell("retrieval_cand", "retrieval",
                  dict(batch=1, n_candidates=1_000_000)),
    ]


ARCH = register(ArchSpec(
    name="two-tower-retrieval", family="recsys", build=build,
    build_smoke=build_smoke, shapes=recsys_shapes,
    source="RecSys'19 (YouTube); unverified"))
