"""chatglm3-6b [dense]: 28L d_model=4096 32H (GQA kv=2) d_ff=13696
vocab=65024 — 2-D RoPE, GQA.  [arXiv:2406.12793; hf]"""
from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig


def build() -> TransformerConfig:
    return TransformerConfig(
        name="chatglm3-6b", n_layers=28, d_model=4096, n_heads=32,
        n_kv_heads=2, d_head=128, d_ff=13696, vocab=65024,
        rope_style="2d", rotary_pct=0.5)


def build_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="chatglm3-6b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
        rope_style="2d", rotary_pct=0.5)


ARCH = register(ArchSpec(
    name="chatglm3-6b", family="lm", build=build, build_smoke=build_smoke,
    shapes=lm_shapes, source="arXiv:2406.12793; hf"))
