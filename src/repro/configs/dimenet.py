"""dimenet [gnn]: n_blocks=6 d_hidden=128 n_bilinear=8 n_spherical=7
n_radial=6.  [arXiv:2003.03123; unverified]"""
from repro.configs.base import ArchSpec, register
from repro.configs.gnn_shapes import gnn_shapes
from repro.models.gnn import DimeNetConfig


def build() -> DimeNetConfig:
    return DimeNetConfig(n_blocks=6, d_hidden=128, n_bilinear=8,
                         n_spherical=7, n_radial=6)


def build_smoke() -> DimeNetConfig:
    return DimeNetConfig(n_blocks=2, d_hidden=32, n_bilinear=4,
                         n_spherical=4, n_radial=4)


ARCH = register(ArchSpec(
    name="dimenet", family="gnn", build=build, build_smoke=build_smoke,
    shapes=gnn_shapes, source="arXiv:2003.03123; unverified"))
