"""mixtral-8x7b [moe]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8 experts top-2, sliding-window attention.  [arXiv:2401.04088; hf]"""
from repro.configs.base import ArchSpec, lm_shapes, register
from repro.models.transformer import TransformerConfig


def build() -> TransformerConfig:
    return TransformerConfig(
        name="mixtral-8x7b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_head=128, d_ff=14336, vocab=32000,
        n_experts=8, top_k=2, rope_theta=1e6, sliding_window=4096)


def build_smoke() -> TransformerConfig:
    return TransformerConfig(
        name="mixtral-8x7b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_head=16, d_ff=128, vocab=256,
        n_experts=4, top_k=2, sliding_window=32, moe_group_size=64)


ARCH = register(ArchSpec(
    name="mixtral-8x7b", family="lm", build=build, build_smoke=build_smoke,
    shapes=lm_shapes, source="arXiv:2401.04088; hf"))
