"""meshgraphnet [gnn]: n_layers=15 d_hidden=128 aggregator=sum mlp_layers=2.
[arXiv:2010.03409; unverified]"""
from repro.configs.base import ArchSpec, register
from repro.configs.gnn_shapes import gnn_shapes
from repro.models.gnn import MeshGraphNetConfig


def build() -> MeshGraphNetConfig:
    return MeshGraphNetConfig(n_layers=15, d_hidden=128, mlp_layers=2)


def build_smoke() -> MeshGraphNetConfig:
    return MeshGraphNetConfig(n_layers=3, d_hidden=32, mlp_layers=2)


ARCH = register(ArchSpec(
    name="meshgraphnet", family="gnn", build=build, build_smoke=build_smoke,
    shapes=gnn_shapes, source="arXiv:2010.03409; unverified"))
