"""Real spherical harmonics (l ≤ 2) + numerically derived Gaunt/CG couplings.

Self-contained E(3)-equivariance machinery for the MACE architecture: no
e3nn dependency in this container, so the real-basis Clebsch–Gordan (Gaunt)
coefficients are computed once, at import, by numerical quadrature of
∫ Y_{l1 m1} Y_{l2 m2} Y_{l3 m3} dΩ on a dense spherical grid. For l ≤ 2 a
128×256 product Gauss–Legendre × uniform grid is exact to ~1e-12.

Conventions: real spherical harmonics with Condon–Shortley-free real basis,
ordered m = -l..l; irrep slices concatenated [l=0 | l=1 | l=2] (dims 1,3,5).
"""
from __future__ import annotations

from functools import lru_cache

import jax.numpy as jnp
import numpy as np

L_MAX = 2
IRREP_DIMS = [2 * l + 1 for l in range(L_MAX + 1)]       # [1, 3, 5]
IRREP_OFF = np.concatenate([[0], np.cumsum(IRREP_DIMS)])  # [0,1,4,9]
SH_DIM = int(IRREP_OFF[-1])                               # 9


def real_sph_harm_l2(xyz: np.ndarray | jnp.ndarray, np_mod=jnp):
    """Real spherical harmonics Y_lm(r̂) for l=0..2. xyz: (..., 3) unit
    vectors → (..., 9). Works for numpy and jnp via np_mod."""
    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    c0 = 0.5 * np.sqrt(1.0 / np.pi)
    c1 = np.sqrt(3.0 / (4 * np.pi))
    out = [
        np_mod.full(x.shape, c0) if hasattr(np_mod, "full") else c0,
        c1 * y, c1 * z, c1 * x,
        0.5 * np.sqrt(15 / np.pi) * x * y,
        0.5 * np.sqrt(15 / np.pi) * y * z,
        0.25 * np.sqrt(5 / np.pi) * (3 * z * z - 1.0),
        0.5 * np.sqrt(15 / np.pi) * x * z,
        0.25 * np.sqrt(15 / np.pi) * (x * x - y * y),
    ]
    return np_mod.stack(out, axis=-1)


@lru_cache(maxsize=1)
def gaunt_tensor() -> np.ndarray:
    """G[i, j, k] = ∫ Y_i Y_j Y_k dΩ over the 9-dim l≤2 basis (numpy)."""
    n_theta, n_phi = 128, 256
    # Gauss-Legendre in cos(theta)
    ct, wt = np.polynomial.legendre.leggauss(n_theta)
    phi = (np.arange(n_phi) + 0.5) * (2 * np.pi / n_phi)
    wp = 2 * np.pi / n_phi
    st = np.sqrt(1 - ct ** 2)
    xyz = np.stack(
        [st[:, None] * np.cos(phi)[None, :],
         st[:, None] * np.sin(phi)[None, :],
         np.broadcast_to(ct[:, None], (n_theta, n_phi))], axis=-1)
    ys = real_sph_harm_l2(xyz, np_mod=np)          # (T, P, 9)
    w = wt[:, None] * wp                           # (T, 1)
    g = np.einsum("tpi,tpj,tpk,tp->ijk", ys, ys, ys, np.broadcast_to(w, ys.shape[:2]))
    g[np.abs(g) < 1e-10] = 0.0
    return g


def irrep_slices():
    return [slice(int(IRREP_OFF[l]), int(IRREP_OFF[l + 1]))
            for l in range(L_MAX + 1)]


def tensor_product(a: jnp.ndarray, b: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """Equivariant product: out_k = Σ_ij G[i,j,k] a_i b_j, per channel.

    a, b: (..., 9, C); g: (9, 9, 9) → (..., 9, C). The Gaunt contraction is
    the real-basis CG coupling truncated back to l ≤ 2."""
    return jnp.einsum("ijk,...ic,...jc->...kc", jnp.asarray(g, a.dtype), a, b)
