"""Two-tower retrieval model (YouTube DNN / RecSys'19 lineage).

Architecture (assigned config): embed_dim=256, tower MLP 1024-512-256,
dot-product interaction, sampled-softmax retrieval with in-batch negatives.

Substrate notes (per the brief): the hot path is the sparse embedding
LOOKUP over huge tables. JAX has no native EmbeddingBag — we implement it as
``jnp.take`` + ``jax.ops.segment_sum`` (multi-valued bag features), with the
Pallas one-hot-GEMM kernel (repro/kernels/embedding_bag) as the TPU MXU path
for per-device table shards. Tables are row-sharded over the "model" mesh
axis (mod sharding); GSPMD turns the cross-shard take into an all-to-all —
exactly the production layout of TF DLRM / TorchRec row-wise sharding.

Feature schema (fixed, production-plausible):
  user tower:  user_id (1-hot, huge table), user_geo (1-hot),
               user_hist (bag of item ids, shares the item_id table),
               user_dense (16 floats)
  item tower:  item_id (1-hot, huge table), item_cat (1-hot),
               item_tags (bag, small table)

``retrieval_cand`` scores one query against n_candidates=1e6 precomputed
item embeddings via a single batched dot + top-k (no loop).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    name: str = "two-tower-retrieval"
    embed_dim: int = 256                     # final tower output dim
    tower_mlp: Tuple[int, ...] = (1024, 512, 256)
    interaction: str = "dot"
    # sparse feature tables: rows × dim
    n_users: int = 1 << 25                   # 33.5M user ids
    n_items: int = 1 << 24                   # 16.7M item ids
    n_geo: int = 100_000
    n_tags: int = 100_000
    d_id: int = 128                          # id-table embedding dim
    d_small: int = 32                        # small-table embedding dim
    d_dense: int = 16                        # dense float features
    hist_len: int = 32                       # user history bag length
    tags_len: int = 8                        # item tag bag length
    temperature: float = 0.05
    dtype: str = "float32"

    def param_count(self) -> int:
        emb = (self.n_users * self.d_id + self.n_items * self.d_id
               + self.n_geo * self.d_small + self.n_tags * self.d_small)
        u_in = self.d_id + self.d_id + self.d_small + self.d_dense
        i_in = self.d_id + self.d_small
        mlp = 0
        for d_in, tower_in in ((u_in, True), (i_in, False)):
            dims = (d_in,) + self.tower_mlp
            mlp += sum(dims[i] * dims[i + 1] + dims[i + 1]
                       for i in range(len(dims) - 1))
        return emb + mlp


# ---------------------------------------------------------------------------
# EmbeddingBag: jnp.take + segment_sum  (THE substrate op; see module doc)
# ---------------------------------------------------------------------------

def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  mode: str = "mean") -> jnp.ndarray:
    """table: (V, D); ids: (B, L) int32, -1 = padding. Returns (B, D).

    Pure-jnp EmbeddingBag: gather rows, mask pads, reduce the bag axis.
    (segment_sum formulation: the bag axis IS the segment; a dense reshape
    reduce is identical and layout-friendlier on TPU.)
    """
    b, l = ids.shape
    valid = (ids >= 0)
    safe = jnp.where(valid, ids, 0)
    rows = jnp.take(table, safe, axis=0)                 # (B, L, D)
    rows = rows * valid[..., None].astype(rows.dtype)
    if mode == "sum":
        return rows.sum(axis=1)
    cnt = jnp.maximum(valid.sum(axis=1, keepdims=True), 1).astype(rows.dtype)
    return rows.sum(axis=1) / cnt


def embedding_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Single-valued categorical lookup: (B,) -> (B, D)."""
    return jnp.take(table, ids, axis=0)


# ---------------------------------------------------------------------------
# Params / towers
# ---------------------------------------------------------------------------

def _mlp_params(key, dims):
    ks = jax.random.split(key, len(dims) - 1)
    return {f"w{i}": dense_init(ks[i], (dims[i], dims[i + 1]))
            for i in range(len(dims) - 1)} | \
           {f"b{i}": jnp.zeros((dims[i + 1],)) for i in range(len(dims) - 1)}


def _mlp(p, x):
    n = len([k for k in p if k.startswith("w")])
    for i in range(n):
        x = x @ p[f"w{i}"].astype(x.dtype) + p[f"b{i}"].astype(x.dtype)
        if i < n - 1:
            x = jax.nn.relu(x)
    return x


def init_params(cfg: TwoTowerConfig, key) -> dict:
    ks = jax.random.split(key, 8)
    u_in = cfg.d_id + cfg.d_id + cfg.d_small + cfg.d_dense
    i_in = cfg.d_id + cfg.d_small
    return dict(
        user_id_table=dense_init(ks[0], (cfg.n_users, cfg.d_id), scale=0.02),
        item_id_table=dense_init(ks[1], (cfg.n_items, cfg.d_id), scale=0.02),
        geo_table=dense_init(ks[2], (cfg.n_geo, cfg.d_small), scale=0.02),
        tag_table=dense_init(ks[3], (cfg.n_tags, cfg.d_small), scale=0.02),
        user_mlp=_mlp_params(ks[4], (u_in,) + cfg.tower_mlp),
        item_mlp=_mlp_params(ks[5], (i_in,) + cfg.tower_mlp),
    )


def user_tower(cfg: TwoTowerConfig, params, batch) -> jnp.ndarray:
    """batch: user_id (B,), user_geo (B,), user_hist (B, L), user_dense (B, Dd)."""
    dt = jnp.dtype(cfg.dtype)
    uid = embedding_lookup(params["user_id_table"], batch["user_id"]).astype(dt)
    geo = embedding_lookup(params["geo_table"], batch["user_geo"]).astype(dt)
    hist = embedding_bag(params["item_id_table"], batch["user_hist"]).astype(dt)
    x = jnp.concatenate([uid, hist, geo, batch["user_dense"].astype(dt)], -1)
    u = _mlp(params["user_mlp"], x)
    return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)


def item_tower(cfg: TwoTowerConfig, params, batch, prefix: str = "item") -> jnp.ndarray:
    """batch: {prefix}_id (B,), {prefix}_tags (B, Lt)."""
    dt = jnp.dtype(cfg.dtype)
    iid = embedding_lookup(params["item_id_table"], batch[f"{prefix}_id"]).astype(dt)
    tags = embedding_bag(params["tag_table"], batch[f"{prefix}_tags"]).astype(dt)
    x = jnp.concatenate([iid, tags], -1)
    v = _mlp(params["item_mlp"], x)
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


# ---------------------------------------------------------------------------
# Training: sampled softmax with in-batch negatives
# ---------------------------------------------------------------------------

def retrieval_loss(cfg: TwoTowerConfig, params, batch) -> jnp.ndarray:
    """In-batch sampled softmax: positives on the diagonal of U @ I^T."""
    u = user_tower(cfg, params, batch)                       # (B, D)
    v = item_tower(cfg, params, batch)                       # (B, D)
    logits = (u @ v.T) / cfg.temperature                     # (B, B)
    b = logits.shape[0]
    # log-Q correction for in-batch sampling bias (uniform proxy): constant
    # shift — omitted (uniform negatives); labels are the diagonal.
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(
        logp, jnp.arange(b)[:, None], axis=-1))


def make_train_step(cfg: TwoTowerConfig, opt_cfg=None, lr: float = 1e-3):
    from repro.optim import AdamWConfig, adamw_update
    opt_cfg = opt_cfg or AdamWConfig(weight_decay=0.0)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: retrieval_loss(cfg, p, batch))(params)
        params, opt_state = adamw_update(params, grads, opt_state,
                                         jnp.float32(lr), opt_cfg)
        return params, opt_state, loss
    return train_step


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def make_serve_step(cfg: TwoTowerConfig):
    """Online scoring: user tower + dot against per-request candidate embs."""

    def serve_step(params, batch):
        u = user_tower(cfg, params, batch)                   # (B, D)
        cand = batch["cand_emb"]                             # (B, C, D)
        return jnp.einsum("bd,bcd->bc", u, cand.astype(u.dtype))
    return serve_step


def make_bulk_score_step(cfg: TwoTowerConfig):
    """Offline scoring: full forward of both towers + elementwise dot."""

    def bulk_step(params, batch):
        u = user_tower(cfg, params, batch)
        v = item_tower(cfg, params, batch)
        return jnp.sum(u * v, axis=-1)
    return bulk_step


def make_retrieval_step(cfg: TwoTowerConfig, top_k: int = 100):
    """One query vs n_candidates≈1e6: item tower over the candidate corpus
    shard + batched dot + global top-k. No loop over candidates."""

    def retrieval_step(params, batch):
        u = user_tower(cfg, params, batch)                   # (1, D)
        v = item_tower(cfg, params, batch, prefix="cand")    # (C, D)
        scores = (v @ u[0]).astype(jnp.float32)              # (C,)
        return jax.lax.top_k(scores, top_k)
    return retrieval_step


# ---------------------------------------------------------------------------
# Synthetic batches + ShapeDtypeStruct specs (dry-run)
# ---------------------------------------------------------------------------

def synth_batch(cfg: TwoTowerConfig, batch: int, seed: int = 0,
                with_items: bool = True) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    out = dict(
        user_id=rng.integers(0, cfg.n_users, batch).astype(np.int32),
        user_geo=rng.integers(0, cfg.n_geo, batch).astype(np.int32),
        user_hist=np.where(
            rng.random((batch, cfg.hist_len)) < 0.8,
            rng.integers(0, cfg.n_items, (batch, cfg.hist_len)), -1
        ).astype(np.int32),
        user_dense=rng.normal(size=(batch, cfg.d_dense)).astype(np.float32),
    )
    if with_items:
        out["item_id"] = rng.integers(0, cfg.n_items, batch).astype(np.int32)
        out["item_tags"] = np.where(
            rng.random((batch, cfg.tags_len)) < 0.7,
            rng.integers(0, cfg.n_tags, (batch, cfg.tags_len)), -1
        ).astype(np.int32)
    return out


def batch_spec(cfg: TwoTowerConfig, kind: str, batch: int,
               n_candidates: int = 0) -> Dict[str, jax.ShapeDtypeStruct]:
    f32, i32 = jnp.float32, jnp.int32
    user = dict(
        user_id=jax.ShapeDtypeStruct((batch,), i32),
        user_geo=jax.ShapeDtypeStruct((batch,), i32),
        user_hist=jax.ShapeDtypeStruct((batch, cfg.hist_len), i32),
        user_dense=jax.ShapeDtypeStruct((batch, cfg.d_dense), f32),
    )
    if kind == "train" or kind == "bulk":
        return user | dict(
            item_id=jax.ShapeDtypeStruct((batch,), i32),
            item_tags=jax.ShapeDtypeStruct((batch, cfg.tags_len), i32),
        )
    if kind == "serve":
        return user | dict(
            cand_emb=jax.ShapeDtypeStruct(
                (batch, 256, cfg.tower_mlp[-1]), f32))
    if kind == "retrieval":
        return user | dict(
            cand_id=jax.ShapeDtypeStruct((n_candidates,), i32),
            cand_tags=jax.ShapeDtypeStruct((n_candidates, cfg.tags_len), i32),
        )
    raise ValueError(kind)
