"""Model stacks: LM transformers, GNNs, recsys towers, MCE-as-arch."""
