"""Decoder-only LM transformer covering the five assigned LM architectures.

One parameterised implementation:
  * GQA with arbitrary (n_heads, n_kv_heads),
  * RoPE (standard / partial / ChatGLM 2-D), configurable theta,
  * optional per-head qk RMS-norm (Qwen3),
  * optional sliding-window attention + rolling KV cache (Mixtral),
  * dense GLU FFN or GShard-style top-k MoE (Mixtral, Phi-3.5-MoE),
  * bias-free projections (all five archs are no-bias),
  * scan-over-layers with configurable remat policy.

Forward modes:
  * `forward(params, tokens)`            — training / prefill logits,
  * `prefill(params, tokens)`            — logits + KV cache,
  * `decode_step(params, cache, token)`  — single-token serve step.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    # MoE (None -> dense)
    n_experts: Optional[int] = None
    top_k: int = 2
    capacity_factor: float = 1.25
    # attention details
    rope_theta: float = 10000.0
    rope_style: str = "neox"            # 'neox' | '2d'
    rotary_pct: float = 1.0
    qk_norm: bool = False
    sliding_window: Optional[int] = None
    # misc
    act: str = "silu"
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    remat: str = "nothing_saveable"     # 'none' | 'nothing_saveable' | 'dots'
    moe_group_size: int = 1024
    # roofline calibration: unroll the layer/KV scans so XLA cost analysis
    # (which counts while bodies ONCE) sees every iteration. Never used for
    # real training (compile-time cost); see launch/dryrun.py --calibrated.
    unroll_scans: bool = False
    # chunked cross-entropy: compute log-softmax over sequence chunks of this
    # size (0 = whole sequence at once). Cuts logits activation memory from
    # O(B·S·V) to O(B·chunk·V); the backward recomputes per chunk.
    loss_chunk: int = 0
    # activation sharding constraints (§Perf optimization): (dp_axes, tp_axis,
    # heads_tp) — when set, activations are pinned batch-parallel over
    # dp_axes and Megatron-TP over tp_axis (heads/d_ff/vocab), preventing
    # GSPMD from replicating the batch when weight shardings conflict
    # (observed on qwen3: 40 heads % 16 != 0 → replicated attention).
    shard_hints: Optional[Tuple] = None
    # recompute flash-block internals in bwd instead of saving the
    # (n_blocks, B, H, Sq, KV) probability stacks (§Perf optimization)
    remat_blocks: bool = False

    @property
    def is_moe(self) -> bool:
        return self.n_experts is not None

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads

    def param_count(self) -> int:
        d, f, v = self.d_model, self.d_ff, self.vocab
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head \
            + self.n_heads * self.d_head * d
        if self.is_moe:
            ffn = self.n_experts * 3 * d * f + d * self.n_experts
        else:
            ffn = 3 * d * f
        per_layer = attn + ffn + 2 * d + (2 * self.n_heads * 0)
        emb = v * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top_k experts count)."""
        if not self.is_moe:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        attn = d * (self.n_heads + 2 * self.n_kv_heads) * self.d_head \
            + self.n_heads * self.d_head * d
        ffn = self.top_k * 3 * d * f + d * self.n_experts
        per_layer = attn + ffn + 2 * d
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + emb + d


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_params(cfg: TransformerConfig, key) -> dict:
    keys = jax.random.split(key, 12)
    d, hd = cfg.d_model, cfg.d_head
    nl = cfg.n_layers

    def li(k, *shape, scale=None):
        return L.dense_init(k, (nl,) + shape, scale)

    layer = dict(
        ln_attn=jnp.ones((nl, d), jnp.float32),
        wq=li(keys[0], d, cfg.n_heads, hd),
        wk=li(keys[1], d, cfg.n_kv_heads, hd),
        wv=li(keys[2], d, cfg.n_kv_heads, hd),
        wo=li(keys[3], cfg.n_heads, hd, d, scale=1.0 / np.sqrt(cfg.n_heads * hd)),
        ln_ffn=jnp.ones((nl, d), jnp.float32),
    )
    if cfg.qk_norm:
        layer["q_norm"] = jnp.ones((nl, hd), jnp.float32)
        layer["k_norm"] = jnp.ones((nl, hd), jnp.float32)
    if cfg.is_moe:
        e = cfg.n_experts
        layer.update(
            router=li(keys[4], d, e, scale=0.02),
            w_in=li(keys[5], e, d, cfg.d_ff, scale=1.0 / np.sqrt(d)),
            w_gate=li(keys[6], e, d, cfg.d_ff, scale=1.0 / np.sqrt(d)),
            w_out=li(keys[7], e, cfg.d_ff, d, scale=1.0 / np.sqrt(cfg.d_ff)),
        )
    else:
        layer.update(
            w_in=li(keys[5], d, cfg.d_ff),
            w_gate=li(keys[6], d, cfg.d_ff),
            w_out=li(keys[7], cfg.d_ff, d, scale=1.0 / np.sqrt(cfg.d_ff)),
        )
    params = dict(
        embed=L.dense_init(keys[8], (cfg.vocab, d), scale=1.0),
        layers=layer,
        ln_final=jnp.ones((d,), jnp.float32),
    )
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(keys[9], (d, cfg.vocab))
    return params


# ---------------------------------------------------------------------------
# Layer body (shared by train / prefill / decode via kv-cache kwargs)
# ---------------------------------------------------------------------------

def _hint(cfg: TransformerConfig, x, kind: str):
    """Apply an activation sharding constraint (no-op without hints).

    shard_hints = (dp_axes, tp_axis, heads_tp, ctx_parallel):
      heads_tp      — shard attention heads over tp (requires divisibility);
      ctx_parallel  — shard the QUERY sequence dim over tp instead (context
                      parallelism; legal for causal flash streaming since
                      every query row consumes the same KV stream). Used
                      when head count does not divide the tp axis (qwen3).
    """
    if cfg.shard_hints is None:
        return x
    from jax.sharding import PartitionSpec as P
    h = cfg.shard_hints
    dp, tp, heads_tp = h[:3]
    ctx = h[3] if len(h) > 3 else False
    ffn_tp = h[4] if len(h) > 4 else True
    seq_res = h[5] if len(h) > 5 else False
    q_spec = (P(dp, None, tp, None) if heads_tp else
              P(dp, tp, None, None) if ctx else
              P(dp, None, None, None))
    spec = {
        # seq_res: Megatron sequence parallelism — the residual stream stays
        # sequence-sharded between blocks; GSPMD decomposes the TP
        # all-reduces into reduce-scatter + all-gather pairs around it
        "tokens3d": P(dp, tp, None) if seq_res else P(dp, None, None),
        "heads": q_spec,                                     # (B, S, H, dh)
        "kv": P(dp, None, None, None),                       # (B, S, KV, dh)
        # ffn_tp=False: ZeRO-style — weights gathered at use, activations
        # stay batch-parallel (wins when B·S·D ≫ D·F per layer)
        "ffn": P(dp, None, tp) if ffn_tp else P(dp, None, None),
        "logits": P(dp, None, tp),                           # (B, S, V)
    }[kind]
    return jax.lax.with_sharding_constraint(x, spec)


def _attention(cfg: TransformerConfig, lp, x, positions, *, cache_kv=None,
               q_offset=0, valid_kv=None, kv_block=1024):
    """x: (B, S, D). Returns (out, (k, v) of this call)."""
    dt = x.dtype
    h = L.rms_norm(x, lp["ln_attn"], cfg.norm_eps)
    q = _hint(cfg, jnp.einsum("bsd,dhk->bshk", h, lp["wq"].astype(dt)),
              "heads")
    k = _hint(cfg, jnp.einsum("bsd,dhk->bshk", h, lp["wk"].astype(dt)), "kv")
    v = _hint(cfg, jnp.einsum("bsd,dhk->bshk", h, lp["wv"].astype(dt)), "kv")
    if cfg.qk_norm:
        q = L.rms_norm(q, lp["q_norm"], cfg.norm_eps)
        k = L.rms_norm(k, lp["k_norm"], cfg.norm_eps)
    d_rot = int(cfg.d_head * cfg.rotary_pct)
    if cfg.rope_style == "2d":
        # ChatGLM 2-D RoPE: two position channels drive the two halves of
        # the rotary dims; causal LM uses (pos, 0) channels.
        half = d_rot // 2
        inv1 = L.rope_freqs(cfg.d_head, cfg.rope_theta, half)
        pos_c, blk_c = positions, jnp.zeros_like(positions)
        q = L.apply_rope(q, pos_c, inv1, half)
        k = L.apply_rope(k, pos_c, inv1, half)
        # second channel is zeros for pure causal data: no-op rotation
    else:
        inv = L.rope_freqs(cfg.d_head, cfg.rope_theta, d_rot)
        q = L.apply_rope(q, positions, inv, d_rot)
        k = L.apply_rope(k, positions, inv, d_rot)

    if cache_kv is not None:
        k_all, v_all = cache_kv
    else:
        k_all, v_all = k, v
    k_exp = L.repeat_kv(k_all, cfg.q_per_kv)
    v_exp = L.repeat_kv(v_all, cfg.q_per_kv)
    out = L.blockwise_attention(
        q, k_exp, v_exp, causal=(cache_kv is None), q_offset=q_offset,
        window=cfg.sliding_window, valid_kv=valid_kv, kv_block=kv_block,
        unroll=cfg.unroll_scans, remat_blocks=cfg.remat_blocks)
    out = jnp.einsum("bshk,hkd->bsd", out, lp["wo"].astype(dt))
    return _hint(cfg, out, "tokens3d"), (k, v)


def _ffn(cfg: TransformerConfig, lp, x):
    h = L.rms_norm(x, lp["ln_ffn"], cfg.norm_eps)
    if cfg.is_moe:
        y, aux = L.moe_ffn(h, lp["router"], lp["w_in"], lp["w_gate"],
                           lp["w_out"], top_k=cfg.top_k,
                           capacity_factor=cfg.capacity_factor,
                           group_size=cfg.moe_group_size, act=cfg.act)
        return _hint(cfg, y, "tokens3d"), aux
    hint = (lambda t: _hint(cfg, t, "ffn")) if cfg.shard_hints else None
    y = L.glu_ffn(h, lp["w_in"], lp["w_gate"], lp["w_out"], cfg.act,
                  hint=hint)
    return _hint(cfg, y, "tokens3d"), 0.0


def _layer(cfg: TransformerConfig, lp, x, positions, **kw):
    x = _hint(cfg, x, "tokens3d")
    a, kv = _attention(cfg, lp, x, positions, **kw)
    x = x + a
    f, aux = _ffn(cfg, lp, x)
    return x + f, aux, kv


def _remat_wrap(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if cfg.remat == "dots" else
              jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(fn, policy=policy)


# ---------------------------------------------------------------------------
# Forward modes
# ---------------------------------------------------------------------------

def forward(cfg: TransformerConfig, params, tokens) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training forward. tokens: (B, S) -> (logits (B,S,V) fp32, aux_loss)."""
    dt = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    x = _hint(cfg, params["embed"][tokens].astype(dt), "tokens3d")
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, lp):
        x, aux = carry
        x2, aux2, _ = _layer(cfg, lp, x, positions)
        return (x2, aux + aux2), None

    body = _remat_wrap(cfg, body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["layers"],
                               unroll=cfg.n_layers if cfg.unroll_scans else 1)
    x = L.rms_norm(x, params["ln_final"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"].T if cfg.tie_embeddings else None)
    logits = _hint(cfg, jnp.einsum("bsd,dv->bsv", x, head.astype(dt)),
                   "logits")
    return logits.astype(jnp.float32), aux / cfg.n_layers


def lm_loss(cfg: TransformerConfig, params, tokens, targets,
            aux_weight: float = 0.01):
    if not cfg.loss_chunk:
        logits, aux = forward(cfg, params, tokens)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        return jnp.mean(nll) + aux_weight * aux
    return _lm_loss_chunked(cfg, params, tokens, targets, aux_weight)


def _lm_loss_chunked(cfg: TransformerConfig, params, tokens, targets,
                     aux_weight: float):
    """Memory-lean loss: run the trunk once, then compute the vocab
    projection + log-softmax per sequence chunk under remat, so the (B, S, V)
    logits tensor is never materialised (beyond one chunk). This is the
    standard chunked-cross-entropy trick for huge-vocab LMs."""
    dt = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    x = params["embed"][tokens].astype(dt)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None], (b, s))

    def body(carry, lp):
        x, aux = carry
        x2, aux2, _ = _layer(cfg, lp, x, positions)
        return (x2, aux + aux2), None

    body = _remat_wrap(cfg, body)
    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["layers"],
                               unroll=cfg.n_layers if cfg.unroll_scans else 1)
    x = L.rms_norm(x, params["ln_final"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"].T if cfg.tie_embeddings else None)

    c = cfg.loss_chunk
    n_chunks = -(-s // c)
    s_pad = n_chunks * c
    if s_pad != s:
        x = jnp.pad(x, ((0, 0), (0, s_pad - s), (0, 0)))
        targets = jnp.pad(targets, ((0, 0), (0, s_pad - s)))
    xc = x.reshape(b, n_chunks, c, -1).swapaxes(0, 1)        # (C, B, c, D)
    tc = targets.reshape(b, n_chunks, c).swapaxes(0, 1)

    @jax.checkpoint
    def chunk_nll(xi, ti):
        logits = _hint(cfg, jnp.einsum("bcd,dv->bcv", xi, head.astype(dt)),
                       "logits").astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return jnp.sum(-jnp.take_along_axis(logp, ti[..., None],
                                            axis=-1)[..., 0])

    def scan_body(tot, inp):
        xi, ti = inp
        return tot + chunk_nll(xi, ti), None

    total, _ = jax.lax.scan(scan_body, jnp.float32(0.0), (xc, tc),
                            unroll=n_chunks if cfg.unroll_scans else 1)
    return total / (b * s) + aux_weight * aux / cfg.n_layers


# ---- serving --------------------------------------------------------------

def cache_len(cfg: TransformerConfig, seq_len: int) -> int:
    """Rolling SWA caches hold only the window (Mixtral rolling buffer)."""
    if cfg.sliding_window is not None:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_cache(cfg: TransformerConfig, batch: int, seq_len: int, dtype=None):
    dt = dtype or jnp.dtype(cfg.dtype)
    c = cache_len(cfg, seq_len)
    shape = (cfg.n_layers, batch, c, cfg.n_kv_heads, cfg.d_head)
    return dict(k=jnp.zeros(shape, dt), v=jnp.zeros(shape, dt),
                pos=jnp.zeros((), jnp.int32))


def decode_step(cfg: TransformerConfig, params, cache, token):
    """token: (B, 1) int32. Returns (logits (B,1,V), new cache).

    The cache position `cache.pos` is the number of tokens already inside.
    Rolling (SWA) caches wrap modulo the window."""
    dt = jnp.dtype(cfg.dtype)
    b = token.shape[0]
    c = cache["k"].shape[2]
    pos = cache["pos"]
    x = params["embed"][token].astype(dt)
    positions = jnp.full((b, 1), pos, jnp.int32)
    slot = pos % c if cfg.sliding_window is not None else pos
    n_filled = jnp.minimum(pos + 1, c)
    valid = (jnp.arange(c)[None, :] < n_filled) & jnp.ones((b, 1), bool)

    def body(x, inputs):
        lp, k_l, v_l = inputs
        # write slot first, then attend over the filled prefix
        a_in = x

        def attn_with_cache(xx):
            h = L.rms_norm(xx, lp["ln_attn"], cfg.norm_eps)
            q = jnp.einsum("bsd,dhk->bshk", h, lp["wq"].astype(dt))
            k = jnp.einsum("bsd,dhk->bshk", h, lp["wk"].astype(dt))
            v = jnp.einsum("bsd,dhk->bshk", h, lp["wv"].astype(dt))
            if cfg.qk_norm:
                q = L.rms_norm(q, lp["q_norm"], cfg.norm_eps)
                k = L.rms_norm(k, lp["k_norm"], cfg.norm_eps)
            d_rot = int(cfg.d_head * cfg.rotary_pct)
            if cfg.rope_style == "2d":
                half = d_rot // 2
                inv1 = L.rope_freqs(cfg.d_head, cfg.rope_theta, half)
                q = L.apply_rope(q, positions, inv1, half)
                k = L.apply_rope(k, positions, inv1, half)
            else:
                inv = L.rope_freqs(cfg.d_head, cfg.rope_theta, d_rot)
                q = L.apply_rope(q, positions, inv, d_rot)
                k = L.apply_rope(k, positions, inv, d_rot)
            k_new = jax.lax.dynamic_update_slice(
                k_l, k.astype(k_l.dtype), (0, slot, 0, 0))
            v_new = jax.lax.dynamic_update_slice(
                v_l, v.astype(v_l.dtype), (0, slot, 0, 0))
            k_exp = L.repeat_kv(k_new, cfg.q_per_kv)
            v_exp = L.repeat_kv(v_new, cfg.q_per_kv)
            if cfg.sliding_window is None:
                out = L.blockwise_attention(
                    q, k_exp, v_exp, causal=False, valid_kv=valid,
                    kv_block=2048, unroll=cfg.unroll_scans)
            else:
                # rolling buffer: every filled slot is within the window by
                # construction; position masking is handled by validity
                out = L.blockwise_attention(
                    q, k_exp, v_exp, causal=False, valid_kv=valid,
                    kv_block=min(2048, c), unroll=cfg.unroll_scans)
            out = jnp.einsum("bshk,hkd->bsd", out, lp["wo"].astype(dt))
            return out, k_new, v_new

        a, k_new, v_new = attn_with_cache(a_in)
        x = x + a
        f, _ = _ffn(cfg, lp, x)
        return x + f, (k_new, v_new)

    x, kvs = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]),
                          unroll=cfg.n_layers if cfg.unroll_scans else 1)
    x = L.rms_norm(x, params["ln_final"], cfg.norm_eps)
    head = params.get("lm_head", params["embed"].T if cfg.tie_embeddings else None)
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(dt))
    new_cache = dict(k=kvs[0], v=kvs[1], pos=pos + 1)
    return logits.astype(jnp.float32), new_cache
