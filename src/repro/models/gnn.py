"""GNN architectures: MeshGraphNet, SchNet, DimeNet, MACE.

All four share one batch format (`GraphBatch`) with fixed, padded shapes:
  * node features (N, F) + positions (N, 3) + validity masks,
  * directed edge list (src, dst) with mask,
  * triplet list (edge_kj, edge_ji) with mask for the angular archs
    (DimeNet / MACE correlation terms),
  * graph_id per node for batched-small-graph pooling.

Message passing is `jax.ops.segment_sum` over the edge list — JAX's sparse
substrate (see repro/kernels/segment_spmm for the MXU dense path used by the
molecule shape). Tasks: node regression (MeshGraphNet, minibatch
classification) and graph-level energy regression (SchNet/DimeNet/MACE),
matching each family's canonical use.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import equivariant as E3
from repro.models.layers import dense_init, layer_norm


# ---------------------------------------------------------------------------
# Batch format
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class GraphShapes:
    n_nodes: int
    n_edges: int
    d_feat: int
    n_triplets: int = 0
    n_graphs: int = 1


def batch_spec(shapes: GraphShapes, dtype=jnp.float32) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the dry-run."""
    s = dict(
        node_feat=jax.ShapeDtypeStruct((shapes.n_nodes, shapes.d_feat), dtype),
        positions=jax.ShapeDtypeStruct((shapes.n_nodes, 3), dtype),
        node_mask=jax.ShapeDtypeStruct((shapes.n_nodes,), jnp.bool_),
        src=jax.ShapeDtypeStruct((shapes.n_edges,), jnp.int32),
        dst=jax.ShapeDtypeStruct((shapes.n_edges,), jnp.int32),
        edge_mask=jax.ShapeDtypeStruct((shapes.n_edges,), jnp.bool_),
        graph_id=jax.ShapeDtypeStruct((shapes.n_nodes,), jnp.int32),
        targets=jax.ShapeDtypeStruct((shapes.n_nodes,), dtype),
    )
    if shapes.n_triplets:
        s["trip_kj"] = jax.ShapeDtypeStruct((shapes.n_triplets,), jnp.int32)
        s["trip_ji"] = jax.ShapeDtypeStruct((shapes.n_triplets,), jnp.int32)
        s["trip_mask"] = jax.ShapeDtypeStruct((shapes.n_triplets,), jnp.bool_)
    return s


def mlp_params(key, dims: List[int], name: str = "mlp") -> dict:
    ks = jax.random.split(key, len(dims) - 1)
    return {f"w{i}": dense_init(ks[i], (dims[i], dims[i + 1]))
            for i in range(len(dims) - 1)} | \
           {f"b{i}": jnp.zeros((dims[i + 1],)) for i in range(len(dims) - 1)}


def mlp_apply(p: dict, x, act=jax.nn.silu, final_act: bool = False):
    n = len([k for k in p if k.startswith("w")])
    for i in range(n):
        x = x @ p[f"w{i}"].astype(x.dtype) + p[f"b{i}"].astype(x.dtype)
        if i < n - 1 or final_act:
            x = act(x)
    return x


def seg_sum(msgs, dst, n):
    return jax.ops.segment_sum(msgs, dst, num_segments=n)


def _edge_vectors(batch):
    pos = batch["positions"]
    vec = pos[batch["dst"]] - pos[batch["src"]]
    dist = jnp.linalg.norm(vec + 1e-12, axis=-1)
    return vec, dist


def rbf_expand(dist, n_rbf: int, cutoff: float):
    """Gaussian radial basis on [0, cutoff] (SchNet-style)."""
    centers = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = n_rbf / cutoff
    return jnp.exp(-gamma * jnp.square(dist[..., None] - centers))


def bessel_rbf(dist, n_rbf: int, cutoff: float):
    """DimeNet spherical Bessel radial basis."""
    d = jnp.clip(dist, 1e-6, cutoff)[..., None]
    n = jnp.arange(1, n_rbf + 1)
    return jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * d / cutoff) / d


def cosine_cutoff(dist, cutoff: float):
    return 0.5 * (jnp.cos(jnp.pi * jnp.clip(dist / cutoff, 0, 1)) + 1.0)


# ===========================================================================
# MeshGraphNet  [arXiv:2010.03409]
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class MeshGraphNetConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_out: int = 1
    aggregator: str = "sum"


def mgn_init(cfg: MeshGraphNetConfig, key, d_feat: int) -> dict:
    ks = jax.random.split(key, 4 + cfg.n_layers * 2)
    h = cfg.d_hidden
    hidden = [h] * cfg.mlp_layers
    p = dict(
        enc_node=mlp_params(ks[0], [d_feat] + hidden),
        enc_edge=mlp_params(ks[1], [4] + hidden),   # (vec, |vec|)
        dec=mlp_params(ks[2], hidden + [cfg.d_out]),
        blocks=[],
    )
    blocks = []
    for i in range(cfg.n_layers):
        blocks.append(dict(
            edge=mlp_params(ks[3 + 2 * i], [3 * h] + hidden),
            node=mlp_params(ks[4 + 2 * i], [2 * h] + hidden),
            ln_e=jnp.ones((h,)), ln_e_b=jnp.zeros((h,)),
            ln_n=jnp.ones((h,)), ln_n_b=jnp.zeros((h,)),
        ))
    p["blocks"] = blocks
    return p


def mgn_forward(cfg: MeshGraphNetConfig, params, batch):
    n = batch["node_feat"].shape[0]
    vec, dist = _edge_vectors(batch)
    e_feat = jnp.concatenate([vec, dist[:, None]], axis=-1)
    h = mlp_apply(params["enc_node"], batch["node_feat"], final_act=True)
    e = mlp_apply(params["enc_edge"], e_feat, final_act=True)
    emask = batch["edge_mask"][:, None]
    for blk in params["blocks"]:
        msg_in = jnp.concatenate([e, h[batch["src"]], h[batch["dst"]]], axis=-1)
        e = e + layer_norm(mlp_apply(blk["edge"], msg_in),
                           blk["ln_e"], blk["ln_e_b"])
        agg = seg_sum(e * emask, batch["dst"], n)
        h = h + layer_norm(mlp_apply(blk["node"],
                                     jnp.concatenate([h, agg], axis=-1)),
                           blk["ln_n"], blk["ln_n_b"])
    return mlp_apply(params["dec"], h)[..., 0]      # node-level output


# ===========================================================================
# SchNet  [arXiv:1706.08566]
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0


def ssp(x):  # shifted softplus
    return jax.nn.softplus(x) - np.log(2.0)


def schnet_init(cfg: SchNetConfig, key, d_feat: int) -> dict:
    ks = jax.random.split(key, 2 + cfg.n_interactions * 4)
    h = cfg.d_hidden
    p = dict(embed=mlp_params(ks[0], [d_feat, h]),
             out=mlp_params(ks[1], [h, h // 2, 1]), blocks=[])
    for i in range(cfg.n_interactions):
        p["blocks"].append(dict(
            filt=mlp_params(ks[2 + 4 * i], [cfg.n_rbf, h, h]),
            in_dense=mlp_params(ks[3 + 4 * i], [h, h]),
            out_dense=mlp_params(ks[4 + 4 * i], [h, h, h]),
        ))
    return p


def schnet_forward(cfg: SchNetConfig, params, batch):
    n = batch["node_feat"].shape[0]
    _, dist = _edge_vectors(batch)
    rbf = rbf_expand(dist, cfg.n_rbf, cfg.cutoff)
    fcut = cosine_cutoff(dist, cfg.cutoff) * batch["edge_mask"]
    h = mlp_apply(params["embed"], batch["node_feat"])
    for blk in params["blocks"]:
        w = mlp_apply(blk["filt"], rbf, act=ssp, final_act=True) * fcut[:, None]
        x = mlp_apply(blk["in_dense"], h)
        msgs = x[batch["src"]] * w                 # cfconv
        agg = seg_sum(msgs, batch["dst"], n)
        h = h + mlp_apply(blk["out_dense"], agg, act=ssp)
    atom_e = mlp_apply(params["out"], h, act=ssp)[..., 0]
    return atom_e * batch["node_mask"]              # per-atom energies


def pool_energy(atom_e, graph_id, n_graphs: int):
    return jax.ops.segment_sum(atom_e, graph_id, num_segments=n_graphs)


# ===========================================================================
# DimeNet  [arXiv:2003.03123]
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0


def _angular_basis(cos_angle, n_spherical: int):
    """Chebyshev angular basis T_k(cosθ) — stands in for the spherical
    Bessel × Legendre 2-D basis of the paper (same span for fixed radius)."""
    out = [jnp.ones_like(cos_angle), cos_angle]
    for _ in range(2, n_spherical):
        out.append(2 * cos_angle * out[-1] - out[-2])
    return jnp.stack(out[:n_spherical], axis=-1)


def dimenet_init(cfg: DimeNetConfig, key, d_feat: int) -> dict:
    ks = jax.random.split(key, 4 + cfg.n_blocks * 6)
    h = cfg.d_hidden
    p = dict(
        embed_node=mlp_params(ks[0], [d_feat, h]),
        embed_edge=mlp_params(ks[1], [2 * h + cfg.n_radial, h]),
        out=mlp_params(ks[2], [h, h, 1]),
        blocks=[],
    )
    sbf_dim = cfg.n_spherical * cfg.n_radial
    for i in range(cfg.n_blocks):
        p["blocks"].append(dict(
            w_sbf=dense_init(ks[3 + 6 * i], (sbf_dim, cfg.n_bilinear)),
            w_bilin=dense_init(ks[4 + 6 * i], (cfg.n_bilinear, h, h)) * 0.1,
            w_rbf=dense_init(ks[5 + 6 * i], (cfg.n_radial, h)),
            msg=mlp_params(ks[6 + 6 * i], [h, h]),
            upd=mlp_params(ks[7 + 6 * i], [h, h]),
        ))
    return p


def dimenet_forward(cfg: DimeNetConfig, params, batch):
    n = batch["node_feat"].shape[0]
    e = batch["src"].shape[0]
    vec, dist = _edge_vectors(batch)
    rbf = bessel_rbf(dist, cfg.n_radial, cfg.cutoff) * batch["edge_mask"][:, None]
    h = mlp_apply(params["embed_node"], batch["node_feat"])
    m = mlp_apply(params["embed_edge"], jnp.concatenate(
        [h[batch["src"]], h[batch["dst"]], rbf], axis=-1), final_act=True)

    # triplet angles: for triplet (kj, ji): angle between edge kj and ji
    kj, ji = batch["trip_kj"], batch["trip_ji"]
    vkj = vec[kj]
    vji = vec[ji]
    cosang = jnp.sum(vkj * vji, -1) / (
        jnp.linalg.norm(vkj + 1e-12, axis=-1) * jnp.linalg.norm(vji + 1e-12, axis=-1))
    ang = _angular_basis(jnp.clip(cosang, -1, 1), cfg.n_spherical)
    sbf = (ang[:, :, None] * rbf[kj][:, None, :]).reshape(ang.shape[0], -1)
    tmask = batch["trip_mask"][:, None]

    for blk in params["blocks"]:
        # directional message passing over triplets
        a = sbf @ blk["w_sbf"].astype(sbf.dtype)               # (T, nb)
        mk = mlp_apply(blk["msg"], m)[kj]                       # (T, H)
        inter = jnp.einsum("tb,bhg,th->tg", a, blk["w_bilin"].astype(a.dtype), mk)
        agg = seg_sum(inter * tmask, ji, e)
        m = m + agg + mlp_apply(blk["upd"],
                                m * (rbf @ blk["w_rbf"].astype(m.dtype)))
    atom = seg_sum(m * batch["edge_mask"][:, None], batch["dst"], n)
    return (mlp_apply(params["out"], atom, final_act=False)[..., 0]
            * batch["node_mask"])


# ===========================================================================
# MACE  [arXiv:2206.07697]
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class MACEConfig:
    name: str = "mace"
    n_layers: int = 2
    d_hidden: int = 128
    l_max: int = 2
    correlation: int = 3
    n_rbf: int = 8
    cutoff: float = 5.0


def mace_init(cfg: MACEConfig, key, d_feat: int) -> dict:
    ks = jax.random.split(key, 3 + cfg.n_layers * 5)
    h = cfg.d_hidden
    p = dict(embed=mlp_params(ks[0], [d_feat, h]),
             readout=mlp_params(ks[1], [h, h // 2, 1]), blocks=[])
    for i in range(cfg.n_layers):
        p["blocks"].append(dict(
            radial=mlp_params(ks[2 + 5 * i], [cfg.n_rbf, h, h]),
            w_msg=dense_init(ks[3 + 5 * i], (h, h)),
            # per-correlation-order mixing weights (product basis)
            w_prod=[dense_init(k2, (h, h)) * 0.5
                    for k2 in jax.random.split(ks[4 + 5 * i], cfg.correlation)],
            w_upd=dense_init(ks[5 + 5 * i], (h, h)),
        ))
    return p


def mace_forward(cfg: MACEConfig, params, batch):
    """Equivariant message passing with Gaunt tensor products.

    Node state: (N, 9, H) — l≤2 irreps × channels. Scalar (l=0) slice is the
    invariant readout channel. correlation_order=3 is realised as iterated
    Gaunt products of the aggregated A-features (MACE product basis,
    truncated to l ≤ 2)."""
    g = jnp.asarray(E3.gaunt_tensor(), batch["positions"].dtype)
    n = batch["node_feat"].shape[0]
    vec, dist = _edge_vectors(batch)
    unit = vec / jnp.maximum(dist[:, None], 1e-9)
    sh = E3.real_sph_harm_l2(unit)                           # (E, 9)
    rbf = bessel_rbf(dist, cfg.n_rbf, cfg.cutoff)
    fcut = (cosine_cutoff(dist, cfg.cutoff) * batch["edge_mask"])[:, None]

    h0 = mlp_apply(params["embed"], batch["node_feat"])      # (N, H)
    state = jnp.zeros((n, E3.SH_DIM, h0.shape[-1]), h0.dtype)
    state = state.at[:, 0, :].set(h0)

    for blk in params["blocks"]:
        r = mlp_apply(blk["radial"], rbf, final_act=True) * fcut   # (E, H)
        # message: R(r) · (Y(r̂) ⊗ h_j), Gaunt-coupled to l≤2
        hj = state[batch["src"]]                              # (E, 9, H)
        hj = jnp.einsum("...ic,cd->...id", hj, blk["w_msg"].astype(hj.dtype))
        sh_c = jnp.broadcast_to(sh[:, :, None], hj.shape)
        msg = E3.tensor_product(sh_c, hj, g) * r[:, None, :]
        a = seg_sum(msg, batch["dst"], n)                     # (N, 9, H)
        # product basis: B = Σ_ν w_ν · a^(⊗ν) (iterated Gaunt products)
        b = jnp.zeros_like(a)
        prod = a
        for nu, w in enumerate(blk["w_prod"]):
            b = b + jnp.einsum("...ic,cd->...id", prod, w.astype(a.dtype))
            if nu + 1 < len(blk["w_prod"]):
                prod = E3.tensor_product(prod, a, g)
        state = state + jnp.einsum("...ic,cd->...id", b,
                                   blk["w_upd"].astype(b.dtype))
    inv = state[:, 0, :]                                      # invariant slice
    return (mlp_apply(params["readout"], inv, act=jax.nn.silu)[..., 0]
            * batch["node_mask"])
