"""Train/serve step factories for the GNN stack + synthetic batch builders."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph
from repro.graph.triplets import build_triplets
from repro.models import gnn as G
from repro.optim import AdamWConfig, adamw_update


FORWARD = {
    "meshgraphnet": (G.MeshGraphNetConfig, G.mgn_init, G.mgn_forward, "node"),
    "schnet": (G.SchNetConfig, G.schnet_init, G.schnet_forward, "energy"),
    "dimenet": (G.DimeNetConfig, G.dimenet_init, G.dimenet_forward, "energy"),
    "mace": (G.MACEConfig, G.mace_init, G.mace_forward, "energy"),
}


def gnn_loss(arch: str, cfg, params, batch, n_graphs: int):
    _, _, fwd, task = FORWARD[arch]
    out = fwd(cfg, params, batch)                    # (N,) node-level
    mask = batch["node_mask"].astype(out.dtype)
    if task == "energy":
        pred = G.pool_energy(out * mask, batch["graph_id"], n_graphs)
        tgt = G.pool_energy(batch["targets"] * mask, batch["graph_id"], n_graphs)
        return jnp.mean(jnp.square(pred - tgt))
    diff = jnp.square(out - batch["targets"]) * mask
    return jnp.sum(diff) / jnp.maximum(jnp.sum(mask), 1.0)


def make_gnn_train_step(arch: str, cfg, n_graphs: int,
                        opt_cfg: AdamWConfig = AdamWConfig(weight_decay=0.0),
                        lr: float = 1e-3):
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: gnn_loss(arch, cfg, p, batch, n_graphs))(params)
        params, opt_state = adamw_update(params, grads, opt_state,
                                         jnp.float32(lr), opt_cfg)
        return params, opt_state, loss
    return step


def make_gnn_infer_step(arch: str, cfg):
    _, _, fwd, _ = FORWARD[arch]

    def step(params, batch):
        return fwd(cfg, params, batch)
    return step


# ---------------------------------------------------------------------------
# Synthetic batch builders (smoke tests + examples)
# ---------------------------------------------------------------------------

def batch_from_graph(g: CSRGraph, d_feat: int, seed: int = 0,
                     with_triplets: bool = False, cap_per_edge: int = 16,
                     n_graphs: int = 1) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    ei = g.edge_index()
    src, dst = ei[0], ei[1]
    batch = dict(
        node_feat=rng.normal(size=(g.n, d_feat)).astype(np.float32),
        positions=rng.normal(size=(g.n, 3)).astype(np.float32),
        node_mask=np.ones(g.n, dtype=bool),
        src=src.astype(np.int32),
        dst=dst.astype(np.int32),
        edge_mask=np.ones(len(src), dtype=bool),
        graph_id=np.zeros(g.n, dtype=np.int32),
        targets=rng.normal(size=(g.n,)).astype(np.float32),
    )
    if with_triplets:
        kj, ji, m = build_triplets(src, dst, g.n, cap_per_edge)
        batch["trip_kj"] = kj
        batch["trip_ji"] = ji
        batch["trip_mask"] = m
    return batch


def batch_molecules(n_graphs: int, nodes_per_graph: int, d_feat: int,
                    seed: int = 0, with_triplets: bool = False,
                    cap_per_edge: int = 16) -> Dict[str, np.ndarray]:
    """Batched random geometric molecules (cutoff graph over random coords)."""
    rng = np.random.default_rng(seed)
    n = n_graphs * nodes_per_graph
    pos = rng.normal(size=(n_graphs, nodes_per_graph, 3)).astype(np.float32) * 2.0
    srcs, dsts = [], []
    for b in range(n_graphs):
        d = np.linalg.norm(pos[b][:, None] - pos[b][None, :], axis=-1)
        a, bb = np.nonzero((d < 3.0) & (d > 0))
        srcs.append(a + b * nodes_per_graph)
        dsts.append(bb + b * nodes_per_graph)
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    batch = dict(
        node_feat=rng.normal(size=(n, d_feat)).astype(np.float32),
        positions=pos.reshape(n, 3),
        node_mask=np.ones(n, dtype=bool),
        src=src, dst=dst,
        edge_mask=np.ones(len(src), dtype=bool),
        graph_id=np.repeat(np.arange(n_graphs, dtype=np.int32), nodes_per_graph),
        targets=rng.normal(size=(n,)).astype(np.float32),
    )
    if with_triplets:
        kj, ji, m = build_triplets(src, dst, n, cap_per_edge)
        batch["trip_kj"] = kj
        batch["trip_ji"] = ji
        batch["trip_mask"] = m
    return batch
