"""Shared neural building blocks (functional, pytree params, no flax).

Conventions:
  * params are nested dicts of jnp arrays (stored fp32, cast to the compute
    dtype inside forward),
  * layer-stacked weights carry a leading n_layers dim for lax.scan,
  * all attention is blockwise (flash-style log-sum-exp streaming over KV
    chunks) so 32k-token prefill never materialises an S×S score matrix.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    fan_in = shape[0] if len(shape) >= 2 else 1
    scale = scale if scale is not None else 1.0 / np.sqrt(fan_in)
    return jax.random.normal(key, shape, dtype) * scale


def rms_norm(x, weight, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * weight.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x, weight, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * weight + bias
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float, rotary_dims: Optional[int] = None):
    d_rot = rotary_dims or d_head
    inv = 1.0 / (theta ** (np.arange(0, d_rot, 2, dtype=np.float32) / d_rot))
    return jnp.asarray(inv)  # (d_rot/2,)


def apply_rope(x, positions, inv_freq, rotary_dims: Optional[int] = None):
    """x: (B, S, H, D); positions: (B, S) int32. GPT-NeoX rotate-half on the
    first `rotary_dims` dims (partial rotary, ChatGLM-style, when < D)."""
    b, s, h, d = x.shape
    d_rot = rotary_dims or d
    ang = positions[..., None].astype(jnp.float32) * inv_freq[None, None, :]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr = x[..., :d_rot].astype(jnp.float32)
    x1, x2 = xr[..., : d_rot // 2], xr[..., d_rot // 2:]
    rot = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    out = jnp.concatenate([rot.astype(x.dtype), x[..., d_rot:]], axis=-1)
    return out


def rope_positions_2d(b, s, prefix_len: Optional[int] = None):
    """ChatGLM 2-D RoPE position channels: (pos_channel, block_channel).

    For pure causal LM data the block channel is zeros (no prefix part);
    the two channels drive the two halves of the rotary dims."""
    pos = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
    blk = jnp.zeros((b, s), jnp.int32)
    return pos, blk


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention — pure JAX, no S×S materialisation
# ---------------------------------------------------------------------------

def _attend_block(q, k, v, mask, scale):
    """q: (B, H, Sq, D), k/v: (B, H, Skb, D), mask: (B, 1|H, Sq, Skb)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o, m, l


def blockwise_attention(q, k, v, *, causal: bool, q_offset=0,
                        window: Optional[int] = None, kv_block: int = 1024,
                        valid_kv: Optional[jnp.ndarray] = None,
                        unroll: bool = False, remat_blocks: bool = False):
    """Streaming softmax attention.

    q: (B, Sq, H, D); k, v: (B, Sk, H, D) (same head count — GQA expansion is
    done by the caller). Scans KV blocks carrying (acc, max, sum); memory is
    O(Sq·kv_block) instead of O(Sq·Sk).

    `window`: sliding-window attention width (Mistral/Mixtral SWA) — queries
    attend to keys in (pos_q - window, pos_q].
    `valid_kv`: (B, Sk) bool mask for ragged/rolling caches.
    """
    b, sq, h, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    qt = jnp.swapaxes(q, 1, 2)                   # (B, H, Sq, D)
    kv_block = min(kv_block, sk)
    n_blocks = -(-sk // kv_block)
    sk_pad = n_blocks * kv_block
    if sk_pad != sk:
        pad = ((0, 0), (0, sk_pad - sk), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        pad_valid = jnp.arange(sk_pad) < sk
    else:
        pad_valid = None

    kb = jnp.swapaxes(k, 1, 2).reshape(b, h, n_blocks, kv_block, d)
    vb = jnp.swapaxes(v, 1, 2).reshape(b, h, n_blocks, kv_block, d)
    q_pos = q_offset + jnp.arange(sq)

    def body(carry, blk):
        acc, m_run, l_run = carry
        kblk, vblk, blk_idx = blk
        k_pos = blk_idx * kv_block + jnp.arange(kv_block)
        # build the mask at batch-1 unless a batch-dependent validity mask
        # exists — a (B, 1, Sq, KV) bool would be materialised per block and
        # (worse) hoisted out of the scan as a stacked (n_blocks, B, ...)
        # buffer by XLA's loop-invariant motion.
        mask = jnp.ones((1, 1, sq, kv_block), bool)
        if causal:
            mask &= (q_pos[:, None] >= k_pos[None, :])[None, None]
        if window is not None:
            mask &= (q_pos[:, None] - k_pos[None, :] < window)[None, None]
        if pad_valid is not None:
            mask &= pad_valid[k_pos][None, None, None, :]
        if valid_kv is not None:
            vk = jnp.take(valid_kv, jnp.clip(k_pos, 0, sk - 1), axis=1)
            mask = mask & vk[:, None, None, :]
        o, m, l = _attend_block(qt, kblk, vblk, mask, scale)
        m_new = jnp.maximum(m_run, m)
        alpha = jnp.exp(m_run - m_new)
        beta = jnp.exp(m - m_new)
        acc = acc * alpha[..., None] + o * beta[..., None]
        l_new = l_run * alpha + l * beta
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((b, h, sq, d), jnp.float32)
    m0 = jnp.full((b, h, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    kb_s = jnp.moveaxis(kb, 2, 0)
    vb_s = jnp.moveaxis(vb, 2, 0)
    if remat_blocks:
        # flash-style backward: recompute block scores/probabilities in the
        # bwd pass instead of letting scan save the (n_blocks, B, H, Sq, KV)
        # probability stacks as residuals
        body = jax.checkpoint(body)
    (acc, m_run, l_run), _ = jax.lax.scan(
        body, (acc0, m0, l0), (kb_s, vb_s, jnp.arange(n_blocks)),
        unroll=n_blocks if unroll else 1)
    out = acc / jnp.maximum(l_run, 1e-30)[..., None]
    return jnp.swapaxes(out.astype(q.dtype), 1, 2)   # (B, Sq, H, D)


def repeat_kv(x, n_rep: int):
    """(B, S, KV, D) -> (B, S, KV*n_rep, D), kv head h serves q heads
    [h*n_rep, (h+1)*n_rep)."""
    if n_rep == 1:
        return x
    b, s, kv, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, n_rep, d)
                            ).reshape(b, s, kv * n_rep, d)


# ---------------------------------------------------------------------------
# FFN: GLU (dense) + GShard-style top-k MoE
# ---------------------------------------------------------------------------

def glu_ffn(x, w_in, w_gate, w_out, act: str = "silu", hint=None):
    h = jnp.einsum("bsd,df->bsf", x, w_in.astype(x.dtype))
    g = jnp.einsum("bsd,df->bsf", x, w_gate.astype(x.dtype))
    if hint is not None:            # Megatron-TP: (B, S, F) sharded on F
        h, g = hint(h), hint(g)
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g)
    return jnp.einsum("bsf,fd->bsd", h * g, w_out.astype(x.dtype))


def moe_ffn(x, router_w, w_in, w_gate, w_out, *, top_k: int,
            capacity_factor: float = 1.25, group_size: int = 1024,
            act: str = "silu"):
    """GShard/Mixtral top-k MoE with grouped capacity dispatch.

    x: (B, S, D); router_w: (D, E); expert weights: (E, D, F) / (E, F, D).
    Tokens are processed in groups so dispatch tensors stay bounded; experts
    are a sharded leading dim (EP over 'model' when E divides the axis,
    otherwise F is sharded — see repro/sharding/lm.py).
    Returns (y, aux_loss).
    """
    b, s, d = x.shape
    e = router_w.shape[1]
    t = b * s
    g = max(t // group_size, 1)
    gs = t // g
    xg = x.reshape(g, gs, d)
    logits = jnp.einsum("gtd,de->gte", xg, router_w.astype(x.dtype))
    logits = logits.astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    # aux load-balance loss (Switch): E * mean(fraction) . mean(prob)
    me = jnp.mean(probs, axis=1)                              # (G, E)
    gates, top_idx = jax.lax.top_k(probs, top_k)              # (G, T, K)
    onehot = jax.nn.one_hot(top_idx, e, dtype=jnp.float32)    # (G, T, K, E)
    ce = jnp.mean(jnp.sum(onehot, axis=2), axis=1)            # (G, E)
    aux = jnp.mean(jnp.sum(me * ce, axis=-1)) * e

    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    capacity = int(np.ceil(gs * top_k * capacity_factor / e))
    # position of each (token, k) within its expert queue
    flat_assign = onehot                                      # (G,T,K,E)
    pos = (jnp.cumsum(flat_assign.reshape(g, gs * top_k, e), axis=1)
           - flat_assign.reshape(g, gs * top_k, e))
    pos = pos.reshape(g, gs, top_k, e)
    keep = flat_assign * (pos < capacity)
    pos_onehot = jax.nn.one_hot(
        jnp.sum(pos * flat_assign, axis=-1).astype(jnp.int32),
        capacity, dtype=jnp.float32)                          # (G,T,K,C)
    # dispatch: (G, T, E, C)
    dispatch = jnp.einsum("gtke,gtkc->gtec", keep, pos_onehot)
    combine = jnp.einsum("gtke,gtk,gtkc->gtec", keep,
                         gates.astype(jnp.float32), pos_onehot)
    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xg)
    hh = jnp.einsum("gecd,edf->gecf", xe, w_in.astype(x.dtype))
    gg = jnp.einsum("gecd,edf->gecf", xe, w_gate.astype(x.dtype))
    gg = jax.nn.silu(gg) if act == "silu" else jax.nn.gelu(gg)
    ye = jnp.einsum("gecf,efd->gecd", hh * gg, w_out.astype(x.dtype))
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)
    return y.reshape(b, s, d), aux
