"""GPipe-style pipeline parallelism for the dense transformer (PP axis).

jax-native formulation (DESIGN.md §5): stages are a shard_map over the
"pp" mesh axis; the classic GPipe schedule (M microbatches through S
stages in M+S−1 ticks) is a lax.scan whose carry is the inter-stage
activation buffer, moved stage-to-stage with lax.ppermute. Backward is
automatic: ppermute transposes to the reverse permute, so jax.grad of the
pipelined forward IS the GPipe backward schedule (bubble included).

Layout: layer-stacked params (L, ...) reshape to (S, L/S, ...) and shard
P("pp") on the stage dim — each device owns only its stage's weights.
Embedding/head run replicated outside the pipelined trunk (they are not
layer-stacked). Intended composition: pp × data (DP) × model (TP) —
the test exercises pp alone on virtual devices.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import transformer as T
from repro.models import layers as L
from repro.sharding.compat import shard_map


def stack_stages(layer_params: dict, n_stages: int) -> dict:
    """(L, ...) layer-stacked tree -> (S, L/S, ...)."""
    def split(x):
        l = x.shape[0]
        assert l % n_stages == 0, f"{l} layers not divisible by {n_stages}"
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])
    return jax.tree.map(split, layer_params)


def _stage_forward(cfg: T.TransformerConfig, stage_layers, x, positions):
    def body(carry, lp):
        y, _, _ = T._layer(cfg, lp, carry, positions)
        return y, None
    out, _ = jax.lax.scan(body, x, stage_layers)
    return out


def pipeline_forward(cfg: T.TransformerConfig, params, tokens, *,
                     mesh: Mesh, n_microbatches: int, pp_axis: str = "pp"):
    """Training/prefill forward with the trunk pipelined over `pp_axis`.

    params: dict with 'embed', 'layers' STAGE-STACKED (S, L/S, ...),
    'ln_final' (+ optional 'lm_head'). tokens: (B, S_seq) with
    B % n_microbatches == 0. Returns fp32 logits (B, S_seq, V).
    """
    n_stages = mesh.shape[pp_axis]
    dt = jnp.dtype(cfg.dtype)
    b, s = tokens.shape
    m = n_microbatches
    assert b % m == 0, "batch must divide into microbatches"
    mb = b // m
    x = params["embed"][tokens].astype(dt)              # (B, S, D)
    x_mbs = x.reshape(m, mb, s, -1)
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32)[None],
                                 (mb, s))

    def pipe(stage_layers, xs):
        sid = jax.lax.axis_index(pp_axis)
        stage_layers = jax.tree.map(lambda t: t[0], stage_layers)
        n_ticks = m + n_stages - 1
        out0 = jnp.zeros_like(xs)
        buf0 = jnp.zeros_like(xs[0])

        def tick(carry, t):
            buf, out = carry
            inject = xs[jnp.clip(t, 0, m - 1)]
            xin = jnp.where(sid == 0, inject, buf)
            y = _stage_forward(cfg, stage_layers, xin, positions)
            recv = jax.lax.ppermute(
                y, pp_axis, [(i, (i + 1) % n_stages)
                             for i in range(n_stages)])
            idx = t - (n_stages - 1)
            keep = (sid == n_stages - 1) & (idx >= 0)
            upd = out.at[jnp.clip(idx, 0, m - 1)].set(y)
            out = jnp.where(keep, upd, out)
            return (recv, out), None

        (_, out), _ = jax.lax.scan(tick, (buf0, out0),
                                   jnp.arange(n_ticks))
        # only the last stage holds real outputs; replicate via psum
        out = jax.lax.psum(
            jnp.where(sid == n_stages - 1, out, jnp.zeros_like(out)),
            pp_axis)
        return out

    specs_layers = jax.tree.map(lambda _: P(pp_axis), params["layers"])
    pipe_fn = shard_map(
        pipe, mesh=mesh, in_specs=(specs_layers, P()), out_specs=P(),
        check_vma=False)
    y = pipe_fn(params["layers"], x_mbs)
    y = y.reshape(b, s, -1)
    y = L.rms_norm(y, params["ln_final"], cfg.norm_eps)
    head = params.get("lm_head",
                      params["embed"].T if cfg.tie_embeddings else None)
    logits = jnp.einsum("bsd,dv->bsv", y, head.astype(dt))
    return logits.astype(jnp.float32)


def pipeline_loss(cfg, params, tokens, targets, *, mesh, n_microbatches,
                  pp_axis: str = "pp"):
    logits = pipeline_forward(cfg, params, tokens, mesh=mesh,
                              n_microbatches=n_microbatches, pp_axis=pp_axis)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, targets[..., None],
                                         axis=-1))


def make_pipeline_train_step(cfg, mesh, n_microbatches: int,
                             pp_axis: str = "pp", lr: float = 1e-3):
    """GPipe training step (params stage-stacked, stage-sharded)."""
    from repro.optim import AdamWConfig, adamw_update

    def step(params, opt_state, tokens, targets):
        loss, grads = jax.value_and_grad(
            lambda p: pipeline_loss(cfg, p, tokens, targets, mesh=mesh,
                                    n_microbatches=n_microbatches,
                                    pp_axis=pp_axis))(params)
        params, opt_state = adamw_update(
            params, grads, opt_state, jnp.float32(lr),
            AdamWConfig(weight_decay=0.0))
        return params, opt_state, loss

    return step
