"""Jit-able step functions for LM training and serving."""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.optim import AdamWConfig, adamw_update


def make_train_step(cfg: T.TransformerConfig, opt_cfg: AdamWConfig = AdamWConfig(),
                    lr: float = 3e-4):
    """Returns train_step(params, opt_state, tokens, targets) -> (params, opt, loss)."""

    def train_step(params, opt_state, tokens, targets):
        loss, grads = jax.value_and_grad(
            lambda p: T.lm_loss(cfg, p, tokens, targets))(params)
        params, opt_state = adamw_update(params, grads, opt_state,
                                         jnp.float32(lr), opt_cfg)
        return params, opt_state, loss

    return train_step


def make_prefill_step(cfg: T.TransformerConfig):
    """prefill(params, tokens) -> (last-token logits, kv cache).

    Builds the cache with one full forward (training-mode attention), then
    packs per-layer K/V. Rolling SWA caches keep the trailing window."""

    def prefill(params, tokens):
        b, s = tokens.shape
        dt = jnp.dtype(cfg.dtype)
        x = params["embed"][tokens].astype(dt)
        positions = jnp.broadcast_to(
            jnp.arange(s, dtype=jnp.int32)[None], (b, s))

        def body(x, lp):
            x2, _, kv = T._layer(cfg, lp, x, positions)
            k, v = kv
            c = T.cache_len(cfg, s)
            if c != s:
                # rolling buffer layout: entry for absolute position p lives
                # in slot p % c; the last c tokens occupy the buffer
                k = _roll_pack(k, c)
                v = _roll_pack(v, c)
            return x2, (k, v)

        x, kvs = jax.lax.scan(body, x, params["layers"],
                              unroll=cfg.n_layers if cfg.unroll_scans else 1)
        x = L_rms(x, params["ln_final"], cfg.norm_eps)
        head = params.get("lm_head",
                          params["embed"].T if cfg.tie_embeddings else None)
        logits = jnp.einsum("bd,dv->bv", x[:, -1], head.astype(dt))
        cache = dict(k=kvs[0], v=kvs[1], pos=jnp.int32(s))
        return logits.astype(jnp.float32), cache

    return prefill


def _roll_pack(k, c):
    """Keep the last c positions, placed at slot (abs_pos % c)."""
    s = k.shape[1]
    tail = k[:, s - c:]
    offset = (s - c) % c
    return jnp.roll(tail, shift=offset, axis=1)


def L_rms(x, w, eps):
    from repro.models.layers import rms_norm
    return rms_norm(x, w, eps)


def make_decode_step(cfg: T.TransformerConfig):
    def decode(params, cache, token):
        return T.decode_step(cfg, params, cache, token)
    return decode
