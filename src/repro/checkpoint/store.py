"""Fault-tolerant pytree checkpointing: atomic, keep-k, async, elastic.

Design (1000+ node posture, DESIGN.md §5):

* **Atomic commit** — arrays are written to ``<dir>/step_<n>.tmp/`` and the
  directory is os.replace'd into place; a manifest.json written last is the
  commit record. A crash mid-write never corrupts the resume point.
* **Keep-k GC** — oldest committed steps beyond ``keep`` are deleted after a
  successful commit (never before).
* **Async save** — ``save_async`` snapshots device arrays to host
  (jax.device_get, the only sync point) then commits on a worker thread so
  the train loop overlaps checkpoint I/O with the next steps.
* **Elastic restore** — arrays are stored unsharded (full logical value per
  leaf, np.save). On load, the caller passes the *current* shardings and the
  arrays are device_put with them — a restart with a different mesh
  re-shards transparently. (At real multi-host scale each host writes its
  addressable shards; the manifest schema carries the leaf paths either
  way — the single-process container exercises the full logical-value path.)
* **Step metadata** — arbitrary JSON (data cursor, RNG key, schedule state)
  rides in the manifest so the data pipeline resumes exactly.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import numpy as np


def _flatten_with_paths(tree: Any) -> Tuple[List[Tuple[str, Any]], Any]:
    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves_with_paths:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    return str(p)


def save_checkpoint(directory: str, step: int, tree: Any,
                    meta: Optional[Dict] = None) -> str:
    """Synchronous atomic checkpoint. Returns the committed path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _ = _flatten_with_paths(tree)
    names = []
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        names.append(dict(key=key, file=fname, shape=list(arr.shape),
                          dtype=str(arr.dtype)))
    manifest = dict(step=step, leaves=names, meta=meta or {})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


def _committed_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(directory, name, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def load_checkpoint(directory: str, tree_like: Any,
                    step: Optional[int] = None,
                    shardings: Optional[Any] = None) -> Tuple[Any, int, Dict]:
    """Restore (tree, step, meta). `tree_like` provides the pytree structure;
    `shardings` (same structure, NamedSharding leaves) re-shards elastically."""
    steps = _committed_steps(directory)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints in {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = [np.load(os.path.join(path, rec["file"]))
              for rec in manifest["leaves"]]
    flat_like, treedef = jax.tree_util.tree_flatten(tree_like)
    assert len(flat_like) == len(arrays), \
        f"checkpoint has {len(arrays)} leaves, model expects {len(flat_like)}"
    if shardings is not None:
        flat_sh = jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding))
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, flat_sh)]
    else:
        arrays = [jax.numpy.asarray(a) for a in arrays]
    return treedef.unflatten(arrays), manifest["step"], manifest.get("meta", {})


class CheckpointManager:
    """Keep-k + async wrapper around save/load."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---- save ------------------------------------------------------------
    def save(self, step: int, tree: Any, meta: Optional[Dict] = None) -> None:
        save_checkpoint(self.directory, step, tree, meta)
        self._gc()

    def save_async(self, step: int, tree: Any,
                   meta: Optional[Dict] = None) -> None:
        """Snapshot to host now; write + commit on a worker thread."""
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.directory, step, host_tree, meta)
                self._gc()
            except BaseException as e:  # pragma: no cover - surfaced in wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ---- load ------------------------------------------------------------
    def restore(self, tree_like: Any, step: Optional[int] = None,
                shardings: Optional[Any] = None) -> Tuple[Any, int, Dict]:
        self.wait()
        return load_checkpoint(self.directory, tree_like, step, shardings)

    def latest_step(self) -> Optional[int]:
        steps = _committed_steps(self.directory)
        return steps[-1] if steps else None

    def _gc(self) -> None:
        steps = _committed_steps(self.directory)
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
