"""Vectorized host-side bitset packing — the ingest hot path.

`prepare()` used to pack every adjacency bitset row with a per-vertex
`np.isin` python loop: O(Σ|P| + Σ|X|) numpy calls per graph, each on a
tiny array, so the TPU idled behind the host on large graphs. This
module packs a whole bucket of subproblems with a constant number of
vectorized passes:

* universes become one sorted `(subproblem, vertex) → local-rank` key
  table (rank remap);
* CSR adjacency for every member is gathered with the ranges trick
  (`_ranges`), no per-vertex slicing;
* membership of each gathered neighbor in its subproblem's universe is a
  single `searchsorted` sort-merge join;
* rows materialize with one `np.bitwise_or.at` scatter.

A uint8 popcount LUT (`popcount_sum`) serves the driver's cost model
without the 32× `np.unpackbits` memory blowup.

Layering: this module sits in the graph layer — it may import numpy and
`graph.csr` siblings only, never `repro.core`/`repro.kernels` (enforced
by tests/test_engine_layering.py).
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

WORD = 32
_U1 = np.uint32(1)
_POP8 = np.array([bin(i).count("1") for i in range(256)], dtype=np.uint8)


def popcount_sum(a: np.ndarray, axis=None) -> np.ndarray:
    """Popcount of a uint32 array summed over `axis` (LUT, no unpackbits).

    `axis` indexes the dims of `a`; the trailing word axis is viewed as
    4 bytes, so summing over the last axis of `a` sums the bytes too.
    Peak extra memory is 1× `a.nbytes` (the uint8 LUT gather), vs 32×
    for ``np.unpackbits(a.view(np.uint8))``.
    """
    a = np.ascontiguousarray(a, dtype=np.uint32)
    per_byte = _POP8[a.view(np.uint8).reshape(a.shape[:-1] + (-1,))]
    return per_byte.sum(axis=axis, dtype=np.int64)


def pack_bits(ids: np.ndarray, words: int) -> np.ndarray:
    """Single bitset: set bit `i` for every i in `ids` (local indices)."""
    out = np.zeros(words, dtype=np.uint32)
    if len(ids):
        ids = np.asarray(ids, dtype=np.int64)
        np.bitwise_or.at(out, ids // WORD,
                         _U1 << (ids % WORD).astype(np.uint32))
    return out


def _ranges(starts: np.ndarray, counts: np.ndarray) -> np.ndarray:
    """Concatenated [s, s+c) index ranges (CSR multi-row gather trick)."""
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, dtype=np.int64)
    shift = starts.astype(np.int64) - np.concatenate(
        ([0], np.cumsum(counts)[:-1]))
    return np.arange(total, dtype=np.int64) + np.repeat(shift, counts)


def prefix_bits(u_sizes: np.ndarray, words: int) -> np.ndarray:
    """(R, words) bitsets with the first u_sizes[k] bits set (vectorized)."""
    u_sizes = np.asarray(u_sizes, dtype=np.int64)
    full = u_sizes // WORD
    rem = u_sizes % WORD
    wi = np.arange(words, dtype=np.int64)[None, :]
    partial = ((np.int64(1) << rem) - 1).astype(np.uint32)[:, None]
    p = np.where(wi < full[:, None], np.uint32(0xFFFFFFFF), np.uint32(0))
    return np.where(wi == full[:, None], partial, p).astype(np.uint32)


def pack_bucket(indptr: np.ndarray, indices: np.ndarray, n: int,
                p_lists: Sequence[np.ndarray],
                x_lists: Sequence[np.ndarray],
                bucket: int) -> Tuple[np.ndarray, np.ndarray,
                                      np.ndarray, np.ndarray]:
    """Pack a bucket of (P, X) subproblems into fixed-shape bitset tensors.

    p_lists[k]/x_lists[k] are global vertex ids in local (rank) order; the
    local index of `p_lists[k][j]` is `j`. Returns `(a, p0, x_rows,
    x_alive)` with shapes `(R, bucket, W)`, `(R, W)`, `(R, XC, W)`,
    `(R, XC)` where `XC` is the pow2 pad of the max per-subproblem count
    of X rows that intersect the universe (all-zero rows are dropped,
    matching the legacy per-row packer bit for bit).
    """
    r = len(p_lists)
    words = bucket // WORD
    if r == 0:
        return (np.zeros((0, bucket, words), np.uint32),
                np.zeros((0, words), np.uint32),
                np.zeros((0, 1, words), np.uint32),
                np.zeros((0, 1), bool))
    u_sizes = np.fromiter((len(p) for p in p_lists), np.int64, count=r)
    uni = np.concatenate([np.asarray(p, np.int64) for p in p_lists])
    u_off = np.concatenate(([0], np.cumsum(u_sizes)))
    uni_sub = np.repeat(np.arange(r, dtype=np.int64), u_sizes)
    uni_loc = np.arange(len(uni), dtype=np.int64) - u_off[uni_sub]

    keys = uni_sub * n + uni                 # unique: (sub, vertex) pairs
    ks = np.argsort(keys)
    keys_s, loc_s = keys[ks], uni_loc[ks]

    def rows_for(members: np.ndarray, sub_of: np.ndarray) -> np.ndarray:
        """(len(members), words) rows: N(member) ∩ universe(sub_of)."""
        starts = indptr[members]
        counts = (indptr[members + 1] - starts).astype(np.int64)
        nbr = indices[_ranges(starts, counts)].astype(np.int64)
        own = np.repeat(np.arange(len(members), dtype=np.int64), counts)
        q = sub_of[own] * n + nbr
        pos = np.minimum(np.searchsorted(keys_s, q), len(keys_s) - 1)
        hit = keys_s[pos] == q
        own, lidx = own[hit], loc_s[pos[hit]]
        out = np.zeros(len(members) * words, np.uint32)
        np.bitwise_or.at(out, own * words + lidx // WORD,
                         _U1 << (lidx % WORD).astype(np.uint32))
        return out.reshape(len(members), words)

    a = np.zeros((r, bucket, words), np.uint32)
    a[uni_sub, uni_loc] = rows_for(uni, uni_sub)
    p0 = prefix_bits(u_sizes, words)

    x_sizes = np.fromiter((len(x) for x in x_lists), np.int64, count=r)
    if int(x_sizes.sum()) == 0:
        return a, p0, np.zeros((r, 1, words), np.uint32), np.zeros((r, 1), bool)
    xs = np.concatenate([np.asarray(x, np.int64) for x in x_lists if len(x)])
    x_sub = np.repeat(np.arange(r, dtype=np.int64), x_sizes)
    x_off = np.concatenate(([0], np.cumsum(x_sizes)))
    raw = rows_for(xs, x_sub)
    keep = raw.any(axis=1)                   # drop rows disjoint from P
    kept_per_sub = np.zeros(r, np.int64)
    np.add.at(kept_per_sub, x_sub[keep], 1)
    xc_raw = max(int(kept_per_sub.max()), 1)
    xc = 1 << (xc_raw - 1).bit_length()      # pow2 pad: bounded recompiles
    cum = np.cumsum(keep.astype(np.int64))
    pre = np.concatenate(([0], cum))
    new_pos = cum - 1 - pre[x_off[x_sub]]    # kept-row rank within its sub
    x_rows = np.zeros((r, xc, words), np.uint32)
    x_alive = np.zeros((r, xc), bool)
    x_rows[x_sub[keep], new_pos[keep]] = raw[keep]
    x_alive[x_sub[keep], new_pos[keep]] = True
    return a, p0, x_rows, x_alive
