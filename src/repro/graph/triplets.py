"""Host-side triplet index construction for directional GNNs (DimeNet).

A triplet (k→j, j→i) pairs every incoming edge of j with every outgoing edge
of j (k ≠ i). Counts explode on dense graphs (Σ_j d(j)²), so a per-edge cap
bounds the fixed shape: for each edge (j→i), at most `cap` incoming edges of
j are paired (nearest-sorted order — matches molecular practice where the
cutoff graph bounds the neighbour count anyway).
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def build_triplets(src: np.ndarray, dst: np.ndarray, n_nodes: int,
                   cap_per_edge: int = 16) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Returns (trip_kj, trip_ji, mask): indices into the edge list.

    trip_kj[t] = edge id of (k→j); trip_ji[t] = edge id of (j→i)."""
    e = len(src)
    order = np.argsort(dst, kind="stable")
    sorted_dst = dst[order]
    starts = np.searchsorted(sorted_dst, np.arange(n_nodes), side="left")
    ends = np.searchsorted(sorted_dst, np.arange(n_nodes), side="right")
    kj_list, ji_list = [], []
    for ji in range(e):
        j = src[ji]
        i = dst[ji]
        incoming = order[starts[j]:ends[j]]          # edges (k→j)
        incoming = incoming[src[incoming] != i][:cap_per_edge]
        kj_list.append(incoming)
        ji_list.append(np.full(len(incoming), ji, dtype=np.int64))
    if kj_list:
        kj = np.concatenate(kj_list).astype(np.int32)
        ji = np.concatenate(ji_list).astype(np.int32)
    else:
        kj = np.zeros(0, np.int32)
        ji = np.zeros(0, np.int32)
    mask = np.ones(len(kj), dtype=bool)
    return kj, ji, mask


def triplet_budget(n_edges: int, cap_per_edge: int = 16) -> int:
    return n_edges * cap_per_edge
