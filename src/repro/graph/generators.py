"""Synthetic graph generators matched to the paper's dataset regimes.

The paper benchmarks 18 SNAP / Network Repository graphs. This container is
offline, so the benchmark suite generates synthetic stand-ins from the same
structural families:

- `grid_road`        — road-network analogue (inf-road-usa, roadNet-CA):
                       sparse, degeneracy ~2-3, fully removed by global
                       reduction (paper Fig 8).
- `random_geometric` — delaunay-ish proximity graph (sc-delaunay_n23):
                       min degree > 2, untouched by global reduction.
- `barabasi_albert`  — power-law social/web analogue (as-skitter, web-Google).
- `erdos_renyi`      — uniform random control.
- `caveman`          — community graph with many overlapping cliques.
- `kronecker`        — scale-free RMAT-style graph (soc-/com- analogues).
- `moon_moser`       — worst-case 3^(n/3) maximal cliques (correctness
                       stress; K_{3,3,...,3} complete multipartite).
- `complete_graph`   — K_n sanity.
All generators are deterministic given `seed`.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph, from_edge_list


def erdos_renyi(n: int, p: float, seed: int = 0) -> CSRGraph:
    rng = np.random.default_rng(seed)
    iu, ju = np.triu_indices(n, k=1)
    mask = rng.random(len(iu)) < p
    return from_edge_list(n, np.stack([iu[mask], ju[mask]], axis=1))


def barabasi_albert(n: int, m_attach: int = 4, seed: int = 0) -> CSRGraph:
    """Preferential attachment; repeated-target sampling (fast, numpy)."""
    rng = np.random.default_rng(seed)
    m_attach = max(1, min(m_attach, n - 1))
    targets = list(range(m_attach))
    edges = []
    repeated = []  # endpoint multiset for preferential attachment
    for v in range(m_attach, n):
        chosen = set()
        while len(chosen) < m_attach:
            if repeated and rng.random() < 0.9:
                cand = repeated[rng.integers(len(repeated))]
            else:
                cand = int(rng.integers(v))
            if cand != v:
                chosen.add(int(cand))
        for t in chosen:
            edges.append((v, t))
            repeated.extend([v, t])
        targets.append(v)
    return from_edge_list(n, np.array(edges, dtype=np.int64))


def random_geometric(n: int, radius: float | None = None, seed: int = 0) -> CSRGraph:
    """2-D random geometric graph (delaunay-like locality, high clustering)."""
    rng = np.random.default_rng(seed)
    pts = rng.random((n, 2))
    if radius is None:
        radius = float(np.sqrt(8.0 / max(n, 1)))  # avg degree ~ 8*pi/4
    # grid-bucketed neighbor search to stay O(n)
    cell = radius
    gx = (pts[:, 0] / cell).astype(np.int64)
    gy = (pts[:, 1] / cell).astype(np.int64)
    buckets: dict = {}
    for i, (a, b) in enumerate(zip(gx.tolist(), gy.tolist())):
        buckets.setdefault((a, b), []).append(i)
    edges = []
    r2 = radius * radius
    for (a, b), members in buckets.items():
        neigh = []
        for da in (-1, 0, 1):
            for db in (-1, 0, 1):
                neigh.extend(buckets.get((a + da, b + db), []))
        neigh = np.array(neigh)
        for i in members:
            d2 = np.sum((pts[neigh] - pts[i]) ** 2, axis=1)
            for j in neigh[(d2 < r2) & (neigh > i)]:
                edges.append((i, int(j)))
    return from_edge_list(n, np.array(edges, dtype=np.int64) if edges else np.zeros((0, 2)))


def grid_road(side: int, drop_frac: float = 0.1, seed: int = 0) -> CSRGraph:
    """Road-network analogue: 2-D lattice with random edge dropout.

    Degeneracy ≤ 2 ⇒ fully removed by the paper's global reduction, matching
    inf-road-usa / roadNet-CA behaviour in Fig 8.
    """
    rng = np.random.default_rng(seed)
    n = side * side
    idx = np.arange(n).reshape(side, side)
    right = np.stack([idx[:, :-1].ravel(), idx[:, 1:].ravel()], axis=1)
    down = np.stack([idx[:-1, :].ravel(), idx[1:, :].ravel()], axis=1)
    edges = np.concatenate([right, down])
    keep = rng.random(len(edges)) >= drop_frac
    return from_edge_list(n, edges[keep])


def caveman(n_cliques: int, clique_size: int, rewire: float = 0.1, seed: int = 0) -> CSRGraph:
    """Connected caveman-style community graph (many maximal cliques)."""
    rng = np.random.default_rng(seed)
    n = n_cliques * clique_size
    edges = []
    for c in range(n_cliques):
        base = c * clique_size
        for i in range(clique_size):
            for j in range(i + 1, clique_size):
                edges.append((base + i, base + j))
        # ring link to next cave
        edges.append((base, ((c + 1) % n_cliques) * clique_size))
    edges = np.array(edges, dtype=np.int64)
    flip = rng.random(len(edges)) < rewire
    edges[flip, 1] = rng.integers(0, n, size=flip.sum())
    return from_edge_list(n, edges)


def kronecker(scale: int, edge_factor: int = 8, seed: int = 0,
              a: float = 0.57, b: float = 0.19, c: float = 0.19) -> CSRGraph:
    """RMAT/Kronecker generator (Graph500-style), scale = log2(n)."""
    rng = np.random.default_rng(seed)
    n = 1 << scale
    m = n * edge_factor
    src = np.zeros(m, dtype=np.int64)
    dst = np.zeros(m, dtype=np.int64)
    for bit in range(scale):
        r = rng.random(m)
        go_right = (r > a) & (r <= a + b)
        go_down = (r > a + b) & (r <= a + b + c)
        go_diag = r > a + b + c
        src += ((go_down | go_diag).astype(np.int64)) << bit
        dst += ((go_right | go_diag).astype(np.int64)) << bit
    return from_edge_list(n, np.stack([src, dst], axis=1))


def moon_moser(k: int) -> CSRGraph:
    """Complete multipartite K_{3,3,...,3} with k parts: 3^k maximal cliques."""
    n = 3 * k
    part = np.arange(n) // 3
    iu, ju = np.triu_indices(n, k=1)
    mask = part[iu] != part[ju]
    return from_edge_list(n, np.stack([iu[mask], ju[mask]], axis=1))


def complete_graph(n: int) -> CSRGraph:
    iu, ju = np.triu_indices(n, k=1)
    return from_edge_list(n, np.stack([iu, ju], axis=1))
