"""Vertex orderings: exact degeneracy order (host) and parallel k-core peel (JAX).

The exact order uses the O(n+m) bucket-queue algorithm (Matula & Beck). The
JAX version performs *round-based* peeling: each round removes every vertex
whose residual degree is ≤ the current core level k. Vertices removed in
round order (arbitrary within a round) still satisfy the BKdegen invariant
|N⁺(v)| ≤ λ, because at removal time a vertex's residual degree (which upper
bounds its later neighbors, including same-round ones ordered after it)
is ≤ k ≤ λ.
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph


def degeneracy_order(g: CSRGraph) -> Tuple[np.ndarray, np.ndarray, int]:
    """Exact degeneracy order (Matula–Beck bucket queue, O(n+m)).

    Returns (order, rank, degeneracy): order[i] = i-th vertex peeled;
    rank[v] = position of v in order; degeneracy = max residual degree seen.
    """
    n = g.n
    if n == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z, 0
    deg = g.degrees().astype(np.int64).copy()
    max_deg = int(deg.max())
    # counting sort of vertices by degree — a stable argsort fills the
    # degree buckets in increasing-vertex order, exactly like the classic
    # per-vertex insertion loop but vectorized
    bin_start = np.zeros(max_deg + 2, dtype=np.int64)
    np.add.at(bin_start, deg + 1, 1)
    bin_start = np.cumsum(bin_start)
    vert = np.argsort(deg, kind="stable")
    pos = np.empty(n, dtype=np.int64)
    pos[vert] = np.arange(n)
    bin_ = bin_start[:-1].copy()           # bucket front pointers

    dptr = g.indptr.tolist()
    dind = g.indices.tolist()
    degeneracy = 0
    deg_list = deg.tolist()
    pos_list = pos.tolist()
    bin_list = bin_.tolist()
    vert_list = vert.tolist()
    for i in range(n):
        v = vert_list[i]
        dv = deg_list[v]
        if dv > degeneracy:
            degeneracy = dv
        for u in dind[dptr[v]:dptr[v + 1]]:
            du = deg_list[u]
            if du > dv:
                pu = pos_list[u]
                pw = bin_list[du]
                w = vert_list[pw]
                if u != w:
                    vert_list[pu] = w
                    vert_list[pw] = u
                    pos_list[u] = pw
                    pos_list[w] = pu
                bin_list[du] = pw + 1
                deg_list[u] = du - 1
    order = np.asarray(vert_list, dtype=np.int64)
    rank = np.empty(n, dtype=np.int64)
    rank[order] = np.arange(n)
    return order, rank, degeneracy


def core_numbers(g: CSRGraph) -> np.ndarray:
    """Host core numbers: core[v] = max k s.t. v is in a k-core."""
    n = g.n
    deg = g.degrees().astype(np.int64).copy()
    core = np.zeros(n, dtype=np.int64)
    removed = np.zeros(n, dtype=bool)
    import heapq

    heap = [(int(d), v) for v, d in enumerate(deg)]
    heapq.heapify(heap)
    k = 0
    while heap:
        d, v = heapq.heappop(heap)
        if removed[v] or d != deg[v]:
            continue
        removed[v] = True
        k = max(k, int(d))
        core[v] = k
        for u in g.neighbors(v):
            if not removed[u]:
                deg[u] -= 1
                heapq.heappush(heap, (int(deg[u]), u))
    return core


@partial(jax.jit, static_argnames=("n",))
def _peel_rounds(src: jnp.ndarray, dst: jnp.ndarray, n: int):
    """Round-based peel (device path). Returns peel-round id per vertex.

    `src`/`dst`: (2m,) directed edge endpoints. O(m) segment-sum degree
    recomputation per round inside a while_loop.
    """

    def cond(state):
        _, _, alive, _ = state
        return jnp.any(alive)

    def body(state):
        k, rnd, alive, out_round = state
        deg = jax.ops.segment_sum(
            alive[dst].astype(jnp.int32) * alive[src].astype(jnp.int32),
            src,
            num_segments=n,
        )
        peel = alive & (deg <= k)
        any_peel = jnp.any(peel)
        # if nothing peels at level k, raise k; else peel one round
        k_next = jnp.where(any_peel, k, k + 1)
        rnd_next = rnd + jnp.where(any_peel, 1, 0)
        out_round = jnp.where(peel, rnd, out_round)
        alive = alive & ~peel
        return k_next, rnd_next, alive, out_round

    state = (
        jnp.int32(0),
        jnp.int32(0),
        jnp.ones(n, dtype=bool),
        jnp.full((n,), jnp.iinfo(jnp.int32).max, jnp.int32),
    )
    _, _, _, out_round = jax.lax.while_loop(cond, body, state)
    return out_round


def kcore_peel_jax(g: CSRGraph) -> np.ndarray:
    """JAX round-based peel order. Returns rank (position) per vertex.

    Ties within a round broken by vertex id. The resulting order satisfies
    the |N⁺(v)| ≤ λ invariant (see module docstring).
    """
    if g.n == 0:
        return np.zeros(0, dtype=np.int64)
    src = np.repeat(np.arange(g.n, dtype=np.int64), g.degrees())
    rounds = np.asarray(
        _peel_rounds(jnp.asarray(src, jnp.int32), jnp.asarray(g.indices, jnp.int32), g.n)
    )
    order = np.lexsort((np.arange(g.n), rounds))
    rank = np.empty(g.n, dtype=np.int64)
    rank[order] = np.arange(g.n)
    return rank
