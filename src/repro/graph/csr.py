"""CSR graph structure (undirected, simple) used across the framework.

The canonical host representation is numpy CSR with sorted adjacency lists.
Device code receives either (indptr, indices) jnp arrays or padded/bitset
derivatives built by `repro.core`.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Undirected simple graph in CSR form.

    indptr:  (n+1,) int64 — row offsets.
    indices: (m*2,) int32 — concatenated sorted adjacency lists (both
             directions stored; m counts undirected edges).
    """

    indptr: np.ndarray
    indices: np.ndarray

    # ---- basic accessors -------------------------------------------------
    @property
    def n(self) -> int:
        return len(self.indptr) - 1

    @property
    def m(self) -> int:
        return int(len(self.indices) // 2)

    def degree(self, v: int | None = None):
        degs = np.diff(self.indptr)
        return degs if v is None else int(degs[v])

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int64)

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v] : self.indptr[v + 1]]

    def has_edge(self, u: int, v: int) -> bool:
        nb = self.neighbors(u)
        i = np.searchsorted(nb, v)
        return bool(i < len(nb) and nb[i] == v)

    def edges(self) -> np.ndarray:
        """(m, 2) array of undirected edges with u < v."""
        src = np.repeat(np.arange(self.n, dtype=np.int64), self.degrees())
        dst = self.indices.astype(np.int64)
        keep = src < dst
        return np.stack([src[keep], dst[keep]], axis=1).astype(np.int32)

    def edge_index(self) -> np.ndarray:
        """(2, 2m) directed COO edge index (GNN convention, both directions)."""
        src = np.repeat(np.arange(self.n, dtype=np.int64), self.degrees())
        return np.stack([src.astype(np.int32), self.indices.astype(np.int32)])

    # ---- invariants ------------------------------------------------------
    def validate(self) -> None:
        assert self.indptr[0] == 0 and self.indptr[-1] == len(self.indices)
        assert np.all(np.diff(self.indptr) >= 0)
        for v in range(self.n):
            nb = self.neighbors(v)
            assert np.all(np.diff(nb) > 0), f"adjacency of {v} not sorted/unique"
            assert not np.any(nb == v), f"self loop at {v}"

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return f"CSRGraph(n={self.n}, m={self.m})"


def from_edge_list(n: int, edges: Iterable[Tuple[int, int]] | np.ndarray) -> CSRGraph:
    """Build a CSRGraph from an iterable of undirected edges.

    Deduplicates, drops self loops, symmetrizes, sorts adjacency lists.
    """
    e = np.asarray(list(edges) if not isinstance(edges, np.ndarray) else edges, dtype=np.int64)
    if e.size == 0:
        return CSRGraph(np.zeros(n + 1, dtype=np.int64), np.zeros(0, dtype=np.int32))
    e = e.reshape(-1, 2)
    e = e[e[:, 0] != e[:, 1]]  # no self loops
    lo = np.minimum(e[:, 0], e[:, 1])
    hi = np.maximum(e[:, 0], e[:, 1])
    key = lo * n + hi
    _, uniq = np.unique(key, return_index=True)
    lo, hi = lo[uniq], hi[uniq]
    src = np.concatenate([lo, hi])
    dst = np.concatenate([hi, lo])
    order = np.lexsort((dst, src))
    src, dst = src[order], dst[order]
    indptr = np.zeros(n + 1, dtype=np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRGraph(indptr, dst.astype(np.int32))


def induced_subgraph(g: CSRGraph, keep: np.ndarray) -> Tuple[CSRGraph, np.ndarray]:
    """Induced subgraph on `keep` (bool mask or vertex ids).

    Returns (subgraph, old_ids) where old_ids[i] is the original id of new
    vertex i.
    """
    if keep.dtype == bool:
        old_ids = np.nonzero(keep)[0]
    else:
        old_ids = np.sort(np.asarray(keep, dtype=np.int64))
    remap = -np.ones(g.n, dtype=np.int64)
    remap[old_ids] = np.arange(len(old_ids))
    edges = g.edges()
    a, b = remap[edges[:, 0]], remap[edges[:, 1]]
    sel = (a >= 0) & (b >= 0)
    sub = from_edge_list(len(old_ids), np.stack([a[sel], b[sel]], axis=1))
    return sub, old_ids


def remove_edges(g: CSRGraph, drop: np.ndarray) -> CSRGraph:
    """Remove an (k, 2) array of undirected edges from g."""
    if len(drop) == 0:
        return g
    edges = g.edges().astype(np.int64)
    dl = np.minimum(drop[:, 0], drop[:, 1]).astype(np.int64)
    dh = np.maximum(drop[:, 0], drop[:, 1]).astype(np.int64)
    dropset = set((dl * g.n + dh).tolist())
    key = edges[:, 0] * g.n + edges[:, 1]
    keep = np.array([k not in dropset for k in key.tolist()], dtype=bool)
    return from_edge_list(g.n, edges[keep])
