"""Graph substrate: CSR structures, generators, orderings, sampling.

Everything here is framework-level plumbing shared by the paper core
(`repro.core`) and the GNN/recsys model stacks. Host-side preprocessing is
numpy (this mirrors production graph systems, where graph loading/reordering
is a CPU ingest stage); device-side compute is jnp.
"""
from repro.graph.csr import CSRGraph, from_edge_list, induced_subgraph
from repro.graph.generators import (
    erdos_renyi,
    barabasi_albert,
    random_geometric,
    grid_road,
    moon_moser,
    complete_graph,
    caveman,
    kronecker,
)
from repro.graph.order import degeneracy_order, core_numbers, kcore_peel_jax
from repro.graph.sampler import NeighborSampler

__all__ = [
    "CSRGraph",
    "from_edge_list",
    "induced_subgraph",
    "erdos_renyi",
    "barabasi_albert",
    "random_geometric",
    "grid_road",
    "moon_moser",
    "complete_graph",
    "caveman",
    "kronecker",
    "degeneracy_order",
    "core_numbers",
    "kcore_peel_jax",
    "NeighborSampler",
]
