"""Multi-hop uniform neighbor sampler (GraphSAGE-style) with fixed shapes.

Produces padded, fixed-shape sampled subgraphs suitable for jit'd training
steps: the `minibatch_lg` shape (batch_nodes=1024, fanout 15-10) requires a
real sampler over the full CSR graph. Sampling is a host-side data-pipeline
stage (numpy), as in production systems (DGL/PyG samplers run on CPU workers).
"""
from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class SampledBlock:
    """One bipartite message-passing block (layer) of a sampled subgraph.

    edge src/dst are indices into the *global* node id table `node_ids` of the
    parent SampledSubgraph. Padded edges have src == dst == pad_node and
    mask == False.
    """

    src: np.ndarray          # (E_pad,) int32 — local node index of message source
    dst: np.ndarray          # (E_pad,) int32 — local node index of message target
    mask: np.ndarray         # (E_pad,) bool  — valid-edge mask


@dataclasses.dataclass
class SampledSubgraph:
    node_ids: np.ndarray     # (N_pad,) int32 global ids (padded with 0)
    node_mask: np.ndarray    # (N_pad,) bool
    blocks: List[SampledBlock]
    seeds: np.ndarray        # (batch,) int32 — local indices of seed nodes


class NeighborSampler:
    """Uniform fanout sampler. Deterministic given (seed, batch_index)."""

    def __init__(self, g: CSRGraph, fanouts: Tuple[int, ...], batch_nodes: int, seed: int = 0):
        self.g = g
        self.fanouts = tuple(fanouts)
        self.batch_nodes = batch_nodes
        self.seed = seed
        # fixed output shapes (padded): layer l has at most batch * prod(fanout[:l+1]) edges
        self.node_budget = batch_nodes
        self.edge_budgets = []
        cur = batch_nodes
        for f in self.fanouts:
            self.edge_budgets.append(cur * f)
            cur = cur * f
            self.node_budget += cur

    def sample(self, batch_index: int) -> SampledSubgraph:
        rng = np.random.default_rng((self.seed, batch_index))
        n = self.g.n
        seeds = rng.choice(n, size=self.batch_nodes, replace=n < self.batch_nodes)
        frontier = seeds.astype(np.int64)
        all_nodes = [frontier]
        raw_blocks = []  # (src_global, dst_global) per hop
        for f in self.fanouts:
            deg = self.g.degrees()[frontier]
            # uniform with replacement; deg-0 nodes get self edges (masked out)
            offs = rng.integers(0, np.maximum(deg, 1)[:, None],
                                size=(len(frontier), f))
            src = self.g.indices[
                np.minimum(self.g.indptr[frontier][:, None] + offs, len(self.g.indices) - 1)
            ].astype(np.int64)
            valid = (deg > 0)[:, None] & (offs < deg[:, None])
            dst = np.broadcast_to(frontier[:, None], src.shape)
            raw_blocks.append((src.ravel(), dst.ravel(), valid.ravel()))
            frontier = np.unique(src[valid])
            all_nodes.append(frontier)
        # build global->local map over the union of sampled nodes
        uniq = np.unique(np.concatenate(all_nodes))
        n_pad = self.node_budget
        if len(uniq) > n_pad:  # cannot happen (budget is the worst case) but guard
            uniq = uniq[:n_pad]
        local = {int(v): i for i, v in enumerate(uniq.tolist())}
        node_ids = np.zeros(n_pad, dtype=np.int32)
        node_ids[: len(uniq)] = uniq
        node_mask = np.zeros(n_pad, dtype=bool)
        node_mask[: len(uniq)] = True
        blocks = []
        for (src, dst, valid), budget in zip(raw_blocks, self.edge_budgets):
            ls = np.array([local.get(int(s), 0) for s in src.tolist()], dtype=np.int32)
            ld = np.array([local.get(int(d), 0) for d in dst.tolist()], dtype=np.int32)
            pad = budget - len(ls)
            assert pad >= 0
            blocks.append(
                SampledBlock(
                    src=np.concatenate([ls, np.zeros(pad, np.int32)]),
                    dst=np.concatenate([ld, np.zeros(pad, np.int32)]),
                    mask=np.concatenate([valid, np.zeros(pad, bool)]),
                )
            )
        seed_local = np.array([local[int(s)] for s in seeds.tolist()], dtype=np.int32)
        return SampledSubgraph(node_ids=node_ids, node_mask=node_mask, blocks=blocks, seeds=seed_local)
