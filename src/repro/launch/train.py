"""End-to-end training driver (any arch family, CPU-runnable smoke scale).

Production posture: sharded params via pjit over the host mesh, chunked
checkpoint/restart (keep-k, async), deterministic data stream keyed by step,
straggler-free synchronous SPMD. The same loop the multi-pod deployment runs
— the mesh is just bigger there.

Usage:
  python -m repro.launch.train --arch mixtral-8x7b --smoke --steps 50
  python -m repro.launch.train --arch two-tower-retrieval --smoke --steps 30 \
      --ckpt /tmp/tt_ckpt --resume
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_arch
from repro.launch.mesh import make_host_mesh
from repro.optim import adamw_init


def _lm_setup(cfg, smoke: bool):
    from repro.data.tokens import TokenStream
    from repro.models import transformer as T
    from repro.models.lm_steps import make_train_step

    batch, seq = (8, 128) if smoke else (256, 4096)
    stream = TokenStream(vocab=cfg.vocab, seq_len=seq, global_batch=batch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    step_fn = make_train_step(cfg)

    def make_batch(step):
        toks, tgts = stream.batch(step)
        return dict(tokens=jnp.asarray(toks), targets=jnp.asarray(tgts))

    def apply(params, opt, b):
        return step_fn(params, opt, b["tokens"], b["targets"])

    return params, apply, make_batch


def _gnn_setup(arch, cfg, smoke: bool):
    from repro.models.gnn_steps import (FORWARD, batch_molecules,
                                        batch_from_graph, make_gnn_train_step)
    from repro.graph.generators import random_geometric

    _, init, _, _ = FORWARD[arch]
    if arch in ("schnet", "dimenet", "mace"):
        d_feat = 16
        n_graphs = 8 if smoke else 128
        b0 = batch_molecules(n_graphs, 12, d_feat, with_triplets=(arch == "dimenet"))
    else:
        d_feat = 16
        n_graphs = 1
        g = random_geometric(256 if smoke else 4096, seed=0)
        b0 = batch_from_graph(g, d_feat)
    params = init(cfg, jax.random.PRNGKey(0), d_feat)
    step_fn = make_gnn_train_step(arch, cfg, n_graphs)

    def make_batch(step):
        return {k: jnp.asarray(v) for k, v in b0.items()}

    def apply(params, opt, b):
        return step_fn(params, opt, b)

    return params, apply, make_batch


def _recsys_setup(cfg, smoke: bool):
    from repro.models import recsys as R

    batch = 256 if smoke else 65536
    params = R.init_params(cfg, jax.random.PRNGKey(0))
    step_fn = R.make_train_step(cfg)

    def make_batch(step):
        return {k: jnp.asarray(v)
                for k, v in R.synth_batch(cfg, batch, seed=step).items()}

    def apply(params, opt, b):
        return step_fn(params, opt, b)

    return params, apply, make_batch


def train(arch: str, steps: int = 50, smoke: bool = True,
          ckpt_dir: Optional[str] = None, resume: bool = False,
          ckpt_every: int = 10, log_every: int = 10,
          fail_at_step: Optional[int] = None) -> Dict[str, Any]:
    """Returns dict(final_loss, losses, restored_from). `fail_at_step`
    simulates a node failure mid-run (tests exercise restart)."""
    spec = get_arch(arch)
    cfg = spec.build_smoke() if smoke else spec.build()
    if spec.family == "lm":
        params, apply, make_batch = _lm_setup(cfg, smoke)
    elif spec.family == "gnn":
        params, apply, make_batch = _gnn_setup(arch, cfg, smoke)
    elif spec.family == "recsys":
        params, apply, make_batch = _recsys_setup(cfg, smoke)
    else:
        raise ValueError(f"train.py drives lm/gnn/recsys archs, not "
                         f"{spec.family}; use repro.launch.mce_run")

    opt = adamw_init(params)
    start = 0
    restored_from = None
    mgr = CheckpointManager(ckpt_dir, keep=3) if ckpt_dir else None
    if mgr and resume and mgr.latest_step() is not None:
        (params, opt), start, meta = mgr.restore((params, opt))
        restored_from = start
    jit_step = jax.jit(apply, donate_argnums=(0, 1))

    losses = []
    t0 = time.time()
    loop_ok = False
    try:
        for step in range(start, steps):
            if fail_at_step is not None and step == fail_at_step:
                raise RuntimeError(f"simulated node failure at step {step}")
            batch = make_batch(step)
            params, opt, loss = jit_step(params, opt, batch)
            if step % log_every == 0 or step == steps - 1:
                lv = float(loss)
                losses.append((step, lv))
                print(f"step {step:5d} loss {lv:.4f} "
                      f"({(time.time()-t0):.1f}s)", flush=True)
            if mgr and (step + 1) % ckpt_every == 0:
                mgr.save_async(step + 1, (params, opt), meta=dict(arch=arch))
        loop_ok = True
    finally:
        # flush the async writer even on the failure path — an in-flight
        # snapshot must commit (or surface its error) before we propagate,
        # otherwise resume races the worker thread for latest_step()
        if mgr:
            try:
                mgr.wait()
            except Exception as flush_err:
                if loop_ok:
                    raise
                # a training exception is already propagating — the flush
                # error must not mask it, but leave a diagnostic trail
                print(f"WARNING: checkpoint flush failed during error "
                      f"propagation: {flush_err!r}", file=sys.stderr,
                      flush=True)
    if mgr:
        mgr.save(steps, (params, opt), meta=dict(arch=arch))
    return dict(final_loss=float(loss), losses=losses,
                restored_from=restored_from, params=params)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()
    out = train(args.arch, steps=args.steps, smoke=args.smoke,
                ckpt_dir=args.ckpt, resume=args.resume)
    print(f"final loss: {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
