"""Distributed MCE launcher: the paper's RMCE over a device mesh.

Usage:
  python -m repro.launch.mce_run --graph ba:n=2000,m=6 --backend pivot
  python -m repro.launch.mce_run --graph rgg:n=5000 --no-global-red
  python -m repro.launch.mce_run --graph er:n=300,p=0.2 --ckpt /tmp/mce.json
  python -m repro.launch.mce_run --graph ba:n=5000,m=8 --engine auto

Before shipping changes to anything this launcher dispatches (driver,
engine, kernels), run the repo's static analyzer — it catches the bug
classes this codebase has actually shipped (vmap-unsafe kernel
accumulators, tracer leaks into Python control flow, donation
use-after-free, layering violations):

  PYTHONPATH=src python -m repro.analysis src/repro --strict

(or `mce_lint src/repro --strict` once installed). See DESIGN.md §7 for
the rule families and the suppression syntax.
"""
from __future__ import annotations

import argparse
import time

from repro.core.engine import EngineConfig
from repro.core.driver import DistributedMCE
from repro.graph import generators as gen


def _num(v: str):
    """int where possible, float fallback — '1e-3' and '2.5' both parse."""
    try:
        return int(v)
    except ValueError:
        return float(v)


def parse_graph(desc: str):
    """'family:key=val,...' -> CSRGraph."""
    fam, _, rest = desc.partition(":")
    kw = {}
    if rest:
        for kv in rest.split(","):
            k, _, v = kv.partition("=")
            kw[k] = _num(v)
    if fam == "er":
        return gen.erdos_renyi(int(kw.get("n", 500)), kw.get("p", 0.1),
                               seed=int(kw.get("seed", 0)))
    if fam == "ba":
        return gen.barabasi_albert(int(kw.get("n", 2000)),
                                   int(kw.get("m", 4)),
                                   seed=int(kw.get("seed", 0)))
    if fam == "rgg":
        return gen.random_geometric(int(kw.get("n", 2000)),
                                    seed=int(kw.get("seed", 0)))
    if fam == "road":
        return gen.grid_road(int(kw.get("side", 64)),
                             seed=int(kw.get("seed", 0)))
    if fam == "caveman":
        return gen.caveman(int(kw.get("c", 50)), int(kw.get("k", 8)),
                           seed=int(kw.get("seed", 0)))
    if fam == "kron":
        return gen.kronecker(int(kw.get("scale", 12)),
                             int(kw.get("ef", 8)), seed=int(kw.get("seed", 0)))
    raise ValueError(f"unknown graph family {fam}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="ba:n=2000,m=6")
    ap.add_argument("--backend",
                    choices=("pivot", "rcd", "revised", "hybrid"),
                    default="pivot",
                    help="hybrid: pivot branching plus per-node early "
                         "termination / X-domination pruning and a "
                         "density-triggered vertex-branch switch "
                         "(DESIGN.md §2.7)")
    ap.add_argument("--chunk", type=int, default=512)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--no-global-red", dest="gred", action="store_false")
    ap.add_argument("--no-dynamic-red", dest="dred", action="store_false")
    ap.add_argument("--no-x-red", dest="xred", action="store_false")
    ap.add_argument("--materialize", action="store_true",
                    help="legacy mode: pack every bucket before device step 1")
    ap.add_argument("--stream-roots", type=int, default=1024,
                    help="streamed bucket flush size (part of the elastic "
                         "schedule identity — keep it fixed across restarts)")
    ap.add_argument("--split-threshold", type=int, default=None)
    ap.add_argument("--engine", choices=("perroot", "persistent", "auto"),
                    default="perroot",
                    help="perroot: lock-step vmap over chunk roots; "
                         "persistent: lane-refill work queue (one while_loop "
                         "per shard, exhausted lanes claim the next root); "
                         "auto: per-bucket choice from the root-cost skew")
    ap.add_argument("--lanes", type=int, default=64,
                    help="persistent engine: resident DFS lanes per shard")
    ap.add_argument("--no-steal", dest="steal", action="store_false",
                    help="persistent engine: disable lane work-stealing "
                         "(idle lanes adopting half of a victim lane's "
                         "shallowest splittable branch set)")
    ap.add_argument("--steal-victim", choices=("branchiest", "deepest"),
                    default="branchiest",
                    help="steal victim policy: 'branchiest' picks the lane "
                         "with the largest donation-slot branch set, "
                         "'deepest' the legacy deepest lane (pure "
                         "scheduling — counters/sets bit-identical)")
    ap.add_argument("--window-steps", type=int, default=0,
                    help="walk this many DFS frame-steps per stack "
                         "round-trip over a VMEM-resident stack window "
                         "(0 = one step per trip). Per-root walks need "
                         "pivot + --no-dynamic-red; the persistent engine "
                         "windows every config (fused kernel when "
                         "eligible, windowed dfs_step otherwise)")
    args = ap.parse_args()

    g = parse_graph(args.graph)
    print(f"graph: n={g.n} m={g.m}")
    t0 = time.time()
    drv = DistributedMCE(
        g, chunk=args.chunk, ckpt_path=args.ckpt,
        cfg=EngineConfig(dynamic_red=args.dred, backend=args.backend,
                         steal=args.steal, steal_victim=args.steal_victim,
                         window_steps=args.window_steps),
        global_red=args.gred, x_red=args.xred,
        streaming=not args.materialize, stream_roots=args.stream_roots,
        split_threshold=args.split_threshold,
        engine=args.engine, lanes=args.lanes)
    init_s = time.time() - t0
    t0 = time.time()
    res = drv.run(resume=args.resume)
    run_s = time.time() - t0
    print(f"maximal cliques: {res.cliques} "
          f"(pre-reported {res.pre_reported}, calls {res.calls}, "
          f"branches {res.branches})")
    if res.iters_exhausted:
        print("WARNING: max_iters hit — counts are a lower bound; "
              "raise EngineConfig.max_iters")
    tm = drv.stream.timings if drv.stream is not None else {}
    stage_str = " ".join(f"{k} {v:.2f}s" for k, v in tm.items())
    n_buckets = (drv.stream.num_buckets if drv.stream is not None
                 else len(drv.prep.buckets))
    print(f"prep stages: {stage_str or f'(materialized in {init_s:.2f}s)'}")
    print(f"run {run_s:.2f}s  shards={drv.n_shards} buckets={n_buckets} "
          f"chunks={drv.stats['chunks']}  "
          f"device_wait {drv.stats['device_wait_s']:.2f}s  "
          f"host_pack {drv.stats['host_pack_s']:.2f}s "
          f"(overlapped {100 * drv.overlap_fraction:.0f}%)")
    if args.engine == "auto":
        print(f"engine choices: {drv.stats['engine_choices']}")
    lc = drv.last_counters
    if lc.get("lane_iters"):
        print(f"lane occupancy: {lc['live_iters'] / lc['lane_iters']:.2f} "
              f"(live {lc['live_iters']} / capacity {lc['lane_iters']})")
    if lc.get("steals") or lc.get("entry_terms"):
        print(f"queue: steals={lc.get('steals', 0)} "
              f"entry_terms={lc.get('entry_terms', 0)}")
    wtrips = lc.get("window_spills", 0) + lc.get("window_hits", 0)
    if wtrips:
        print(f"window: spills={lc['window_spills']} "
              f"hits={lc['window_hits']} "
              f"boundary_stall={lc['window_spills'] / wtrips:.2f}")


if __name__ == "__main__":
    main()
