import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()
"""Multi-pod dry-run: lower + compile every (arch × shape) on the production
meshes, record memory/cost/collective analysis for the roofline.

MUST be run as a module entry (``python -m repro.launch.dryrun``) or imported
before anything else touches jax — the XLA_FLAGS line above executes before
any jax import so `jax.make_mesh((2,16,16), ...)` can build 512 host devices.

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x7b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out experiments/dryrun
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback
from typing import Dict, Optional

import jax
import numpy as np

# TPU v5e hardware model (per chip)
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # B/s
ICI_BW = 50e9                     # B/s per link (~per-axis effective)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "opaque": 0,
}

_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"(\(?[a-z0-9\[\],{}\s/_]*\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * b
    return total


_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_GROUPS_ITOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _group_size(line: str) -> int:
    m = _GROUPS_ITOTA_RE.search(line)
    if m:  # iota format [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if not m:
        return 1
    first = m.group(1).split("},{")[0]
    return max(1, first.count(",") + 1)


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-op-kind {count, bytes, link_bytes} from post-SPMD optimized HLO.

    link_bytes ≈ per-device bytes crossing ICI, ring-algorithm model:
      all-reduce       2 (g-1)/g × size
      all-gather         (g-1)/g × size(output)   [per-shard input × (g-1)]
      reduce-scatter     (g-1)/g × size(input)
      all-to-all         (g-1)/g × size
      collective-permute          size
    `-start/-done` async pairs are counted once (at -start; bare ops too).
    """
    out: Dict[str, Dict[str, float]] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_RE.match(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2).lower()
        size = _shape_bytes(shape_str)
        g = _group_size(line)
        if g <= 1:
            link = 0.0
        elif kind == "all-reduce":
            link = 2.0 * (g - 1) / g * size
        elif kind == "collective-permute":
            link = float(size)
        else:
            link = (g - 1) / g * size
        rec = out.setdefault(kind, dict(count=0, bytes=0.0, link_bytes=0.0))
        rec["count"] += 1
        rec["bytes"] += size
        rec["link_bytes"] += link
    return out


@dataclasses.dataclass
class DryrunRecord:
    arch: str
    cell: str
    kind: str
    mesh: str
    n_devices: int
    ok: bool
    error: Optional[str] = None
    compile_s: float = 0.0
    # per-device terms from the partitioned module
    flops_per_device: float = 0.0
    bytes_per_device: float = 0.0
    peak_memory_per_device: float = 0.0
    argument_bytes: float = 0.0
    output_bytes: float = 0.0
    collectives: Dict = dataclasses.field(default_factory=dict)
    link_bytes_per_device: float = 0.0
    model_flops: float = 0.0
    # secondary: raw XLA cost_analysis numbers (while bodies counted once)
    flops_ca: float = 0.0
    bytes_ca: float = 0.0
    # roofline terms (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    note: str = ""


def _measure(prog, mesh, save_hlo: Optional[str] = None) -> Dict[str, float]:
    """Lower + compile one CellProgram; return per-device terms.

    Primary flops/bytes/link come from the trip-count-weighted HLO walker
    (repro.launch.hlo_cost) — ``cost_analysis()`` counts while bodies once
    (verified, see EXPERIMENTS.md §Methodology) and is kept as a secondary
    record (flops_ca / bytes_ca)."""
    from repro.launch.hlo_cost import analyze, xla_cost_analysis

    t0 = time.time()
    with mesh:
        jitted = jax.jit(prog.fn, donate_argnums=prog.donate)
        lowered = jitted.lower(*prog.args)
        compiled = lowered.compile()
    out = dict(compile_s=time.time() - t0)
    cost = xla_cost_analysis(compiled)
    out["flops_ca"] = float(cost.get("flops", 0.0))
    out["bytes_ca"] = float(cost.get("bytes accessed", 0.0))
    try:
        mem = compiled.memory_analysis()
        out["peak"] = float(getattr(mem, "peak_memory_in_bytes", 0))
        out["args"] = float(getattr(mem, "argument_size_in_bytes", 0))
        out["outs"] = float(getattr(mem, "output_size_in_bytes", 0))
    except Exception:
        out["peak"] = out["args"] = out["outs"] = 0.0
    hlo = compiled.as_text()
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    w = analyze(hlo)
    out["flops"] = w["flops"]
    out["bytes"] = w["bytes"]
    out["link"] = w["link"]
    out["collectives"] = w["collectives"]
    return out


def run_cell(arch: str, cell_name: str, multi_pod: bool,
             save_hlo: Optional[str] = None,
             cfg_map=None) -> DryrunRecord:
    """One dry-run cell: lower + compile + trip-count-weighted HLO costing.

    `cfg_map` (LM family): config transform hook used by the §Perf
    hillclimb to lower optimized variants of the same cell."""
    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    n_dev = int(np.prod(list(mesh.shape.values())))
    rec = DryrunRecord(arch=arch, cell=cell_name, kind="?", mesh=mesh_name,
                       n_devices=n_dev, ok=False)
    try:
        prog = build_cell(arch, cell_name, mesh, cfg_map=cfg_map)
        rec.kind = prog.kind
        rec.model_flops = prog.model_flops
        rec.note = prog.note
        m = _measure(prog, mesh, save_hlo)
        rec.compile_s = m["compile_s"]
        rec.peak_memory_per_device = m["peak"]
        rec.argument_bytes = m["args"]
        rec.output_bytes = m["outs"]
        rec.collectives = m["collectives"]
        rec.flops_ca = m["flops_ca"]
        rec.bytes_ca = m["bytes_ca"]
        rec.flops_per_device = m["flops"]
        rec.bytes_per_device = m["bytes"]
        rec.link_bytes_per_device = m["link"]
        rec.t_compute = m["flops"] / PEAK_FLOPS_BF16
        rec.t_memory = m["bytes"] / HBM_BW
        rec.t_collective = m["link"] / ICI_BW
        terms = dict(compute=rec.t_compute, memory=rec.t_memory,
                     collective=rec.t_collective)
        rec.bottleneck = max(terms, key=terms.get)
        rec.ok = True
    except Exception as e:
        rec.error = f"{type(e).__name__}: {e}\n{traceback.format_exc()}"
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=("on", "off", "both"),
                    default="off")
    ap.add_argument("--out", default=None, help="JSON output directory")
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args()

    from repro.launch.cells import all_cells

    todo = []
    if args.all:
        todo = [(a, c, s) for a, c, s in all_cells()]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch/--shape or --all required")
        todo = [(args.arch, args.shape, None)]

    pods = {"on": [True], "off": [False], "both": [False, True]}[args.multi_pod]
    if args.out:
        os.makedirs(args.out, exist_ok=True)

    n_fail = 0
    for arch, cell, skip in todo:
        for mp in pods:
            mesh_name = "2x16x16" if mp else "16x16"
            tag = f"{arch}/{cell}@{mesh_name}"
            if skip:
                print(f"[SKIP] {tag}: {skip}")
                if args.out:
                    rec = DryrunRecord(arch=arch, cell=cell, kind="skip",
                                       mesh=mesh_name, n_devices=0, ok=True,
                                       note=f"SKIPPED: {skip}")
                    _dump(args.out, rec)
                continue
            rec = run_cell(arch, cell, mp, save_hlo=args.save_hlo)
            if rec.ok:
                print(f"[ OK ] {tag}: compile={rec.compile_s:.1f}s "
                      f"flops/dev={rec.flops_per_device:.3e} "
                      f"bytes/dev={rec.bytes_per_device:.3e} "
                      f"link/dev={rec.link_bytes_per_device:.3e} "
                      f"peakmem/dev={rec.peak_memory_per_device/2**30:.2f}GiB "
                      f"bottleneck={rec.bottleneck}")
            else:
                n_fail += 1
                first = rec.error.splitlines()[0] if rec.error else "?"
                print(f"[FAIL] {tag}: {first}")
            if args.out:
                _dump(args.out, rec)
    print(f"dry-run finished: {n_fail} failures")
    return 1 if n_fail else 0


def _dump(out_dir: str, rec: DryrunRecord) -> None:
    name = f"{rec.arch}__{rec.cell}__{rec.mesh}.json".replace("/", "_")
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(dataclasses.asdict(rec), f, indent=1)


if __name__ == "__main__":
    sys.exit(main())
