"""Analytic MODEL_FLOPS per (arch × shape): the "useful work" definition.

LM follows the brief: 6·N·D (train) / 2·N·D (inference) with N = active
params. GNN/recsys count the model's actual einsum structure (message MLPs,
triplet bilinear forms, irrep tensor products, tower GEMMs) — forward ×1,
train ×3 (fwd + ~2× bwd). Scatter/gather adds bytes, not flops.
"""
from __future__ import annotations

TRAIN_MULT = 3.0      # fwd + 2x bwd


def _mlp_flops(batch: float, dims) -> float:
    return 2.0 * batch * sum(a * b for a, b in zip(dims[:-1], dims[1:]))


def gnn_model_flops(arch: str, cfg, meta: dict) -> float:
    n, e = float(meta["raw_nodes"]), float(meta["raw_edges"])
    f = float(meta["d_feat"])
    if arch == "meshgraphnet":
        h = float(cfg.d_hidden)
        fwd = (_mlp_flops(n, (f, h, h)) + _mlp_flops(e, (4, h, h))
               + cfg.n_layers * (_mlp_flops(e, (3 * h, h, h))
                                 + _mlp_flops(n, (2 * h, h, h)))
               + _mlp_flops(n, (h, h, 1)))
    elif arch == "schnet":
        h = float(cfg.d_hidden)
        r = float(cfg.n_rbf)
        fwd = (_mlp_flops(n, (f, h))
               + cfg.n_interactions * (_mlp_flops(e, (r, h, h))
                                       + _mlp_flops(n, (h, h)) + e * h
                                       + _mlp_flops(n, (h, h, h)))
               + _mlp_flops(n, (h, h // 2, 1)))
    elif arch == "dimenet":
        h = float(cfg.d_hidden)
        t = float(meta.get("n_triplets", meta["raw_edges"] * 16))
        sbf = cfg.n_spherical * cfg.n_radial
        fwd = (_mlp_flops(n, (f, h)) + _mlp_flops(e, (2 * h + cfg.n_radial, h))
               + cfg.n_blocks * (
                   2.0 * t * sbf * cfg.n_bilinear              # sbf @ w_sbf
                   + 2.0 * t * cfg.n_bilinear * h * h          # bilinear form
                   + _mlp_flops(e, (h, h)) * 2                 # msg + upd
                   + 2.0 * e * cfg.n_radial * h)
               + _mlp_flops(n, (h, h, 1)))
    elif arch == "mace":
        h = float(cfg.d_hidden)
        irr = 9.0
        tp = 2.0 * irr * irr * irr * h                         # gaunt product
        fwd = (_mlp_flops(n, (f, h))
               + cfg.n_layers * (
                   _mlp_flops(e, (cfg.n_rbf, h, h))            # radial
                   + 2.0 * e * irr * h * h                     # w_msg
                   + e * tp                                    # msg product
                   + (cfg.correlation - 1) * n * tp            # product basis
                   + cfg.correlation * 2.0 * n * irr * h * h   # w_prod mixes
                   + 2.0 * n * irr * h * h)                    # w_upd
               + _mlp_flops(n, (h, h // 2, 1)))
    else:
        raise KeyError(arch)
    return TRAIN_MULT * fwd


def recsys_model_flops(cfg, kind: str, meta: dict) -> float:
    b = float(meta.get("batch", 1))
    u_in = cfg.d_id * 2 + cfg.d_small + cfg.d_dense
    i_in = cfg.d_id + cfg.d_small
    u_tower = _mlp_flops(1, (u_in,) + cfg.tower_mlp)
    i_tower = _mlp_flops(1, (i_in,) + cfg.tower_mlp)
    d = cfg.tower_mlp[-1]
    if kind == "train":
        return TRAIN_MULT * (b * (u_tower + i_tower) + 2.0 * b * b * d)
    if kind == "serve":
        return b * u_tower + 2.0 * b * 256 * d
    if kind == "bulk":
        return b * (u_tower + i_tower)
    if kind == "retrieval":
        c = float(meta["n_candidates"])
        return b * u_tower + c * i_tower + 2.0 * c * d
    raise KeyError(kind)
