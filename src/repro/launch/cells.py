"""Dry-run cell builders: (architecture × input shape × mesh) → lowerable.

For every cell this module produces ``CellProgram``: a jit-able step
function plus ShapeDtypeStruct arguments carrying NamedShardings — lowering
never allocates the (multi-TB) full-size arrays. One builder per arch
family; the launcher and the roofline harness both consume it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchSpec, ShapeCell, get_arch
from repro.launch.mesh import data_axes


@dataclasses.dataclass
class CellProgram:
    arch: str
    cell: str
    kind: str
    fn: Callable                     # jit-able step function
    args: Tuple[Any, ...]            # ShapeDtypeStructs with .sharding
    donate: Tuple[int, ...] = ()
    static: Dict[str, Any] = dataclasses.field(default_factory=dict)
    # MODEL_FLOPS (useful work definition) for the roofline's utilisation row
    model_flops: float = 0.0
    note: str = ""


def _sds(shape, dtype, mesh, spec) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, dtype,
                                sharding=NamedSharding(mesh, spec))


def _shard_tree(shapes_tree, specs_tree, mesh):
    """Zip a ShapeDtypeStruct tree with a PartitionSpec tree -> sharded SDS."""
    return jax.tree.map(
        lambda s, p: _sds(s.shape, s.dtype, mesh, p), shapes_tree, specs_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def _replicated_tree(shapes_tree, mesh):
    return jax.tree.map(
        lambda s: _sds(s.shape, s.dtype, mesh, P()), shapes_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


# ===========================================================================
# LM family
# ===========================================================================

def _lm_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh,
             cfg_map=None) -> CellProgram:
    from repro.models import transformer as T
    from repro.models.lm_steps import make_train_step, make_prefill_step
    from repro.optim import adamw_init
    from repro.sharding.lm import lm_sharding, opt_state_specs

    cfg = spec.build()
    if cfg_map is not None:
        cfg = cfg_map(cfg)
    dp = data_axes(mesh)
    sh = lm_sharding(cfg, mesh, dp_axes=dp)
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda: T.init_params(cfg, key))
    params_sds = _shard_tree(params_shape, sh.param_specs, mesh)

    seq = cell.meta["seq_len"]
    batch = cell.meta["global_batch"]
    tok_spec = sh.token_spec(batch)

    n_active = cfg.active_param_count()
    if cell.kind == "train":
        opt_shape = jax.eval_shape(lambda: adamw_init(params_shape))
        opt_sds = _shard_tree(opt_shape, opt_state_specs(sh), mesh)
        tokens = _sds((batch, seq), jnp.int32, mesh, tok_spec)
        targets = _sds((batch, seq), jnp.int32, mesh, tok_spec)
        fn = make_train_step(cfg)
        model_flops = 6.0 * n_active * batch * seq
        return CellProgram(spec.name, cell.name, "train", fn,
                           (params_sds, opt_sds, tokens, targets),
                           donate=(0, 1), model_flops=model_flops)
    if cell.kind == "prefill":
        tokens = _sds((batch, seq), jnp.int32, mesh, tok_spec)
        fn = make_prefill_step(cfg)
        model_flops = 2.0 * n_active * batch * seq
        return CellProgram(spec.name, cell.name, "prefill", fn,
                           (params_sds, tokens), model_flops=model_flops)
    # decode: one new token against a seq_len KV cache
    cache_shape = jax.eval_shape(
        lambda: T.init_cache(cfg, batch, seq))
    cache_sds = _shard_tree(cache_shape,
                            sh.cache_spec(cfg, batch, T.cache_len(cfg, seq)),
                            mesh)
    token = _sds((batch, 1), jnp.int32, mesh, tok_spec)
    fn = lambda p, c, t: T.decode_step(cfg, p, c, t)
    model_flops = 2.0 * n_active * batch * 1
    return CellProgram(spec.name, cell.name, "decode", fn,
                       (params_sds, cache_sds, token), donate=(1,),
                       model_flops=model_flops)


# ===========================================================================
# GNN family
# ===========================================================================

def _gnn_param_flops(arch: str, cfg, meta) -> float:
    from repro.launch.flops import gnn_model_flops
    return gnn_model_flops(arch, cfg, meta)


def _gnn_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh) -> CellProgram:
    from repro.models import gnn as G
    from repro.models.gnn_steps import FORWARD, make_gnn_train_step
    from repro.optim import adamw_init
    from repro.sharding.gnn import gnn_sharding

    cfg = spec.build()
    meta = dict(cell.meta)
    if spec.name != "dimenet":
        meta["n_triplets"] = 0
    dp = data_axes(mesh)
    sh = gnn_sharding(mesh, meta, dp_axes=dp)

    shapes = G.GraphShapes(n_nodes=meta["n_nodes"], n_edges=meta["n_edges"],
                           d_feat=meta["d_feat"],
                           n_triplets=meta.get("n_triplets", 0),
                           n_graphs=meta.get("n_graphs", 1))
    batch_shape = G.batch_spec(shapes)
    batch_sds = {k: _sds(v.shape, v.dtype, mesh, sh.batch_specs[k])
                 for k, v in batch_shape.items()}

    _, init, fwd, _ = FORWARD[spec.name]
    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda: init(cfg, key, meta["d_feat"]))
    params_sds = _replicated_tree(params_shape, mesh)
    opt_shape = jax.eval_shape(lambda: adamw_init(params_shape))
    opt_sds = _replicated_tree(opt_shape, mesh)

    fn = make_gnn_train_step(spec.name, cfg, meta.get("n_graphs", 1))
    return CellProgram(spec.name, cell.name, "train", fn,
                       (params_sds, opt_sds, batch_sds), donate=(0, 1),
                       model_flops=_gnn_param_flops(spec.name, cfg, meta))


# ===========================================================================
# Recsys family
# ===========================================================================

def _recsys_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh) -> CellProgram:
    from repro.models import recsys as R
    from repro.optim import adamw_init
    from repro.sharding.recsys import recsys_sharding

    cfg = spec.build()
    dp = data_axes(mesh)
    kind = {"train": "train", "serve": "serve", "bulk": "bulk",
            "retrieval": "retrieval"}[cell.kind]
    sh = recsys_sharding(cfg, mesh, kind, cell.meta, dp_axes=dp)

    key = jax.random.PRNGKey(0)
    params_shape = jax.eval_shape(lambda: R.init_params(cfg, key))
    params_sds = _shard_tree(params_shape, sh.param_specs, mesh)

    batch = cell.meta.get("batch", 1)
    spec_map = R.batch_spec(cfg, kind, batch,
                            n_candidates=cell.meta.get("n_candidates", 0))
    batch_sds = {k: _sds(v.shape, v.dtype, mesh, sh.batch_specs[k])
                 for k, v in spec_map.items()}

    from repro.launch.flops import recsys_model_flops
    model_flops = recsys_model_flops(cfg, kind, cell.meta)
    if kind == "train":
        opt_shape = jax.eval_shape(lambda: adamw_init(params_shape))
        opt_specs = dict(mu=sh.param_specs, nu=sh.param_specs, step=P())
        opt_sds = _shard_tree(opt_shape, opt_specs, mesh)
        fn = R.make_train_step(cfg)
        return CellProgram(spec.name, cell.name, "train", fn,
                           (params_sds, opt_sds, batch_sds), donate=(0, 1),
                           model_flops=model_flops)
    fn = {"serve": R.make_serve_step, "bulk": R.make_bulk_score_step,
          "retrieval": R.make_retrieval_step}[kind](cfg)
    return CellProgram(spec.name, cell.name, kind, fn,
                       (params_sds, batch_sds), model_flops=model_flops)


# ===========================================================================
# MCE (the paper's own arch)
# ===========================================================================

def _mce_cell(spec: ArchSpec, cell: ShapeCell, mesh: Mesh) -> CellProgram:
    from repro.core.engine import EngineConfig
    from repro.core.driver import _sharded_counts

    cfg_arch = spec.build()
    dp = data_axes(mesh)
    n_shards = int(np.prod([mesh.shape[a] for a in dp]))
    m = cell.meta
    r, u, xc = m["roots_chunk"], m["u_pad"], m["x_pad"]
    w = u // 32
    ecfg = EngineConfig(dynamic_red=cfg_arch.dynamic_red,
                        backend=cfg_arch.backend, out_cap=0,
                        max_iters=1 << 20)
    sp = P(dp)
    a = _sds((n_shards, r, u, w), jnp.uint32, mesh, sp)
    p0 = _sds((n_shards, r, w), jnp.uint32, mesh, sp)
    xr = _sds((n_shards, r, xc, w), jnp.uint32, mesh, sp)
    xa = _sds((n_shards, r, xc), jnp.bool_, mesh, sp)
    rz = _sds((n_shards, r), jnp.int32, mesh, sp)

    def fn(a_, p_, x_, l_, z_):
        return _sharded_counts(a_, p_, x_, l_, z_, ecfg, mesh, dp)

    # per while-iteration useful work: deg_P popcount rows over (U, W) words
    model_flops = float(n_shards * r * u * w)
    return CellProgram(spec.name, cell.name, "mce", fn, (a, p0, xr, xa, rz),
                       model_flops=model_flops,
                       note="flops counted per DFS iteration (while_loop "
                            "body), not per full enumeration")


# ===========================================================================
# Dispatcher
# ===========================================================================

def build_cell(arch: str, cell_name: str, mesh: Mesh,
               cfg_map=None) -> CellProgram:
    """cfg_map (LM family only): transform the model config before building
    — the dry-run's roofline calibration lowers 1-/2-layer unrolled variants
    with it (see launch/dryrun.py --calibrated)."""
    spec = get_arch(arch)
    cfg = spec.build()
    cells = {c.name: c for c in spec.shapes(cfg)}
    cell = cells[cell_name]
    if cell.skip_reason:
        raise ValueError(f"cell {arch}/{cell_name} is skipped: "
                         f"{cell.skip_reason}")
    if spec.family == "lm":
        return _lm_cell(spec, cell, mesh, cfg_map=cfg_map)
    builder = {"gnn": _gnn_cell, "recsys": _recsys_cell,
               "mce": _mce_cell}[spec.family]
    return builder(spec, cell, mesh)


def input_specs(arch: str, cell_name: str, mesh: Mesh):
    """ShapeDtypeStruct stand-ins (with shardings) for every input of the
    cell's step function — the no-allocation dry-run contract."""
    return build_cell(arch, cell_name, mesh).args


def all_cells():
    """Yield (arch, cell_name, skip_reason|None) over the assignment matrix."""
    from repro.configs import list_archs
    for arch in list_archs():
        spec = get_arch(arch)
        cfg = spec.build()
        for cell in spec.shapes(cfg):
            yield arch, cell.name, cell.skip_reason
