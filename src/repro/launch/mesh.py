"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — the dry-run must set XLA_FLAGS before the
first jax device query, and tests must see the real 1-device CPU.
"""
from __future__ import annotations

from typing import Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 single-pod (256 chips) or 2×16×16 multi-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> Tuple[str, ...]:
    """The batch-parallel axes of a production mesh."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def make_host_mesh():
    """All local devices on one 'data' axis (tests, examples, single host)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
