"""Long-lived MCE service: pack once, answer many queries (DESIGN.md §6).

`serve.py`-style deployment posture for clique workloads: a resident
`PrepStream` with `cache=True` owns the packed `RootBucket`s. The first
query streams them (host packing overlapped with device execution via
the double-buffered driver); every later query — a different pivot
backend, dynamic-reduction ablation, or re-count after an elastic mesh
resize — replays the cached buckets with zero host prep.

Usage:
  PYTHONPATH=src python -m repro.launch.mce_service --graph ba:n=3000,m=6
"""
from __future__ import annotations

import argparse
import time
from typing import Optional, Sequence

from jax.sharding import Mesh

from repro.core.driver import DistributedMCE
from repro.core.engine import EngineConfig, MCEResult, PrepStream
from repro.graph.csr import CSRGraph


class MCEService:
    """Resident prepared-stream handle + per-query distributed drivers.

    `stats` accumulates occupancy/health counters ACROSS queries (cached
    replays included): `live_iters` / `lane_iters` are the useful vs
    capacity lane-trips of every engine dispatch (occupancy() = ratio),
    `truncated` counts chunks that hit cfg.max_iters with work left,
    `window_spills` / `window_hits` split windowed lane-trips by whether
    they ended at a stack boundary (boundary_stall() = spill fraction),
    and `engine_choices` tallies the per-bucket auto-policy picks. The
    per-query deltas ride on each returned result as `res.stats`.
    """

    def __init__(self, g: CSRGraph, *, mesh: Optional[Mesh] = None,
                 axis: str = "data", chunk: int = 1024,
                 bucket_sizes: Sequence[int] = (32, 64, 128, 256, 512, 1024),
                 max_x_rows: int = 8192,
                 split_threshold: Optional[int] = None,
                 stream_roots: int = 1024,
                 engine: str = "perroot", lanes: int = 64):
        self.stream = PrepStream(g, bucket_sizes=bucket_sizes,
                                 max_x_rows=max_x_rows,
                                 split_threshold=split_threshold,
                                 stream_roots=stream_roots, cache=True)
        self.mesh = mesh
        self.axis = axis
        self.chunk = chunk
        self.engine = engine
        self.lanes = lanes
        self.queries = 0
        self.stats = {"live_iters": 0, "lane_iters": 0, "truncated": 0,
                      "steals": 0, "entry_terms": 0,
                      "window_spills": 0, "window_hits": 0,
                      "engine_choices": {"perroot": 0, "persistent": 0}}

    def occupancy(self) -> float:
        """Useful lane-trips / lane-trip capacity over all queries so far."""
        cap = self.stats["lane_iters"]
        return self.stats["live_iters"] / cap if cap else 0.0

    # stream_occupancy is the health metric the window tentpole moves:
    # occupancy() already folds window trips into both numerator and
    # capacity (lane_iters scales by window_steps), so it stays the
    # cross-engine comparable ratio and this is just the named alias the
    # launch summaries print alongside boundary_stall.
    def stream_occupancy(self) -> float:
        """Alias of occupancy() under its DESIGN.md §2.6 stream name."""
        return self.occupancy()

    def boundary_stall(self) -> float:
        """Fraction of windowed lane-trips that ended at a stack boundary.

        window_spills / (window_spills + window_hits): a *spill* is a
        windowed trip that stopped short of its K steps (window overflow/
        underflow forced an HBM round-trip), a *hit* ran all K steps
        VMEM-resident. 0.0 when no windowed trips ran (window_steps=0 or
        perroot-only queries) — low is good."""
        trips = self.stats["window_spills"] + self.stats["window_hits"]
        return self.stats["window_spills"] / trips if trips else 0.0

    def query(self, cfg: EngineConfig = EngineConfig(),
              ckpt_path: Optional[str] = None,
              resume: bool = False,
              engine: Optional[str] = None,
              lanes: Optional[int] = None) -> MCEResult:
        """Run one counting query over the shared packed buckets.

        `engine`/`lanes` override the service defaults for this query
        only (e.g. A/B the persistent queue against lock-step vmap on
        identical packed buckets). Only `None` means "use the service
        default" — a falsy-but-explicit override (empty string, 0) is a
        caller error and raises instead of silently falling back."""
        if engine is None:
            engine = self.engine
        elif engine not in ("perroot", "persistent", "auto"):
            raise ValueError(f"unknown engine override {engine!r} "
                             "(expected 'perroot'|'persistent'|'auto')")
        if lanes is None:
            lanes = self.lanes
        elif not isinstance(lanes, int) or isinstance(lanes, bool) \
                or lanes < 1:
            raise ValueError(f"lanes override must be a positive int, "
                             f"got {lanes!r}")
        kwargs = {} if self.mesh is None else {"mesh": self.mesh,
                                               "axis": self.axis}
        drv = DistributedMCE(prep=self.stream, chunk=self.chunk,
                             ckpt_path=ckpt_path, cfg=cfg,
                             engine=engine, lanes=lanes, **kwargs)
        res = drv.run(resume=resume)
        self.queries += 1
        delta = {k: int(drv.last_counters.get(k, 0))
                 for k in ("live_iters", "lane_iters", "truncated",
                           "steals", "entry_terms",
                           "window_spills", "window_hits")}
        delta["engine_choices"] = dict(drv.stats["engine_choices"])
        for k in ("live_iters", "lane_iters", "truncated",
                  "steals", "entry_terms",
                  "window_spills", "window_hits"):
            self.stats[k] += delta[k]
        for k, v in delta["engine_choices"].items():
            self.stats["engine_choices"][k] += v
        res.stats = delta  # per-query slice of the accumulated service stats
        return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="ba:n=3000,m=6")
    ap.add_argument("--chunk", type=int, default=512)
    ap.add_argument("--engine", default="perroot",
                    choices=["perroot", "persistent", "auto"])
    ap.add_argument("--lanes", type=int, default=64)
    args = ap.parse_args()
    from repro.launch.mce_run import parse_graph

    g = parse_graph(args.graph)
    svc = MCEService(g, chunk=args.chunk, engine=args.engine,
                     lanes=args.lanes)
    for label, cfg in [("pivot", EngineConfig(backend="pivot")),
                       ("pivot-nodyn", EngineConfig(backend="pivot",
                                                    dynamic_red=False)),
                       ("pivot-win", EngineConfig(backend="pivot",
                                                  window_steps=8))]:
        t0 = time.time()
        res = svc.query(cfg)
        occ = (res.stats["live_iters"] / res.stats["lane_iters"]
               if res.stats["lane_iters"] else 0.0)
        wtrips = res.stats["window_spills"] + res.stats["window_hits"]
        stall = res.stats["window_spills"] / wtrips if wtrips else 0.0
        print(f"{label:12s} cliques={res.cliques} calls={res.calls} "
              f"occ={occ:.2f} stall={stall:.2f} {time.time() - t0:.2f}s "
              f"({'cold: streamed+packed' if svc.queries == 1 else 'cached buckets'})")
    print(f"service: {svc.queries} queries, "
          f"stream_occupancy {svc.stream_occupancy():.2f}, "
          f"boundary_stall {svc.boundary_stall():.2f} "
          f"(spills={svc.stats['window_spills']} "
          f"hits={svc.stats['window_hits']}), "
          f"engine_choices={svc.stats['engine_choices']}")


if __name__ == "__main__":
    main()
