"""Long-lived MCE service: pack once, answer many queries (DESIGN.md §6).

`serve.py`-style deployment posture for clique workloads: a resident
`PrepStream` with `cache=True` owns the packed `RootBucket`s. The first
query streams them (host packing overlapped with device execution via
the double-buffered driver); every later query — a different pivot
backend, dynamic-reduction ablation, or re-count after an elastic mesh
resize — replays the cached buckets with zero host prep.

Usage:
  PYTHONPATH=src python -m repro.launch.mce_service --graph ba:n=3000,m=6
"""
from __future__ import annotations

import argparse
import time
from typing import Optional, Sequence

from jax.sharding import Mesh

from repro.core.driver import DistributedMCE
from repro.core.engine import EngineConfig, MCEResult, PrepStream
from repro.graph.csr import CSRGraph


class MCEService:
    """Resident prepared-stream handle + per-query distributed drivers."""

    def __init__(self, g: CSRGraph, *, mesh: Optional[Mesh] = None,
                 axis: str = "data", chunk: int = 1024,
                 bucket_sizes: Sequence[int] = (32, 64, 128, 256, 512, 1024),
                 max_x_rows: int = 8192,
                 split_threshold: Optional[int] = None,
                 stream_roots: int = 1024):
        self.stream = PrepStream(g, bucket_sizes=bucket_sizes,
                                 max_x_rows=max_x_rows,
                                 split_threshold=split_threshold,
                                 stream_roots=stream_roots, cache=True)
        self.mesh = mesh
        self.axis = axis
        self.chunk = chunk
        self.queries = 0

    def query(self, cfg: EngineConfig = EngineConfig(),
              ckpt_path: Optional[str] = None,
              resume: bool = False) -> MCEResult:
        """Run one counting query over the shared packed buckets."""
        kwargs = {} if self.mesh is None else {"mesh": self.mesh,
                                               "axis": self.axis}
        drv = DistributedMCE(prep=self.stream, chunk=self.chunk,
                             ckpt_path=ckpt_path, cfg=cfg, **kwargs)
        res = drv.run(resume=resume)
        self.queries += 1
        return res


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="ba:n=3000,m=6")
    ap.add_argument("--chunk", type=int, default=512)
    args = ap.parse_args()
    from repro.launch.mce_run import parse_graph

    g = parse_graph(args.graph)
    svc = MCEService(g, chunk=args.chunk)
    for label, cfg in [("pivot", EngineConfig(backend="pivot")),
                       ("pivot-nodyn", EngineConfig(backend="pivot",
                                                    dynamic_red=False)),
                       ("pivot-warm", EngineConfig(backend="pivot"))]:
        t0 = time.time()
        res = svc.query(cfg)
        print(f"{label:12s} cliques={res.cliques} calls={res.calls} "
              f"{time.time() - t0:.2f}s "
              f"({'cold: streamed+packed' if svc.queries == 1 else 'cached buckets'})")


if __name__ == "__main__":
    main()
