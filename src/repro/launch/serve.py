"""Serving driver: batched LM decode loop + recsys scoring service.

Production posture: a fixed-shape decode step jitted once, a request queue
batched to the step's batch size, KV caches as device-resident state. For
recsys, the retrieval path scores a query against a candidate corpus shard.

Usage:
  python -m repro.launch.serve --arch qwen3-14b --smoke --tokens 32
  python -m repro.launch.serve --arch two-tower-retrieval --smoke
"""
from __future__ import annotations

import argparse
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch


def serve_lm(arch: str, smoke: bool = True, batch: int = 4,
             prompt_len: int = 16, new_tokens: int = 16,
             temperature: float = 0.0) -> Dict:
    """Prefill a batch of prompts, then greedy/temperature decode."""
    from repro.models import transformer as T
    from repro.models.lm_steps import make_prefill_step, make_decode_step

    spec = get_arch(arch)
    assert spec.family == "lm", f"{arch} is not an LM arch"
    cfg = spec.build_smoke() if smoke else spec.build()
    params = T.init_params(cfg, jax.random.PRNGKey(0))

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (batch, prompt_len)).astype(np.int32)

    prefill = jax.jit(make_prefill_step(cfg))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(1,))

    t0 = time.time()
    # serve caches sized for the full conversation
    total = prompt_len + new_tokens
    logits, cache = prefill(params, jnp.asarray(prompts))
    # re-home the prefill cache into a total-length buffer
    full = T.init_cache(cfg, batch, total)
    c = cache["k"].shape[2]
    full["k"] = jax.lax.dynamic_update_slice(
        full["k"], cache["k"], (0, 0, 0, 0, 0))
    full["v"] = jax.lax.dynamic_update_slice(
        full["v"], cache["v"], (0, 0, 0, 0, 0))
    cache = dict(k=full["k"], v=full["v"], pos=cache["pos"])
    t_prefill = time.time() - t0

    out_tokens: List[np.ndarray] = []
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    t0 = time.time()
    key = jax.random.PRNGKey(1)
    for i in range(new_tokens):
        out_tokens.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, cache, tok)
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits[:, -1] / temperature, axis=-1
            ).astype(jnp.int32)[:, None]
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    t_decode = time.time() - t0
    gen = np.stack(out_tokens, axis=1)
    return dict(generated=gen, prefill_s=t_prefill, decode_s=t_decode,
                tok_per_s=batch * new_tokens / max(t_decode, 1e-9))


def serve_recsys(smoke: bool = True, batch: int = 64,
                 n_candidates: int = 4096, top_k: int = 10) -> Dict:
    from repro.models import recsys as R

    spec = get_arch("two-tower-retrieval")
    cfg = spec.build_smoke() if smoke else spec.build()
    params = R.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    retrieval = jax.jit(R.make_retrieval_step(cfg, top_k=top_k))
    b = R.synth_batch(cfg, 1, seed=0, with_items=False)
    b["cand_id"] = rng.integers(0, cfg.n_items, n_candidates).astype(np.int32)
    b["cand_tags"] = rng.integers(-1, cfg.n_tags,
                                  (n_candidates, cfg.tags_len)).astype(np.int32)
    t0 = time.time()
    scores, idx = retrieval(params, {k: jnp.asarray(v) for k, v in b.items()})
    scores.block_until_ready()
    t_retrieval = time.time() - t0

    serve = jax.jit(R.make_serve_step(cfg))
    sb = R.synth_batch(cfg, batch, seed=1, with_items=False)
    sb["cand_emb"] = rng.normal(
        size=(batch, 256, cfg.tower_mlp[-1])).astype(np.float32)
    t0 = time.time()
    s = serve(params, {k: jnp.asarray(v) for k, v in sb.items()})
    s.block_until_ready()
    t_serve = time.time() - t0
    return dict(top_idx=np.asarray(idx), retrieval_s=t_retrieval,
                serve_s=t_serve, qps=batch / max(t_serve, 1e-9))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--tokens", type=int, default=16)
    args = ap.parse_args()
    spec = get_arch(args.arch)
    if spec.family == "lm":
        out = serve_lm(args.arch, smoke=args.smoke, new_tokens=args.tokens)
        print(f"prefill {out['prefill_s']:.2f}s decode {out['decode_s']:.2f}s "
              f"({out['tok_per_s']:.1f} tok/s)")
    elif spec.family == "recsys":
        out = serve_recsys(smoke=args.smoke)
        print(f"retrieval {out['retrieval_s']*1e3:.1f}ms "
              f"serve {out['serve_s']*1e3:.1f}ms ({out['qps']:.0f} qps)")
    else:
        raise SystemExit(f"serving drives lm/recsys archs, got {spec.family}")


if __name__ == "__main__":
    main()
