"""Trip-count-weighted static cost model over post-optimization HLO text.

Why: ``compiled.cost_analysis()`` counts while-loop bodies ONCE, so any
scan-over-layers model under-reports flops/bytes by ~n_layers (verified in
EXPERIMENTS.md §Roofline/Methodology). The optimized HLO carries
``known_trip_count`` on every counted loop, so this walker computes

    total[term] = Σ_computations  multiplier(comp) × raw[term](comp)

with multiplier = product of trip counts along the while/call chain from
ENTRY. Fusion-internal flops are folded into the fusion op's computation;
fusion bytes are operands+outputs of the fusion op (the HBM model — fused
elementwise chains never round-trip memory).

Costs:
  flops — dot: 2·|out|·Π(contracting dims); elementwise/reduce: |elems|;
  bytes — per op: operand bytes + output bytes (free: parameter, tuple,
          get-tuple-element, bitcast, constant, broadcast-of-scalar);
  link  — collective payload × ring factor (see ``collective_link_bytes``).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[\w\[\],{}\s/]*?\)?)\s*"
    r"([a-z][\w\-]*)\(")
_TRIP_RE = re.compile(r'known_trip_count":\{"n":"(\d+)"')
_CALLS_RE = re.compile(r"(?:calls|body|condition|to_apply|branch_computations)="
                       r"(\{[^}]*\}|%?[\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

FREE_OPS = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            "after-all", "partition-id", "replica-id", "reshape",
            "custom-call"}
ELEMENTWISE_SKIP_FLOPS = {"parameter", "constant", "get-tuple-element",
                          "tuple", "bitcast", "broadcast", "copy", "reshape",
                          "transpose", "iota", "slice", "concatenate",
                          "reverse", "after-all", "partition-id",
                          "replica-id", "convert", "dynamic-slice",
                          "dynamic-update-slice", "pad", "gather", "scatter",
                          "all-gather", "all-reduce", "reduce-scatter",
                          "all-to-all", "collective-permute", "while",
                          "conditional", "call", "custom-call", "fusion",
                          "dot", "convolution", "reduce", "reduce-window",
                          "sort", "rng", "rng-bit-generator", "copy-start",
                          "copy-done", "optimization-barrier"}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """Normalise ``compiled.cost_analysis()`` across jax versions: newer jax
    returns a dict, 0.4.x returns a list with one dict per program."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    elems_total, bytes_total = 0, 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        b = _DTYPE_BYTES.get(dt)
        if b is None:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems_total += n
        bytes_total += n * b
    if elems_total == 0 and shape_str.strip().startswith(("f", "s", "u", "p", "b")):
        # scalar like f32[] — regex above catches it with empty dims (n=1)
        pass
    return elems_total, bytes_total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Op:
    name: str
    shape: str
    opcode: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, str]
    ops: List[Op]


def parse_computations(txt: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in txt.splitlines():
        if cur is None:
            stripped = line.strip()
            m = _COMP_HDR.match(stripped)
            if m and line.rstrip().endswith("{") and "->" in line:
                # balance parens to extract the parameter list (types may be
                # tuples containing parens)
                start = m.end() - 1
                depth, end = 0, start
                for i in range(start, len(stripped)):
                    if stripped[i] == "(":
                        depth += 1
                    elif stripped[i] == ")":
                        depth -= 1
                        if depth == 0:
                            end = i
                            break
                params = {}
                plist = stripped[start + 1:end]
                # split top-level commas only (track () AND [] nesting)
                depth = 0
                cur_tok = []
                toks = []
                for ch in plist:
                    if ch in "([{":
                        depth += 1
                    elif ch in ")]}":
                        depth -= 1
                    if ch == "," and depth == 0:
                        toks.append("".join(cur_tok))
                        cur_tok = []
                    else:
                        cur_tok.append(ch)
                if cur_tok:
                    toks.append("".join(cur_tok))
                for p in toks:
                    pname, _, ptype = p.strip().partition(":")
                    if pname:
                        params[pname.strip().lstrip("%")] = ptype.strip()
                cur = Computation(m.group(1), params, [])
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        op = _parse_op_line(line)
        if op is not None:
            cur.ops.append(op)
    return comps


_ASSIGN_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*")


def _parse_op_line(line: str) -> Optional[Op]:
    """`%var = TYPE opcode(operands), attrs` — TYPE may be a tuple with
    nested parens and /*index=k*/ comments."""
    m = _ASSIGN_RE.match(line)
    if not m:
        return None
    name = m.group(1)
    rest = line[m.end():]
    if rest.startswith("("):                  # tuple type: balance parens
        depth = 0
        end = 0
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        shape = rest[:end + 1]
        rest = rest[end + 1:]
    else:                                     # plain type: first whitespace
        sp = rest.find(" ")
        if sp < 0:
            return None
        shape = rest[:sp]
        rest = rest[sp:]
    m2 = re.match(r"\s*([a-z][\w\-]*)\(", rest)
    if not m2:
        return None
    opcode = m2.group(1)
    paren = rest[m2.end() - 1:]
    depth = 0
    end = 0
    for i, ch in enumerate(paren):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    operands = _OPERAND_RE.findall(paren[:end + 1])
    return Op(name, shape, opcode, operands, line)


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{([^}]*)\}", line)
    if not m:
        return 1
    first = m.group(1).split("},{")[0]
    return max(1, first.count(",") + 1)


def collective_link_bytes(opcode: str, out_bytes: int, g: int) -> float:
    """Ring-algorithm per-device ICI traffic."""
    if g <= 1:
        return 0.0
    if opcode == "all-reduce":
        return 2.0 * (g - 1) / g * out_bytes
    if opcode == "all-gather":
        return (g - 1) / g * out_bytes          # out = full gathered value
    if opcode == "reduce-scatter":
        return (g - 1) * out_bytes              # in = out × g
    if opcode == "all-to-all":
        return (g - 1) / g * out_bytes
    if opcode == "collective-permute":
        return float(out_bytes)
    return 0.0


@dataclasses.dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    link: float = 0.0
    collectives: Dict[str, Dict[str, float]] = dataclasses.field(
        default_factory=dict)


def _resolve(comp: Computation, name: str, symbols: Dict[str, str]) -> str:
    if name in symbols:
        return symbols[name]
    return comp.params.get(name, "")


def _fusion_flops(comps, comp_name, memo) -> float:
    """Elementwise + reduce + dot flops inside a fused computation."""
    if comp_name in memo:
        return memo[comp_name]
    comp = comps.get(comp_name)
    if comp is None:
        return 0.0
    total = 0.0
    symbols = {op.name: op.shape for op in comp.ops}
    for op in comp.ops:
        total += _op_flops(comps, comp, op, symbols, memo)
    memo[comp_name] = total
    return total


def _op_flops(comps, comp, op, symbols, fusion_memo) -> float:
    oc = op.opcode
    if oc == "dot":
        out_elems, _ = shape_elems_bytes(op.shape)
        m = _CONTRACT_RE.search(op.line)
        contract = 1
        if m and op.operands:
            lhs_shape = _resolve(comp, op.operands[0], symbols)
            dims = _shape_dims(lhs_shape)
            for idx in m.group(1).split(","):
                if idx and int(idx) < len(dims):
                    contract *= dims[int(idx)]
        return 2.0 * out_elems * contract
    if oc == "convolution":
        out_elems, _ = shape_elems_bytes(op.shape)
        return 2.0 * out_elems * 128          # coarse (unused by our models)
    if oc in ("reduce", "sort"):
        if op.operands:
            in_shape = _resolve(comp, op.operands[0], symbols)
            elems, _ = shape_elems_bytes(in_shape)
            return float(elems)
        return 0.0
    if oc == "reduce-window":
        out_elems, _ = shape_elems_bytes(op.shape)
        m = re.search(r"window=\{size=([\dx]+)", op.line)
        w = 1
        if m:
            for d in m.group(1).split("x"):
                w *= int(d)
        return float(out_elems * w)
    if oc == "fusion":
        m = re.search(r"calls=%?([\w.\-]+)", op.line)
        if m:
            return _fusion_flops(comps, m.group(1), fusion_memo)
        return 0.0
    if oc in ELEMENTWISE_SKIP_FLOPS:
        return 0.0
    out_elems, _ = shape_elems_bytes(op.shape)
    return float(out_elems)                    # generic elementwise


_TRANSPARENT = ("bitcast", "reshape", "transpose", "copy",
                "get-tuple-element", "convert")


def _slice_only_bytes(comp: "Computation", name: str,
                      depth: int = 0) -> Optional[float]:
    """If every use of `name` inside the fused computation reaches a
    (dynamic-)slice through layout-transparent ops, return the sliced bytes
    actually read; else None (full read)."""
    if depth > 6:
        return None
    uses = [op for op in comp.ops if name in op.operands]
    if not uses:
        return 0.0
    total = 0.0
    for u in uses:
        if u.opcode in ("dynamic-slice", "slice"):
            total += shape_elems_bytes(u.shape)[1]
        elif u.opcode in _TRANSPARENT:
            sub = _slice_only_bytes(comp, u.name, depth + 1)
            if sub is None:
                return None
            total += sub
        else:
            return None
    return total


def _fusion_root(comp: "Computation") -> Optional[Op]:
    for op in comp.ops:
        if "ROOT" in op.line:
            return op
    return comp.ops[-1] if comp.ops else None


def _trace_dus(comp: "Computation", root: Op) -> Optional[Op]:
    """Resolve the root through transparent ops to an in-place update op
    (dynamic-update-slice or scatter — both alias their buffer operand)."""
    cur = root
    seen = 0
    by_name = {op.name: op for op in comp.ops}
    while cur is not None and seen < 6:
        if cur.opcode in ("dynamic-update-slice", "scatter"):
            return cur
        if cur.opcode in _TRANSPARENT and cur.operands:
            cur = by_name.get(cur.operands[0])
            seen += 1
            continue
        return None
    return None


def _fusion_param_bytes(comps, called: str, idx: int, full_bytes: float,
                        memo: Dict) -> float:
    """Bytes actually read from fusion parameter `idx` (slice-aware)."""
    key = (called, idx)
    if key in memo:
        return memo[key]
    comp = comps.get(called)
    out = full_bytes
    if comp is not None:
        pnames = list(comp.params)
        if idx < len(pnames):
            sliced = _slice_only_bytes(comp, pnames[idx])
            if sliced is not None:
                out = min(float(sliced), full_bytes)
    memo[key] = out
    return out


def _fusion_dus_info(comps, called: str, memo: Dict):
    """(is_dus_root, update_bytes, buffer_param_index) for a fused comp."""
    key = ("dus", called)
    if key in memo:
        return memo[key]
    comp = comps.get(called)
    res = (False, 0.0, -1)
    if comp is not None:
        root = _fusion_root(comp)
        dus = _trace_dus(comp, root) if root else None
        if dus is not None and len(dus.operands) > 1:
            by_name = {op.name: op for op in comp.ops}
            upd_idx = 2 if dus.opcode == "scatter" else 1
            upd_idx = min(upd_idx, len(dus.operands) - 1)
            upd = by_name.get(dus.operands[upd_idx])
            upd_b = shape_elems_bytes(upd.shape)[1] if upd else 0.0
            # which fusion param is the aliased buffer (operand 0 chain)?
            pidx = -1
            cur = by_name.get(dus.operands[0])
            hops = 0
            while cur is not None and hops < 6:
                if cur.opcode == "parameter":
                    pnames = list(comp.params)
                    if cur.name in pnames:
                        pidx = pnames.index(cur.name)
                    break
                cur = (by_name.get(cur.operands[0])
                       if cur.operands else None)
                hops += 1
            # parameters may appear as comp.params rather than ops
            if pidx < 0 and dus.operands[0] in comp.params:
                pidx = list(comp.params).index(dus.operands[0])
            res = (True, float(upd_b), pidx)
    memo[key] = res
    return res


def _op_bytes(comp, op, symbols, comps=None,
              fusion_bytes_memo: Optional[Dict] = None) -> float:
    oc = op.opcode
    if oc in FREE_OPS or oc == "while" or oc == "conditional" or oc == "call":
        return 0.0
    _, out_b = shape_elems_bytes(op.shape)
    if oc == "broadcast":
        in_b = sum(shape_elems_bytes(_resolve(comp, o, symbols))[1]
                   for o in op.operands)
        return float(out_b + in_b)
    if oc == "dynamic-update-slice":
        upd = (shape_elems_bytes(_resolve(comp, op.operands[1], symbols))[1]
               if len(op.operands) > 1 else out_b)
        return 2.0 * upd
    if oc == "dynamic-slice":
        return 2.0 * out_b
    if oc == "scatter":
        upd = (shape_elems_bytes(_resolve(comp, op.operands[2], symbols))[1]
               if len(op.operands) > 2 else out_b)
        return 2.0 * upd
    if oc == "fusion" and comps is not None:
        m = re.search(r"calls=%?([\w.\-]+)", op.line)
        called = m.group(1) if m else None
        memo = fusion_bytes_memo if fusion_bytes_memo is not None else {}
        is_dus, upd_b, buf_idx = (_fusion_dus_info(comps, called, memo)
                                  if called else (False, 0.0, -1))
        in_b = 0.0
        for i, o in enumerate(op.operands):
            if is_dus and i == buf_idx:
                continue      # aliased in-place buffer: not actually read
            fb = shape_elems_bytes(_resolve(comp, o, symbols))[1]
            in_b += (_fusion_param_bytes(comps, called, i, fb, memo)
                     if called else fb)
        if is_dus:
            return float(in_b + upd_b)   # write = the updated region only
        return float(in_b + out_b)
    in_b = sum(shape_elems_bytes(_resolve(comp, o, symbols))[1]
               for o in op.operands)
    return float(in_b + out_b)


def analyze(txt: str) -> Dict[str, object]:
    """Weighted totals over the module. Returns flops/bytes/link/collectives
    plus the multiplier map (for debugging)."""
    comps = parse_computations(txt)
    fusion_memo: Dict[str, float] = {}

    # raw (unweighted) per-computation costs; record call edges
    raw: Dict[str, CompCost] = {}
    edges: Dict[str, List[Tuple[str, float]]] = {c: [] for c in comps}
    fused: set = set()
    fusion_bytes_memo: Dict = {}
    for cname, comp in comps.items():
        cost = CompCost()
        symbols = {op.name: op.shape for op in comp.ops}
        for op in comp.ops:
            if op.opcode == "while":
                trip = 1.0
                m = _TRIP_RE.search(op.line)
                if m:
                    trip = float(m.group(1))
                for attr in ("body", "condition"):
                    m2 = re.search(attr + r"=%?([\w.\-]+)", op.line)
                    if m2:
                        edges[cname].append((m2.group(1), trip))
                continue
            if op.opcode in ("call", "conditional", "async-start"):
                for m2 in re.finditer(r"(?:to_apply|branch_computations=\{?|"
                                      r"called_computations=\{?)"
                                      r"%?([\w.\-]+)", op.line):
                    edges[cname].append((m2.group(1), 1.0))
                continue
            if op.opcode == "fusion":
                m2 = re.search(r"calls=%?([\w.\-]+)", op.line)
                if m2:
                    fused.add(m2.group(1))
            base = op.opcode.replace("-start", "") \
                if op.opcode.endswith("-start") else op.opcode
            if base in COLLECTIVES:
                _, out_b = shape_elems_bytes(op.shape)
                # async -start ops wrap the result in an extra tuple copy of
                # the input; use the final element heuristically: out_b is
                # tuple (in, out) for -start — halve it.
                if op.opcode.endswith("-start"):
                    out_b = out_b / 2
                g = _group_size(op.line)
                link = collective_link_bytes(base, out_b, g)
                rec = cost.collectives.setdefault(
                    base, dict(count=0, bytes=0.0, link_bytes=0.0))
                rec["count"] += 1
                rec["bytes"] += out_b
                rec["link_bytes"] += link
                cost.link += link
                cost.bytes += 2.0 * out_b     # HBM in+out of the payload
                continue
            if op.opcode.endswith("-done"):
                continue
            cost.flops += _op_flops(comps, comp, op, symbols, fusion_memo)
            cost.bytes += _op_bytes(comp, op, symbols, comps,
                                    fusion_bytes_memo)
        raw[cname] = cost

    # multipliers from ENTRY (last computation in scheduled HLO text is the
    # entry; more robustly: the one named *main* or not referenced anywhere)
    referenced = {t for outs in edges.values() for t, _ in outs}
    entry = None
    for cname in comps:
        if "main" in cname:
            entry = cname
    if entry is None:
        cands = [c for c in comps if c not in referenced and c not in fused]
        entry = cands[-1] if cands else next(iter(comps))

    mult: Dict[str, float] = {c: 0.0 for c in comps}
    mult[entry] = 1.0
    # propagate along edges to fixpoint (computations form a DAG)
    for _ in range(len(comps)):
        changed = False
        for src, outs in edges.items():
            if mult.get(src, 0.0) <= 0:
                continue
            for dst, w in outs:
                if dst in mult:
                    want = mult[src] * w
                    if want > mult[dst]:
                        mult[dst] = want
                        changed = True
        if not changed:
            break

    total = CompCost()
    for cname, cost in raw.items():
        if cname in fused:
            continue                      # folded into fusion op sites
        m = mult.get(cname, 0.0)
        if m <= 0:
            continue
        total.flops += m * cost.flops
        total.bytes += m * cost.bytes
        total.link += m * cost.link
        for k, v in cost.collectives.items():
            rec = total.collectives.setdefault(
                k, dict(count=0, bytes=0.0, link_bytes=0.0))
            rec["count"] += m * v["count"]
            rec["bytes"] += m * v["bytes"]
            rec["link_bytes"] += m * v["link_bytes"]
    return dict(flops=total.flops, bytes=total.bytes, link=total.link,
                collectives=total.collectives, multipliers=mult)
