"""TPU-native bitset Bron–Kerbosch engine with the paper's RMCE reductions.

The CPU paper's recursive, pointer-chasing search is re-derived as fixed-shape
bitset dataflow (see DESIGN.md §2):

* Per root v (degeneracy order), the *local universe* is N⁺(v) (size ≤ λ),
  packed into W = ⌈U/32⌉ uint32 words.
* `A` (U, W): induced adjacency bitsets among the universe.
* The forbidden set is split in two parts:
    - X0 rows (XC, W): P-neighbourhood bitsets of surviving earlier
      neighbours (after the ignoreId maximality-check reduction) with a
      per-frame alive mask. Earlier neighbours with an empty P-neighbourhood
      can never witness anything this root could report; dropped at prep.
    - Xp (W,): universe members moved into X (classic BK "visited" bits plus
      the dynamic-reduction advance-reported vertices).
* The recursion is an explicit DFS stack advanced by `lax.while_loop`; every
  paper reduction becomes bitset algebra (deg_P = popcount(A & P) rows — the
  paper's set-intersection hot spot, Pallas kernel on TPU).
* vmap over roots; buckets of padded (U, XC) shapes; shard_map over the mesh
  in `repro.core.driver`.

Counting is always on; enumeration into a bounded buffer is optional
(`out_cap > 0`) with an overflow flag.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Sequence, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph
from repro.graph.order import degeneracy_order
from repro.kernels.bitset_ops import ref as bitref

WORD = 32
U32 = jnp.uint32
FULL = jnp.uint32(0xFFFFFFFF)


# ===========================================================================
# Host-side preparation
# ===========================================================================

@dataclasses.dataclass
class RootBucket:
    """Fixed-shape batch of root subproblems sharing one padding."""

    u_pad: int                      # padded universe size (multiple of 32)
    x_pad: int                      # padded X0 row count
    a: np.ndarray                   # (R, U, W) uint32 induced adjacency
    p0: np.ndarray                  # (R, W) uint32 initial candidate bitset
    x_rows: np.ndarray              # (R, XC, W) uint32 X0 row bitsets
    x_alive0: np.ndarray            # (R, XC) bool
    roots: np.ndarray               # (R,) int64 original vertex ids
    rsz0: np.ndarray                # (R,) int32 |R| at entry (>1 for split roots)
    bases: List[tuple]              # per-root base clique vertices
    universes: List[np.ndarray]     # per-root local->global id maps

    @property
    def num_roots(self) -> int:
        return len(self.roots)


@dataclasses.dataclass
class PreparedMCE:
    buckets: List[RootBucket]
    pre_reported: List[frozenset]
    n: int
    degeneracy: int
    order: np.ndarray
    rank: np.ndarray


def _pack_bits(ids: np.ndarray, words: int) -> np.ndarray:
    out = np.zeros(words, dtype=np.uint32)
    if len(ids):
        np.bitwise_or.at(out, ids // WORD,
                         np.uint32(1) << (ids % WORD).astype(np.uint32))
    return out


def _stage_subproblem(staged, bucket_sizes, base, p_set, x_set,
                      adj_sorted, rank):
    """Pack one (R=base, P=p_set, X=x_set) subproblem into its bucket."""
    p_ids = np.array(sorted(p_set, key=lambda u: rank[u]), dtype=np.int64)
    u_size = len(p_ids)
    bucket = next((b for b in bucket_sizes if u_size <= b), None)
    if bucket is None:
        raise ValueError(f"universe {u_size} exceeds largest bucket")
    words = bucket // WORD
    a_rows = np.zeros((bucket, words), dtype=np.uint32)
    for j, u in enumerate(p_ids):
        mask = np.isin(p_ids, adj_sorted[int(u)], assume_unique=True)
        a_rows[j] = _pack_bits(np.nonzero(mask)[0].astype(np.int64), words)
    xr = []
    for x in sorted(x_set, key=lambda u: rank[u]):
        mask = np.isin(p_ids, adj_sorted[int(x)], assume_unique=True)
        if mask.any():
            xr.append(_pack_bits(np.nonzero(mask)[0].astype(np.int64), words))
    staged[bucket].append(dict(
        root=base[0], base=tuple(base),
        p0=_pack_bits(np.arange(u_size), words), a=a_rows,
        x_rows=xr, universe=p_ids))


def _split_root(v, p_ids, x_set, adj, rank):
    """Expand root (R={v}, P, X) one pivot-pruned BK level on the host.

    Yields (base=(v, w), P_w, X_w) per branch vertex w — identical semantics
    to one level of Algorithm 2, so clique sets are preserved exactly."""
    p_set = set(p_ids.tolist())
    pool = p_set | x_set
    pivot = max(pool, key=lambda u: (len(adj[u] & p_set), -rank[u]))
    branch = [w for w in p_ids.tolist() if w not in adj[pivot]]
    p_cur = set(p_set)
    x_cur = set(x_set)
    for w in branch:
        p_cur.discard(w)
        yield (v, w), p_cur & adj[w], x_cur & adj[w]
        x_cur.add(w)


def prepare(g: CSRGraph, *, global_red: bool = True, x_red: bool = True,
            bucket_sizes: Sequence[int] = (32, 64, 128, 256, 512, 1024),
            max_x_rows: int = 8192,
            split_threshold: Optional[int] = None) -> PreparedMCE:
    """Host preprocessing: reductions, ordering, bitset packing, bucketing.

    split_threshold: straggler mitigation by over-decomposition — roots with
    |P| > threshold are expanded ONE BK level on the host (pivot-pruned
    branching, exactly Algorithm 2's first level) into per-branch
    subproblems with |R|=2. The search tree is re-dealt at a finer grain so
    one pathological hub cannot stall its whole shard (DESIGN.md §5)."""
    pre_reported: List[frozenset] = []
    if global_red:
        from repro.core.global_reduction import global_reduce_host

        red = global_reduce_host(g)
        g_work = red.graph
        pre_reported = list(red.reported)
    else:
        g_work = g

    order, rank, lam = degeneracy_order(g_work)
    adj = [set(g_work.neighbors(v).tolist()) for v in range(g_work.n)]
    adj_sorted = [g_work.neighbors(v) for v in range(g_work.n)]

    kept_x: Optional[List[Set[int]]] = None
    if x_red:
        from repro.core.xreduction import x_prune_roots

        kept_x = x_prune_roots(adj, order, rank)

    staged: Dict[int, List[dict]] = {b: [] for b in bucket_sizes}
    for i in range(g_work.n):
        v = int(order[i])
        if not adj[v]:
            continue
        p_ids = np.array(sorted((u for u in adj[v] if rank[u] > i),
                                key=lambda u: rank[u]), dtype=np.int64)
        if len(p_ids) == 0:
            continue  # all its cliques are found from earlier roots
        u_size = len(p_ids)
        bucket = next((b for b in bucket_sizes if u_size <= b), None)
        if bucket is None:
            raise ValueError(f"universe {u_size} exceeds largest bucket")
        x_set = kept_x[i] if kept_x is not None else {u for u in adj[v]
                                                      if rank[u] < i}
        if split_threshold is not None and u_size > split_threshold:
            for base, p_sub, x_sub in _split_root(v, p_ids, x_set, adj, rank):
                if not p_sub:
                    if not x_sub:
                        pre_reported.append(frozenset(base))
                    continue
                _stage_subproblem(staged, bucket_sizes, base, p_sub, x_sub,
                                  adj_sorted, rank)
            continue
        _stage_subproblem(staged, bucket_sizes, (v,), set(p_ids.tolist()),
                          x_set, adj_sorted, rank)

    buckets: List[RootBucket] = []
    for b in bucket_sizes:
        items = staged[b]
        if not items:
            continue
        xc = max(max((len(it["x_rows"]) for it in items), default=0), 1)
        xc = 1 << (xc - 1).bit_length()     # pow2 pad: bounded recompile count
        if xc > max_x_rows:
            raise ValueError(f"X0 rows {xc} exceed cap {max_x_rows}")
        words = b // WORD
        r = len(items)
        a = np.zeros((r, b, words), dtype=np.uint32)
        p0 = np.zeros((r, words), dtype=np.uint32)
        x_rows = np.zeros((r, xc, words), dtype=np.uint32)
        x_alive = np.zeros((r, xc), dtype=bool)
        roots = np.zeros(r, dtype=np.int64)
        rsz0 = np.ones(r, dtype=np.int32)
        bases = []
        universes = []
        for k, it in enumerate(items):
            a[k] = it["a"]
            p0[k] = it["p0"]
            for j, row in enumerate(it["x_rows"]):
                x_rows[k, j] = row
                x_alive[k, j] = True
            roots[k] = it["root"]
            base = it.get("base", (it["root"],))
            bases.append(base)
            rsz0[k] = len(base)
            universes.append(it["universe"])
        buckets.append(RootBucket(u_pad=b, x_pad=xc, a=a, p0=p0, x_rows=x_rows,
                                  x_alive0=x_alive, roots=roots, rsz0=rsz0,
                                  bases=bases, universes=universes))
    return PreparedMCE(buckets=buckets, pre_reported=pre_reported, n=g.n,
                       degeneracy=lam, order=order, rank=rank)


# ===========================================================================
# Small bitset helpers (device)
# ===========================================================================

def _popcount(bits):
    return jnp.sum(jax.lax.population_count(bits), axis=-1).astype(jnp.int32)


def _any_bit(bits):
    return jnp.any(bits != 0, axis=-1)


def _first_bit_index(bits):
    nz = bits != 0
    w = jnp.argmax(nz)
    word = bits[w]
    low = word & (U32(0) - word)
    pos = jax.lax.population_count(low - U32(1))
    return (w * WORD + pos).astype(jnp.int32)


def _test_bit(bits, index):
    word = bits[index // WORD]
    return ((word >> (index % WORD).astype(U32)) & U32(1)) != 0


def _bitset_to_mask(bits, u):
    idx = jnp.arange(u)
    words = bits[idx // WORD]
    return ((words >> (idx % WORD).astype(U32)) & U32(1)) != 0


def _eye_bits(u, words):
    """(U, W) constant: EYE[i] = bitset with only bit i."""
    idx = jnp.arange(u)
    col = jnp.arange(words)
    return jnp.where(col[None, :] == (idx[:, None] // WORD),
                     U32(1) << (idx[:, None] % WORD).astype(U32), U32(0))


def _mask_to_bitset(mask, words, eye):
    return jnp.bitwise_or.reduce(
        jnp.where(mask[:, None], eye, U32(0)), axis=0)


def _or_reduce(rows, sel):
    return jnp.bitwise_or.reduce(
        jnp.where(sel[:, None], rows, U32(0)), axis=0)


def _and_reduce(rows, sel):
    return jnp.bitwise_and.reduce(
        jnp.where(sel[:, None], rows, FULL), axis=0)


def _single_bit_index_rows(rows):
    nz = rows != 0
    word_idx = jnp.argmax(nz, axis=1)
    word = jnp.take_along_axis(rows, word_idx[:, None], axis=1)[:, 0]
    low = word & (U32(0) - word)
    pos = jax.lax.population_count(low - U32(1))
    return (word_idx * WORD + pos).astype(jnp.int32)


# ===========================================================================
# Engine configuration + carry
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class EngineConfig:
    dynamic_red: bool = True
    backend: str = "pivot"          # 'pivot' | 'rcd' | 'revised'
    out_cap: int = 0                # >0: enumerate into a fixed buffer
    max_iters: int = 1 << 30
    # §Perf: reuse the post-reduction degree vector for pivot scoring via
    # deg_P''(u) = deg_P'(u) − |full| (full vertices neighbor all of P'),
    # eliminating one of the three AND+popcount sweeps over A per call.
    reuse_degrees: bool = True


def _carry_init(cfg: EngineConfig, words: int):
    cap = max(cfg.out_cap, 1)
    return dict(
        cliques=jnp.int32(0),
        calls=jnp.int32(0),
        branches=jnp.int32(0),
        sum_px=jnp.int32(0),
        out_rows=jnp.zeros((cap, words), dtype=jnp.uint32),
        out_sizes=jnp.zeros((cap,), dtype=jnp.int32),
        out_n=jnp.int32(0),
        overflow=jnp.bool_(False),
    )


def _report_single(carry, cfg, bits, size, enable):
    cnt = enable.astype(jnp.int32)
    carry = dict(carry, cliques=carry["cliques"] + cnt)
    if cfg.out_cap:
        cap = cfg.out_cap
        pos = jnp.where(enable & (carry["out_n"] < cap), carry["out_n"], cap)
        carry["out_rows"] = carry["out_rows"].at[pos].set(bits, mode="drop")
        carry["out_sizes"] = carry["out_sizes"].at[pos].set(size, mode="drop")
        carry["overflow"] = carry["overflow"] | (enable & (carry["out_n"] >= cap))
        carry["out_n"] = jnp.minimum(carry["out_n"] + cnt, cap)
    return carry


def _report_multi(carry, cfg, rows, sizes, mask):
    cnt = jnp.sum(mask.astype(jnp.int32))
    carry = dict(carry, cliques=carry["cliques"] + cnt)
    if cfg.out_cap:
        cap = cfg.out_cap
        offs = carry["out_n"] + jnp.cumsum(mask.astype(jnp.int32)) - 1
        pos = jnp.where(mask & (offs < cap), offs, cap)
        carry["out_rows"] = carry["out_rows"].at[pos].set(rows, mode="drop")
        carry["out_sizes"] = carry["out_sizes"].at[pos].set(sizes, mode="drop")
        carry["overflow"] = carry["overflow"] | jnp.any(mask & (offs >= cap))
        carry["out_n"] = jnp.minimum(carry["out_n"] + cnt, cap)
    return carry


# ===========================================================================
# Call-entry: dynamic reduction + leaf report + branch-set construction
# ===========================================================================

def _enter(carry, cfg, A, x_rows, eye, eye_x, P, Xp, xal, rsz, Rb,
           enable=None):
    """BK call entry for (R, P, X). Returns (carry, push?, frame).

    `enable` gates every carry side-effect (counter bumps, clique reports):
    the DFS body runs _enter unconditionally (straight-line, no lax.cond —
    see _run_root) and masks it out on pop-only iterations."""
    U, words = A.shape
    XC = x_rows.shape[0]
    enable = jnp.bool_(True) if enable is None else enable
    en_i = enable.astype(jnp.int32)
    carry = dict(carry, calls=carry["calls"] + en_i)
    carry["sum_px"] = (carry["sum_px"] + (_popcount(P) + _popcount(Xp)
                       + _popcount(xal)) * en_i)
    xal_mask = _bitset_to_mask(xal, XC)

    # ---- dynamic reduction (paper Lemmas 5, 7, 8) ----
    if cfg.dynamic_red:
        degP = bitref.and_popcount_rows(A, P)              # (U,)
        in_p = _bitset_to_mask(P, U)
        xp_mask = _bitset_to_mask(Xp, U)
        marked_bits = _or_reduce(x_rows, xal_mask) | _or_reduce(A, xp_mask)
        marked = _bitset_to_mask(marked_bits, U)

        # dynamic degree-zero (Lemma 5)
        deg0 = in_p & (degP == 0)
        rep0 = deg0 & ~marked
        carry = _report_multi(carry, cfg, Rb[None, :] | eye,
                              jnp.full((U,), rsz + 1, jnp.int32),
                              rep0 & enable)
        Xp = Xp | _mask_to_bitset(rep0, words, eye)

        # relaxed dynamic degree-one (Lemma 7)
        deg1 = in_p & (degP == 1)
        partner = _single_bit_index_rows(A & P[None, :])   # valid where deg1
        pclip = jnp.clip(partner, 0, U - 1)
        partner_deg1 = deg1 & deg1[pclip]
        mutual_skip = partner_deg1 & (pclip < jnp.arange(U))
        cond = deg1 & ~mutual_skip & (~marked | ~marked[pclip])
        pair_rows = Rb[None, :] | eye | eye[pclip]
        carry = _report_multi(carry, cfg, pair_rows,
                              jnp.full((U,), rsz + 2, jnp.int32),
                              cond & enable)
        rem1 = cond | (partner_deg1 & cond[pclip])
        Xp = Xp | _mask_to_bitset(rem1, words, eye)
        removed = deg0 | rem1
        P = P & ~_mask_to_bitset(removed, words, eye)

        # dynamic degree-(|P|-1) (Lemma 8)
        degP2 = bitref.and_popcount_rows(A, P)
        in_p2 = _bitset_to_mask(P, U)
        psize = _popcount(P)
        full = in_p2 & (degP2 == psize - 1) & (psize > 0)
        any_full = jnp.any(full)
        n_full = jnp.sum(full.astype(jnp.int32))
        full_bits = _mask_to_bitset(full, words, eye)
        common = _and_reduce(A, full)                      # C(S) over universe
        sub_ok = bitref.and_popcount_rows(jnp.bitwise_not(x_rows), full_bits) == 0
        P, Xp, xal, Rb, rsz = (
            jnp.where(any_full, P & ~full_bits, P),
            jnp.where(any_full, Xp & common, Xp),
            jnp.where(any_full, xal & _mask_to_bitset(sub_ok, eye_x.shape[1],
                                                      eye_x), xal),
            jnp.where(any_full, Rb | full_bits, Rb),
            jnp.where(any_full, rsz + n_full, rsz),
        )
    else:
        degP2 = None
        n_full = jnp.int32(0)

    # ---- leaf report ----
    p_empty = ~_any_bit(P)
    x_empty = ~_any_bit(xal) & ~_any_bit(Xp)
    carry = _report_single(carry, cfg, Rb, rsz,
                           p_empty & x_empty & (rsz >= 2) & enable)
    push = ~p_empty & enable

    # ---- branch set (pivot backends; rcd recomputes per visit) ----
    if cfg.backend in ("pivot", "revised"):
        if cfg.dynamic_red and cfg.reuse_degrees:
            # §Perf: every `full` vertex was adjacent to ALL of P', so
            # deg over the final P is exactly degP2 − n_full for surviving
            # P members — reuse instead of a third AND+popcount sweep of A.
            degP = degP2 - n_full
        else:
            degP = bitref.and_popcount_rows(A, P)
        in_p = _bitset_to_mask(P, U)
        if cfg.backend == "revised":
            pool = in_p
        else:
            pool = in_p | _bitset_to_mask(Xp, U)
        uni_scores = jnp.where(pool, degP, -1)
        best_u = jnp.argmax(uni_scores)
        x_scores = jnp.where(_bitset_to_mask(xal, XC),
                             bitref.and_popcount_rows(x_rows, P), -1)
        best_x = jnp.argmax(x_scores)
        use_x = x_scores[best_x] > uni_scores[best_u]
        pivot_row = jnp.where(use_x, x_rows[best_x], A[best_u])
        B = P & ~pivot_row
    else:
        B = jnp.zeros_like(P)
    return carry, push, (P, B, Xp, Rb, rsz, xal)


# ===========================================================================
# Per-root DFS driver
# ===========================================================================

def _run_root(a, p0, x_rows, x_alive0, rsz0, cfg: EngineConfig):
    """Run the full BK subtree of one root. Returns the final carry dict.

    The X0 alive set is carried as a PACKED BITSET (§Perf iteration 3):
    the bool stack (D, XC) dominated the while carry traffic 8:1."""
    U, words = a.shape
    XC = x_rows.shape[0]
    xc_words = max(-(-XC // WORD), 1)
    D = U + 2
    eye = _eye_bits(U, words)
    eye_x = _eye_bits(XC, xc_words)
    xal_bits0 = _mask_to_bitset(x_alive0, xc_words, eye_x)

    carry0 = _carry_init(cfg, words)
    # root frame: R = {v} (rsz=1), Rb covers universe additions only
    carry0, push0, frame0 = _enter(
        carry0, cfg, a, x_rows, eye, eye_x,
        p0, jnp.zeros(words, U32), xal_bits0,
        rsz0.astype(jnp.int32), jnp.zeros(words, U32))

    st_P = jnp.zeros((D, words), U32).at[0].set(frame0[0])
    st_B = jnp.zeros((D, words), U32).at[0].set(frame0[1])
    st_Xp = jnp.zeros((D, words), U32).at[0].set(frame0[2])
    st_Rb = jnp.zeros((D, words), U32).at[0].set(frame0[3])
    st_rsz = jnp.zeros((D,), jnp.int32).at[0].set(frame0[4])
    st_xal = jnp.zeros((D, xc_words), U32).at[0].set(frame0[5])
    depth0 = jnp.where(push0, jnp.int32(0), jnp.int32(-1))

    def cond(s):
        return (s[0] >= 0) & (s[1] < cfg.max_iters)

    def body(s):
        """Straight-line masked DFS step — no lax.cond.

        Under vmap a cond lowers to SELECT over both branch results, which
        copies every stack buffer per iteration (measured: >40% of the
        engine's HBM bytes). Instead, branch work always executes with its
        carry side-effects gated by `has_branch`, and stack writes land in
        frames that are DEAD on the pop path (slots > new depth), so they
        need no gating at all. (§Perf iteration 2, EXPERIMENTS.md.)"""
        depth, it, stP, stB, stXp, stRb, strsz, stxal, carry = s
        P = stP[depth]
        B = stB[depth]
        Xp = stXp[depth]
        Rb = stRb[depth]
        rsz = strsz[depth]
        xal = stxal[depth]

        if cfg.backend in ("pivot", "revised"):
            has_branch = _any_bit(B)
            w = _first_bit_index(B)
        else:
            # rcd: clique test decides report-and-pop vs min-degree branch
            degP = bitref.and_popcount_rows(a, P)
            in_p = _bitset_to_mask(P, U)
            psize = _popcount(P)
            is_clique = jnp.all(~in_p | (degP == psize - 1))
            has_branch = ~is_clique
            w = jnp.argmin(jnp.where(in_p, degP, jnp.int32(1 << 30)))
            w = w.astype(jnp.int32)

        # ---- pop path: rcd maximality check + report (gated) ----
        if cfg.backend == "rcd":
            # report R ∪ P if no X vertex dominates P (paper Alg 3):
            # x blocks iff P ⊆ N(x) ⟺ popcount(P & ~N(x)) == 0
            x0_sub = _popcount(P[None, :] & jnp.bitwise_not(x_rows))
            x0_block = jnp.any(_bitset_to_mask(xal, XC) & (x0_sub == 0))
            xp_mask = _bitset_to_mask(Xp, U)
            xp_sub = _popcount(P[None, :] & jnp.bitwise_not(a))
            xp_block = jnp.any(xp_mask & (xp_sub == 0))
            size = rsz + _popcount(P)
            ok = (~x0_block & ~xp_block & (size >= 2) & _any_bit(P)
                  & ~has_branch)
            carry = _report_single(carry, cfg, Rb | P, size, ok)

        # ---- branch path: always computed, side-effects gated ----
        wbit = eye[w]
        childP = P & a[w]
        childXp = Xp & a[w]
        # X0 rows stay alive iff adjacent to w (bit w of their row)
        row_word = jax.lax.dynamic_index_in_dim(
            x_rows, w // WORD, axis=1, keepdims=False)
        adj_w = ((row_word >> (w % WORD).astype(U32)) & U32(1)) != 0
        childxal = xal & _mask_to_bitset(adj_w, xc_words, eye_x)
        carry = dict(carry,
                     branches=carry["branches"] + has_branch.astype(jnp.int32))
        carry, push, frame = _enter(carry, cfg, a, x_rows, eye, eye_x,
                                    childP, childXp, childxal,
                                    rsz + 1, Rb | wbit, enable=has_branch)
        # update current frame (dead slot on the pop path — no gating):
        # P \ w, X ∪ w, B \ w
        stP = stP.at[depth].set(jnp.where(has_branch, P & ~wbit, P))
        stXp = stXp.at[depth].set(jnp.where(has_branch, Xp | wbit, Xp))
        if cfg.backend in ("pivot", "revised"):
            stB = stB.at[depth].set(jnp.where(has_branch, B & ~wbit, B))
        # write child frame (slot depth+1 is dead unless pushed)
        nd = depth + 1
        stP = stP.at[nd].set(frame[0])
        stB = stB.at[nd].set(frame[1])
        stXp = stXp.at[nd].set(frame[2])
        stRb = stRb.at[nd].set(frame[3])
        strsz = strsz.at[nd].set(frame[4])
        stxal = stxal.at[nd].set(frame[5])
        new_depth = jnp.where(has_branch,
                              jnp.where(push, nd, depth), depth - 1)
        return new_depth, it + 1, stP, stB, stXp, stRb, strsz, stxal, carry

    state = (depth0, jnp.int32(0), st_P, st_B, st_Xp, st_Rb, st_rsz, st_xal,
             carry0)
    state = jax.lax.while_loop(cond, body, state)
    return state[-1]


@partial(jax.jit, static_argnames=("cfg",))
def run_bucket(a, p0, x_rows, x_alive0, rsz0, cfg: EngineConfig):
    """vmap the per-root DFS over a bucket. Returns dict of per-root stats."""
    return jax.vmap(lambda aa, pp, xr, xa, rr: _run_root(aa, pp, xr, xa, rr,
                                                         cfg))(
        a, p0, x_rows, x_alive0, rsz0)


# ===========================================================================
# High-level API
# ===========================================================================

@dataclasses.dataclass
class MCEResult:
    cliques: int
    calls: int
    branches: int
    sum_px: int
    pre_reported: int
    enumerated: Optional[List[frozenset]] = None
    overflow: bool = False


def run(g: CSRGraph, *, global_red: bool = True, dynamic_red: bool = True,
        x_red: bool = True, backend: str = "pivot",
        enumerate_cliques: bool = False, out_cap: int = 4096,
        bucket_sizes: Sequence[int] = (32, 64, 128, 256, 512, 1024),
        split_threshold: Optional[int] = None) -> MCEResult:
    """End-to-end single-host MCE: prepare on host, run buckets on device."""
    prep = prepare(g, global_red=global_red, x_red=x_red,
                   bucket_sizes=bucket_sizes, split_threshold=split_threshold)
    cfg = EngineConfig(dynamic_red=dynamic_red, backend=backend,
                       out_cap=out_cap if enumerate_cliques else 0)
    total = MCEResult(cliques=len(prep.pre_reported), calls=0, branches=0,
                      sum_px=0, pre_reported=len(prep.pre_reported),
                      enumerated=list(prep.pre_reported) if enumerate_cliques else None)
    for bucket in prep.buckets:
        out = run_bucket(jnp.asarray(bucket.a), jnp.asarray(bucket.p0),
                         jnp.asarray(bucket.x_rows),
                         jnp.asarray(bucket.x_alive0),
                         jnp.asarray(bucket.rsz0), cfg)
        out = jax.tree.map(np.asarray, out)
        total.cliques += int(out["cliques"].sum())
        total.calls += int(out["calls"].sum())
        total.branches += int(out["branches"].sum())
        total.sum_px += int(out["sum_px"].sum())
        if enumerate_cliques:
            total.overflow |= bool(out["overflow"].any())
            for r in range(bucket.num_roots):
                uni = bucket.universes[r]
                base = [int(b) for b in bucket.bases[r]]
                for k in range(int(out["out_n"][r])):
                    bits = out["out_rows"][r, k]
                    members = _unpack_bits_np(bits)
                    clique = frozenset(base + [int(uni[m]) for m in members])
                    total.enumerated.append(clique)
    return total


def _unpack_bits_np(bits: np.ndarray) -> np.ndarray:
    out = []
    for wi, word in enumerate(bits):
        word = int(word)
        while word:
            low = word & -word
            out.append(wi * WORD + low.bit_length() - 1)
            word ^= low
    return np.array(out, dtype=np.int64)
