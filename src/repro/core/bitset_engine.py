"""Compatibility shim — the engine now lives in `repro.core.engine`.

The monolithic TPU bitset Bron–Kerbosch engine was split into layered
modules (DESIGN.md §2): `engine.prepare` (host-side packing/bucketing),
`engine.frames` (frame/stack layout + config), `engine.reductions`
(dynamic-reduction lemmas), `engine.pivot` (pivot strategies), and
`engine.loop` (the `lax.while_loop` DFS driver + `run()`); all bitset set
algebra dispatches through `repro.kernels.bitset_ops.ops` (DESIGN.md §3).

This module only re-exports the public API so existing imports keep
working. New code should import from `repro.core.engine` directly.
"""
from repro.core.engine.frames import (EngineConfig, Frame,  # noqa: F401
                                      FrameStack)
from repro.core.engine.loop import (MCEResult, enter_call, run,  # noqa: F401
                                    run_bucket, run_root)
from repro.core.engine.pipeline import PrepStream  # noqa: F401
from repro.core.engine.prepare import (PreparedMCE, RootBucket,  # noqa: F401
                                       _unpack_bits_np, prepare)

# Historical alias (pre-layering underscore name; same signature). The old
# `_enter` is NOT aliased: its signature changed (RootContext replaces the
# A/x_rows/eye/eye_x positionals) — use engine.loop.enter_call.
_run_root = run_root
