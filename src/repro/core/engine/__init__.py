"""Layered bitset Bron–Kerbosch MCE engine (DESIGN.md §2).

The CPU paper's recursive, pointer-chasing search is re-derived as
fixed-shape bitset dataflow, split into swappable layers:

* `prepare`    — fixed-shape containers + one-shot materializing API
* `pipeline`   — staged streaming ingest (reduce → order → stage → pack),
                 yielding `RootBucket`s incrementally (`PrepStream`)
* `frames`     — frame/stack layout, config, counter carry
* `reductions` — dynamic degree-0/1/|P|−1 lemmas as pure frame functions
* `pivot`      — pivot/branch-selection strategies behind one interface
* `loop`       — the `lax.while_loop` DFS driver + single-host `run()`

All bitset set algebra dispatches through `repro.kernels.bitset_ops.ops`
(Pallas on TPU, jnp elsewhere) — the single choke point for the paper's
73.6%-of-time set intersections. `repro.core.bitset_engine` remains as a
thin re-export shim for existing callers.
"""
from repro.core.engine.frames import (BACKENDS, EngineConfig,  # noqa: F401
                                      Frame, FrameStack, PIVOT_BACKENDS)
from repro.core.engine.loop import (MCEResult, choose_engine,  # noqa: F401
                                    dfs_step, enter_call, root_cost_skew,
                                    run, run_bucket, run_bucket_persistent,
                                    run_root, run_stream_persistent)
from repro.core.engine.pipeline import PrepStream, RootSpec  # noqa: F401
from repro.core.engine.prepare import (PreparedMCE, RootBucket,  # noqa: F401
                                       estimate_costs, prepare)
