"""Frame & stack layout for the bitset BK engine (DESIGN.md §2.3).

A BK call is a fixed-shape *frame* of bitsets over the root's local
universe; the explicit DFS stack is one pre-allocated buffer per frame
field, depth-indexed. Everything here is shape/layout plumbing — the
search semantics live in `reductions`, `pivot`, and `loop`.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.bitset_ops import ops as bitops

WORD = 32
U32 = jnp.uint32
FULL = jnp.uint32(0xFFFFFFFF)


# ===========================================================================
# Small bitset helpers (device) — index/layout glue; all popcount/AND set
# algebra over row matrices goes through repro.kernels.bitset_ops.ops.
# ===========================================================================

def popcount(bits):
    return bitops.popcount_words(bits)


def any_bit(bits):
    return jnp.any(bits != 0, axis=-1)


def first_bit_index(bits):
    nz = bits != 0
    w = jnp.argmax(nz)
    word = bits[w]
    low = word & (U32(0) - word)
    pos = jax.lax.population_count(low - U32(1))
    return (w * WORD + pos).astype(jnp.int32)


def test_bit(bits, index):
    word = bits[index // WORD]
    return ((word >> (index % WORD).astype(U32)) & U32(1)) != 0


def bitset_to_mask(bits, u):
    idx = jnp.arange(u)
    words = bits[idx // WORD]
    return ((words >> (idx % WORD).astype(U32)) & U32(1)) != 0


def eye_bits(u, words):
    """(U, W) constant: EYE[i] = bitset with only bit i."""
    idx = jnp.arange(u)
    col = jnp.arange(words)
    return jnp.where(col[None, :] == (idx[:, None] // WORD),
                     U32(1) << (idx[:, None] % WORD).astype(U32), U32(0))


def mask_to_bitset(mask, eye):
    return jnp.bitwise_or.reduce(
        jnp.where(mask[:, None], eye, U32(0)), axis=0)


def or_reduce(rows, sel):
    return jnp.bitwise_or.reduce(
        jnp.where(sel[:, None], rows, U32(0)), axis=0)


def and_reduce(rows, sel):
    # De Morgan (AND-reduce = ~OR-reduce of complements): jnp's bitwise_and
    # reduction builds a signed -1 identity that overflows uint32 on numpy≥2.
    return jnp.bitwise_not(jnp.bitwise_or.reduce(
        jnp.where(sel[:, None], jnp.bitwise_not(rows), U32(0)), axis=0))


def single_bit_index_rows(rows):
    nz = rows != 0
    word_idx = jnp.argmax(nz, axis=1)
    word = jnp.take_along_axis(rows, word_idx[:, None], axis=1)[:, 0]
    low = word & (U32(0) - word)
    pos = jax.lax.population_count(low - U32(1))
    return (word_idx * WORD + pos).astype(jnp.int32)


# ===========================================================================
# Engine configuration
# ===========================================================================

BACKENDS = ("pivot", "rcd", "revised", "hybrid")
# Backends that precompute a branch set B at call entry ('rcd' re-selects
# per visit instead); 'hybrid' is pivot-family with a per-node
# vertex-branching override plus early termination (DESIGN.md §2.7).
PIVOT_BACKENDS = ("pivot", "revised", "hybrid")


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    dynamic_red: bool = True
    backend: str = "pivot"          # one of BACKENDS
    out_cap: int = 0                # >0: enumerate into a fixed buffer
    max_iters: int = 1 << 30
    # §Perf: reuse the post-reduction degree vector for pivot scoring via
    # deg_P''(u) = deg_P'(u) − |full| (full vertices neighbor all of P'),
    # eliminating one of the three AND+popcount sweeps over A per call.
    reuse_degrees: bool = True
    # 'hybrid' branch selection: switch from pivot- to vertex-branching
    # (B = P) when the induced density 2|E[P]| / (|P|·(|P|−1)) reaches this
    # threshold — near-clique nodes early-terminate in their children, so
    # the pivot sweep's pruning buys nothing there (DESIGN.md §2.7).
    hybrid_density: float = 0.9
    # Persistent-engine lane work stealing (DESIGN.md §2.6 STEAL): when the
    # root queue is drained and a lane idles, it adopts half of the deepest
    # live lane's bottom-of-stack branch set. Pure scheduling — counters and
    # enumerated sets are bit-identical either way (pivot-family backends
    # only; 'rcd' carries no branch set and never steals).
    steal: bool = True
    # Steal victim policy: 'branchiest' (default) picks the lane whose
    # donation slot has the largest remaining branch set — the biggest
    # transferable subtree — 'deepest' keeps the legacy deepest-lane
    # heuristic. Pure scheduling either way (bit-identical counters/sets).
    steal_victim: str = "branchiest"
    # VMEM stack windowing: >0 walks K frame-steps per stack round-trip
    # with a WINDOW_FRAMES-deep window resident. Eligible per-root walks
    # (pivot backend, dynamic_red off, counting only) and the persistent
    # engine's eligible configs use the fused `dfs_step_window`/
    # `dfs_step_window_lanes` dispatch; other persistent configs window
    # the ordinary dfs_step (enumeration, dynamic reduction, rcd/hybrid
    # all work from inside the window — DESIGN.md §2.6/§3). 0 = off.
    window_steps: int = 0
    # Engine-step window DEPTH (frames). 0 = auto: the kernel-contract
    # path always uses the literal `bitset_ops.WINDOW_FRAMES` (its VMEM
    # scratch shape), and the engine-step path defaults to the FULL stack
    # — the degenerate window: no re-centering, no boundary stops, the
    # whole stack rides the trip as loop carry. Set >0 to bound the
    # engine-step window (e.g. when stack residency is VMEM-limited);
    # a kernel-eligible config stays kernel-eligible only at 0 or
    # WINDOW_FRAMES. Pure scheduling — counters/sets bit-identical.
    window_frames: int = 0


# ===========================================================================
# Per-root constant context + per-call frame + DFS stack
# ===========================================================================

class RootContext(NamedTuple):
    """Per-root constants threaded through the DFS (never stacked)."""
    A: jnp.ndarray          # (U, W) induced adjacency bitsets
    x_rows: jnp.ndarray     # (XC, W) X0 row bitsets
    eye: jnp.ndarray        # (U, W) one-hot bitsets over the universe
    eye_x: jnp.ndarray      # (XC, XCW) one-hot bitsets over X0 rows

    @property
    def u(self) -> int:
        return self.A.shape[0]

    @property
    def words(self) -> int:
        return self.A.shape[1]

    @property
    def xc(self) -> int:
        return self.x_rows.shape[0]

    @property
    def xc_words(self) -> int:
        return self.eye_x.shape[1]


def make_context(a, x_rows) -> RootContext:
    u, words = a.shape
    xc = x_rows.shape[0]
    xc_words = max(-(-xc // WORD), 1)
    return RootContext(A=a, x_rows=x_rows, eye=eye_bits(u, words),
                       eye_x=eye_bits(xc, xc_words))


class Frame(NamedTuple):
    """One BK call: (R, P, X) in bitset form plus the branch set B."""
    P: jnp.ndarray          # (W,)  candidate bitset
    B: jnp.ndarray          # (W,)  branch set (pivot-pruned P)
    Xp: jnp.ndarray         # (W,)  universe members moved into X
    Rb: jnp.ndarray         # (W,)  universe additions to the base clique
    rsz: jnp.ndarray        # ()    |R| including the host-side base
    xal: jnp.ndarray        # (XCW,) packed alive mask over X0 rows


class FrameStack(NamedTuple):
    """Depth-indexed DFS stack: one pre-allocated buffer per Frame field.

    The X0 alive set is carried as a PACKED BITSET (§Perf iteration 3):
    the bool stack (D, XC) dominated the while carry traffic 8:1."""
    P: jnp.ndarray          # (D, W)
    B: jnp.ndarray          # (D, W)
    Xp: jnp.ndarray         # (D, W)
    Rb: jnp.ndarray         # (D, W)
    rsz: jnp.ndarray        # (D,)
    xal: jnp.ndarray        # (D, XCW)

    @staticmethod
    def alloc(depth: int, words: int, xc_words: int) -> "FrameStack":
        return FrameStack(
            P=jnp.zeros((depth, words), U32),
            B=jnp.zeros((depth, words), U32),
            Xp=jnp.zeros((depth, words), U32),
            Rb=jnp.zeros((depth, words), U32),
            rsz=jnp.zeros((depth,), jnp.int32),
            xal=jnp.zeros((depth, xc_words), U32))

    def read(self, d) -> Frame:
        return Frame(P=self.P[d], B=self.B[d], Xp=self.Xp[d], Rb=self.Rb[d],
                     rsz=self.rsz[d], xal=self.xal[d])

    def write(self, d, **fields) -> "FrameStack":
        """Write a subset of frame fields at depth d (others untouched, so
        pop-path-dead slots need no extra stores)."""
        return self._replace(**{k: getattr(self, k).at[d].set(v)
                                for k, v in fields.items()})

    def push(self, d, frame: Frame) -> "FrameStack":
        return self.write(d, **frame._asdict())


# ===========================================================================
# Counter/enumeration carry
# ===========================================================================

def carry_init(cfg: EngineConfig, words: int, track_root: bool = False):
    cap = max(cfg.out_cap, 1)
    carry = dict(
        cliques=jnp.int32(0),
        calls=jnp.int32(0),
        branches=jnp.int32(0),
        sum_px=jnp.int32(0),
        out_rows=jnp.zeros((cap, words), dtype=jnp.uint32),
        out_sizes=jnp.zeros((cap,), dtype=jnp.int32),
        out_n=jnp.int32(0),
        overflow=jnp.bool_(False),
    )
    if track_root and cfg.out_cap:
        # persistent lanes interleave roots, so every enumerated clique
        # records which queue slot produced it (per-root decode needs the
        # root's universe/base); `cur_root` is updated on each lane refill
        carry["cur_root"] = jnp.int32(0)
        carry["out_root"] = jnp.zeros((cap,), dtype=jnp.int32)
    return carry


def report_single(carry, cfg, bits, size, enable):
    cnt = enable.astype(jnp.int32)
    carry = dict(carry, cliques=carry["cliques"] + cnt)
    if cfg.out_cap:
        cap = cfg.out_cap
        pos = jnp.where(enable & (carry["out_n"] < cap), carry["out_n"], cap)
        carry["out_rows"] = carry["out_rows"].at[pos].set(bits, mode="drop")
        carry["out_sizes"] = carry["out_sizes"].at[pos].set(size, mode="drop")
        if "out_root" in carry:
            carry["out_root"] = carry["out_root"].at[pos].set(
                carry["cur_root"], mode="drop")
        carry["overflow"] = carry["overflow"] | (enable & (carry["out_n"] >= cap))
        carry["out_n"] = jnp.minimum(carry["out_n"] + cnt, cap)
    return carry


def report_multi(carry, cfg, rows, sizes, mask):
    cnt = jnp.sum(mask.astype(jnp.int32))
    carry = dict(carry, cliques=carry["cliques"] + cnt)
    if cfg.out_cap:
        cap = cfg.out_cap
        offs = carry["out_n"] + jnp.cumsum(mask.astype(jnp.int32)) - 1
        pos = jnp.where(mask & (offs < cap), offs, cap)
        carry["out_rows"] = carry["out_rows"].at[pos].set(rows, mode="drop")
        carry["out_sizes"] = carry["out_sizes"].at[pos].set(sizes, mode="drop")
        if "out_root" in carry:
            carry["out_root"] = carry["out_root"].at[pos].set(
                carry["cur_root"], mode="drop")
        carry["overflow"] = carry["overflow"] | jnp.any(mask & (offs >= cap))
        carry["out_n"] = jnp.minimum(carry["out_n"] + cnt, cap)
    return carry
