"""Pivot/branch-selection strategies behind one interface (DESIGN.md §2.4).

Backends:
  'pivot'   — Tomita max-|N(u) ∩ P| pivot over P ∪ X (universe + X0 rows)
  'revised' — same but the pool is restricted to P (paper's revised BK)
  'rcd'     — top-down clique test + min-degree branching, selected per
              visit (no branch set is precomputed at call entry)

Every score sweep is a fused AND+popcount(+argmax) dispatch through
`bitset_ops.ops`; nothing here touches `ref`/`kernel` directly.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine import frames as fr
from repro.kernels.bitset_ops import ops as bitops


def branch_set(cfg, ctx: fr.RootContext, P, Xp, xal, red, deg=None):
    """Branch set B = P \\ N(pivot) for the 'pivot'/'revised' backends.

    `red` is the ReducedFrame from dynamic_reduce (None when dynamic
    reduction is off); with cfg.reuse_degrees its degP2/n_full replace the
    third AND+popcount sweep over A (§Perf). With dynamic reduction off,
    `deg` (the fused frame-step degree vector over this very P) plays the
    same role — jnp.where + argmax over it matches and_popcount_argmax's
    scores and tie-breaking exactly."""
    U = ctx.u
    XC = ctx.xc
    in_p = fr.bitset_to_mask(P, U)
    if cfg.backend == "revised":
        pool = in_p
    else:
        pool = in_p | fr.bitset_to_mask(Xp, U)

    if red is not None and cfg.reuse_degrees:
        # §Perf: every `full` vertex was adjacent to ALL of P', so deg over
        # the final P is exactly degP2 − n_full for surviving P members —
        # reuse instead of a third AND+popcount sweep of A.
        uni_scores = jnp.where(pool, red.degP2 - red.n_full, -1)
        best_u = jnp.argmax(uni_scores)
        su = uni_scores[best_u]
    elif deg is not None and cfg.reuse_degrees:
        uni_scores = jnp.where(pool, deg, -1)
        best_u = jnp.argmax(uni_scores)
        su = uni_scores[best_u]
    else:
        best_u, su = bitops.and_popcount_argmax(ctx.A, P, pool)
    best_x, sx = bitops.and_popcount_argmax(ctx.x_rows, P,
                                            fr.bitset_to_mask(xal, XC))
    use_x = sx > su
    pivot_row = jnp.where(use_x, ctx.x_rows[best_x], ctx.A[best_u])
    return P & ~pivot_row


def rcd_select(ctx: fr.RootContext, P):
    """'rcd' per-visit branching: (has_branch, w).

    P is a clique iff every member has degree |P|−1 inside P; otherwise
    branch on the minimum-degree member."""
    degP = bitops.and_popcount_rows(ctx.A, P)
    in_p = fr.bitset_to_mask(P, ctx.u)
    psize = fr.popcount(P)
    is_clique = jnp.all(~in_p | (degP == psize - 1))
    w = jnp.argmin(jnp.where(in_p, degP, jnp.int32(1 << 30)))
    return ~is_clique, w.astype(jnp.int32)


def rcd_maximality_report(carry, cfg, ctx: fr.RootContext, P, Xp, xal, Rb,
                          rsz, has_branch):
    """'rcd' pop-path report: R ∪ P if no forbidden vertex dominates P.

    x blocks iff P ⊆ N(x) ⟺ popcount(P & ~N(x)) == 0 — one fused
    batched-mask dispatch over the stacked X0 rows + universe-X adjacency
    (paper Alg 3)."""
    XC = ctx.xc
    U = ctx.u
    not_nbrs = jnp.concatenate([jnp.bitwise_not(ctx.x_rows),
                                jnp.bitwise_not(ctx.A)], axis=0)
    sub = bitops.and_popcount_many(P[None, :], not_nbrs)[:, 0]   # (XC + U,)
    in_x = jnp.concatenate([fr.bitset_to_mask(xal, XC),
                            fr.bitset_to_mask(Xp, U)])
    blocked = jnp.any(in_x & (sub == 0))
    size = rsz + fr.popcount(P)
    ok = (~blocked & (size >= 2) & fr.any_bit(P) & ~has_branch)
    return fr.report_single(carry, cfg, Rb | P, size, ok)
