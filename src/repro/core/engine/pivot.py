"""Pivot/branch-selection strategies behind one interface (DESIGN.md §2.4).

Backends:
  'pivot'   — Tomita max-|N(u) ∩ P| pivot over P ∪ X (universe + X0 rows)
  'revised' — same but the pool is restricted to P (paper's revised BK)
  'rcd'     — top-down clique test + min-degree branching, selected per
              visit (no branch set is precomputed at call entry)
  'hybrid'  — 'pivot' plus the per-node checks of Wang et al. (PAPERS.md):
              early termination / X-domination pruning at call entry
              (`hybrid_early_term`) and a density-triggered switch to
              vertex branching (B = P) on near-clique nodes

Every score sweep is a fused AND+popcount(+argmax) dispatch through
`bitset_ops.ops`; nothing here touches `ref`/`kernel` directly.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.engine import frames as fr
from repro.kernels.bitset_ops import ops as bitops


def branch_set(cfg, ctx: fr.RootContext, P, Xp, xal, red, deg=None):
    """Branch set B for the 'pivot'/'revised'/'hybrid' backends.

    B = P \\ N(pivot), except that 'hybrid' overrides to vertex branching
    (B = P) on nodes whose induced density reaches cfg.hybrid_density.

    `red` is the ReducedFrame from dynamic_reduce (None when dynamic
    reduction is off); with cfg.reuse_degrees its degP2/n_full replace the
    third AND+popcount sweep over A (§Perf). With dynamic reduction off,
    `deg` (the fused frame-step degree vector over this very P) plays the
    same role — jnp.where + argmax over it matches and_popcount_argmax's
    scores and tie-breaking exactly."""
    U = ctx.u
    XC = ctx.xc
    in_p = fr.bitset_to_mask(P, U)
    if cfg.backend == "revised":
        pool = in_p
    else:
        pool = in_p | fr.bitset_to_mask(Xp, U)

    if red is not None and cfg.reuse_degrees:
        # §Perf: every `full` vertex was adjacent to ALL of P', so deg over
        # the final P is exactly degP2 − n_full for surviving P members —
        # reuse instead of a third AND+popcount sweep of A.
        deg_vec = red.degP2 - red.n_full
    elif deg is not None and cfg.reuse_degrees:
        deg_vec = deg
    elif cfg.backend == "hybrid":
        # hybrid's density test needs the whole degree vector, not just the
        # argmax — one ref-matching sweep instead of the fused pivot-select
        deg_vec = bitops.and_popcount_rows(ctx.A, P)
    else:
        deg_vec = None
    if deg_vec is not None:
        uni_scores = jnp.where(pool, deg_vec, -1)
        best_u = jnp.argmax(uni_scores)
        su = uni_scores[best_u]
    else:
        best_u, su = bitops.and_popcount_argmax(ctx.A, P, pool)
    best_x, sx = bitops.and_popcount_argmax(ctx.x_rows, P,
                                            fr.bitset_to_mask(xal, XC))
    use_x = sx > su
    pivot_row = jnp.where(use_x, ctx.x_rows[best_x], ctx.A[best_u])
    B = P & ~pivot_row
    if cfg.backend == "hybrid":
        # per-node branch selection (Wang et al.): on a near-clique P the
        # pivot prunes almost nothing while its children early-terminate
        # immediately, so branch on every vertex (B = P) instead of paying
        # the pivot's serialization. Σ_{v∈P} deg_P(v) = 2|E[P]|, so the
        # density trigger is sum_deg ≥ hybrid_density · |P|·(|P|−1); counts
        # stay < 2^24 (U ≤ 1024), exact in f32.
        psize = fr.popcount(P)
        sum_deg = jnp.sum(jnp.where(in_p, deg_vec, 0))
        dense = (sum_deg.astype(jnp.float32) >=
                 cfg.hybrid_density * psize.astype(jnp.float32) *
                 (psize - 1).astype(jnp.float32))
        B = jnp.where(dense, P, B)
    return B


def hybrid_early_term(carry, cfg, ctx: fr.RootContext, P, Xp, xal, Rb, rsz,
                      enable):
    """'hybrid' call-entry checks (Wang et al., PAPERS.md): one fused
    census over the stacked adjacency + X0 rows decides, per node,

    * early termination — P induces a clique (every member is adjacent to
      the |P|−1 others), so R ∪ P is the subtree's ONLY maximal candidate:
      report it (unless dominated) and pop without recursing;
    * X-domination pruning — some forbidden x dominates P (P ⊆ N(x)), so
      every candidate R ∪ S with S ⊆ P below this node is extendable by x
      (x is adjacent to all of R by the X invariant): pop silently.

    Returns (carry, stop); stop=True means don't push the frame. The
    report side-effect is gated by `enable`, so the persistent engine's
    refill claims and live-masked lane steps inherit the same gating as
    every other carry write — no extra plumbing per dispatch path."""
    rows = jnp.concatenate([ctx.A, ctx.x_rows], axis=0)
    in_p = jnp.concatenate([fr.bitset_to_mask(P, ctx.u),
                            jnp.zeros((ctx.xc,), bool)])
    in_x = jnp.concatenate([fr.bitset_to_mask(Xp, ctx.u),
                            fr.bitset_to_mask(xal, ctx.xc)])
    n_full, n_dom = bitops.clique_counts(rows, P, in_p, in_x)
    psize = fr.popcount(P)
    is_clique = (n_full == psize) & (psize > 0)
    dominated = n_dom > 0
    size = rsz + psize
    carry = fr.report_single(carry, cfg, Rb | P, size,
                             is_clique & ~dominated & (size >= 2) & enable)
    # psize == 0 makes domination vacuous (pc == 0 == |P| for every alive
    # x), but the empty-P frame is never pushed anyway — keep stop False
    # there so the leaf report path stays the single authority.
    return carry, is_clique | (dominated & (psize > 0))


def rcd_select(ctx: fr.RootContext, P):
    """'rcd' per-visit branching: (has_branch, w).

    P is a clique iff every member has degree |P|−1 inside P; otherwise
    branch on the minimum-degree member."""
    degP = bitops.and_popcount_rows(ctx.A, P)
    in_p = fr.bitset_to_mask(P, ctx.u)
    psize = fr.popcount(P)
    is_clique = jnp.all(~in_p | (degP == psize - 1))
    w = jnp.argmin(jnp.where(in_p, degP, jnp.int32(1 << 30)))
    return ~is_clique, w.astype(jnp.int32)


def rcd_maximality_report(carry, cfg, ctx: fr.RootContext, P, Xp, xal, Rb,
                          rsz, has_branch):
    """'rcd' pop-path report: R ∪ P if no forbidden vertex dominates P.

    x blocks iff P ⊆ N(x) ⟺ popcount(P & ~N(x)) == 0 — one fused
    batched-mask dispatch over the stacked X0 rows + universe-X adjacency
    (paper Alg 3)."""
    XC = ctx.xc
    U = ctx.u
    not_nbrs = jnp.concatenate([jnp.bitwise_not(ctx.x_rows),
                                jnp.bitwise_not(ctx.A)], axis=0)
    sub = bitops.and_popcount_many(P[None, :], not_nbrs)[:, 0]   # (XC + U,)
    in_x = jnp.concatenate([fr.bitset_to_mask(xal, XC),
                            fr.bitset_to_mask(Xp, U)])
    blocked = jnp.any(in_x & (sub == 0))
    size = rsz + fr.popcount(P)
    ok = (~blocked & (size >= 2) & fr.any_bit(P) & ~has_branch)
    return fr.report_single(carry, cfg, Rb | P, size, ok)
