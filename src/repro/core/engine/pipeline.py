"""Staged streaming ingest pipeline: reduce → order → stage → pack.

`prepare()` used to materialize every bucket before the first device
step, packing rows with per-vertex python loops. `PrepStream` runs the
same preparation as four explicit stages and *yields* finished
`RootBucket`s incrementally, so the distributed driver can overlap host
packing with device execution (DESIGN.md §6):

  reduce : device deg-0/1 peel (`global_reduction.peel_low_degree` →
           `global_reduce_jnp`) + host cascade on the residual graph
  order  : exact degeneracy order, adjacency sets, X-reduction
  stage  : per-root subproblem specs in degeneracy order; roots whose
           |P| exceeds the largest bucket or whose X rows exceed
           `max_x_rows` (or `split_threshold`, if set) are expanded one
           pivot-pruned BK level — recursively, so ANY graph runs
           without hand-tuning
  pack   : group specs by bucket size; every `stream_roots` staged roots
           of a size flush as one `RootBucket` via the vectorized
           `graph.pack.pack_bucket` scatter path

Streaming identity contract: the bucket sequence is a pure function of
(graph, bucket_sizes, stream_roots, split_threshold, reductions) — NOT
of the device count — so the driver's canonical cost-descending cursor
stays elastic across restarts with a different shard count. With
`stream_roots=0` (no mid-stream flush) the sequence is exactly the
legacy one-bucket-per-size layout, which is how `prepare()` keeps its
old contract.

Reports discovered while staging (a split branch whose P and X are both
empty is a maximal clique) land in `late_reported`, not `pre_reported`:
a streaming consumer learns them only as the stream advances, and they
are regenerated deterministically on every fresh iteration.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterator, List, Optional, Sequence, Set

import numpy as np

from repro.graph.csr import CSRGraph
from repro.graph.order import degeneracy_order
from repro.graph.pack import pack_bucket
from repro.core.engine.prepare import PreparedMCE, RootBucket


@dataclasses.dataclass
class RootSpec:
    """One staged (R=base, P, X) subproblem, not yet packed."""

    base: tuple                 # clique vertices accumulated by splitting
    p_ids: np.ndarray           # (|P|,) int64 global ids, rank-ascending
    x_ids: np.ndarray           # (|X|,) int64 global ids, rank-ascending


@dataclasses.dataclass
class _Front:
    """Output of the reduce+order stages (run once per stream)."""

    g: CSRGraph                 # residual graph (original vertex ids)
    order: np.ndarray
    rank: np.ndarray
    degeneracy: int
    adj: List[Set[int]]
    kept_x: Optional[List[Set[int]]]


def _expand_one_level(base, p_ids, x_set, adj, rank):
    """Expand (R=base, P, X) one pivot-pruned BK level on the host.

    Yields (base + (w,), P_w, X_w) per branch vertex w — identical
    semantics to one level of Algorithm 2, so clique sets are preserved
    exactly (over-decomposition, DESIGN.md §5)."""
    p_set = set(p_ids.tolist())
    pool = p_set | x_set
    pivot = max(pool, key=lambda u: (len(adj[u] & p_set), -rank[u]))
    branch = [w for w in p_ids.tolist() if w not in adj[pivot]]
    p_cur = set(p_set)
    x_cur = set(x_set)
    for w in branch:
        p_cur.discard(w)
        yield base + (w,), p_cur & adj[w], x_cur & adj[w]
        x_cur.add(w)


class PrepStream:
    """Lazily staged, incrementally packed MCE preparation.

    Iterating yields `RootBucket`s as they fill. With `cache=True` a
    complete first pass retains the packed buckets, so long-lived
    deployments (launch.mce_service) reuse them across queries without
    re-packing. A stream is single-consumer while a pass is in flight.
    """

    def __init__(self, g: CSRGraph, *, global_red: bool = True,
                 x_red: bool = True,
                 bucket_sizes: Sequence[int] = (32, 64, 128, 256, 512, 1024),
                 max_x_rows: int = 8192,
                 split_threshold: Optional[int] = None,
                 stream_roots: int = 1024, cache: bool = True):
        self.g = g
        self.global_red = global_red
        self.x_red = x_red
        self.bucket_sizes = tuple(sorted(bucket_sizes))
        self.max_x_rows = max_x_rows
        self.split_threshold = split_threshold
        self.stream_roots = stream_roots
        self.cache = cache
        self.pre_reported: List[frozenset] = []
        self.late_reported: List[frozenset] = []
        self.timings: Dict[str, float] = {
            "reduce": 0.0, "order": 0.0, "stage": 0.0, "pack": 0.0}
        self.num_buckets = 0        # buckets yielded by the last/current pass
        self._front: Optional[_Front] = None
        self._cached: Optional[List[RootBucket]] = None

    # ---- stages 1+2: reduce + order (run once, lazily) -------------------

    def front(self) -> _Front:
        if self._front is not None:
            return self._front
        t0 = time.perf_counter()
        if self.global_red:
            from repro.core.global_reduction import (global_reduce_host,
                                                     reduce_prepass)

            residual, pre_reports = reduce_prepass(self.g)
            red = global_reduce_host(residual)
            g_work = red.graph
            self.pre_reported = pre_reports + list(red.reported)
        else:
            g_work = self.g
        self.timings["reduce"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        order, rank, lam = degeneracy_order(g_work)
        # python-list slicing beats 20k tiny numpy slices by ~5x here
        idx_list = g_work.indices.tolist()
        ptr = g_work.indptr.tolist()
        adj = [set(idx_list[ptr[v]:ptr[v + 1]]) for v in range(g_work.n)]
        kept_x = None
        if self.x_red:
            from repro.core.xreduction import x_prune_roots

            kept_x = x_prune_roots(adj, order, rank)
        self.timings["order"] = time.perf_counter() - t0
        self._front = _Front(g=g_work, order=order, rank=rank, degeneracy=lam,
                             adj=adj, kept_x=kept_x)
        return self._front

    # ---- stage 3: root staging with recursive auto-split -----------------

    def _rank_sorted(self, vs) -> np.ndarray:
        arr = np.fromiter(vs, dtype=np.int64, count=len(vs)) \
            if not isinstance(vs, np.ndarray) else vs.astype(np.int64)
        if len(arr) <= 1:
            return arr
        return arr[np.argsort(self._front.rank[arr])]

    def _x_fits(self, x_set) -> bool:
        k = len(x_set)
        return k == 0 or (1 << (k - 1).bit_length()) <= self.max_x_rows

    def _emit(self, base: tuple, p_ids: np.ndarray, x_set,
              force_split: bool) -> Iterator[RootSpec]:
        """Yield the spec, or split it until every piece fits.

        Iterative pre-order walk (a K_n hub splits one level per vertex,
        which would blow the python recursion limit for n ≳ 1000)."""
        f = self._front
        work = [(base, p_ids, x_set, force_split)]
        while work:
            base, p_ids, x_set, force = work.pop()
            if (not force and len(p_ids) <= self.bucket_sizes[-1]
                    and self._x_fits(x_set)):
                yield RootSpec(base=base, p_ids=p_ids,
                               x_ids=self._rank_sorted(x_set))
                continue
            children = []
            for base2, p_sub, x_sub in _expand_one_level(base, p_ids, x_set,
                                                         f.adj, f.rank):
                if not p_sub:
                    if not x_sub:
                        self.late_reported.append(frozenset(base2))
                    continue
                children.append((base2, self._rank_sorted(p_sub), x_sub,
                                 False))
            work.extend(reversed(children))   # preserve branch order

    def _specs(self) -> Iterator[RootSpec]:
        f = self.front()
        rank = f.rank
        degs = np.diff(f.g.indptr).tolist()   # cheap python guard per vertex
        for i in range(f.g.n):
            v = int(f.order[i])
            if degs[v] == 0:
                continue
            nb = f.g.neighbors(v).astype(np.int64)
            later = rank[nb] > i
            p_ids = nb[later]
            if len(p_ids) == 0:
                continue        # all its cliques are found from earlier roots
            p_ids = p_ids[np.argsort(rank[p_ids])]
            if f.kept_x is not None:
                x_set = f.kept_x[i]
            else:
                x_set = {int(u) for u in nb[~later]}
            force = (self.split_threshold is not None
                     and len(p_ids) > self.split_threshold)
            yield from self._emit((v,), p_ids, x_set, force)

    # ---- stage 4: bucket packing + flush ---------------------------------

    def _pack(self, bucket: int, specs: List[RootSpec],
              n_pad: int = 0) -> RootBucket:
        t0 = time.perf_counter()
        f = self._front
        a, p0, x_rows, x_alive = pack_bucket(
            f.g.indptr, f.g.indices, f.g.n,
            [s.p_ids for s in specs], [s.x_ids for s in specs], bucket)
        out = RootBucket(
            u_pad=bucket, x_pad=x_rows.shape[1], a=a, p0=p0, x_rows=x_rows,
            x_alive0=x_alive,
            roots=np.array([s.base[0] for s in specs], np.int64),
            rsz0=np.array([len(s.base) for s in specs], np.int32),
            bases=[s.base for s in specs],
            universes=[s.p_ids for s in specs],
            n_pad=n_pad)
        self.timings["pack"] += time.perf_counter() - t0
        return out

    def _pad_count(self, n: int) -> int:
        """Remainder-flush pad: round the root count up to the smallest
        pow2 fraction of `stream_roots` that fits, so a long run's
        executable shapes converge to O(log stream_roots) distinct root
        counts per bucket size instead of one fresh compile per arbitrary
        remainder (compile-count hygiene)."""
        if not self.stream_roots or n >= self.stream_roots:
            return 0
        frac = self.stream_roots
        while frac // 2 >= n:
            frac //= 2
        return frac - n

    def _bucket_of(self, u_size: int) -> int:
        for b in self.bucket_sizes:
            if u_size <= b:
                return b
        raise AssertionError("oversized spec escaped auto-split")

    def __iter__(self) -> Iterator[RootBucket]:
        if self._cached is not None:
            return iter(self._cached)
        return self._generate()

    def _generate(self) -> Iterator[RootBucket]:
        self.front()
        self.late_reported = []
        self.num_buckets = 0
        done: List[RootBucket] = []
        pending: Dict[int, List[RootSpec]] = {b: [] for b in self.bucket_sizes}
        t_mark = time.perf_counter()

        def flush(b: int) -> RootBucket:
            """Pack + book-keep one bucket; staging time since the last
            yield (minus pack time) lands in the `stage` timing."""
            pack_before = self.timings["pack"]
            specs = pending[b]
            n_pad = self._pad_count(len(specs))
            if n_pad:
                empty = np.zeros(0, np.int64)
                specs = specs + [RootSpec(base=(-1,), p_ids=empty,
                                          x_ids=empty)] * n_pad
            bk = self._pack(b, specs, n_pad=n_pad)
            pending[b] = []
            self.num_buckets += 1
            if self.cache:
                done.append(bk)
            self.timings["stage"] += (time.perf_counter() - t_mark
                                      - (self.timings["pack"] - pack_before))
            return bk

        for spec in self._specs():
            b = self._bucket_of(len(spec.p_ids))
            pending[b].append(spec)
            if self.stream_roots and len(pending[b]) >= self.stream_roots:
                yield flush(b)
                t_mark = time.perf_counter()
        for b in self.bucket_sizes:
            if pending[b]:
                yield flush(b)
                t_mark = time.perf_counter()
        if self.cache:
            self._cached = done

    # ---- legacy one-shot API ---------------------------------------------

    def materialize(self) -> PreparedMCE:
        """Drain the stream into the legacy `PreparedMCE` container."""
        buckets = list(self)
        f = self.front()
        return PreparedMCE(buckets=buckets,
                           pre_reported=self.pre_reported
                           + list(self.late_reported),
                           n=self.g.n, degeneracy=f.degeneracy,
                           order=f.order, rank=f.rank)
