"""Dynamic (per-call) reductions as pure functions on frames (DESIGN.md §4).

The paper's Lemmas 5 (degree-0), 7 (relaxed degree-1) and 8 (degree-|P|−1)
become bitset algebra over the frame: every degree vector is one fused
AND+popcount sweep through `bitset_ops.ops`, every report is a masked
multi-row append to the carry. No control flow — callers gate side-effects
with `enable` so the DFS body stays straight-line under vmap.
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.engine import frames as fr
from repro.kernels.bitset_ops import ops as bitops


class ReducedFrame(NamedTuple):
    """Post-reduction frame pieces + degree info reusable by pivot select."""
    P: jnp.ndarray
    Xp: jnp.ndarray
    xal: jnp.ndarray
    Rb: jnp.ndarray
    rsz: jnp.ndarray
    degP2: jnp.ndarray      # deg over the Lemma-5/7-reduced P (pre-Lemma-8)
    n_full: jnp.ndarray     # |full| absorbed by Lemma 8


def dynamic_reduce(carry, cfg, ctx: fr.RootContext, P, Xp, xal, rsz, Rb,
                   enable, pre=None):
    """Apply Lemmas 5/7/8 to the call (R, P, X); report advance cliques.

    Returns (carry, ReducedFrame). All clique reports are gated by `enable`;
    the frame outputs are well-defined garbage when enable is False (the
    caller's stack write lands in a dead slot).

    `pre` is the optional (degP, partner) pair from the fused frame-step
    kernel — the DFS body already swept A against this call's P to build
    it, so passing it here removes the first AND+popcount sweep and the
    Lemma-7 partner extraction from this function."""
    U = ctx.u
    XC = ctx.xc
    A, x_rows, eye, eye_x = ctx.A, ctx.x_rows, ctx.eye, ctx.eye_x
    xal_mask = fr.bitset_to_mask(xal, XC)

    if pre is None:
        degP = bitops.and_popcount_rows(A, P)          # (U,)
        partner0 = fr.single_bit_index_rows(bitops.and_rows(A, P))
    else:
        degP, partner0 = pre
    in_p = fr.bitset_to_mask(P, U)
    xp_mask = fr.bitset_to_mask(Xp, U)
    marked_bits = fr.or_reduce(x_rows, xal_mask) | fr.or_reduce(A, xp_mask)
    marked = fr.bitset_to_mask(marked_bits, U)

    # dynamic degree-zero (Lemma 5)
    deg0 = in_p & (degP == 0)
    rep0 = deg0 & ~marked
    carry = fr.report_multi(carry, cfg, Rb[None, :] | eye,
                            jnp.full((U,), rsz + 1, jnp.int32),
                            rep0 & enable)
    Xp = Xp | fr.mask_to_bitset(rep0, eye)

    # relaxed dynamic degree-one (Lemma 7)
    deg1 = in_p & (degP == 1)
    partner = partner0                                 # valid where deg == 1
    pclip = jnp.clip(partner, 0, U - 1)
    partner_deg1 = deg1 & deg1[pclip]
    mutual_skip = partner_deg1 & (pclip < jnp.arange(U))
    cond = deg1 & ~mutual_skip & (~marked | ~marked[pclip])
    pair_rows = Rb[None, :] | eye | eye[pclip]
    carry = fr.report_multi(carry, cfg, pair_rows,
                            jnp.full((U,), rsz + 2, jnp.int32),
                            cond & enable)
    rem1 = cond | (partner_deg1 & cond[pclip])
    Xp = Xp | fr.mask_to_bitset(rem1, eye)
    removed = deg0 | rem1
    P = P & ~fr.mask_to_bitset(removed, eye)

    # dynamic degree-(|P|-1) (Lemma 8)
    degP2 = bitops.and_popcount_rows(A, P)
    in_p2 = fr.bitset_to_mask(P, U)
    psize = fr.popcount(P)
    full = in_p2 & (degP2 == psize - 1) & (psize > 0)
    any_full = jnp.any(full)
    n_full = jnp.sum(full.astype(jnp.int32))
    full_bits = fr.mask_to_bitset(full, eye)
    common = fr.and_reduce(A, full)                      # C(S) over universe
    sub_ok = bitops.and_popcount_rows(jnp.bitwise_not(x_rows), full_bits) == 0
    P, Xp, xal, Rb, rsz = (
        jnp.where(any_full, P & ~full_bits, P),
        jnp.where(any_full, Xp & common, Xp),
        jnp.where(any_full, xal & fr.mask_to_bitset(sub_ok, eye_x), xal),
        jnp.where(any_full, Rb | full_bits, Rb),
        jnp.where(any_full, rsz + n_full, rsz),
    )
    return carry, ReducedFrame(P=P, Xp=Xp, xal=xal, Rb=Rb, rsz=rsz,
                               degP2=degP2, n_full=n_full)
