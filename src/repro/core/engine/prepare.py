"""Host-side MCE preparation: containers + the one-shot `prepare()` API.

The actual work — reductions, ordering, staging, packing — lives in the
staged streaming pipeline (`engine.pipeline.PrepStream`, DESIGN.md §6);
this module keeps the fixed-shape containers the device side consumes
and the legacy materializing entry point. Pure numpy — nothing here runs
on device; the device side consumes packed buckets via `engine.loop`.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import numpy as np

from repro.graph.csr import CSRGraph
from repro.graph.pack import pack_bits as _pack_bits  # noqa: F401 (legacy name)
from repro.graph.pack import popcount_sum

WORD = 32


@dataclasses.dataclass
class RootBucket:
    """Fixed-shape batch of root subproblems sharing one padding."""

    u_pad: int                      # padded universe size (multiple of 32)
    x_pad: int                      # padded X0 row count
    a: np.ndarray                   # (R, U, W) uint32 induced adjacency
    p0: np.ndarray                  # (R, W) uint32 initial candidate bitset
    x_rows: np.ndarray              # (R, XC, W) uint32 X0 row bitsets
    x_alive0: np.ndarray            # (R, XC) bool
    roots: np.ndarray               # (R,) int64 original vertex ids
    rsz0: np.ndarray                # (R,) int32 |R| at entry (>1 for split roots)
    bases: List[tuple]              # per-root base clique vertices
    universes: List[np.ndarray]     # per-root local->global id maps
    cost_order: Optional[np.ndarray] = None   # driver memo: canonical
    # cost-descending root order — cached so service-style replays of a
    # cached bucket skip the O(packed bytes) cost rescan
    cost_skew: Optional[float] = None  # driver memo: max/mean of the real
    # (unpadded) root costs — the engine="auto" signal, cached with
    # cost_order for the same replay reason
    n_pad: int = 0                  # trailing no-op pad roots (remainder
    # flushes padded to pow2 fractions of stream_roots; each contributes
    # exactly one engine call and nothing else — callers subtract)

    @property
    def num_roots(self) -> int:
        return len(self.roots)


def estimate_costs(bucket: RootBucket) -> np.ndarray:
    """Per-root cost proxy: |P| * (1 + mean induced degree)^2.

    The BK subtree size grows with local density; this proxy ranks hub-like
    roots above sparse ones, which is all static balancing needs. Popcounts
    go through the uint8 LUT (`graph.pack.popcount_sum`) — the previous
    `np.unpackbits(bucket.a.view(np.uint8))` materialized 32× the bucket's
    bytes just to sum bits."""
    p_sizes = np.array([len(u) for u in bucket.universes], dtype=np.float64)
    pc = popcount_sum(bucket.a, axis=(1, 2)).astype(np.float64)
    mean_deg = pc / np.maximum(p_sizes, 1)
    return p_sizes * (1.0 + mean_deg) ** 2


@dataclasses.dataclass
class PreparedMCE:
    buckets: List[RootBucket]
    pre_reported: List[frozenset]
    n: int
    degeneracy: int
    order: np.ndarray
    rank: np.ndarray


def _unpack_bits_np(bits: np.ndarray) -> np.ndarray:
    out = []
    for wi, word in enumerate(bits):
        word = int(word)
        while word:
            low = word & -word
            out.append(wi * WORD + low.bit_length() - 1)
            word ^= low
    return np.array(out, dtype=np.int64)


def prepare(g: CSRGraph, *, global_red: bool = True, x_red: bool = True,
            bucket_sizes: Sequence[int] = (32, 64, 128, 256, 512, 1024),
            max_x_rows: int = 8192,
            split_threshold: Optional[int] = None) -> PreparedMCE:
    """Host preprocessing: reductions, ordering, bitset packing, bucketing.

    One-shot wrapper over the streaming pipeline with no mid-stream
    flushes (`stream_roots=0`), which reproduces the legacy layout: one
    `RootBucket` per bucket size, roots in degeneracy order. Roots whose
    |P| exceeds the largest bucket — or whose X rows exceed `max_x_rows`
    — are auto-split one pivot-pruned BK level at a time (recursively)
    instead of raising, so any graph runs without hand-tuning.

    split_threshold: straggler mitigation by over-decomposition — roots
    with |P| > threshold are expanded ONE BK level on the host
    (pivot-pruned branching, exactly Algorithm 2's first level) into
    per-branch subproblems. The search tree is re-dealt at a finer grain
    so one pathological hub cannot stall its whole shard (DESIGN.md §5).
    """
    from repro.core.engine.pipeline import PrepStream

    return PrepStream(g, global_red=global_red, x_red=x_red,
                      bucket_sizes=bucket_sizes, max_x_rows=max_x_rows,
                      split_threshold=split_threshold, stream_roots=0,
                      cache=False).materialize()
