"""Host-side MCE preparation: reductions, ordering, packing, bucketing.

Turns a CSR graph into fixed-shape `RootBucket` batches of bitset
subproblems (DESIGN.md §2.1–§2.2). Pure numpy — nothing here runs on
device; the device side consumes the packed buckets via `engine.loop`.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Set

import numpy as np

from repro.graph.csr import CSRGraph
from repro.graph.order import degeneracy_order

WORD = 32


@dataclasses.dataclass
class RootBucket:
    """Fixed-shape batch of root subproblems sharing one padding."""

    u_pad: int                      # padded universe size (multiple of 32)
    x_pad: int                      # padded X0 row count
    a: np.ndarray                   # (R, U, W) uint32 induced adjacency
    p0: np.ndarray                  # (R, W) uint32 initial candidate bitset
    x_rows: np.ndarray              # (R, XC, W) uint32 X0 row bitsets
    x_alive0: np.ndarray            # (R, XC) bool
    roots: np.ndarray               # (R,) int64 original vertex ids
    rsz0: np.ndarray                # (R,) int32 |R| at entry (>1 for split roots)
    bases: List[tuple]              # per-root base clique vertices
    universes: List[np.ndarray]     # per-root local->global id maps

    @property
    def num_roots(self) -> int:
        return len(self.roots)


@dataclasses.dataclass
class PreparedMCE:
    buckets: List[RootBucket]
    pre_reported: List[frozenset]
    n: int
    degeneracy: int
    order: np.ndarray
    rank: np.ndarray


def _pack_bits(ids: np.ndarray, words: int) -> np.ndarray:
    out = np.zeros(words, dtype=np.uint32)
    if len(ids):
        np.bitwise_or.at(out, ids // WORD,
                         np.uint32(1) << (ids % WORD).astype(np.uint32))
    return out


def _unpack_bits_np(bits: np.ndarray) -> np.ndarray:
    out = []
    for wi, word in enumerate(bits):
        word = int(word)
        while word:
            low = word & -word
            out.append(wi * WORD + low.bit_length() - 1)
            word ^= low
    return np.array(out, dtype=np.int64)


def _stage_subproblem(staged, bucket_sizes, base, p_set, x_set,
                      adj_sorted, rank):
    """Pack one (R=base, P=p_set, X=x_set) subproblem into its bucket."""
    p_ids = np.array(sorted(p_set, key=lambda u: rank[u]), dtype=np.int64)
    u_size = len(p_ids)
    bucket = next((b for b in bucket_sizes if u_size <= b), None)
    if bucket is None:
        raise ValueError(f"universe {u_size} exceeds largest bucket")
    words = bucket // WORD
    a_rows = np.zeros((bucket, words), dtype=np.uint32)
    for j, u in enumerate(p_ids):
        mask = np.isin(p_ids, adj_sorted[int(u)], assume_unique=True)
        a_rows[j] = _pack_bits(np.nonzero(mask)[0].astype(np.int64), words)
    xr = []
    for x in sorted(x_set, key=lambda u: rank[u]):
        mask = np.isin(p_ids, adj_sorted[int(x)], assume_unique=True)
        if mask.any():
            xr.append(_pack_bits(np.nonzero(mask)[0].astype(np.int64), words))
    staged[bucket].append(dict(
        root=base[0], base=tuple(base),
        p0=_pack_bits(np.arange(u_size), words), a=a_rows,
        x_rows=xr, universe=p_ids))


def _split_root(v, p_ids, x_set, adj, rank):
    """Expand root (R={v}, P, X) one pivot-pruned BK level on the host.

    Yields (base=(v, w), P_w, X_w) per branch vertex w — identical semantics
    to one level of Algorithm 2, so clique sets are preserved exactly."""
    p_set = set(p_ids.tolist())
    pool = p_set | x_set
    pivot = max(pool, key=lambda u: (len(adj[u] & p_set), -rank[u]))
    branch = [w for w in p_ids.tolist() if w not in adj[pivot]]
    p_cur = set(p_set)
    x_cur = set(x_set)
    for w in branch:
        p_cur.discard(w)
        yield (v, w), p_cur & adj[w], x_cur & adj[w]
        x_cur.add(w)


def prepare(g: CSRGraph, *, global_red: bool = True, x_red: bool = True,
            bucket_sizes: Sequence[int] = (32, 64, 128, 256, 512, 1024),
            max_x_rows: int = 8192,
            split_threshold: Optional[int] = None) -> PreparedMCE:
    """Host preprocessing: reductions, ordering, bitset packing, bucketing.

    split_threshold: straggler mitigation by over-decomposition — roots with
    |P| > threshold are expanded ONE BK level on the host (pivot-pruned
    branching, exactly Algorithm 2's first level) into per-branch
    subproblems with |R|=2. The search tree is re-dealt at a finer grain so
    one pathological hub cannot stall its whole shard (DESIGN.md §5)."""
    pre_reported: List[frozenset] = []
    if global_red:
        from repro.core.global_reduction import global_reduce_host

        red = global_reduce_host(g)
        g_work = red.graph
        pre_reported = list(red.reported)
    else:
        g_work = g

    order, rank, lam = degeneracy_order(g_work)
    adj = [set(g_work.neighbors(v).tolist()) for v in range(g_work.n)]
    adj_sorted = [g_work.neighbors(v) for v in range(g_work.n)]

    kept_x: Optional[List[Set[int]]] = None
    if x_red:
        from repro.core.xreduction import x_prune_roots

        kept_x = x_prune_roots(adj, order, rank)

    staged: Dict[int, List[dict]] = {b: [] for b in bucket_sizes}
    for i in range(g_work.n):
        v = int(order[i])
        if not adj[v]:
            continue
        p_ids = np.array(sorted((u for u in adj[v] if rank[u] > i),
                                key=lambda u: rank[u]), dtype=np.int64)
        if len(p_ids) == 0:
            continue  # all its cliques are found from earlier roots
        u_size = len(p_ids)
        bucket = next((b for b in bucket_sizes if u_size <= b), None)
        if bucket is None:
            raise ValueError(f"universe {u_size} exceeds largest bucket")
        x_set = kept_x[i] if kept_x is not None else {u for u in adj[v]
                                                      if rank[u] < i}
        if split_threshold is not None and u_size > split_threshold:
            for base, p_sub, x_sub in _split_root(v, p_ids, x_set, adj, rank):
                if not p_sub:
                    if not x_sub:
                        pre_reported.append(frozenset(base))
                    continue
                _stage_subproblem(staged, bucket_sizes, base, p_sub, x_sub,
                                  adj_sorted, rank)
            continue
        _stage_subproblem(staged, bucket_sizes, (v,), set(p_ids.tolist()),
                          x_set, adj_sorted, rank)

    buckets: List[RootBucket] = []
    for b in bucket_sizes:
        items = staged[b]
        if not items:
            continue
        xc = max(max((len(it["x_rows"]) for it in items), default=0), 1)
        xc = 1 << (xc - 1).bit_length()     # pow2 pad: bounded recompile count
        if xc > max_x_rows:
            raise ValueError(f"X0 rows {xc} exceed cap {max_x_rows}")
        words = b // WORD
        r = len(items)
        a = np.zeros((r, b, words), dtype=np.uint32)
        p0 = np.zeros((r, words), dtype=np.uint32)
        x_rows = np.zeros((r, xc, words), dtype=np.uint32)
        x_alive = np.zeros((r, xc), dtype=bool)
        roots = np.zeros(r, dtype=np.int64)
        rsz0 = np.ones(r, dtype=np.int32)
        bases = []
        universes = []
        for k, it in enumerate(items):
            a[k] = it["a"]
            p0[k] = it["p0"]
            for j, row in enumerate(it["x_rows"]):
                x_rows[k, j] = row
                x_alive[k, j] = True
            roots[k] = it["root"]
            base = it.get("base", (it["root"],))
            bases.append(base)
            rsz0[k] = len(base)
            universes.append(it["universe"])
        buckets.append(RootBucket(u_pad=b, x_pad=xc, a=a, p0=p0, x_rows=x_rows,
                                  x_alive0=x_alive, roots=roots, rsz0=rsz0,
                                  bases=bases, universes=universes))
    return PreparedMCE(buckets=buckets, pre_reported=pre_reported, n=g.n,
                       degeneracy=lam, order=order, rank=rank)
