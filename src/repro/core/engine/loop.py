"""The `lax.while_loop` DFS driver + single-host API (DESIGN.md §2.5).

Composes the layers: `prepare` stages host-side buckets, `reductions`
applies the per-call lemmas, `pivot` picks branch sets, and this module
owns call entry, the explicit stack walk, the vmap over roots, and the
end-to-end `run()`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import frames as fr
from repro.core.engine import pivot as piv
from repro.core.engine import reductions as red
from repro.core.engine.frames import U32, WORD, EngineConfig, Frame, FrameStack
from repro.core.engine.prepare import _unpack_bits_np, prepare
from repro.graph.csr import CSRGraph


# ===========================================================================
# Call-entry: dynamic reduction + leaf report + branch-set construction
# ===========================================================================

def enter_call(carry, cfg, ctx: fr.RootContext, P, Xp, xal, rsz, Rb,
               enable=None):
    """BK call entry for (R, P, X). Returns (carry, push?, Frame).

    `enable` gates every carry side-effect (counter bumps, clique reports):
    the DFS body runs enter_call unconditionally (straight-line, no
    lax.cond — see run_root) and masks it out on pop-only iterations."""
    XC = ctx.xc
    enable = jnp.bool_(True) if enable is None else enable
    en_i = enable.astype(jnp.int32)
    carry = dict(carry, calls=carry["calls"] + en_i)
    carry["sum_px"] = (carry["sum_px"] + (fr.popcount(P) + fr.popcount(Xp)
                       + fr.popcount(xal)) * en_i)

    # ---- dynamic reduction (paper Lemmas 5, 7, 8) ----
    if cfg.dynamic_red:
        carry, rf = red.dynamic_reduce(carry, cfg, ctx, P, Xp, xal, rsz, Rb,
                                       enable)
        P, Xp, xal, Rb, rsz = rf.P, rf.Xp, rf.xal, rf.Rb, rf.rsz
    else:
        rf = None

    # ---- leaf report ----
    p_empty = ~fr.any_bit(P)
    x_empty = ~fr.any_bit(xal) & ~fr.any_bit(Xp)
    carry = fr.report_single(carry, cfg, Rb, rsz,
                             p_empty & x_empty & (rsz >= 2) & enable)
    push = ~p_empty & enable

    # ---- branch set (pivot backends; rcd recomputes per visit) ----
    if cfg.backend in ("pivot", "revised"):
        B = piv.branch_set(cfg, ctx, P, Xp, xal, rf)
    else:
        B = jnp.zeros_like(P)
    return carry, push, Frame(P=P, B=B, Xp=Xp, Rb=Rb, rsz=rsz, xal=xal)


# ===========================================================================
# Per-root DFS driver
# ===========================================================================

def run_root(a, p0, x_rows, x_alive0, rsz0, cfg: EngineConfig):
    """Run the full BK subtree of one root. Returns the final carry dict."""
    U, words = a.shape
    ctx = fr.make_context(a, x_rows)
    D = U + 2
    xal_bits0 = fr.mask_to_bitset(x_alive0, ctx.eye_x)

    carry0 = fr.carry_init(cfg, words)
    # root frame: R = {v} (rsz=1), Rb covers universe additions only
    carry0, push0, frame0 = enter_call(
        carry0, cfg, ctx, p0, jnp.zeros(words, U32), xal_bits0,
        rsz0.astype(jnp.int32), jnp.zeros(words, U32))

    stack0 = FrameStack.alloc(D, words, ctx.xc_words).push(0, frame0)
    depth0 = jnp.where(push0, jnp.int32(0), jnp.int32(-1))

    def cond(s):
        return (s[0] >= 0) & (s[1] < cfg.max_iters)

    def body(s):
        """Straight-line masked DFS step — no lax.cond.

        Under vmap a cond lowers to SELECT over both branch results, which
        copies every stack buffer per iteration (measured: >40% of the
        engine's HBM bytes). Instead, branch work always executes with its
        carry side-effects gated by `has_branch`, and stack writes land in
        frames that are DEAD on the pop path (slots > new depth), so they
        need no gating at all. (§Perf iteration 2, EXPERIMENTS.md.)"""
        depth, it, stack, carry = s
        f = stack.read(depth)

        if cfg.backend in ("pivot", "revised"):
            has_branch = fr.any_bit(f.B)
            w = fr.first_bit_index(f.B)
        else:
            # rcd: clique test decides report-and-pop vs min-degree branch
            has_branch, w = piv.rcd_select(ctx, f.P)

        # ---- pop path: rcd maximality check + report (gated) ----
        if cfg.backend == "rcd":
            carry = piv.rcd_maximality_report(carry, cfg, ctx, f.P, f.Xp,
                                              f.xal, f.Rb, f.rsz, has_branch)

        # ---- branch path: always computed, side-effects gated ----
        wbit = ctx.eye[w]
        childP = f.P & a[w]
        childXp = f.Xp & a[w]
        # X0 rows stay alive iff adjacent to w (bit w of their row)
        row_word = jax.lax.dynamic_index_in_dim(
            x_rows, w // WORD, axis=1, keepdims=False)
        adj_w = ((row_word >> (w % WORD).astype(U32)) & U32(1)) != 0
        childxal = f.xal & fr.mask_to_bitset(adj_w, ctx.eye_x)
        carry = dict(carry,
                     branches=carry["branches"] + has_branch.astype(jnp.int32))
        carry, push, child = enter_call(carry, cfg, ctx, childP, childXp,
                                        childxal, f.rsz + 1, f.Rb | wbit,
                                        enable=has_branch)
        # update current frame (dead slot on the pop path — no gating):
        # P \ w, X ∪ w, B \ w
        cur = dict(P=jnp.where(has_branch, f.P & ~wbit, f.P),
                   Xp=jnp.where(has_branch, f.Xp | wbit, f.Xp))
        if cfg.backend in ("pivot", "revised"):
            cur["B"] = jnp.where(has_branch, f.B & ~wbit, f.B)
        stack = stack.write(depth, **cur)
        # write child frame (slot depth+1 is dead unless pushed)
        nd = depth + 1
        stack = stack.push(nd, child)
        new_depth = jnp.where(has_branch,
                              jnp.where(push, nd, depth), depth - 1)
        return new_depth, it + 1, stack, carry

    state = (depth0, jnp.int32(0), stack0, carry0)
    state = jax.lax.while_loop(cond, body, state)
    return state[-1]


@partial(jax.jit, static_argnames=("cfg",))
def run_bucket(a, p0, x_rows, x_alive0, rsz0, cfg: EngineConfig):
    """vmap the per-root DFS over a bucket. Returns dict of per-root stats."""
    return jax.vmap(lambda aa, pp, xr, xa, rr: run_root(aa, pp, xr, xa, rr,
                                                        cfg))(
        a, p0, x_rows, x_alive0, rsz0)


# ===========================================================================
# High-level API
# ===========================================================================

@dataclasses.dataclass
class MCEResult:
    cliques: int
    calls: int
    branches: int
    sum_px: int
    pre_reported: int
    enumerated: Optional[List[frozenset]] = None
    overflow: bool = False


def run(g: CSRGraph, *, global_red: bool = True, dynamic_red: bool = True,
        x_red: bool = True, backend: str = "pivot",
        enumerate_cliques: bool = False, out_cap: int = 4096,
        bucket_sizes: Sequence[int] = (32, 64, 128, 256, 512, 1024),
        max_x_rows: int = 8192,
        split_threshold: Optional[int] = None) -> MCEResult:
    """End-to-end single-host MCE: prepare on host, run buckets on device."""
    prep = prepare(g, global_red=global_red, x_red=x_red,
                   bucket_sizes=bucket_sizes, max_x_rows=max_x_rows,
                   split_threshold=split_threshold)
    cfg = EngineConfig(dynamic_red=dynamic_red, backend=backend,
                       out_cap=out_cap if enumerate_cliques else 0)
    total = MCEResult(cliques=len(prep.pre_reported), calls=0, branches=0,
                      sum_px=0, pre_reported=len(prep.pre_reported),
                      enumerated=list(prep.pre_reported) if enumerate_cliques else None)
    for bucket in prep.buckets:
        out = run_bucket(jnp.asarray(bucket.a), jnp.asarray(bucket.p0),
                         jnp.asarray(bucket.x_rows),
                         jnp.asarray(bucket.x_alive0),
                         jnp.asarray(bucket.rsz0), cfg)
        out = jax.tree.map(np.asarray, out)
        total.cliques += int(out["cliques"].sum())
        total.calls += int(out["calls"].sum())
        total.branches += int(out["branches"].sum())
        total.sum_px += int(out["sum_px"].sum())
        if enumerate_cliques:
            total.overflow |= bool(out["overflow"].any())
            for r in range(bucket.num_roots):
                uni = bucket.universes[r]
                base = [int(b) for b in bucket.bases[r]]
                for k in range(int(out["out_n"][r])):
                    bits = out["out_rows"][r, k]
                    members = _unpack_bits_np(bits)
                    clique = frozenset(base + [int(uni[m]) for m in members])
                    total.enumerated.append(clique)
    return total
