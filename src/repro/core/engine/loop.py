"""The `lax.while_loop` DFS driver + single-host API (DESIGN.md §2.5).

Composes the layers: `prepare` stages host-side buckets, `reductions`
applies the per-call lemmas, `pivot` picks branch sets, and this module
owns call entry, the explicit stack walk, the vmap over roots, and the
end-to-end `run()`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import frames as fr
from repro.core.engine import pivot as piv
from repro.core.engine import reductions as red
from repro.core.engine.frames import U32, WORD, EngineConfig, Frame, FrameStack
from repro.core.engine.prepare import (_unpack_bits_np, estimate_costs,
                                       prepare)
from repro.graph.csr import CSRGraph
from repro.kernels.bitset_ops import ops as bitops


# ===========================================================================
# Call-entry: dynamic reduction + leaf report + branch-set construction
# ===========================================================================

def enter_call(carry, cfg, ctx: fr.RootContext, P, Xp, xal, rsz, Rb,
               enable=None, pre=None):
    """BK call entry for (R, P, X). Returns (carry, push?, Frame).

    `enable` gates every carry side-effect (counter bumps, clique reports):
    the DFS body runs enter_call unconditionally (straight-line, no
    lax.cond — see run_root) and masks it out on pop-only iterations.

    `pre` is the fused frame-step kernel's (deg, partner) pair over this
    call's P — the DFS body computes it while constructing the child sets,
    so dynamic reduction (and pivot scoring when reduction is off) reuses
    it instead of re-sweeping A."""
    XC = ctx.xc
    enable = jnp.bool_(True) if enable is None else enable
    en_i = enable.astype(jnp.int32)
    carry = dict(carry, calls=carry["calls"] + en_i)
    carry["sum_px"] = (carry["sum_px"] + (fr.popcount(P) + fr.popcount(Xp)
                       + fr.popcount(xal)) * en_i)

    # ---- dynamic reduction (paper Lemmas 5, 7, 8) ----
    if cfg.dynamic_red:
        carry, rf = red.dynamic_reduce(carry, cfg, ctx, P, Xp, xal, rsz, Rb,
                                       enable, pre=pre)
        P, Xp, xal, Rb, rsz = rf.P, rf.Xp, rf.xal, rf.Rb, rf.rsz
    else:
        rf = None

    # ---- leaf report ----
    p_empty = ~fr.any_bit(P)
    x_empty = ~fr.any_bit(xal) & ~fr.any_bit(Xp)
    carry = fr.report_single(carry, cfg, Rb, rsz,
                             p_empty & x_empty & (rsz >= 2) & enable)
    push = ~p_empty & enable

    # ---- hybrid early termination + X-domination pruning (§2.7) ----
    if cfg.backend == "hybrid":
        # P a clique -> report R ∪ P and pop; P dominated by a forbidden
        # vertex -> pop silently. Reports are gated by `enable`, so every
        # dispatch path (run_root vmap, persistent refill/lane step) gets
        # the live-mask gating for free.
        carry, stop = piv.hybrid_early_term(carry, cfg, ctx, P, Xp, xal,
                                            Rb, rsz, enable)
        push = push & ~stop

    # ---- branch set (pivot backends; rcd recomputes per visit) ----
    if cfg.backend in fr.PIVOT_BACKENDS:
        B = piv.branch_set(cfg, ctx, P, Xp, xal, rf,
                           deg=None if pre is None else pre[0])
    else:
        B = jnp.zeros_like(P)
    return carry, push, Frame(P=P, B=B, Xp=Xp, Rb=Rb, rsz=rsz, xal=xal)


# ===========================================================================
# Shared DFS step + per-root DFS driver
# ===========================================================================

def dfs_step(cfg, ctx: fr.RootContext, depth, stack, carry, live=None):
    """One straight-line masked DFS step — no lax.cond.

    Under vmap a cond lowers to SELECT over both branch results, which
    copies every stack buffer per iteration (measured: >40% of the
    engine's HBM bytes). Instead, branch work always executes with its
    carry side-effects gated by `has_branch`, and stack writes land in
    frames that are DEAD on the pop path (slots > new depth), so they
    need no gating at all. (§Perf iteration 2, EXPERIMENTS.md.)

    `live=None` is the per-root path (depth is known >= 0 inside the
    while loop). The persistent engine passes `live = depth >= 0` per
    lane: a dead lane reads/writes clamped slot 0, every side-effect is
    masked off, and its depth passes through unchanged until a refill
    revives it. Dead-lane stack writes are harmless: the clamped slot-0
    write stores the frame's own values back, and the slot-1 child push
    is overwritten by the next real push before any read (pushes always
    precede descends)."""
    lv = jnp.bool_(True) if live is None else live
    d = depth if live is None else jnp.maximum(depth, 0)
    f = stack.read(d)

    if cfg.backend in fr.PIVOT_BACKENDS:
        has_branch = fr.any_bit(f.B) & lv
        w = fr.first_bit_index(f.B)
    else:
        # rcd: clique test decides report-and-pop vs min-degree branch
        hb, w = piv.rcd_select(ctx, f.P)
        has_branch = hb & lv

    # ---- pop path: rcd maximality check + report (gated) ----
    if cfg.backend == "rcd":
        carry = piv.rcd_maximality_report(carry, cfg, ctx, f.P, f.Xp,
                                          f.xal, f.Rb, f.rsz,
                                          has_branch | ~lv)

    # ---- branch path: always computed, side-effects gated ----
    wbit = ctx.eye[w]
    # fused frame step: child sets + child degree sweep + Lemma-7 partner
    # in one kernel pass over A (threaded into enter_call as `pre`)
    childP, childXp, deg, partner = bitops.frame_step(ctx.A, f.P, f.Xp,
                                                      ctx.A[w])
    # X0 rows stay alive iff adjacent to w (bit w of their row)
    row_word = jax.lax.dynamic_index_in_dim(
        ctx.x_rows, w // WORD, axis=1, keepdims=False)
    adj_w = ((row_word >> (w % WORD).astype(U32)) & U32(1)) != 0
    childxal = f.xal & fr.mask_to_bitset(adj_w, ctx.eye_x)
    carry = dict(carry,
                 branches=carry["branches"] + has_branch.astype(jnp.int32))
    carry, push, child = enter_call(carry, cfg, ctx, childP, childXp,
                                    childxal, f.rsz + 1, f.Rb | wbit,
                                    enable=has_branch, pre=(deg, partner))
    # update current frame (dead slot on the pop path — no gating):
    # P \ w, X ∪ w, B \ w
    cur = dict(P=jnp.where(has_branch, f.P & ~wbit, f.P),
               Xp=jnp.where(has_branch, f.Xp | wbit, f.Xp))
    if cfg.backend in fr.PIVOT_BACKENDS:
        cur["B"] = jnp.where(has_branch, f.B & ~wbit, f.B)
    stack = stack.write(d, **cur)
    # write child frame (slot depth+1 is dead unless pushed)
    nd = d + 1
    stack = stack.push(nd, child)
    new_depth = jnp.where(has_branch, jnp.where(push, nd, d), d - 1)
    if live is not None:
        new_depth = jnp.where(lv, new_depth, depth)
    return new_depth, stack, carry


def run_root(a, p0, x_rows, x_alive0, rsz0, cfg: EngineConfig):
    """Run the full BK subtree of one root. Returns the final carry dict
    plus `iters` (loop iterations used) and `truncated` (1 iff the walk
    hit cfg.max_iters with frames still live — the counts are partial)."""
    U, words = a.shape
    ctx = fr.make_context(a, x_rows)
    D = U + 2
    xal_bits0 = fr.mask_to_bitset(x_alive0, ctx.eye_x)

    carry0 = fr.carry_init(cfg, words)
    # root frame: R = {v} (rsz=1), Rb covers universe additions only
    carry0, push0, frame0 = enter_call(
        carry0, cfg, ctx, p0, jnp.zeros(words, U32), xal_bits0,
        rsz0.astype(jnp.int32), jnp.zeros(words, U32))

    stack0 = FrameStack.alloc(D, words, ctx.xc_words).push(0, frame0)
    depth0 = jnp.where(push0, jnp.int32(0), jnp.int32(-1))

    def cond(s):
        return (s[0] >= 0) & (s[1] < cfg.max_iters)

    def body(s):
        depth, it, stack, carry = s
        depth, stack, carry = dfs_step(cfg, ctx, depth, stack, carry)
        return depth, it + 1, stack, carry

    state = (depth0, jnp.int32(0), stack0, carry0)
    depth, it, _stack, carry = jax.lax.while_loop(cond, body, state)
    return dict(carry, iters=it, truncated=(depth >= 0).astype(jnp.int32))


@partial(jax.jit, static_argnames=("cfg",))
def run_bucket(a, p0, x_rows, x_alive0, rsz0, cfg: EngineConfig):
    """vmap the per-root DFS over a bucket. Returns dict of per-root stats."""
    return jax.vmap(lambda aa, pp, xr, xa, rr: run_root(aa, pp, xr, xa, rr,
                                                        cfg))(
        a, p0, x_rows, x_alive0, rsz0)


# ===========================================================================
# Persistent bucket engine: lane-refill work queue (DESIGN.md §2.6)
# ===========================================================================

@partial(jax.jit, static_argnames=("cfg", "lanes"))
def run_bucket_persistent(a, p0, x_rows, x_alive0, rsz0, cfg: EngineConfig,
                          lanes: int = 64):
    """One jitted while_loop over a (LANES, …) batch of DFS states fed by a
    device-resident root work queue.

    The per-root `run_bucket` vmaps lock-step: every lane spins (masked)
    until the slowest root in the bucket finishes. Here a lane whose
    subtree exhausts (`depth < 0`) claims the next unstarted root inside
    the loop body — shared claim counter + per-lane exclusive-cumsum
    offsets, no host round-trip — and reinitializes its stack in place, so
    lanes stay saturated until the queue drains. Roots are consumed in the
    caller's array order (the driver passes its cost-descending canonical
    order, so the queue order IS the checkpoint cursor order).

    The refill phase is wrapped in a real `lax.cond`: unlike the vmapped
    per-root body (where cond lowers to SELECT), this loop is not under
    vmap, so iterations with no exhausted lane skip the (LANES, U, W)
    root-context gathers entirely.

    Returns the per-lane carry dict plus scalars: `iters` (loop trips),
    `live_iters` (Σ useful lane-trips: live lanes per trip, plus claims
    whose root completed inside its entry call — those do their whole
    subtree's work in the refill; occupancy = live_iters /
    (iters·lanes)), `claimed`, and `truncated` (1 iff cfg.max_iters hit
    with work remaining)."""
    R, U, words = a.shape
    XC = x_rows.shape[1]
    L = lanes
    D = U + 2
    eye = fr.eye_bits(U, words)
    xc_words = max(-(-XC // WORD), 1)
    eye_x = fr.eye_bits(XC, xc_words)

    track = bool(cfg.out_cap)
    carry0 = jax.tree.map(
        lambda x: jnp.zeros((L,) + x.shape, x.dtype),
        fr.carry_init(cfg, words, track_root=track))
    stack0 = jax.tree.map(
        lambda x: jnp.zeros((L,) + x.shape, x.dtype),
        FrameStack.alloc(D, words, xc_words))
    state0 = (jnp.int32(0),                    # it: loop trips
              jnp.int32(0),                    # cp: queue claim counter
              jnp.int32(0),                    # ls: Σ live lanes (occupancy)
              jnp.full((L,), jnp.int32(-1)),   # per-lane DFS depth
              jnp.zeros((L, U, words), U32),   # per-lane adjacency context
              jnp.zeros((L, XC, words), U32),  # per-lane X0 rows
              stack0, carry0)

    def cond(s):
        it, cp, _ls, depth = s[0], s[1], s[2], s[3]
        return ((cp < R) | jnp.any(depth >= 0)) & (it < cfg.max_iters)

    def refill(args):
        """Claim protocol: exhausted lanes take consecutive queue slots."""
        cp, ls, depth, al, xrl, stack, carry = args
        exh = depth < 0
        exh_i = exh.astype(jnp.int32)
        offs = jnp.cumsum(exh_i) - exh_i       # exclusive cumsum per lane
        cand = cp + offs
        claim = exh & (cand < R)
        idx = jnp.where(claim, cand, 0)
        a_new = jnp.take(a, idx, axis=0)
        p_new = jnp.take(p0, idx, axis=0)
        xr_new = jnp.take(x_rows, idx, axis=0)
        xa_new = jnp.take(x_alive0, idx, axis=0)
        rz_new = jnp.take(rsz0, idx, axis=0)

        def lane_entry(claim_l, idx_l, a_l, p_l, xr_l, xa_l, rz_l,
                       depth_l, A_l, XR_l, stack_l, carry_l):
            ctx = fr.RootContext(A=a_l, x_rows=xr_l, eye=eye, eye_x=eye_x)
            if "cur_root" in carry_l:
                carry_l = dict(carry_l, cur_root=jnp.where(
                    claim_l, idx_l, carry_l["cur_root"]))
            xal0 = fr.mask_to_bitset(xa_l, eye_x)
            carry_l, push, f0 = enter_call(
                carry_l, cfg, ctx, p_l, jnp.zeros(words, U32), xal0,
                rz_l.astype(jnp.int32), jnp.zeros(words, U32),
                enable=claim_l)
            # merge the fresh root frame into stack slot 0 where claimed
            old0 = stack_l.read(0)
            f0m = Frame(*(jnp.where(claim_l, n, o)
                          for n, o in zip(f0, old0)))
            stack_l = stack_l.push(0, f0m)
            depth_l = jnp.where(claim_l,
                                jnp.where(push, jnp.int32(0), jnp.int32(-1)),
                                depth_l)
            A_l = jnp.where(claim_l, a_l, A_l)
            XR_l = jnp.where(claim_l, xr_l, XR_l)
            return depth_l, A_l, XR_l, stack_l, carry_l

        depth, al, xrl, stack, carry = jax.vmap(lane_entry)(
            claim, idx, a_new, p_new, xr_new, xa_new, rz_new,
            depth, al, xrl, stack, carry)
        cp = cp + jnp.sum(claim.astype(jnp.int32))
        # a claimed root that finished inside its entry call (no push) did
        # its whole subtree's work this trip — count it as a useful trip
        ls = ls + jnp.sum((claim & (depth < 0)).astype(jnp.int32))
        return cp, ls, depth, al, xrl, stack, carry

    def body(s):
        it, cp, ls, depth, al, xrl, stack, carry = s
        need = (cp < R) & jnp.any(depth < 0)
        cp, ls, depth, al, xrl, stack, carry = jax.lax.cond(
            need, refill, lambda args: args,
            (cp, ls, depth, al, xrl, stack, carry))
        ls = ls + jnp.sum((depth >= 0).astype(jnp.int32))

        def lane_step(a_l, xr_l, depth_l, stack_l, carry_l):
            ctx = fr.RootContext(A=a_l, x_rows=xr_l, eye=eye, eye_x=eye_x)
            return dfs_step(cfg, ctx, depth_l, stack_l, carry_l,
                            live=depth_l >= 0)

        depth, stack, carry = jax.vmap(lane_step)(al, xrl, depth, stack,
                                                  carry)
        return it + 1, cp, ls, depth, al, xrl, stack, carry

    it, cp, ls, depth, _al, _xrl, _stack, carry = jax.lax.while_loop(
        cond, body, state0)
    out = dict(carry)
    out["iters"] = it
    out["live_iters"] = ls
    out["claimed"] = cp
    out["truncated"] = ((cp < R) | jnp.any(depth >= 0)).astype(jnp.int32)
    return out


# ===========================================================================
# High-level API
# ===========================================================================

def root_cost_skew(costs) -> float:
    """max/mean skew of a per-root cost proxy, hardened for edge buckets.

    Degenerate inputs (empty, all-zero/all-pad, NaN/inf costs) answer 1.0
    — "uniform", which routes to perroot downstream — instead of crashing
    on a length-0 max or exploding to max/1e-12 on an all-but-zero mean.
    The skew is clamped to n_roots: max/mean ≤ n holds for any nonnegative
    vector, so anything larger is float-noise from a near-zero mean and
    would otherwise misroute trivial buckets to the persistent engine.
    Shared by `choose_engine` and the driver's per-bucket memo so cached
    replays and fresh runs always agree."""
    costs = np.asarray(costs, dtype=np.float64)
    n = int(costs.size)
    if n == 0:
        return 1.0
    m = float(costs.max())
    mean = float(costs.mean())
    if not np.isfinite(m) or m <= 0.0 or mean <= 0.0:
        return 1.0
    return min(m / mean, float(n))


def choose_engine(costs: Optional[np.ndarray] = None, *, lanes: int = 64,
                  skew: Optional[float] = None,
                  n_roots: Optional[int] = None,
                  skew_threshold: float = 4.0, min_roots: int = 16):
    """Pick (engine, lanes) for one bucket from its root-cost skew.

    skew = max/mean of the per-root cost proxy (`prepare.estimate_costs`).
    A uniform bucket (skew < threshold) runs the lock-step per-root vmap:
    every lane finishes together, so a work queue would add claim overhead
    and win nothing. A skewed bucket runs the persistent lane-refill
    queue — that is exactly the regime where lock-step lanes idle behind
    the one hub root. Lanes are sized so the queue actually refills
    (>= ~4 roots per lane on average), clamped to [8, lanes]; tiny
    buckets (< min_roots) stay on perroot where one compile per shape is
    cheaper than the queue machinery.

    Callers treat explicit engine= flags as overrides; this is only the
    `engine="auto"` policy, kept in the engine layer so both the
    single-host `run()` and the distributed driver share it (the driver
    imports the engine, never the reverse — DESIGN.md §6). Pass
    `skew=`/`n_roots=` instead of `costs` when the skew is already
    memoized (the driver caches it on the bucket for cached replays).
    Edge buckets never crash or misroute: empty/all-pad/degenerate cost
    vectors score skew 1.0 and the skew is clamped to n_roots either way
    (`root_cost_skew`)."""
    if costs is not None:
        costs = np.asarray(costs, dtype=np.float64)
        n_roots = int(costs.size)
        skew = root_cost_skew(costs)   # 1.0 on empty/all-pad/degenerate
    if skew is None or n_roots is None or not np.isfinite(skew):
        return "perroot", lanes
    skew = min(skew, float(max(n_roots, 1)))   # memoized-skew callers too
    if n_roots < min_roots or skew < skew_threshold:
        return "perroot", lanes
    per_lane = max(1, n_roots // 4)
    refill_lanes = 1 << (per_lane.bit_length() - 1)   # largest pow2 <= n/4
    return "persistent", max(8, min(lanes, refill_lanes))


@dataclasses.dataclass
class MCEResult:
    cliques: int
    calls: int
    branches: int
    sum_px: int
    pre_reported: int
    enumerated: Optional[List[frozenset]] = None
    overflow: bool = False
    iters_exhausted: bool = False
    stats: Optional[dict] = None   # service layer: per-query occupancy
    # counters (live_iters/lane_iters/truncated/engine_choices) — see
    # launch.mce_service.MCEService


def run(g: CSRGraph, *, global_red: bool = True, dynamic_red: bool = True,
        x_red: bool = True, backend: str = "pivot",
        enumerate_cliques: bool = False, out_cap: int = 4096,
        bucket_sizes: Sequence[int] = (32, 64, 128, 256, 512, 1024),
        max_x_rows: int = 8192,
        split_threshold: Optional[int] = None,
        engine: str = "perroot", lanes: int = 64) -> MCEResult:
    """End-to-end single-host MCE: prepare on host, run buckets on device.

    `engine='persistent'` routes each bucket through the lane-refill work
    queue (`run_bucket_persistent` with min(lanes, roots) lanes); the
    default 'perroot' path vmaps one lock-step lane per root.
    `engine='auto'` picks per bucket from the root-cost skew
    (`choose_engine`); the explicit flags remain hard overrides."""
    if engine not in ("perroot", "persistent", "auto"):
        raise ValueError(f"unknown engine {engine!r}")
    if backend not in fr.BACKENDS:
        raise ValueError(f"unknown backend {backend!r} "
                         f"(expected one of {fr.BACKENDS})")
    prep = prepare(g, global_red=global_red, x_red=x_red,
                   bucket_sizes=bucket_sizes, max_x_rows=max_x_rows,
                   split_threshold=split_threshold)
    cfg = EngineConfig(dynamic_red=dynamic_red, backend=backend,
                       out_cap=out_cap if enumerate_cliques else 0)
    total = MCEResult(cliques=len(prep.pre_reported), calls=0, branches=0,
                      sum_px=0, pre_reported=len(prep.pre_reported),
                      enumerated=list(prep.pre_reported) if enumerate_cliques else None)
    for bucket in prep.buckets:
        args = (jnp.asarray(bucket.a), jnp.asarray(bucket.p0),
                jnp.asarray(bucket.x_rows), jnp.asarray(bucket.x_alive0),
                jnp.asarray(bucket.rsz0))
        eng_b, lanes_b = engine, lanes
        if engine == "auto":
            total_real = bucket.num_roots - bucket.n_pad
            eng_b, lanes_b = choose_engine(
                estimate_costs(bucket)[:total_real], lanes=lanes)
        if eng_b == "persistent":
            out = run_bucket_persistent(*args, cfg,
                                        lanes=min(lanes_b, bucket.num_roots))
        else:
            out = run_bucket(*args, cfg)
        out = jax.tree.map(np.asarray, out)
        total.cliques += int(out["cliques"].sum())
        # padded no-op roots (compile-count hygiene) are one call each
        total.calls += int(out["calls"].sum()) - bucket.n_pad
        total.branches += int(out["branches"].sum())
        total.sum_px += int(out["sum_px"].sum())
        total.iters_exhausted |= bool(out["truncated"].any())
        if enumerate_cliques:
            total.overflow |= bool(out["overflow"].any())
            if eng_b == "persistent":
                # lanes interleave roots; out_root maps each clique back
                for l in range(out["out_n"].shape[0]):
                    for k in range(int(out["out_n"][l])):
                        r = int(out["out_root"][l, k])
                        uni = bucket.universes[r]
                        base = [int(b) for b in bucket.bases[r]]
                        members = _unpack_bits_np(out["out_rows"][l, k])
                        total.enumerated.append(frozenset(
                            base + [int(uni[m]) for m in members]))
            else:
                for r in range(bucket.num_roots):
                    uni = bucket.universes[r]
                    base = [int(b) for b in bucket.bases[r]]
                    for k in range(int(out["out_n"][r])):
                        bits = out["out_rows"][r, k]
                        members = _unpack_bits_np(bits)
                        clique = frozenset(base + [int(uni[m])
                                                   for m in members])
                        total.enumerated.append(clique)
    return total
