"""The `lax.while_loop` DFS driver + single-host API (DESIGN.md §2.5).

Composes the layers: `prepare` stages host-side buckets, `reductions`
applies the per-call lemmas, `pivot` picks branch sets, and this module
owns call entry, the explicit stack walk, the vmap over roots, and the
end-to-end `run()`.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import frames as fr
from repro.core.engine import pivot as piv
from repro.core.engine import reductions as red
from repro.core.engine.frames import U32, WORD, EngineConfig, Frame, FrameStack
from repro.core.engine.prepare import (_unpack_bits_np, estimate_costs,
                                       prepare)
from repro.graph.csr import CSRGraph
from repro.kernels.bitset_ops import ops as bitops


# ===========================================================================
# Call-entry: dynamic reduction + leaf report + branch-set construction
# ===========================================================================

def enter_call(carry, cfg, ctx: fr.RootContext, P, Xp, xal, rsz, Rb,
               enable=None, pre=None):
    """BK call entry for (R, P, X). Returns (carry, push?, Frame).

    `enable` gates every carry side-effect (counter bumps, clique reports):
    the DFS body runs enter_call unconditionally (straight-line, no
    lax.cond — see run_root) and masks it out on pop-only iterations.

    `pre` is the fused frame-step kernel's (deg, partner) pair over this
    call's P — the DFS body computes it while constructing the child sets,
    so dynamic reduction (and pivot scoring when reduction is off) reuses
    it instead of re-sweeping A."""
    XC = ctx.xc
    enable = jnp.bool_(True) if enable is None else enable
    en_i = enable.astype(jnp.int32)
    carry = dict(carry, calls=carry["calls"] + en_i)
    carry["sum_px"] = (carry["sum_px"] + (fr.popcount(P) + fr.popcount(Xp)
                       + fr.popcount(xal)) * en_i)

    # ---- dynamic reduction (paper Lemmas 5, 7, 8) ----
    if cfg.dynamic_red:
        carry, rf = red.dynamic_reduce(carry, cfg, ctx, P, Xp, xal, rsz, Rb,
                                       enable, pre=pre)
        P, Xp, xal, Rb, rsz = rf.P, rf.Xp, rf.xal, rf.Rb, rf.rsz
    else:
        rf = None

    # ---- leaf report ----
    p_empty = ~fr.any_bit(P)
    x_empty = ~fr.any_bit(xal) & ~fr.any_bit(Xp)
    carry = fr.report_single(carry, cfg, Rb, rsz,
                             p_empty & x_empty & (rsz >= 2) & enable)
    push = ~p_empty & enable

    # ---- hybrid early termination + X-domination pruning (§2.7) ----
    if cfg.backend == "hybrid":
        # P a clique -> report R ∪ P and pop; P dominated by a forbidden
        # vertex -> pop silently. Reports are gated by `enable`, so every
        # dispatch path (run_root vmap, persistent refill/lane step) gets
        # the live-mask gating for free.
        carry, stop = piv.hybrid_early_term(carry, cfg, ctx, P, Xp, xal,
                                            Rb, rsz, enable)
        push = push & ~stop

    # ---- branch set (pivot backends; rcd recomputes per visit) ----
    if cfg.backend in fr.PIVOT_BACKENDS:
        B = piv.branch_set(cfg, ctx, P, Xp, xal, rf,
                           deg=None if pre is None else pre[0])
    else:
        B = jnp.zeros_like(P)
    return carry, push, Frame(P=P, B=B, Xp=Xp, Rb=Rb, rsz=rsz, xal=xal)


# ===========================================================================
# Shared DFS step + per-root DFS driver
# ===========================================================================

def dfs_step(cfg, ctx: fr.RootContext, depth, stack, carry, live=None):
    """One straight-line masked DFS step — no lax.cond.

    Under vmap a cond lowers to SELECT over both branch results, which
    copies every stack buffer per iteration (measured: >40% of the
    engine's HBM bytes). Instead, branch work always executes with its
    carry side-effects gated by `has_branch`, and stack writes land in
    frames that are DEAD on the pop path (slots > new depth), so they
    need no gating at all. (§Perf iteration 2, EXPERIMENTS.md.)

    `live=None` is the per-root path (depth is known >= 0 inside the
    while loop). The persistent engine passes `live = depth >= 0` per
    lane: a dead lane reads/writes clamped slot 0, every side-effect is
    masked off, and its depth passes through unchanged until a refill
    revives it. Dead-lane stack writes are harmless: the clamped slot-0
    write stores the frame's own values back, and the slot-1 child push
    is overwritten by the next real push before any read (pushes always
    precede descends)."""
    lv = jnp.bool_(True) if live is None else live
    d = depth if live is None else jnp.maximum(depth, 0)
    f = stack.read(d)

    if cfg.backend in fr.PIVOT_BACKENDS:
        has_branch = fr.any_bit(f.B) & lv
        w = fr.first_bit_index(f.B)
    else:
        # rcd: clique test decides report-and-pop vs min-degree branch
        hb, w = piv.rcd_select(ctx, f.P)
        has_branch = hb & lv

    # ---- pop path: rcd maximality check + report (gated) ----
    if cfg.backend == "rcd":
        carry = piv.rcd_maximality_report(carry, cfg, ctx, f.P, f.Xp,
                                          f.xal, f.Rb, f.rsz,
                                          has_branch | ~lv)

    # ---- branch path: always computed, side-effects gated ----
    wbit = ctx.eye[w]
    # fused frame step: child sets + child degree sweep + Lemma-7 partner
    # in one kernel pass over A (threaded into enter_call as `pre`)
    childP, childXp, deg, partner = bitops.frame_step(ctx.A, f.P, f.Xp,
                                                      ctx.A[w])
    # X0 rows stay alive iff adjacent to w (bit w of their row)
    row_word = jax.lax.dynamic_index_in_dim(
        ctx.x_rows, w // WORD, axis=1, keepdims=False)
    adj_w = ((row_word >> (w % WORD).astype(U32)) & U32(1)) != 0
    childxal = f.xal & fr.mask_to_bitset(adj_w, ctx.eye_x)
    carry = dict(carry,
                 branches=carry["branches"] + has_branch.astype(jnp.int32))
    carry, push, child = enter_call(carry, cfg, ctx, childP, childXp,
                                    childxal, f.rsz + 1, f.Rb | wbit,
                                    enable=has_branch, pre=(deg, partner))
    # update current frame (dead slot on the pop path — no gating):
    # P \ w, X ∪ w, B \ w
    cur = dict(P=jnp.where(has_branch, f.P & ~wbit, f.P),
               Xp=jnp.where(has_branch, f.Xp | wbit, f.Xp))
    if cfg.backend in fr.PIVOT_BACKENDS:
        cur["B"] = jnp.where(has_branch, f.B & ~wbit, f.B)
    stack = stack.write(d, **cur)
    # write child frame (slot depth+1 is dead unless pushed)
    nd = d + 1
    stack = stack.push(nd, child)
    new_depth = jnp.where(has_branch, jnp.where(push, nd, d), d - 1)
    if live is not None:
        new_depth = jnp.where(lv, new_depth, depth)
    return new_depth, stack, carry


def _window_eligible(cfg: EngineConfig) -> bool:
    """Static gate for the FUSED VMEM stack-window walk: the
    `dfs_step_window`/`dfs_step_window_lanes` kernel contract covers the
    pivot backend with dynamic reduction off and counting only (no
    enumeration buffers ride in the window). Ineligible configs with
    `window_steps > 0` still window in the persistent engine — via the
    engine-step window, which runs the full `dfs_step` contract."""
    return (cfg.window_steps > 0 and cfg.backend == "pivot"
            and not cfg.dynamic_red and not cfg.out_cap
            and cfg.window_frames in (0, bitops.WINDOW_FRAMES))


def run_root_windowed(a, p0, x_rows, x_alive0, rsz0, cfg: EngineConfig):
    """`run_root` with the DFS stack walked through a T-frame VMEM window.

    The plain walk round-trips the whole frame through HBM on every
    `dfs_step`. Here the outer while loop advances `cfg.window_steps`
    frame-steps per trip via the fused `dfs_step_window` dispatch: the
    top-T stack frames stay resident across those steps, and the HBM
    stack is touched only at the window boundary — one T-row slice down,
    one T-row write-back up per trip. The per-frame X0 alive set is not
    stacked at all: it is a closed form of the frame's Rb (see
    ref.dfs_step_window), so the window carries (P, B, Xp, Rb, rsz).
    The window is re-centered each trip (`base = clip(d − T/2, 0, D−T)`),
    so the walk always enters with both push and pop headroom; the kernel
    stops early on window overflow/underflow and this wrapper re-slices.
    Counters are bit-identical to `run_root` (same straight-line masked
    step semantics, steps merely batched per HBM round-trip)."""
    U, words = a.shape
    T = bitops.WINDOW_FRAMES
    ctx = fr.make_context(a, x_rows)
    xal_bits0 = fr.mask_to_bitset(x_alive0, ctx.eye_x)
    carry0 = fr.carry_init(cfg, words)
    carry0, push0, frame0 = enter_call(
        carry0, cfg, ctx, p0, jnp.zeros(words, U32), xal_bits0,
        rsz0.astype(jnp.int32), jnp.zeros(words, U32))
    alive0 = x_alive0.astype(jnp.int32)
    # depth never exceeds U = D − 2 (every push consumes a P vertex), so a
    # freshly centered window always has a free slot above the top frame
    D = max(U + 2, T)
    sP = jnp.zeros((D, words), U32).at[0].set(frame0.P)
    sB = jnp.zeros((D, words), U32).at[0].set(frame0.B)
    sXp = jnp.zeros((D, words), U32).at[0].set(frame0.Xp)
    sRb = jnp.zeros((D, words), U32)
    srsz = jnp.zeros((D,), jnp.int32).at[0].set(frame0.rsz)
    d0 = jnp.where(push0, jnp.int32(0), jnp.int32(-1))

    def cond(s):
        return (s[0] >= 0) & (s[1] < cfg.max_iters)

    def body(s):
        d, it, sP, sB, sXp, sRb, srsz, carry = s
        base = jnp.clip(d - T // 2, 0, D - T)

        def sl(arr):
            return jax.lax.dynamic_slice_in_dim(arr, base, T, axis=0)

        wP, wB, wXp, wRb, wrsz, ctl = bitops.dfs_step_window(
            a, x_rows, ctx.eye, alive0, sl(sP), sl(sB), sl(sXp), sl(sRb),
            sl(srsz), d - base, steps=cfg.window_steps)

        def up(arr, w):
            return jax.lax.dynamic_update_slice_in_dim(arr, w, base, axis=0)

        sP, sB, sXp = up(sP, wP), up(sB, wB), up(sXp, wXp)
        sRb, srsz = up(sRb, wRb), up(srsz, wrsz)
        carry = dict(carry,
                     calls=carry["calls"] + ctl[1],
                     branches=carry["branches"] + ctl[2],
                     sum_px=carry["sum_px"] + ctl[3],
                     cliques=carry["cliques"] + ctl[4])
        return base + ctl[0], it + ctl[5], sP, sB, sXp, sRb, srsz, carry

    state = (d0, jnp.int32(0), sP, sB, sXp, sRb, srsz, carry0)
    out = jax.lax.while_loop(cond, body, state)
    d, it, carry = out[0], out[1], out[7]
    return dict(carry, iters=it, truncated=(d >= 0).astype(jnp.int32))


def run_root(a, p0, x_rows, x_alive0, rsz0, cfg: EngineConfig):
    """Run the full BK subtree of one root. Returns the final carry dict
    plus `iters` (loop iterations used) and `truncated` (1 iff the walk
    hit cfg.max_iters with frames still live — the counts are partial).

    With `cfg.window_steps > 0` and an eligible config (pivot backend,
    dynamic reduction off, counting only) the walk routes through the
    VMEM stack window (`run_root_windowed`) — same counters, K steps per
    HBM stack round-trip."""
    if _window_eligible(cfg):
        return run_root_windowed(a, p0, x_rows, x_alive0, rsz0, cfg)
    U, words = a.shape
    ctx = fr.make_context(a, x_rows)
    D = U + 2
    xal_bits0 = fr.mask_to_bitset(x_alive0, ctx.eye_x)

    carry0 = fr.carry_init(cfg, words)
    # root frame: R = {v} (rsz=1), Rb covers universe additions only
    carry0, push0, frame0 = enter_call(
        carry0, cfg, ctx, p0, jnp.zeros(words, U32), xal_bits0,
        rsz0.astype(jnp.int32), jnp.zeros(words, U32))

    stack0 = FrameStack.alloc(D, words, ctx.xc_words).push(0, frame0)
    depth0 = jnp.where(push0, jnp.int32(0), jnp.int32(-1))

    def cond(s):
        return (s[0] >= 0) & (s[1] < cfg.max_iters)

    def body(s):
        depth, it, stack, carry = s
        depth, stack, carry = dfs_step(cfg, ctx, depth, stack, carry)
        return depth, it + 1, stack, carry

    state = (depth0, jnp.int32(0), stack0, carry0)
    depth, it, _stack, carry = jax.lax.while_loop(cond, body, state)
    return dict(carry, iters=it, truncated=(depth >= 0).astype(jnp.int32))


@partial(jax.jit, static_argnames=("cfg",))
def run_bucket(a, p0, x_rows, x_alive0, rsz0, cfg: EngineConfig):
    """vmap the per-root DFS over a bucket. Returns dict of per-root stats."""
    return jax.vmap(lambda aa, pp, xr, xa, rr: run_root(aa, pp, xr, xa, rr,
                                                        cfg))(
        a, p0, x_rows, x_alive0, rsz0)


# ===========================================================================
# Persistent bucket engine: lane-refill work queue + lane work stealing
# (DESIGN.md §2.6)
# ===========================================================================

def _persistent_state0(cfg: EngineConfig, lanes: int, U: int, words: int,
                       XC: int):
    """Fresh lane state for one same-shape span of the root stream."""
    # depth never exceeds U (= D − 2), and the windowed segment slices
    # WINDOW_FRAMES + 1 consecutive slots per lane (T resident frames plus
    # one spill slot), so guarantee the stack always has slice room
    D = max(U + 2, bitops.WINDOW_FRAMES + 1)
    xc_words = max(-(-XC // WORD), 1)
    track = bool(cfg.out_cap)
    carry0 = jax.tree.map(
        lambda x: jnp.zeros((lanes,) + x.shape, x.dtype),
        fr.carry_init(cfg, words, track_root=track))
    stack0 = jax.tree.map(
        lambda x: jnp.zeros((lanes,) + x.shape, x.dtype),
        FrameStack.alloc(D, words, xc_words))
    return (jnp.int32(0),                        # it: loop trips
            jnp.int32(0),                        # cp: queue claim counter
            jnp.int32(0),                        # ls: Σ useful lane steps
            jnp.int32(0),                        # st: steal count
            jnp.int32(0),                        # et: entry-terminated roots
            jnp.full((lanes,), jnp.int32(-1)),   # per-lane DFS depth
            jnp.zeros((lanes, U, words), U32),   # per-lane adjacency context
            jnp.zeros((lanes, XC, words), U32),  # per-lane X0 rows
            stack0, carry0,
            jnp.int32(0),                        # ws: window spills
            jnp.int32(0))                        # wh: window hits


@partial(jax.jit, static_argnames=("cfg", "lanes", "drain"))
def _persistent_segment(a, p0, x_rows, x_alive0, rsz0, root_base, state,
                        cfg: EngineConfig, lanes: int, drain: bool):
    """One jitted while_loop draining one root slab into a lane state.

    `drain=True` runs until every lane's subtree exhausts (the classic
    per-bucket persistent loop). `drain=False` returns as soon as the
    queue is claimed out (`cp >= R`) with lanes still live — the stream
    caller (`run_stream_persistent`) then re-enters with the NEXT slab and
    the same lane state, so live lanes never drain at a bucket boundary.
    `root_base` offsets `cur_root` so enumerated cliques decode against
    the stream-global root index.

    With `cfg.window_steps > 0` each loop trip walks every live lane
    through up to K frame-steps over a resident stack window instead of
    one step against the full HBM stack (DESIGN.md §2.6 WINDOW): the trip
    slices a per-lane window of consecutive stack slots re-centered on
    the lane's depth, steps it K times — via the lane-batched fused
    `dfs_step_window_lanes` dispatch when the config is window-eligible
    (pivot, no dynamic reduction, counting only), else via an inner
    while_loop of the ordinary `dfs_step` over a T+1-slot window so
    dynamic reduction, rcd/hybrid branching, early termination, and
    enumeration buffers all work from inside the window — and writes the
    window back. Refill claims, steals, slab spans, and checkpoint
    boundaries always observe a flushed stack because windows live only
    within a trip's step phase; a lane stopping on window
    underflow/overflow merely idles for the rest of that trip (its
    neighbors keep stepping) and re-enters the next trip with a freshly
    centered window. Windowing is pure scheduling: the same masked step
    semantics run in a different batching, so counters and enumerated
    sets are bit-identical to the unwindowed walk."""
    R, U, words = a.shape
    XC = x_rows.shape[1]
    L = lanes
    eye = fr.eye_bits(U, words)
    xc_words = max(-(-XC // WORD), 1)
    eye_x = fr.eye_bits(XC, xc_words)
    # 'rcd' carries no branch set at rest — nothing to split, never steals
    can_steal = bool(cfg.steal) and cfg.backend in fr.PIVOT_BACKENDS
    if cfg.steal_victim not in ("branchiest", "deepest"):
        raise ValueError(f"unknown steal_victim {cfg.steal_victim!r} "
                         "(expected 'branchiest' or 'deepest')")
    windowed = cfg.window_steps > 0
    # window-eligible configs run the fused lane-batched kernel contract
    # (aliveness as a closed form of Rb — per-frame xal is NOT maintained
    # inside the window); everything else windows the engine's dfs_step
    win_kernel = _window_eligible(cfg)
    D = int(state[8].P.shape[1])
    if win_kernel:
        T = bitops.WINDOW_FRAMES
        WT = T
    else:
        # engine-path window depth: cfg.window_frames, or the full stack
        # when 0 (the degenerate window — no re-centering, no boundary
        # stops, the whole stack rides the trip as loop carry; the right
        # default wherever stack residency is not VMEM-bounded). The +1
        # is the spill slot; full-depth windows need none (depth <= U =
        # D - 2 < WT - 1, a push can never overflow).
        T = cfg.window_frames if cfg.window_frames > 0 else D
        WT = min(T + 1, D)

    def cond(s):
        it, cp, depth = s[0], s[1], s[5]
        more = ((cp < R) | jnp.any(depth >= 0)) if drain else (cp < R)
        return more & (it < cfg.max_iters)

    def refill(args):
        """Claim protocol: exhausted lanes take consecutive queue slots."""
        cp, ls, et, depth, al, xrl, stack, carry = args
        exh = depth < 0
        exh_i = exh.astype(jnp.int32)
        offs = jnp.cumsum(exh_i) - exh_i       # exclusive cumsum per lane
        cand = cp + offs
        claim = exh & (cand < R)
        idx = jnp.where(claim, cand, 0)
        a_new = jnp.take(a, idx, axis=0)
        p_new = jnp.take(p0, idx, axis=0)
        xr_new = jnp.take(x_rows, idx, axis=0)
        xa_new = jnp.take(x_alive0, idx, axis=0)
        rz_new = jnp.take(rsz0, idx, axis=0)

        def lane_entry(claim_l, idx_l, a_l, p_l, xr_l, xa_l, rz_l,
                       depth_l, A_l, XR_l, stack_l, carry_l):
            ctx = fr.RootContext(A=a_l, x_rows=xr_l, eye=eye, eye_x=eye_x)
            if "cur_root" in carry_l:
                carry_l = dict(carry_l, cur_root=jnp.where(
                    claim_l, root_base + idx_l, carry_l["cur_root"]))
            xal0 = fr.mask_to_bitset(xa_l, eye_x)
            # hybrid's early-termination/X-domination census runs INSIDE
            # enter_call, i.e. inside this refill cond: a claimed root
            # whose P is already an undominated clique reports here and
            # `push` stays False — it never occupies a lane trip.
            carry_l, push, f0 = enter_call(
                carry_l, cfg, ctx, p_l, jnp.zeros(words, U32), xal0,
                rz_l.astype(jnp.int32), jnp.zeros(words, U32),
                enable=claim_l)
            # merge the fresh root frame into stack slot 0 where claimed
            old0 = stack_l.read(0)
            f0m = Frame(*(jnp.where(claim_l, n, o)
                          for n, o in zip(f0, old0)))
            stack_l = stack_l.push(0, f0m)
            depth_l = jnp.where(claim_l,
                                jnp.where(push, jnp.int32(0), jnp.int32(-1)),
                                depth_l)
            A_l = jnp.where(claim_l, a_l, A_l)
            XR_l = jnp.where(claim_l, xr_l, XR_l)
            return depth_l, A_l, XR_l, stack_l, carry_l

        depth, al, xrl, stack, carry = jax.vmap(lane_entry)(
            claim, idx, a_new, p_new, xr_new, xa_new, rz_new,
            depth, al, xrl, stack, carry)
        cp = cp + jnp.sum(claim.astype(jnp.int32))
        # a claimed root that finished inside its entry call (no push) did
        # its whole subtree's work this trip — count it as a useful trip
        done_entry = jnp.sum((claim & (depth < 0)).astype(jnp.int32))
        ls = ls + done_entry
        et = et + done_entry
        return cp, ls, et, depth, al, xrl, stack, carry

    def steal(args):
        """STEAL transition (DESIGN.md §2.6): an idle lane adopts half of
        a live lane's shallowest splittable branch set (slot 0 — the true
        bottom of stack — while it still has branches left). The victim is
        picked by `cfg.steal_victim`: 'branchiest' (default) takes the
        lane whose donation slot has the largest remaining branch set —
        the biggest transferable subtree — while 'deepest' keeps the
        legacy deepest-lane heuristic. Either way the steal is pure
        scheduling: counters and enumerated sets are bit-identical.

        The victim keeps the LOW half of B (the bits its own walk would
        process first); the thief's slot-0 frame is exactly the state the
        victim's frame would reach after branching on every kept bit:
        P \\ keep, Xp ∪ keep, B = donated half. Each branch vertex still
        receives exactly one enter_call with an identical (P, Xp, xal)
        state, so calls/branches/sum_px/cliques and the enumerated set are
        bit-identical to the steal-free walk — stealing is pure
        scheduling. The thief also adopts the victim's root context and
        `cur_root`, so enumeration decode follows the work."""
        st, depth, al, xrl, stack, carry = args
        idle = depth < 0
        # donation point: the victim's SHALLOWEST live frame whose branch
        # set still has >= 2 branches — slot 0 (the true bottom of stack)
        # when it has work left, else the next-shallowest. Shallow frames
        # root the largest remaining subtrees, so halving there moves the
        # most work per steal.
        bcnt = fr.popcount(stack.B)                    # (L, D)
        slot_ix = jnp.arange(bcnt.shape[1], dtype=jnp.int32)[None, :]
        live_slot = (slot_ix <= depth[:, None]) & (bcnt >= 2)
        splittable = (depth >= 0) & jnp.any(live_slot, axis=1)
        do = jnp.any(idle) & jnp.any(splittable)
        # each lane's donation slot is its shallowest splittable frame;
        # score victims by that slot's branch-set size (the work a steal
        # would actually move) under the default 'branchiest' policy
        slot_l = jnp.argmax(live_slot, axis=1).astype(jnp.int32)  # (L,)
        donor = jnp.take_along_axis(bcnt, slot_l[:, None], axis=1)[:, 0]
        if cfg.steal_victim == "deepest":
            victim = jnp.argmax(jnp.where(splittable, depth,
                                          jnp.int32(-1)))
        else:
            victim = jnp.argmax(jnp.where(splittable, donor,
                                          jnp.int32(-1)))
        slot = slot_l[victim]
        thief = jnp.argmax(idle).astype(victim.dtype)
        P0, B0 = stack.P[victim, slot], stack.B[victim, slot]
        Xp0, Rb0 = stack.Xp[victim, slot], stack.Rb[victim, slot]
        rs0 = stack.rsz[victim, slot]
        if win_kernel:
            # kernel-contract windows never write per-frame xal (aliveness
            # is the closed form of Rb), so slots above 0 are stale in the
            # HBM stack; rebuild the donated frame's alive bitset from the
            # victim's slot-0 set — alive0' ∧ (Rb ⊆ N(x)) — which is
            # idempotent when slot == 0 and exact above it (every window
            # frame's Rb extends slot 0's, see dfs_step_window)
            alive_root = fr.bitset_to_mask(stack.xal[victim, 0], XC)
            nrb = fr.popcount(Rb0)
            alive_d = alive_root & (bitops.and_popcount_rows(
                xrl[victim], Rb0) == nrb)
            xa0 = fr.mask_to_bitset(alive_d, eye_x)
        else:
            xa0 = stack.xal[victim, slot]
        # split B at bit rank ceil(|B|/2): keep = lowest-ranked half
        in_b = fr.bitset_to_mask(B0, U)
        ib = in_b.astype(jnp.int32)
        rank = jnp.cumsum(ib) - ib
        keep = fr.mask_to_bitset(
            in_b & (rank < (bcnt[victim, slot] + 1) // 2), eye)
        donate = B0 & ~keep

        def put(arr, lane, d, val):
            return arr.at[lane, d].set(jnp.where(do, val, arr[lane, d]))

        stack = stack._replace(B=put(stack.B, victim, slot, keep))
        stack = stack._replace(
            P=put(stack.P, thief, 0, P0 & ~keep),
            B=put(stack.B, thief, 0, donate),
            Xp=put(stack.Xp, thief, 0, Xp0 | keep),
            Rb=put(stack.Rb, thief, 0, Rb0),
            rsz=put(stack.rsz, thief, 0, rs0),
            xal=put(stack.xal, thief, 0, xa0))
        depth = depth.at[thief].set(
            jnp.where(do, jnp.int32(0), depth[thief]))
        al = al.at[thief].set(jnp.where(do, al[victim], al[thief]))
        xrl = xrl.at[thief].set(jnp.where(do, xrl[victim], xrl[thief]))
        if "cur_root" in carry:
            cr = carry["cur_root"]
            carry = dict(carry, cur_root=cr.at[thief].set(
                jnp.where(do, cr[victim], cr[thief])))
        st = st + do.astype(jnp.int32)
        return st, depth, al, xrl, stack, carry

    def window_phase(cp, depth, al, xrl, stack, carry):
        """One trip's K-step window walk (WINDOW, DESIGN.md §2.6).

        Slices a WT-slot window per lane centered on its depth, steps it
        up to K times HBM-free, writes it back, and reports per-lane
        steps-done. Dead lanes (depth < 0) pass through untouched.

        STAGED REFILL (engine-step path, counting mode): the trip
        boundary pre-claims the next pool of queue roots — gathers their
        contexts and runs their entry calls once, batched — and a lane
        whose SUBTREE exhausts mid-trip (wdep < 0 at window base 0, not
        a mere underflow of a higher-based window) swaps a staged root
        in under a real `lax.cond` instead of idling until the boundary.
        Staged roots are consumed in death order, so `cp + used` remains
        the same prefix cursor the boundary refill maintains (§5); their
        entry-call counter deltas are added exactly once at consumption.
        Enumerating configs (out_cap > 0) skip staging — reports must
        land in the shared output buffer at the step that finds them —
        and fall back to the quorum exit below.

        The walk ends the trip early when a QUORUM of lanes (1/8th, at
        least one) is exhausted beyond what the staged pool can revive
        while a refill or steal could re-arm them. A single empty lane
        idles at most K−1 masked steps — cheaper than paying the trip
        boundary to revive it — but a pile-up of empty lanes is exactly
        the drain stall windowing must not reintroduce. Pure scheduling
        either way — counters/sets bit-identical."""
        K = cfg.window_steps
        live_in = depth >= 0
        base = jnp.clip(depth - T // 2, 0, D - WT)
        full_win = not win_kernel and WT == D   # degenerate: whole stack

        def sl(arr, b):
            return jax.lax.dynamic_slice_in_dim(arr, b, WT, axis=0)

        if full_win:
            wstack = stack                       # base is identically 0
        else:
            wstack = jax.tree.map(
                lambda arr: jax.vmap(sl)(arr, base), stack)
        wd = jnp.where(live_in, depth - base, jnp.int32(-1))
        if win_kernel:
            # lane-batched fused window: per-frame xal is a closed form
            # of Rb inside the window, seeded from each lane's slot-0
            # alive set (valid for every window frame — their Rb all
            # extend slot 0's, so alive0' ∧ Rb ⊆ N(x) is exact)
            alive0l = jax.vmap(
                lambda bits: fr.bitset_to_mask(bits, XC))(stack.xal[:, 0])
            wP, wB, wXp, wRb, wrsz, ctl = bitops.dfs_step_window_lanes(
                al, xrl, eye, alive0l.astype(jnp.int32), wstack.P,
                wstack.B, wstack.Xp, wstack.Rb, wstack.rsz, wd,
                steps=K)
            wstack = wstack._replace(P=wP, B=wB, Xp=wXp, Rb=wRb, rsz=wrsz)
            nd = ctl[:, 0]
            carry = dict(carry,
                         calls=carry["calls"] + ctl[:, 1],
                         branches=carry["branches"] + ctl[:, 2],
                         sum_px=carry["sum_px"] + ctl[:, 3],
                         cliques=carry["cliques"] + ctl[:, 4])
            sdone = ctl[:, 5]
            used = jnp.int32(0)
            nterm = jnp.int32(0)
            stolen = jnp.int32(0)
        else:
            # engine-step window: the full dfs_step contract (dynamic
            # reduction, rcd/hybrid, enumeration carry) over a WT-slot
            # window whose top slot is spill-only — a push landing there
            # parks the lane until the next trip re-centers its window
            stage = cfg.out_cap == 0 and R > 0
            S = max(2, L // 4)

            def one_step(wdep, wstk, car, sd, al_, xrl_):
                lv = (wdep >= 0) & (wdep < WT - 1)
                d_in = jnp.clip(wdep, 0, WT - 2)

                def lane_step(a_l, xr_l, d_l, lv_l, stk_l, car_l):
                    ctx = fr.RootContext(A=a_l, x_rows=xr_l, eye=eye,
                                         eye_x=eye_x)
                    return dfs_step(cfg, ctx, d_l, stk_l, car_l,
                                    live=lv_l)

                ndep, nstk, car = jax.vmap(lane_step)(al_, xrl_, d_in,
                                                      lv, wstk, car)
                if full_win:
                    # depth <= U = D − 2 < WT − 1: a push can never reach
                    # the top slot, so no lane ever parks there
                    wstk = nstk
                else:
                    # dfs_step's "dead-lane writes are harmless" invariant
                    # assumes slots above the lane's depth are dead —
                    # false for a lane PARKED at the spill slot (wdep ==
                    # WT−1, all window slots live), where the masked
                    # step's child push at d_in+1 == WT−1 clobbers the
                    # live top frame. That push is the only live-slot
                    # write a masked step makes (its cur-frame write at
                    # d_in is value-preserving), so restoring the top
                    # slot for parked lanes suffices.
                    parked = wdep >= WT - 1
                    wstk = jax.tree.map(
                        lambda n, o: n.at[:, WT - 1].set(jnp.where(
                            parked.reshape((-1,) + (1,) * (n.ndim - 2)),
                            o[:, WT - 1], n[:, WT - 1])),
                        nstk, wstk)
                wdep = jnp.where(lv, ndep, wdep)
                return wdep, wstk, car, sd + lv.astype(jnp.int32)

            # While the queue still has roots, one empty lane idles at
            # most K−1 masked steps — cheaper than a trip boundary, which
            # is why exit waits for a QUORUM (1/8th of lanes) beyond what
            # the staged pool can still revive: the boundary refill
            # revives all of them in one batch. Once the queue is out,
            # a boundary buys quorum-many steals, so the trip yields at
            # the same quorum — otherwise the drain tail serializes K
            # idle steps per revived lane. `k < 1` forces one step of
            # progress per trip even when idle lanes can't actually be
            # revived (e.g. nothing splittable to steal).
            quorum = jnp.int32(max(1, L // 8))

            if stage:
                # stage the next S queue roots: gather + batched entry
                # calls, skipped entirely (lax.cond) once the queue is
                # out. Entry effects land in per-root counter DELTAS,
                # applied exactly once when a lane consumes the root.
                def do_stage(_):
                    s_idx = cp + jnp.arange(S, dtype=jnp.int32)
                    s_ok = s_idx < R
                    s_cl = jnp.minimum(s_idx, jnp.int32(R - 1))
                    sa_ = jnp.take(a, s_cl, axis=0)
                    sxr_ = jnp.take(x_rows, s_cl, axis=0)

                    def stage_entry(ok_l, p_l, a_l, xr_l, xa_l, rz_l):
                        ctx = fr.RootContext(A=a_l, x_rows=xr_l,
                                             eye=eye, eye_x=eye_x)
                        c1, push_l, f0_l = enter_call(
                            fr.carry_init(cfg, words), cfg, ctx, p_l,
                            jnp.zeros(words, U32),
                            fr.mask_to_bitset(xa_l, eye_x),
                            rz_l.astype(jnp.int32),
                            jnp.zeros(words, U32), enable=ok_l)
                        d_l = jnp.stack([c1["calls"], c1["branches"],
                                         c1["sum_px"], c1["cliques"]])
                        return d_l, push_l, f0_l

                    sdel_, spush_, sf0_ = jax.vmap(stage_entry)(
                        s_ok, jnp.take(p0, s_cl, axis=0), sa_, sxr_,
                        jnp.take(x_alive0, s_cl, axis=0),
                        jnp.take(rsz0, s_cl, axis=0))
                    return (jnp.sum(s_ok.astype(jnp.int32)), sdel_,
                            spush_, sf0_, sa_, sxr_)

                def no_stage(_):
                    return (jnp.int32(0),
                            jnp.zeros((S, 4), jnp.int32),
                            jnp.zeros((S,), jnp.bool_),
                            Frame(P=jnp.zeros((S, words), U32),
                                  B=jnp.zeros((S, words), U32),
                                  Xp=jnp.zeros((S, words), U32),
                                  Rb=jnp.zeros((S, words), U32),
                                  rsz=jnp.zeros((S,), jnp.int32),
                                  xal=jnp.zeros((S, xc_words), U32)),
                            jnp.zeros((S, U, words), U32),
                            jnp.zeros((S, XC, words), U32))

                n_stage, sdel, spush, sf0, sa, sxr = jax.lax.cond(
                    cp < R, do_stage, no_stage, None)
                # in-trip steal needs the victim's donation slot INSIDE
                # its window — guaranteed only by the full-depth window
                # (base is identically 0); bounded windows keep boundary
                # steals instead
                trip_steal = can_steal and full_win
                squorum = jnp.int32(max(1, L // 16))

                def steal_multi(cs):
                    """Multi-way in-trip STEAL: rank-partition the
                    branchiest victim's donation slot across ALL idle
                    lanes in one shot. Each piece t takes the branch
                    bits ranked [t·q, (t+1)·q) with P \\ {lower ranks}
                    and Xp ∪ {lower ranks} — exactly the state the
                    victim's own walk would reach before branching on
                    that piece's first bit, so every branch vertex still
                    receives one enter_call with an identical frame:
                    the halving parity lemma applied k ways. Counters
                    and enumerated sets stay bit-identical."""
                    wdep, wstk, car, al_, xrl_, stl = cs
                    idle = wdep < 0          # base == 0: true exhaustion
                    bcnt = fr.popcount(wstk.B)              # (L, D)
                    slot_ix = jnp.arange(D, dtype=jnp.int32)[None, :]
                    live_slot = ((slot_ix <= wdep[:, None])
                                 & (bcnt >= 2))
                    splittable = (wdep >= 0) & jnp.any(live_slot, axis=1)
                    do = jnp.any(idle) & jnp.any(splittable)
                    slot_l = jnp.argmax(live_slot, axis=1).astype(
                        jnp.int32)
                    donor = jnp.take_along_axis(
                        bcnt, slot_l[:, None], axis=1)[:, 0]
                    if cfg.steal_victim == "deepest":
                        victim = jnp.argmax(jnp.where(
                            splittable, wdep, jnp.int32(-1)))
                    else:
                        victim = jnp.argmax(jnp.where(
                            splittable, donor, jnp.int32(-1)))
                    slot = slot_l[victim]
                    nb = bcnt[victim, slot]
                    B0 = wstk.B[victim, slot]
                    P0 = wstk.P[victim, slot]
                    Xp0 = wstk.Xp[victim, slot]
                    Rb0 = wstk.Rb[victim, slot]
                    rs0 = wstk.rsz[victim, slot]
                    xa0 = wstk.xal[victim, slot]
                    in_b = fr.bitset_to_mask(B0, U)
                    ib = in_b.astype(jnp.int32)
                    rank = jnp.cumsum(ib) - ib
                    n_idle = jnp.sum(idle.astype(jnp.int32))
                    q = -(-nb // jnp.maximum(n_idle + 1, 1))  # ceil
                    # thief t ∈ 1..n_idle takes ranks [t·q, (t+1)·q)
                    ii = idle.astype(jnp.int32)
                    t = jnp.cumsum(ii) * ii                 # 0 for live
                    lo = t * q
                    tk = do & idle & (lo < nb)
                    low_m = in_b[None, :] & (rank[None, :] < lo[:, None])
                    pc_m = (in_b[None, :] & (rank[None, :] >= lo[:, None])
                            & (rank[None, :] < (lo + q)[:, None]))
                    low_b = jax.vmap(fr.mask_to_bitset,
                                     in_axes=(0, None))(low_m, eye)
                    pc_b = jax.vmap(fr.mask_to_bitset,
                                    in_axes=(0, None))(pc_m, eye)

                    def mixs(new, old):
                        return jnp.where(
                            tk.reshape((-1,) + (1,) * (new.ndim - 1)),
                            new, old)

                    wstk = wstk._replace(
                        P=wstk.P.at[:, 0].set(
                            mixs(P0[None] & ~low_b, wstk.P[:, 0])),
                        B=wstk.B.at[:, 0].set(mixs(pc_b, wstk.B[:, 0])),
                        Xp=wstk.Xp.at[:, 0].set(
                            mixs(Xp0[None] | low_b, wstk.Xp[:, 0])),
                        Rb=wstk.Rb.at[:, 0].set(
                            mixs(jnp.broadcast_to(Rb0, (L,) + Rb0.shape),
                                 wstk.Rb[:, 0])),
                        rsz=wstk.rsz.at[:, 0].set(
                            jnp.where(tk, rs0, wstk.rsz[:, 0])),
                        xal=wstk.xal.at[:, 0].set(
                            mixs(jnp.broadcast_to(xa0, (L,) + xa0.shape),
                                 wstk.xal[:, 0])))
                    # the victim keeps piece 0 (ranks < q)
                    keep = fr.mask_to_bitset(in_b & (rank < q), eye)
                    wstk = wstk._replace(B=wstk.B.at[victim, slot].set(
                        jnp.where(do, keep, wstk.B[victim, slot])))
                    wdep = jnp.where(tk, jnp.int32(0), wdep)
                    al_ = mixs(jnp.broadcast_to(
                        al_[victim][None], al_.shape), al_)
                    xrl_ = mixs(jnp.broadcast_to(
                        xrl_[victim][None], xrl_.shape), xrl_)
                    stl = stl + jnp.sum(tk.astype(jnp.int32))
                    return wdep, wstk, car, al_, xrl_, stl

                def consume(cs):
                    """Swap staged roots into dead lanes, death order."""
                    wdep, wstk, car, al_, xrl_, used, ntm = cs
                    dead = (wdep < 0) & (base == 0)
                    di = dead.astype(jnp.int32)
                    idx = used + jnp.cumsum(di) - di
                    idxc = jnp.minimum(idx, jnp.int32(S - 1))
                    tk = dead & (idx < n_stage)

                    def mix(new, old):
                        return jnp.where(
                            tk.reshape((-1,) + (1,) * (new.ndim - 1)),
                            new, old)

                    # dead lanes sit at base 0: window slot 0 IS stack
                    # slot 0, the same slot the boundary refill writes
                    wstk = wstk._replace(**{
                        k: w.at[:, 0].set(
                            mix(jnp.take(n, idxc, axis=0), w[:, 0]))
                        for k, w, n in zip(Frame._fields,
                                           wstk, sf0)})
                    push = jnp.take(spush, idxc)
                    wdep = jnp.where(tk & push, jnp.int32(0), wdep)
                    al_ = mix(jnp.take(sa, idxc, axis=0), al_)
                    xrl_ = mix(jnp.take(sxr, idxc, axis=0), xrl_)
                    dl = (jnp.take(sdel, idxc, axis=0)
                          * tk.astype(jnp.int32)[:, None])
                    car = dict(car,
                               calls=car["calls"] + dl[:, 0],
                               branches=car["branches"] + dl[:, 1],
                               sum_px=car["sum_px"] + dl[:, 2],
                               cliques=car["cliques"] + dl[:, 3])
                    used = used + jnp.sum(tk.astype(jnp.int32))
                    ntm = ntm + jnp.sum((tk & ~push).astype(jnp.int32))
                    return wdep, wstk, car, al_, xrl_, used, ntm

                def wbody(ws):
                    (k, wdep, wstk, car, sd, al_, xrl_, used, ntm,
                     stl) = ws
                    may = (jnp.any((wdep < 0) & (base == 0))
                           & (used < n_stage))
                    wdep, wstk, car, al_, xrl_, used, ntm = jax.lax.cond(
                        may, consume, lambda cs: cs,
                        (wdep, wstk, car, al_, xrl_, used, ntm))
                    if trip_steal:
                        n_dead = jnp.sum((wdep < 0).astype(jnp.int32))
                        may_s = ((cp + used >= R)
                                 & (n_dead >= squorum)
                                 & jnp.any(wdep >= 0))
                        (wdep, wstk, car, al_, xrl_,
                         stl) = jax.lax.cond(
                            may_s, steal_multi, lambda cs: cs,
                            (wdep, wstk, car, al_, xrl_, stl))
                    wdep, wstk, car, sd = one_step(wdep, wstk, car, sd,
                                                   al_, xrl_)
                    return (k + 1, wdep, wstk, car, sd, al_, xrl_,
                            used, ntm, stl)

                def wcond(ws):
                    k, wdep, used = ws[0], ws[1], ws[7]
                    dead = (wdep < 0) & (base == 0)
                    n_dead = jnp.sum(dead.astype(jnp.int32))
                    pool_left = n_stage - used
                    exit_refill = ((cp + used < R)
                                   & (n_dead - pool_left >= quorum))
                    # with in-trip stealing the trip never yields for a
                    # steal — the split happens under a cond inside
                    exit_steal = (jnp.bool_(can_steal and not trip_steal)
                                  & (cp + used >= R)
                                  & (n_dead >= quorum))
                    alive = jnp.any((wdep >= 0) & (wdep < WT - 1))
                    return ((k < K)
                            & (alive | (jnp.any(dead)
                                        & (used < n_stage)))
                            & ((k < 1) | ~(exit_refill | exit_steal)))

                (_, nd, wstack, carry, sdone, al, xrl, used,
                 nterm, stolen) = jax.lax.while_loop(
                    wcond, wbody,
                    (jnp.int32(0), wd, wstack, carry,
                     jnp.zeros_like(wd), al, xrl, jnp.int32(0),
                     jnp.int32(0), jnp.int32(0)))
            else:
                def wbody(ws):
                    k, wdep, wstk, car, sd = ws
                    wdep, wstk, car, sd = one_step(wdep, wstk, car, sd,
                                                   al, xrl)
                    return k + 1, wdep, wstk, car, sd

                def wcond(ws):
                    k, wdep = ws[0], ws[1]
                    # idle-but-revivable: exhausted during this trip
                    # (window at base 0 — a higher-based underflow is a
                    # re-center, not an exhaustion) or dead at entry
                    idle = ~live_in | ((wdep < 0) & (base == 0))
                    n_idle = jnp.sum(idle.astype(jnp.int32))
                    exit_refill = (cp < R) & (n_idle >= quorum)
                    exit_steal = (jnp.bool_(can_steal) & (cp >= R)
                                  & (n_idle >= quorum))
                    return ((k < K)
                            & jnp.any((wdep >= 0) & (wdep < WT - 1))
                            & ((k < 1) | ~(exit_refill | exit_steal)))

                _, nd, wstack, carry, sdone = jax.lax.while_loop(
                    wcond, wbody,
                    (jnp.int32(0), wd, wstack, carry,
                     jnp.zeros_like(wd)))
                used = jnp.int32(0)
                nterm = jnp.int32(0)
                stolen = jnp.int32(0)

        def up(arr, win, b):
            return jax.lax.dynamic_update_slice_in_dim(arr, win, b, axis=0)

        if full_win:
            stack = wstack
        else:
            stack = jax.tree.map(
                lambda arr, win: jax.vmap(up)(arr, win, base), stack,
                wstack)
        # nd >= 0 also covers lanes REVIVED mid-trip by staged refill
        # (dead at entry, live at exit); their base is 0 by definition
        depth = jnp.where(live_in | (nd >= 0), base + nd, depth)
        # a lane that ran all K steps stayed window-resident the whole
        # trip (hit); one that stopped early paid a window boundary —
        # overflow, underflow, or subtree exhaustion (spill)
        fin = sdone >= jnp.int32(K)
        hits = jnp.sum((live_in & fin).astype(jnp.int32))
        spills = jnp.sum((live_in & ~fin).astype(jnp.int32))
        return (depth, al, xrl, stack, carry, jnp.sum(sdone), spills,
                hits, used, nterm, stolen)

    def body(s):
        it, cp, ls, st, et, depth, al, xrl, stack, carry, ws, wh = s
        need = (cp < R) & jnp.any(depth < 0)
        cp, ls, et, depth, al, xrl, stack, carry = jax.lax.cond(
            need, refill, lambda args: args,
            (cp, ls, et, depth, al, xrl, stack, carry))
        if can_steal:
            # only once the queue can no longer feed the idle lane — while
            # roots remain, claiming is strictly cheaper than splitting.
            # A windowed body whose trips steal IN-TRIP (staged, full-
            # depth windows) needs the boundary steal only as a safety
            # net (e.g. a trip that exited with every lane dead); other
            # windowed bodies repeat it up to quorum times: their trips
            # yield once a quorum of lanes idles, so the boundary must
            # re-arm the whole quorum, not just one lane (each repeat
            # picks a fresh thief, and a fresh victim once the last
            # donor's halved slot stops being the branchiest).
            in_trip = (windowed and not win_kernel and WT == D
                       and cfg.out_cap == 0 and R > 0)
            n_st = 1 if in_trip else (max(1, L // 8) if windowed else 1)
            for _ in range(n_st):
                may = jnp.any(depth < 0) & jnp.any(depth >= 0) & (cp >= R)
                st, depth, al, xrl, stack, carry = jax.lax.cond(
                    may, steal, lambda args: args,
                    (st, depth, al, xrl, stack, carry))
        if windowed:
            (depth, al, xrl, stack, carry, steps_done, spills, hits,
             used, nterm, stolen) = window_phase(cp, depth, al, xrl,
                                                stack, carry)
            cp = cp + used          # staged claims advance the cursor
            ls = ls + steps_done + nterm
            et = et + nterm         # staged roots done inside entry
            st = st + stolen        # in-trip multi-way steal pieces
            ws = ws + spills
            wh = wh + hits
        else:
            ls = ls + jnp.sum((depth >= 0).astype(jnp.int32))

            def lane_step(a_l, xr_l, depth_l, stack_l, carry_l):
                ctx = fr.RootContext(A=a_l, x_rows=xr_l, eye=eye,
                                     eye_x=eye_x)
                return dfs_step(cfg, ctx, depth_l, stack_l, carry_l,
                                live=depth_l >= 0)

            depth, stack, carry = jax.vmap(lane_step)(al, xrl, depth,
                                                      stack, carry)
        return (it + 1, cp, ls, st, et, depth, al, xrl, stack, carry,
                ws, wh)

    return jax.lax.while_loop(cond, body, state)


def _persistent_out(state, R: int):
    """Realize a lane state into the public output dict."""
    (it, cp, ls, st, et, depth, _al, _xrl, _stack, carry, ws, wh) = state
    out = dict(carry)
    out["iters"] = it
    out["live_iters"] = ls
    out["claimed"] = cp
    out["steals"] = st
    out["entry_terms"] = et
    out["window_spills"] = ws
    out["window_hits"] = wh
    out["truncated"] = ((cp < R) | jnp.any(depth >= 0)).astype(jnp.int32)
    return out


@partial(jax.jit, static_argnames=("cfg", "lanes"))
def run_bucket_persistent(a, p0, x_rows, x_alive0, rsz0, cfg: EngineConfig,
                          lanes: int = 64):
    """One jitted while_loop over a (LANES, …) batch of DFS states fed by a
    device-resident root work queue.

    The per-root `run_bucket` vmaps lock-step: every lane spins (masked)
    until the slowest root in the bucket finishes. Here a lane whose
    subtree exhausts (`depth < 0`) claims the next unstarted root inside
    the loop body — shared claim counter + per-lane exclusive-cumsum
    offsets, no host round-trip — and reinitializes its stack in place, so
    lanes stay saturated until the queue drains. Roots are consumed in the
    caller's array order (the driver passes its cost-descending canonical
    order, so the queue order IS the checkpoint cursor order).

    The refill phase is wrapped in a real `lax.cond`: unlike the vmapped
    per-root body (where cond lowers to SELECT), this loop is not under
    vmap, so iterations with no exhausted lane skip the (LANES, U, W)
    root-context gathers entirely. Once the queue is claimed out, a second
    cond runs the STEAL transition (cfg.steal, pivot-family backends): an
    idle lane splits off half of the deepest live lane's shallowest
    splittable branch set (slot 0 while it has work, else the frame just
    above it), so a hub root's subtree spreads across lanes instead of
    serializing on one (counters and enumerated sets are unchanged —
    stealing is pure scheduling).

    Returns the per-lane carry dict plus scalars: `iters` (loop trips),
    `live_iters` (Σ useful lane-trips: live lanes per trip, plus claims
    whose root completed inside its entry call — those do their whole
    subtree's work in the refill; occupancy = live_iters /
    (iters·lanes)), `claimed`, `steals` (adopted branch-set halves),
    `entry_terms` (claims that completed inside their entry call — for
    the hybrid backend this includes every root early-terminated by the
    refill-phase census), `window_spills`/`window_hits` (windowed trips
    that stopped early at a window boundary vs ran all K steps resident;
    both 0 when `cfg.window_steps == 0`), and `truncated` (1 iff
    cfg.max_iters hit with work remaining). With `cfg.window_steps > 0`
    `live_iters` counts executed frame-steps (each trip offers up to K
    per lane), so occupancy denominators scale by the window depth."""
    R, U, words = a.shape
    XC = x_rows.shape[1]
    state0 = _persistent_state0(cfg, lanes, U, words, XC)
    state = _persistent_segment(a, p0, x_rows, x_alive0, rsz0,
                                jnp.int32(0), state0, cfg=cfg, lanes=lanes,
                                drain=True)
    return _persistent_out(state, R)


def run_stream_persistent(slabs, cfg: EngineConfig, lanes: int = 64):
    """Bucket-spanning persistent engine over a stream of root slabs.

    `slabs` is an iterable of `(a, p0, x_rows, x_alive0, rsz0)` tuples in
    the caller's (canonical cost-descending) root order. Consecutive slabs
    sharing a shape signature `(U, words, XC)` form a SPAN: the lane state
    (stacks, contexts, counters) carries across their boundary, so lanes
    that are mid-subtree when slab k's queue is claimed out keep running
    while slab k+1's queue feeds the refills — the loop spans the whole
    span instead of draining and re-launching per bucket. Each non-final
    slab runs a `drain=False` segment (returns as soon as its queue is
    claimed out); the span's last slab re-enters with `drain=True`. A
    shape change flushes the span (different frame/stack shapes cannot
    share a compiled loop — those boundaries still re-launch).

    Segments dispatch asynchronously: the host can stage slab k+1 (pack +
    device_put) while the device drains slab k — the driver's §6.4
    double-buffered overlap contract, applied to the root queue itself.

    `cur_root` is offset by the stream-global root base (slab-order prefix
    sums over slab lengths), so `out_root` decodes against the whole
    stream. Returns `(outs, spans)`: `outs[i]` is the i-th span's output
    dict (same schema as `run_bucket_persistent`) and `spans[i] = (lo,
    hi)` its slab index range."""
    outs, spans = [], []
    state = None
    sig = None
    prev = None          # last slab fed to the open span (drain target)
    lanes_g = lanes
    root_base = 0
    lo = 0
    n = 0
    for k, slab in enumerate(slabs):
        n = k + 1
        a = slab[0]
        s = (a.shape[1], a.shape[2], slab[2].shape[1])
        if state is not None and s != sig:
            # shape change: drain the open span and flush its output
            state = _persistent_segment(
                *prev, jnp.int32(root_base - prev[0].shape[0]), state,
                cfg=cfg, lanes=lanes_g, drain=True)
            outs.append(_persistent_out(state, prev[0].shape[0]))
            spans.append((lo, k))
            state, prev = None, None
        if state is None:
            sig = s
            lo = k
            lanes_g = max(1, min(lanes, a.shape[0]))
            state = _persistent_state0(cfg, lanes_g, *s)
        else:
            # re-arm the claim counter for the new slab; everything else
            # (lane depths, stacks, contexts, counters) carries over
            state = (state[0], jnp.int32(0)) + state[2:]
        state = _persistent_segment(*slab, jnp.int32(root_base), state,
                                    cfg=cfg, lanes=lanes_g, drain=False)
        prev = slab
        root_base += a.shape[0]
    if state is not None:
        state = _persistent_segment(
            *prev, jnp.int32(root_base - prev[0].shape[0]), state,
            cfg=cfg, lanes=lanes_g, drain=True)
        outs.append(_persistent_out(state, prev[0].shape[0]))
        spans.append((lo, n))
    return outs, spans


# ===========================================================================
# High-level API
# ===========================================================================

def root_cost_skew(costs) -> float:
    """max/mean skew of a per-root cost proxy, hardened for edge buckets.

    Degenerate inputs (empty, all-zero/all-pad, NaN/inf costs) answer 1.0
    — "uniform", which routes to perroot downstream — instead of crashing
    on a length-0 max or exploding to max/1e-12 on an all-but-zero mean.
    The skew is clamped to n_roots: max/mean ≤ n holds for any nonnegative
    vector, so anything larger is float-noise from a near-zero mean and
    would otherwise misroute trivial buckets to the persistent engine.
    Shared by `choose_engine` and the driver's per-bucket memo so cached
    replays and fresh runs always agree."""
    costs = np.asarray(costs, dtype=np.float64)
    n = int(costs.size)
    if n == 0:
        return 1.0
    m = float(costs.max())
    mean = float(costs.mean())
    if not np.isfinite(m) or m <= 0.0 or mean <= 0.0:
        return 1.0
    return min(m / mean, float(n))


def choose_engine(costs: Optional[np.ndarray] = None, *, lanes: int = 64,
                  skew: Optional[float] = None,
                  n_roots: Optional[int] = None,
                  skew_threshold: float = 4.0, min_roots: int = 16,
                  steal: bool = False):
    """Pick (engine, lanes) for one bucket from its root-cost skew.

    skew = max/mean of the per-root cost proxy (`prepare.estimate_costs`).
    A uniform bucket (skew < threshold) runs the lock-step per-root vmap:
    every lane finishes together, so a work queue would add claim overhead
    and win nothing. A skewed bucket runs the persistent lane-refill
    queue — that is exactly the regime where lock-step lanes idle behind
    the one hub root. Lanes are sized so the queue actually refills
    (>= ~4 roots per lane on average), clamped to [8, lanes]; tiny
    buckets (< min_roots) stay on perroot where one compile per shape is
    cheaper than the queue machinery.

    `steal=True` declares that the config the bucket will actually run
    with can steal (cfg.steal on AND a pivot-family backend): lane work
    stealing splits a hub root's subtree across lanes once the queue
    drains, which de-serializes exactly the moderate-skew buckets the
    plain threshold routes to perroot — so the effective skew threshold
    halves. Callers that can't steal (rcd, cfg.steal off) must pass
    False and keep the conservative boundary.

    Callers treat explicit engine= flags as overrides; this is only the
    `engine="auto"` policy, kept in the engine layer so both the
    single-host `run()` and the distributed driver share it (the driver
    imports the engine, never the reverse — DESIGN.md §6). Pass
    `skew=`/`n_roots=` instead of `costs` when the skew is already
    memoized (the driver caches it on the bucket for cached replays).
    Edge buckets never crash or misroute: empty/all-pad/degenerate cost
    vectors score skew 1.0 and the skew is clamped to n_roots either way
    (`root_cost_skew`)."""
    if costs is not None:
        costs = np.asarray(costs, dtype=np.float64)
        n_roots = int(costs.size)
        skew = root_cost_skew(costs)   # 1.0 on empty/all-pad/degenerate
    if skew is None or n_roots is None or not np.isfinite(skew):
        return "perroot", lanes
    skew = min(skew, float(max(n_roots, 1)))   # memoized-skew callers too
    thr = skew_threshold / 2.0 if steal else skew_threshold
    if n_roots < min_roots or skew < thr:
        return "perroot", lanes
    per_lane = max(1, n_roots // 4)
    refill_lanes = 1 << (per_lane.bit_length() - 1)   # largest pow2 <= n/4
    return "persistent", max(8, min(lanes, refill_lanes))


@dataclasses.dataclass
class MCEResult:
    cliques: int
    calls: int
    branches: int
    sum_px: int
    pre_reported: int
    enumerated: Optional[List[frozenset]] = None
    overflow: bool = False
    iters_exhausted: bool = False
    stats: Optional[dict] = None   # service layer: per-query occupancy
    # counters (live_iters/lane_iters/truncated/engine_choices) — see
    # launch.mce_service.MCEService


def run(g: CSRGraph, *, global_red: bool = True, dynamic_red: bool = True,
        x_red: bool = True, backend: str = "pivot",
        enumerate_cliques: bool = False, out_cap: int = 4096,
        bucket_sizes: Sequence[int] = (32, 64, 128, 256, 512, 1024),
        max_x_rows: int = 8192,
        split_threshold: Optional[int] = None,
        engine: str = "perroot", lanes: int = 64,
        steal: bool = True, steal_victim: str = "branchiest",
        window_steps: int = 0) -> MCEResult:
    """End-to-end single-host MCE: prepare on host, run buckets on device.

    `engine='persistent'` routes each bucket through the lane-refill work
    queue (`run_bucket_persistent` with min(lanes, roots) lanes); the
    default 'perroot' path vmaps one lock-step lane per root.
    `engine='auto'` picks per bucket from the root-cost skew
    (`choose_engine`); the explicit flags remain hard overrides."""
    if engine not in ("perroot", "persistent", "auto"):
        raise ValueError(f"unknown engine {engine!r}")
    if backend not in fr.BACKENDS:
        raise ValueError(f"unknown backend {backend!r} "
                         f"(expected one of {fr.BACKENDS})")
    prep = prepare(g, global_red=global_red, x_red=x_red,
                   bucket_sizes=bucket_sizes, max_x_rows=max_x_rows,
                   split_threshold=split_threshold)
    cfg = EngineConfig(dynamic_red=dynamic_red, backend=backend,
                       out_cap=out_cap if enumerate_cliques else 0,
                       steal=steal, steal_victim=steal_victim,
                       window_steps=window_steps)
    total = MCEResult(cliques=len(prep.pre_reported), calls=0, branches=0,
                      sum_px=0, pre_reported=len(prep.pre_reported),
                      enumerated=list(prep.pre_reported) if enumerate_cliques else None)
    if engine == "persistent":
        # bucket-spanning path: consecutive same-shape buckets share one
        # lane state (run_stream_persistent) — no drain at their boundary
        slabs = [tuple(jnp.asarray(x) for x in
                       (b.a, b.p0, b.x_rows, b.x_alive0, b.rsz0))
                 for b in prep.buckets]
        outs, spans = run_stream_persistent(slabs, cfg, lanes=lanes)
        prefix = np.cumsum([0] + [b.num_roots for b in prep.buckets])
        total.stats = dict(iters=0, live_iters=0, lane_iters=0, steals=0,
                           entry_terms=0, window_spills=0, window_hits=0,
                           spans=len(spans))
        # a windowed trip offers up to K steps per lane, so the occupancy
        # denominator (lane_iters) scales by the window depth
        spt = max(1, window_steps)
        for out, (lo, hi) in zip(outs, spans):
            out = jax.tree.map(np.asarray, out)
            total.stats["iters"] += int(out["iters"])
            total.stats["live_iters"] += int(out["live_iters"])
            # carry is per-lane, so its leading dim is this span's lanes
            total.stats["lane_iters"] += (int(out["iters"])
                                          * int(out["calls"].shape[0])
                                          * spt)
            total.stats["steals"] += int(out["steals"])
            total.stats["entry_terms"] += int(out["entry_terms"])
            total.stats["window_spills"] += int(out["window_spills"])
            total.stats["window_hits"] += int(out["window_hits"])
            total.cliques += int(out["cliques"].sum())
            # padded no-op roots (compile-count hygiene) are one call each
            total.calls += (int(out["calls"].sum())
                            - sum(b.n_pad for b in prep.buckets[lo:hi]))
            total.branches += int(out["branches"].sum())
            total.sum_px += int(out["sum_px"].sum())
            total.iters_exhausted |= bool(out["truncated"].any())
            if enumerate_cliques:
                total.overflow |= bool(out["overflow"].any())
                # out_root carries the stream-global root index; decode it
                # back to (bucket, local root) via the slab prefix sums
                for l in range(out["out_n"].shape[0]):
                    for k in range(int(out["out_n"][l])):
                        r = int(out["out_root"][l, k])
                        bi = int(np.searchsorted(prefix, r,
                                                 side="right")) - 1
                        bucket = prep.buckets[bi]
                        rloc = r - int(prefix[bi])
                        uni = bucket.universes[rloc]
                        base = [int(b) for b in bucket.bases[rloc]]
                        members = _unpack_bits_np(out["out_rows"][l, k])
                        total.enumerated.append(frozenset(
                            base + [int(uni[m]) for m in members]))
        return total
    for bucket in prep.buckets:
        args = (jnp.asarray(bucket.a), jnp.asarray(bucket.p0),
                jnp.asarray(bucket.x_rows), jnp.asarray(bucket.x_alive0),
                jnp.asarray(bucket.rsz0))
        eng_b, lanes_b = engine, lanes
        if engine == "auto":
            total_real = bucket.num_roots - bucket.n_pad
            eng_b, lanes_b = choose_engine(
                estimate_costs(bucket)[:total_real], lanes=lanes,
                steal=steal and backend in fr.PIVOT_BACKENDS)
        if eng_b == "persistent":
            out = run_bucket_persistent(*args, cfg,
                                        lanes=min(lanes_b, bucket.num_roots))
        else:
            out = run_bucket(*args, cfg)
        out = jax.tree.map(np.asarray, out)
        total.cliques += int(out["cliques"].sum())
        # padded no-op roots (compile-count hygiene) are one call each
        total.calls += int(out["calls"].sum()) - bucket.n_pad
        total.branches += int(out["branches"].sum())
        total.sum_px += int(out["sum_px"].sum())
        total.iters_exhausted |= bool(out["truncated"].any())
        if enumerate_cliques:
            total.overflow |= bool(out["overflow"].any())
            if eng_b == "persistent":
                # lanes interleave roots; out_root maps each clique back
                for l in range(out["out_n"].shape[0]):
                    for k in range(int(out["out_n"][l])):
                        r = int(out["out_root"][l, k])
                        uni = bucket.universes[r]
                        base = [int(b) for b in bucket.bases[r]]
                        members = _unpack_bits_np(out["out_rows"][l, k])
                        total.enumerated.append(frozenset(
                            base + [int(uni[m]) for m in members]))
            else:
                for r in range(bucket.num_roots):
                    uni = bucket.universes[r]
                    base = [int(b) for b in bucket.bases[r]]
                    for k in range(int(out["out_n"][r])):
                        bits = out["out_rows"][r, k]
                        members = _unpack_bits_np(bits)
                        clique = frozenset(base + [int(uni[m])
                                                   for m in members])
                        total.enumerated.append(clique)
    return total
