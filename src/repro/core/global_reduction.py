"""Global reduction (paper §4): low-degree vertex + non-triangle edge reduction.

Two implementations:

* `global_reduce_host` — numpy/python cascade queue, exact Algorithm 5 + 6
  semantics run to fixpoint (edge deletions re-enqueue new low-degree
  vertices, per the paper's Figure 3 discussion). This is the ingest-stage
  path a production deployment uses, and the path that *enumerates* the
  advance-reported cliques.
* `global_reduce_jnp` — fixed-shape, mask-based device path (counting mode):
  returns alive masks + counts of advance-reported cliques. This is what runs
  on TPU inside the distributed pipeline where the reduced graph feeds the
  bitset BK engine directly.

Both preserve the paper's invariant  mc(G) = mc(G') + α(ΔV, ΔE).
"""
from __future__ import annotations

import dataclasses
from collections.abc import Sequence
from typing import FrozenSet, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph, from_edge_list


class CliqueReports(Sequence):
    """Lazy, concatenable sequence of advance-reported cliques.

    The vectorized pre-passes report 10^5+ 2-cliques on hub-heavy graphs;
    materializing a frozenset per edge costs ~3µs each — more than the
    entire vectorized sweep. Segments therefore stay as (k, 2) edge
    arrays (or already-built frozenset lists) and rows become frozensets
    only when someone actually enumerates. The counting-mode driver only
    ever calls `len()`, which is O(#segments)."""

    __slots__ = ("_segs",)

    def __init__(self, segments=()):
        self._segs = [s for s in segments if len(s)]

    def __len__(self):
        return sum(len(s) for s in self._segs)

    def __iter__(self):
        for s in self._segs:
            if isinstance(s, np.ndarray):
                for pair in s.tolist():
                    yield frozenset(pair)
            else:
                yield from s

    def __getitem__(self, i):
        if isinstance(i, slice):
            return [self[j] for j in range(*i.indices(len(self)))]
        if i < 0:
            i += len(self)
        if i < 0:
            raise IndexError(i)
        for s in self._segs:
            if i < len(s):
                return frozenset(s[i].tolist()) \
                    if isinstance(s, np.ndarray) else s[i]
            i -= len(s)
        raise IndexError(i)

    def __add__(self, other):
        segs = list(self._segs)
        segs += other._segs if isinstance(other, CliqueReports) else [list(other)]
        return CliqueReports(segs)

    def __radd__(self, other):
        if isinstance(other, (list, CliqueReports)):
            return CliqueReports([list(other)] + self._segs)
        return NotImplemented


@dataclasses.dataclass
class GlobalReduction:
    graph: CSRGraph                      # reduced graph G' (original vertex ids)
    reported: List[FrozenSet[int]]       # α(ΔV, ΔE): maximal cliques reported in advance
    num_deleted_vertices: int
    num_deleted_edges: int


def _common_neighbor_exists(adj: dict, u: int, v: int, exclude: int = -1) -> int:
    """Return a common neighbor of u, v other than `exclude`, or -1."""
    a, b = adj[u], adj[v]
    if len(a) > len(b):
        a, b = b, a
    for w in a:
        if w != exclude and w in b:
            return w
    return -1


def global_reduce_host(g: CSRGraph, vertex_rule: bool = True,
                       edge_rule: bool = True) -> GlobalReduction:
    """Cascaded global reduction to fixpoint (Algorithms 5 + 6).

    Only vertices with at least one edge enter the cascade: isolated
    vertices are removed by Lemma 1 with no report and no edge effects,
    and the returned deletion counters are recomputed from the output
    graph anyway — on pre-peeled residual graphs this skips the bulk of
    the queue."""
    idx_list = g.indices.tolist()
    ptr = g.indptr
    active = np.nonzero(np.diff(ptr) > 0)[0]
    adj = {int(v): set(idx_list[ptr[v]:ptr[v + 1]]) for v in active}
    reported: List[FrozenSet[int]] = []
    deleted_v = 0
    deleted_e = 0
    alive = np.ones(g.n, dtype=bool)

    def kill_edge(a: int, b: int) -> None:
        nonlocal deleted_e
        adj[a].discard(b)
        adj[b].discard(a)
        deleted_e += 1

    def kill_vertex(v: int) -> None:
        nonlocal deleted_v, deleted_e
        for u in list(adj[v]):
            adj[u].discard(v)
            deleted_e += 1
        adj[v].clear()
        alive[v] = False
        deleted_v += 1

    if vertex_rule:
        queue = [v for v in adj if len(adj[v]) <= 2]
        in_q = set(queue)
        qi = 0
        while qi < len(queue):
            v = queue[qi]
            qi += 1
            in_q.discard(v)
            if not alive[v]:
                continue
            d = len(adj[v])
            if d > 2:
                continue
            neighbors = list(adj[v])
            if d == 0:
                # Lemma 1: no report (singletons are not cliques)
                alive[v] = False
                deleted_v += 1
            elif d == 1:
                # Lemma 2
                (u,) = neighbors
                reported.append(frozenset((v, u)))
                kill_vertex(v)
                if alive[u] and len(adj[u]) <= 2 and u not in in_q:
                    queue.append(u); in_q.add(u)
            else:
                # Lemma 3
                u, w = neighbors
                if w in adj[u]:
                    reported.append(frozenset((v, u, w)))
                    # delete v and its two edges; if u,w have no other common
                    # neighbor, edge (u,w) must go too (case 2)
                    other = _common_neighbor_exists(adj, u, w, exclude=v)
                    kill_vertex(v)
                    if other < 0:
                        kill_edge(u, w)
                else:
                    reported.append(frozenset((v, u)))
                    reported.append(frozenset((v, w)))
                    kill_vertex(v)
                for t in (u, w):
                    if alive[t] and len(adj[t]) <= 2 and t not in in_q:
                        queue.append(t); in_q.add(t)

    if edge_rule:
        # Non-triangle edge reduction (Algorithm 6), cascading back into
        # vertex reduction for newly created low-degree vertices.
        visited = set()
        edge_stack = [(u, v) for u in adj if alive[u]
                      for v in adj[u] if u < v]
        for (u, v) in edge_stack:
            if v not in adj[u]:
                continue
            key = (u, v)
            if key in visited:
                continue
            w = _common_neighbor_exists(adj, u, v)
            if w < 0:
                reported.append(frozenset((u, v)))
                kill_edge(u, v)
                # cascade into vertex rule
                if vertex_rule:
                    sub_q = [t for t in (u, v) if alive[t] and len(adj[t]) <= 2]
                    while sub_q:
                        t = sub_q.pop()
                        if not alive[t] or len(adj[t]) > 2:
                            continue
                        nbs = list(adj[t])
                        if len(nbs) == 0:
                            alive[t] = False; deleted_v += 1
                        elif len(nbs) == 1:
                            reported.append(frozenset((t, nbs[0])))
                            kill_vertex(t)
                            sub_q.extend(x for x in nbs if alive[x] and len(adj[x]) <= 2)
                        else:
                            a, b = nbs
                            if b in adj[a]:
                                reported.append(frozenset((t, a, b)))
                                other = _common_neighbor_exists(adj, a, b, exclude=t)
                                kill_vertex(t)
                                if other < 0:
                                    kill_edge(a, b)
                            else:
                                reported.append(frozenset((t, a)))
                                reported.append(frozenset((t, b)))
                                kill_vertex(t)
                            sub_q.extend(x for x in nbs if alive[x] and len(adj[x]) <= 2)
            else:
                visited.add((min(u, v), max(u, v)))
                visited.add((min(u, w), max(u, w)))
                visited.add((min(v, w), max(v, w)))

    edges = [(u, v) for u in adj if alive[u] for v in adj[u] if u < v]
    g2 = from_edge_list(g.n, np.array(edges, dtype=np.int64) if edges else np.zeros((0, 2), np.int64))
    # a vertex counts as deleted once it has no remaining edges (it can never
    # appear in a clique of the reduced search)
    return GlobalReduction(
        graph=g2,
        reported=reported,
        num_deleted_vertices=int(np.sum(g2.degrees() == 0)),
        num_deleted_edges=g.m - g2.m,
    )


# --------------------------------------------------------------------------
# Device path (counting mode, fixed shapes)
# --------------------------------------------------------------------------

def global_reduce_jnp(src: jnp.ndarray, dst: jnp.ndarray, n: int,
                      max_rounds: int = 64) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Low-degree peel on device: returns (vertex_alive, edge_alive) masks.

    Counting-mode global reduction restricted to the degree-0/1 cascade (the
    degree-2 and edge rules need clique reporting, which the host path owns;
    on device they run inside the bitset engine as dynamic reductions, which
    subsume them at the root level). src/dst are the directed edge lists.

    The degree vector is carried in the loop state so each round costs one
    O(m) `segment_sum` (the cond used to recompute the full degree pass,
    doubling the per-round edge traffic).
    """

    def degrees(alive_e):
        return jax.ops.segment_sum(alive_e.astype(jnp.int32), src,
                                   num_segments=n)

    def body(state):
        alive_v, alive_e, deg, it = state
        low = alive_v & (deg <= 1)
        alive_v2 = alive_v & ~low
        alive_e2 = alive_e & alive_v2[src] & alive_v2[dst]
        return alive_v2, alive_e2, degrees(alive_e2), it + 1

    def cond(state):
        alive_v, _alive_e, deg, it = state
        return jnp.any(alive_v & (deg <= 1)) & (it < max_rounds)

    alive_v = jnp.ones(n, dtype=bool)
    alive_e = jnp.ones(src.shape, dtype=bool)
    state = (alive_v, alive_e, degrees(alive_e), jnp.int32(0))
    alive_v, alive_e, _, _ = jax.lax.while_loop(cond, body, state)
    return alive_v, alive_e


def _peel_rounds_np(g: CSRGraph, max_rounds: int = 64) -> np.ndarray:
    """Host mirror of `global_reduce_jnp`'s round-based deg≤1 peel.

    Identical round semantics (all degree-≤1 vertices removed per round,
    same `max_rounds` early-exit) so small-graph ingest skips the device
    round trip yet produces bit-identical alive masks — parity is pinned
    by tests/test_prep_stream.py.
    """
    src = np.repeat(np.arange(g.n, dtype=np.int64), g.degrees())
    dst = g.indices.astype(np.int64)
    alive_v = np.ones(g.n, dtype=bool)
    alive_e = np.ones(len(src), dtype=bool)
    deg = np.bincount(src, minlength=g.n)
    for _ in range(max_rounds):
        low = alive_v & (deg <= 1)
        if not low.any():
            break
        alive_v &= ~low
        alive_e &= alive_v[src] & alive_v[dst]
        deg = np.bincount(src[alive_e], minlength=g.n)
    return alive_v


def peel_low_degree(g: CSRGraph, use_device: Optional[bool] = None
                    ) -> Tuple[CSRGraph, CliqueReports]:
    """Degree-0/1 peel pre-pass for the ingest pipeline (DESIGN.md §6).

    Runs the round-based deg≤1 cascade — on device via `global_reduce_jnp`
    for large graphs, or its host mirror for small ones — then reconstructs
    the advance reports exactly on the host: in a degree-≤1 cascade every
    edge incident to a peeled vertex is removed at a degree-1 event, and
    Lemma 2 reports that edge as a maximal 2-clique (degree-0 removals
    remove no edges and report nothing). Each undirected edge is reported
    once, which also covers the mutual degree-1 pair that a naive
    per-removal replay would double-report.

    Returns `(residual, reports)` where `residual` keeps the original
    vertex ids (peeled vertices become isolated). The cascade may stop at
    `max_rounds` on pathological path-like graphs; any leftover low-degree
    vertices simply flow into the host cascade downstream, so correctness
    never depends on the peel running to fixpoint.
    """
    if g.n == 0 or g.m == 0 or not np.any(g.degrees() == 1):
        # deg-0 removals touch no edges and report nothing, so a graph
        # without degree-1 vertices peels to itself
        return g, CliqueReports()
    if use_device is None:
        use_device = (g.n + 2 * g.m) >= 200_000
    if use_device:
        src = np.repeat(np.arange(g.n, dtype=np.int64), g.degrees())
        av, _ = global_reduce_jnp(jnp.asarray(src, jnp.int32),
                                  jnp.asarray(g.indices, jnp.int32), g.n)
        alive = np.asarray(av)
    else:
        alive = _peel_rounds_np(g)
    if alive.all():
        return g, CliqueReports()
    edges = g.edges().astype(np.int64)
    touched = ~alive[edges[:, 0]] | ~alive[edges[:, 1]]
    reports = CliqueReports([edges[touched]])
    residual = from_edge_list(g.n, edges[~touched])
    return residual, reports


def _triangle_edge_mask(g: CSRGraph) -> np.ndarray:
    """Per-undirected-edge mask: does the edge sit in at least one triangle?

    Vectorized edge-iterator: for each edge expand the smaller-degree
    endpoint's adjacency and membership-test the (other, w) pairs against
    the directed CSR key array with one `searchsorted` — O(Σ_e min deg)
    work, no per-edge python."""
    from repro.graph.pack import _ranges

    e = g.edges().astype(np.int64)
    if len(e) == 0:
        return np.zeros(0, dtype=bool)
    n = g.n
    deg = g.degrees()
    swap = deg[e[:, 0]] > deg[e[:, 1]]
    a = np.where(swap, e[:, 1], e[:, 0])
    b = np.where(swap, e[:, 0], e[:, 1])
    counts = deg[a]                              # ≥1: every endpoint has deg>0
    kt = np.int32 if n * n < (1 << 31) else np.int64
    w = g.indices[_ranges(g.indptr[a], counts)]
    q = np.repeat(b.astype(kt), counts) * kt(n) + w.astype(kt)
    dk = (np.repeat(np.arange(n, dtype=kt), deg) * kt(n)
          + g.indices.astype(kt))                # CSR order — already sorted
    if n * n <= (1 << 29):
        # dense edge-membership bitmap (≤64MB): two gathers per query
        # instead of a binary search per query
        bm = np.zeros((n * n + 7) >> 3, dtype=np.uint8)
        np.bitwise_or.at(bm, dk >> 3, np.uint8(1) << (dk & 7).astype(np.uint8))
        hit = (bm[q >> 3] >> (q & 7).astype(np.uint8)) & np.uint8(1) != 0
    else:
        pos = np.minimum(np.searchsorted(dk, q), len(dk) - 1)
        hit = dk[pos] == q
    # per-edge any() over each contiguous neighbor segment (w == a never
    # hits: u*n+u keys do not exist in a simple graph)
    offs = np.cumsum(counts) - counts
    return np.logical_or.reduceat(hit, offs)


def _batch_lemma3(g: CSRGraph) -> Tuple[CSRGraph, List[np.ndarray], bool]:
    """One conflict-free batch of Lemma-3 degree-2 eliminations.

    Selects a maximal-by-claim set of degree-2 vertices whose *closed*
    neighborhoods are pairwise disjoint (min-claim matching: every
    candidate v stamps {v, u, w} with `np.minimum.at`; v survives iff it
    owns all three cells). Disjoint closed neighborhoods make the batch
    equal to SOME sequential order of Lemma 3 applications: deleting one
    selected vertex — or its case-2 edge (u, w), whose endpoints v owns
    exclusively — cannot change another selected vertex's neighborhood
    or its (u', w') common-neighbor witness set (a degree-2 witness
    adjacent to both u' and w' would itself have claimed them).

    Returns (reduced graph, report segments, changed).
    """
    deg = g.degrees()
    cand = np.nonzero(deg == 2)[0].astype(np.int64)
    if len(cand) == 0:
        return g, [], False
    u = g.indices[g.indptr[cand]].astype(np.int64)
    w = g.indices[g.indptr[cand] + 1].astype(np.int64)
    claim = np.full(g.n, g.n, dtype=np.int64)
    np.minimum.at(claim, cand, cand)
    np.minimum.at(claim, u, cand)
    np.minimum.at(claim, w, cand)
    sel = (claim[cand] == cand) & (claim[u] == cand) & (claim[w] == cand)
    if not sel.any():
        return g, [], False
    v_s, u_s, w_s = cand[sel], u[sel], w[sel]

    from repro.graph.pack import _ranges

    n = g.n
    kt = np.int32 if n * n < (1 << 31) else np.int64
    dk = (np.repeat(np.arange(n, dtype=kt), deg) * kt(n)
          + g.indices.astype(kt))              # directed keys, CSR-sorted
    q = u_s.astype(kt) * kt(n) + w_s.astype(kt)
    pos = np.minimum(np.searchsorted(dk, q), max(len(dk) - 1, 0))
    adj_uw = dk[pos] == q                      # is (u, w) an edge?

    segments: List[np.ndarray] = []
    if (~adj_uw).any():
        # case: u, w non-adjacent -> two maximal 2-cliques {v,u}, {v,w}
        v_n, u_n, w_n = v_s[~adj_uw], u_s[~adj_uw], w_s[~adj_uw]
        segments.append(np.concatenate([np.stack([v_n, u_n], 1),
                                        np.stack([v_n, w_n], 1)]))
    doomed_uw = np.zeros((0, 2), dtype=np.int64)
    if adj_uw.any():
        # case: triangle {v,u,w} is maximal; edge (u,w) dies too unless
        # some OTHER common neighbor keeps it in a second triangle
        v_a, u_a, w_a = v_s[adj_uw], u_s[adj_uw], w_s[adj_uw]
        segments.append(np.stack([v_a, u_a, w_a], 1))
        swap = deg[u_a] > deg[w_a]
        a = np.where(swap, w_a, u_a)           # expand the smaller side
        b = np.where(swap, u_a, w_a)
        counts = deg[a]                        # >= 2: adjacent to v and b
        nb = g.indices[_ranges(g.indptr[a], counts)]
        qq = np.repeat(b.astype(kt), counts) * kt(n) + nb.astype(kt)
        pos = np.minimum(np.searchsorted(dk, qq), max(len(dk) - 1, 0))
        hit = (dk[pos] == qq).astype(np.int64)
        offs = np.cumsum(counts) - counts
        ncom = np.add.reduceat(hit, offs)      # v itself counts once
        lone = ncom < 2
        if lone.any():
            doomed_uw = np.stack([np.minimum(u_a, w_a),
                                  np.maximum(u_a, w_a)], 1)[lone]

    e = g.edges().astype(np.int64)
    in_v = np.zeros(n, dtype=bool)
    in_v[v_s] = True
    drop = in_v[e[:, 0]] | in_v[e[:, 1]]
    if len(doomed_uw):
        ek = np.minimum(e[:, 0], e[:, 1]) * n + np.maximum(e[:, 0], e[:, 1])
        drop |= np.isin(ek, doomed_uw[:, 0] * n + doomed_uw[:, 1])
    g2 = from_edge_list(n, e[~drop])
    return g2, segments, True


def reduce_prepass(g: CSRGraph, max_rounds: int = 16
                   ) -> Tuple[CSRGraph, CliqueReports]:
    """Vectorized global-reduction pre-pass for the ingest pipeline.

    Alternates the deg-0/1 peel (`peel_low_degree`) with a conflict-free
    *batch* Lemma-3 round (`_batch_lemma3`) and a *batch* non-triangle
    edge sweep (Lemma 4) until fixpoint, so the python cascade in
    `global_reduce_host` only ever sees the stubborn core — on hub-heavy
    graphs this is >90% of the vertex+edge rules' work done in a handful
    of numpy passes.

    Batch validity: every edge of a triangle shares a neighbor with the
    other two, so no triangle edge is Lemma-4-removable and no removable
    edge witnesses a triangle — removing all currently non-triangle
    edges at once equals SOME sequential order of Lemma 4 applications.
    Edges that only *become* non-triangle after vertex deletions are
    caught by the next round's peel+sweep or by the host cascade.
    """
    segments: List[np.ndarray] = []
    for _ in range(max_rounds):
        g2, r = peel_low_degree(g)
        changed = g2 is not g
        g = g2
        segments += r._segs
        if g.m == 0:
            break
        g, segs3, ch3 = _batch_lemma3(g)
        segments += segs3
        changed |= ch3
        if g.m == 0:
            break
        tri = _triangle_edge_mask(g)
        if not tri.all():
            e = g.edges().astype(np.int64)
            segments.append(e[~tri])
            g = from_edge_list(g.n, e[tri])
            changed = True
        if not changed:
            break
    return g, CliqueReports(segments)
