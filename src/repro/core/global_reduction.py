"""Global reduction (paper §4): low-degree vertex + non-triangle edge reduction.

Two implementations:

* `global_reduce_host` — numpy/python cascade queue, exact Algorithm 5 + 6
  semantics run to fixpoint (edge deletions re-enqueue new low-degree
  vertices, per the paper's Figure 3 discussion). This is the ingest-stage
  path a production deployment uses, and the path that *enumerates* the
  advance-reported cliques.
* `global_reduce_jnp` — fixed-shape, mask-based device path (counting mode):
  returns alive masks + counts of advance-reported cliques. This is what runs
  on TPU inside the distributed pipeline where the reduced graph feeds the
  bitset BK engine directly.

Both preserve the paper's invariant  mc(G) = mc(G') + α(ΔV, ΔE).
"""
from __future__ import annotations

import dataclasses
from typing import FrozenSet, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.graph.csr import CSRGraph, from_edge_list


@dataclasses.dataclass
class GlobalReduction:
    graph: CSRGraph                      # reduced graph G' (original vertex ids)
    reported: List[FrozenSet[int]]       # α(ΔV, ΔE): maximal cliques reported in advance
    num_deleted_vertices: int
    num_deleted_edges: int


def _common_neighbor_exists(adj: dict, u: int, v: int, exclude: int = -1) -> int:
    """Return a common neighbor of u, v other than `exclude`, or -1."""
    a, b = adj[u], adj[v]
    if len(a) > len(b):
        a, b = b, a
    for w in a:
        if w != exclude and w in b:
            return w
    return -1


def global_reduce_host(g: CSRGraph, vertex_rule: bool = True,
                       edge_rule: bool = True) -> GlobalReduction:
    """Cascaded global reduction to fixpoint (Algorithms 5 + 6)."""
    adj = {v: set(g.neighbors(v).tolist()) for v in range(g.n)}
    reported: List[FrozenSet[int]] = []
    deleted_v = 0
    deleted_e = 0
    alive = np.ones(g.n, dtype=bool)

    def kill_edge(a: int, b: int) -> None:
        nonlocal deleted_e
        adj[a].discard(b)
        adj[b].discard(a)
        deleted_e += 1

    def kill_vertex(v: int) -> None:
        nonlocal deleted_v, deleted_e
        for u in list(adj[v]):
            adj[u].discard(v)
            deleted_e += 1
        adj[v].clear()
        alive[v] = False
        deleted_v += 1

    if vertex_rule:
        queue = [v for v in range(g.n) if len(adj[v]) <= 2]
        in_q = set(queue)
        qi = 0
        while qi < len(queue):
            v = queue[qi]
            qi += 1
            in_q.discard(v)
            if not alive[v]:
                continue
            d = len(adj[v])
            if d > 2:
                continue
            neighbors = list(adj[v])
            if d == 0:
                # Lemma 1: no report (singletons are not cliques)
                alive[v] = False
                deleted_v += 1
            elif d == 1:
                # Lemma 2
                (u,) = neighbors
                reported.append(frozenset((v, u)))
                kill_vertex(v)
                if alive[u] and len(adj[u]) <= 2 and u not in in_q:
                    queue.append(u); in_q.add(u)
            else:
                # Lemma 3
                u, w = neighbors
                if w in adj[u]:
                    reported.append(frozenset((v, u, w)))
                    # delete v and its two edges; if u,w have no other common
                    # neighbor, edge (u,w) must go too (case 2)
                    other = _common_neighbor_exists(adj, u, w, exclude=v)
                    kill_vertex(v)
                    if other < 0:
                        kill_edge(u, w)
                else:
                    reported.append(frozenset((v, u)))
                    reported.append(frozenset((v, w)))
                    kill_vertex(v)
                for t in (u, w):
                    if alive[t] and len(adj[t]) <= 2 and t not in in_q:
                        queue.append(t); in_q.add(t)

    if edge_rule:
        # Non-triangle edge reduction (Algorithm 6), cascading back into
        # vertex reduction for newly created low-degree vertices.
        visited = set()
        edge_stack = [(u, v) for u in range(g.n) if alive[u]
                      for v in adj[u] if u < v]
        for (u, v) in edge_stack:
            if v not in adj[u]:
                continue
            key = (u, v)
            if key in visited:
                continue
            w = _common_neighbor_exists(adj, u, v)
            if w < 0:
                reported.append(frozenset((u, v)))
                kill_edge(u, v)
                # cascade into vertex rule
                if vertex_rule:
                    sub_q = [t for t in (u, v) if alive[t] and len(adj[t]) <= 2]
                    while sub_q:
                        t = sub_q.pop()
                        if not alive[t] or len(adj[t]) > 2:
                            continue
                        nbs = list(adj[t])
                        if len(nbs) == 0:
                            alive[t] = False; deleted_v += 1
                        elif len(nbs) == 1:
                            reported.append(frozenset((t, nbs[0])))
                            kill_vertex(t)
                            sub_q.extend(x for x in nbs if alive[x] and len(adj[x]) <= 2)
                        else:
                            a, b = nbs
                            if b in adj[a]:
                                reported.append(frozenset((t, a, b)))
                                other = _common_neighbor_exists(adj, a, b, exclude=t)
                                kill_vertex(t)
                                if other < 0:
                                    kill_edge(a, b)
                            else:
                                reported.append(frozenset((t, a)))
                                reported.append(frozenset((t, b)))
                                kill_vertex(t)
                            sub_q.extend(x for x in nbs if alive[x] and len(adj[x]) <= 2)
            else:
                visited.add((min(u, v), max(u, v)))
                visited.add((min(u, w), max(u, w)))
                visited.add((min(v, w), max(v, w)))

    edges = [(u, v) for u in range(g.n) if alive[u] for v in adj[u] if u < v]
    g2 = from_edge_list(g.n, np.array(edges, dtype=np.int64) if edges else np.zeros((0, 2), np.int64))
    # a vertex counts as deleted once it has no remaining edges (it can never
    # appear in a clique of the reduced search)
    return GlobalReduction(
        graph=g2,
        reported=reported,
        num_deleted_vertices=int(np.sum(g2.degrees() == 0)),
        num_deleted_edges=g.m - g2.m,
    )


# --------------------------------------------------------------------------
# Device path (counting mode, fixed shapes)
# --------------------------------------------------------------------------

def global_reduce_jnp(src: jnp.ndarray, dst: jnp.ndarray, n: int,
                      max_rounds: int = 64) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Low-degree peel on device: returns (vertex_alive, edge_alive) masks.

    Counting-mode global reduction restricted to the degree-0/1 cascade (the
    degree-2 and edge rules need clique reporting, which the host path owns;
    on device they run inside the bitset engine as dynamic reductions, which
    subsume them at the root level). src/dst are the directed edge lists.
    """

    def body(state):
        alive_v, alive_e, it = state
        deg = jax.ops.segment_sum(alive_e.astype(jnp.int32), src, num_segments=n)
        low = alive_v & (deg <= 1)
        alive_v2 = alive_v & ~low
        alive_e2 = alive_e & alive_v2[src] & alive_v2[dst]
        return alive_v2, alive_e2, it + 1

    def cond(state):
        alive_v, alive_e, it = state
        deg = jax.ops.segment_sum(alive_e.astype(jnp.int32), src, num_segments=n)
        return jnp.any(alive_v & (deg <= 1)) & (it < max_rounds)

    alive_v = jnp.ones(n, dtype=bool)
    alive_e = jnp.ones(src.shape, dtype=bool)
    alive_v, alive_e, _ = jax.lax.while_loop(cond, body, (alive_v, alive_e, jnp.int32(0)))
    return alive_v, alive_e
