"""Reference (oracle) implementations of BK-family MCE and the paper's RMCE.

Pure-Python set-based code. This is the ground truth for:
  * correctness tests of the JAX bitset engine (exact clique-set equality),
  * the paper's counter-based experiments (recursive calls, vertex visits,
    forbidden-set reduction ratios) where instrumentation fidelity matters
    more than wall-time.

Convention (paper Lemma 1): maximal cliques have >= 2 vertices; isolated
vertices are never reported.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, FrozenSet, List, Optional, Set

import numpy as np

from repro.graph.csr import CSRGraph
from repro.graph.order import degeneracy_order


@dataclasses.dataclass
class MCEStats:
    recursive_calls: int = 0
    cliques: int = 0
    vertex_visits: Dict[int, int] = dataclasses.field(default_factory=dict)
    # forbidden-set reduction metrics (root level, paper Fig 10)
    sum_x_before: int = 0
    sum_x_after: int = 0
    subproblems_with_x_reduction: int = 0
    root_subproblems: int = 0
    # global reduction metrics (paper Fig 8)
    deleted_vertices: int = 0
    deleted_edges: int = 0
    pre_reported: int = 0

    def visit(self, vertices) -> None:
        for v in vertices:
            self.vertex_visits[v] = self.vertex_visits.get(v, 0) + 1


def _adj_sets(g: CSRGraph) -> List[Set[int]]:
    return [set(g.neighbors(v).tolist()) for v in range(g.n)]


# --------------------------------------------------------------------------
# Plain BK backends (baselines the paper enhances)
# --------------------------------------------------------------------------

def bk_pivot(g: CSRGraph, stats: Optional[MCEStats] = None,
             collect: bool = True) -> List[FrozenSet[int]]:
    """Tomita-style BK with max-|N(u) ∩ P| pivot, natural top-level call."""
    adj = _adj_sets(g)
    stats = stats if stats is not None else MCEStats()
    out: List[FrozenSet[int]] = []

    def rec(R: Set[int], P: Set[int], X: Set[int]) -> None:
        stats.recursive_calls += 1
        stats.visit(P)
        stats.visit(X)
        if not P and not X:
            if len(R) >= 2:
                stats.cliques += 1
                if collect:
                    out.append(frozenset(R))
            return
        pivot = max(P | X, key=lambda u: len(adj[u] & P))
        for v in list(P - adj[pivot]):
            rec(R | {v}, P & adj[v], X & adj[v])
            P.discard(v)
            X.add(v)

    rec(set(), set(range(g.n)), set())
    return out


def bk_degen(g: CSRGraph, stats: Optional[MCEStats] = None,
             collect: bool = True, backend: str = "pivot") -> List[FrozenSet[int]]:
    """BKdegen [Eppstein et al.]: degeneracy-order roots + BK backend."""
    return _bk_degen_impl(g, stats, collect, backend,
                          global_red=False, dynamic_red=False, x_red=False)


def rmce(g: CSRGraph, stats: Optional[MCEStats] = None, collect: bool = True,
         backend: str = "pivot", global_red: bool = True,
         dynamic_red: bool = True, x_red: bool = True) -> List[FrozenSet[int]]:
    """The paper's RMCE: global + dynamic + maximality-check reductions
    around a BK backend ('pivot' | 'rcd' | 'revised')."""
    return _bk_degen_impl(g, stats, collect, backend,
                          global_red=global_red, dynamic_red=dynamic_red, x_red=x_red)


# --------------------------------------------------------------------------
# Shared degeneracy-rooted driver with optional reductions
# --------------------------------------------------------------------------

def _bk_degen_impl(g: CSRGraph, stats, collect, backend,
                   global_red: bool, dynamic_red: bool, x_red: bool):
    stats = stats if stats is not None else MCEStats()
    out: List[FrozenSet[int]] = []

    if global_red:
        from repro.core.global_reduction import global_reduce_host

        red = global_reduce_host(g)
        g_work = red.graph
        stats.deleted_vertices += red.num_deleted_vertices
        stats.deleted_edges += red.num_deleted_edges
        stats.pre_reported += len(red.reported)
        stats.cliques += len(red.reported)
        if collect:
            out.extend(red.reported)
    else:
        g_work = g

    adj = _adj_sets(g_work)
    order, rank, _ = degeneracy_order(g_work)
    # maximality-check reduction (paper Algorithm 8 + witness chains, see
    # repro.core.xreduction for why plain ignoreId over-prunes)
    kept_x = None
    if x_red:
        from repro.core.xreduction import x_prune_roots

        kept_x = x_prune_roots(adj, order, rank)

    def maybe_dynamic(R: Set[int], P: Set[int], X: Set[int]):
        """Paper Algorithm 7. Mutates copies; returns (R, P, X) or None if
        the subproblem is exhausted by reduction."""
        if not dynamic_red:
            return R, P, X
        marked = set()
        for x in X:
            marked |= adj[x] & P
        degP = {u: len(adj[u] & P) for u in P}  # u ∉ N(u), no self correction
        removed: Set[int] = set()
        # NOTE on soundness: a vertex removed from P *with* an advance report
        # is adjacent to all of R, so the residual R (or R ∪ {partner}) must
        # never surface from the bare (P=∅, X=∅) leaf. We therefore move such
        # vertices into X — the classic BK "visited" semantics with the
        # recursive call replaced by an O(1) report; the usual X ∩ N(·)
        # updates then retire them exactly when they stop extending R.
        # Marked degree-zero vertices are dropped outright (paper Lemma 5(2));
        # the current X ≠ ∅ already blocks the only at-risk leaf.
        to_x: Set[int] = set()
        # dynamic degree-zero (Lemma 5)
        for u in P:
            if degP[u] == 0:
                removed.add(u)
                if u not in marked:
                    _report(R | {u})
                    to_x.add(u)
        # relaxed dynamic degree-one (Lemma 7)
        for u in P:
            if u in removed or degP[u] != 1:
                continue
            (v,) = adj[u] & P
            if v in removed:
                continue
            if u not in marked or v not in marked:
                _report(R | {u, v})
                removed.add(u)
                to_x.add(u)
                if degP.get(v, -1) == 1:
                    removed.add(v)
                    to_x.add(v)
        P = P - removed
        X = X | to_x
        # dynamic degree-(|P|-1) (Lemma 8)
        if P:
            full = {u for u in P if len(adj[u] & P) >= len(P) - 1}
            if full:
                R = R | full
                P = P - full
                for u in full:
                    X = X & adj[u]
        return R, P, X

    def _report(clique: Set[int]) -> None:
        if len(clique) >= 2:
            stats.cliques += 1
            if collect:
                out.append(frozenset(clique))

    def rec_pivot(R: Set[int], P: Set[int], X: Set[int], revised: bool) -> None:
        stats.recursive_calls += 1
        stats.visit(P)
        stats.visit(X)
        R, P, X = maybe_dynamic(R, P, X)
        if not P:
            if not X:
                _report(R)
            return
        pool = P if revised else (P | X)
        pivot = max(pool, key=lambda u: (len(adj[u] & P), -rank[u]))
        for v in sorted(P - adj[pivot], key=lambda u: rank[u]):
            rec_pivot(R | {v}, P & adj[v], X & adj[v], revised)
            P.discard(v)
            X.add(v)

    def rec_rcd(R: Set[int], P: Set[int], X: Set[int]) -> None:
        stats.recursive_calls += 1
        stats.visit(P)
        stats.visit(X)
        R, P, X = maybe_dynamic(R, P, X)
        if not P:
            if not X:
                _report(R)
            return
        # top-down: remove min-degree vertices until P is a clique
        P = set(P)
        X = set(X)
        while True:
            degP = {u: len(adj[u] & P) for u in P}
            if all(d == len(P) - 1 for d in degP.values()):
                break
            v = min(P, key=lambda u: (degP[u], rank[u]))
            rec_rcd(R | {v}, P & adj[v], X & adj[v])
            P.discard(v)
            X.add(v)
        if not any(P <= adj[x] for x in X):
            _report(R | P)

    for i in range(g_work.n):
        v = int(order[i])
        if global_red and not adj[v]:
            continue  # vertex deleted by global reduction: no root subproblem
        P = {u for u in adj[v] if rank[u] > i}
        X_full = {u for u in adj[v] if rank[u] < i}
        stats.root_subproblems += 1
        stats.sum_x_before += len(X_full)
        if x_red:
            X = set(kept_x[i])
            if len(X) < len(X_full):
                stats.subproblems_with_x_reduction += 1
        else:
            X = X_full
        stats.sum_x_after += len(X)
        if backend == "rcd":
            rec_rcd({v}, P, X)
        else:
            rec_pivot({v}, P, X, revised=(backend == "revised"))
    return out


def maximal_cliques_brute(g: CSRGraph) -> Set[FrozenSet[int]]:
    """Exponential brute force over all vertex subsets (tiny graphs only)."""
    from itertools import combinations

    adj = _adj_sets(g)
    cliques: Set[FrozenSet[int]] = set()
    n = g.n
    assert n <= 16, "brute force capped at n=16"
    subsets = []
    for k in range(2, n + 1):
        for comb in combinations(range(n), k):
            if all(b in adj[a] for a, b in combinations(comb, 2)):
                subsets.append(set(comb))
    for s in subsets:
        if not any(s < t for t in map(set, subsets)):
            cliques.add(frozenset(s))
    return cliques
