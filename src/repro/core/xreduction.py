"""Maximality-check reduction (paper §6, Algorithm 8) — shared host logic.

Computes, for every root v in degeneracy order, the pruned forbidden set
X'(v) ⊆ N⁻(v) using the ignoreId array, extended with *witness pointers* and
per-root chain resolution.

Why witnesses: Algorithm 8 as printed prunes x whenever ignoreId[x] < i, but
neighbourhood dominations can be cyclic in dense graphs (x dominated by y, y
by z, z by x — all three would be pruned, losing every maximality witness and
emitting non-maximal cliques). We store who dominates whom and, per root,
prune x only if its witness chain terminates at a kept vertex; a cycle
(mutually equal P-neighbourhoods) keeps exactly its min-rank member. This
preserves Lemma 9 exactly — validated against brute force in tests.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Set

import numpy as np


def x_prune_roots(adj: Sequence[Set[int]], order: np.ndarray,
                  rank: np.ndarray) -> List[Set[int]]:
    """Return kept_X[i] (set of vertices) for each root position i."""
    n = len(adj)
    ignore_id = np.full(n, n, dtype=np.int64)
    ignore_wit = np.full(n, -1, dtype=np.int64)
    kept: List[Set[int]] = []
    # N⁺(u) depends on u alone; memoize it instead of rebuilding the set for
    # every (root, u) incidence (that rebuild was O(Σ_v Σ_{u∈P(v)} deg(u)),
    # the dominant term of host prep on hub-heavy graphs)
    nup_cache: Dict[int, Set[int]] = {}

    def nu_plus_of(u: int) -> Set[int]:
        s = nup_cache.get(u)
        if s is None:
            ru = rank[u]
            s = {w for w in adj[u] if rank[w] > ru}
            nup_cache[u] = s
        return s

    for i in range(n):
        v = int(order[i])
        if not adj[v]:
            kept.append(set())
            continue
        P = {u for u in adj[v] if rank[u] > i}
        X_full = {u for u in adj[v] if rank[u] < i}
        kept.append(resolve_keeps(X_full, i, ignore_id, ignore_wit, rank))
        for u in P:
            nu_plus = nu_plus_of(u)
            if (P - {u}) <= nu_plus:
                if rank[u] < ignore_id[v]:
                    ignore_id[v] = rank[u]
                    ignore_wit[v] = u
            elif nu_plus <= P:
                if i < ignore_id[u]:
                    ignore_id[u] = i
                    ignore_wit[u] = v
    return kept


def resolve_keeps(X_full: Set[int], i: int, ignore_id: np.ndarray,
                  ignore_wit: np.ndarray, rank: np.ndarray) -> Set[int]:
    """Subset of X_full kept at root rank i (witness-chain resolution)."""
    memo: Dict[int, bool] = {}

    def walk(u: int) -> bool:
        path: List[int] = []
        on_path: Set[int] = set()
        cur = u
        while True:
            if cur in memo or ignore_id[cur] >= i:
                if cur not in memo:
                    memo[cur] = True
                for x in path:
                    memo[x] = False
                break
            if cur in on_path:
                cyc = path[path.index(cur):]
                keep_v = min(cyc, key=lambda x: rank[x])
                for x in path:
                    memo[x] = x == keep_v
                break
            path.append(cur)
            on_path.add(cur)
            cur = int(ignore_wit[cur])
        return memo[u]

    return {x for x in X_full if walk(x)}
