"""Distributed MCE runtime: shard_map fan-out, load balancing, checkpointing.

Deployment model for 1000+ nodes (DESIGN.md §5):

* Root subproblems are independent — MCE is data-parallel over roots. The
  production mesh's `pod` × `data` axes form the root-parallel dimension;
  `model` stays size-1 for MCE (a bitset subtree does not split further
  without work-stealing, which SPMD forbids; instead we over-decompose).
* **Straggler mitigation** is static balancing: per bucket, roots are sorted
  by a cost estimate (|P|·2^{λ̂} proxy: universe² × mean row popcount) and
  dealt round-robin across shards, so each shard receives the same cost mass
  (LPT-style). Lockstep waste inside a vmap batch is bounded by chunking:
  each shard processes `chunk` roots per device step, so a pathological root
  stalls one chunk, not the epoch.
* **Fault tolerance**: after every chunk the accumulated counters + cursor
  are checkpointed host-side. The cursor counts roots completed in the
  *canonical cost-descending order* — a pure function of the prepared graph
  only, NOT of the device count — so an *elastic* restart with a different
  device count resumes at exactly the same root (tested in
  tests/test_distributed.py::test_elastic_restart_different_device_count).
"""
from __future__ import annotations

import dataclasses
import json
import os
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.engine import (EngineConfig, MCEResult, PreparedMCE,
                               RootBucket, prepare, run_root)
from repro.graph.csr import CSRGraph
from repro.sharding.compat import shard_map

COUNTER_KEYS = ("cliques", "calls", "branches", "sum_px")


# ---------------------------------------------------------------------------
# Cost-balanced root scheduling
# ---------------------------------------------------------------------------

def estimate_costs(bucket: RootBucket) -> np.ndarray:
    """Per-root cost proxy: |P| * (1 + mean induced degree)^2.

    The BK subtree size grows with local density; this proxy ranks hub-like
    roots above sparse ones, which is all static balancing needs."""
    p_sizes = np.array([len(u) for u in bucket.universes], dtype=np.float64)
    pc = np.unpackbits(bucket.a.view(np.uint8), axis=-1).sum(axis=(1, 2))
    mean_deg = pc / np.maximum(p_sizes, 1)
    return p_sizes * (1.0 + mean_deg) ** 2


def canonical_order(costs: np.ndarray) -> np.ndarray:
    """Cost-descending stable order — the shard-count-INDEPENDENT schedule.

    Elasticity contract: the checkpoint cursor counts *roots completed in
    this order*; a restart with any device count resumes at the same root."""
    return np.argsort(-costs, kind="stable")


def deal_roots(costs: np.ndarray, n_shards: int) -> List[np.ndarray]:
    """Sort by cost desc, deal round-robin -> per-shard root index lists."""
    order = canonical_order(costs)
    return [order[s::n_shards] for s in range(n_shards)]


# ---------------------------------------------------------------------------
# Sharded bucket execution
# ---------------------------------------------------------------------------

def _shard_batch(bucket: RootBucket, idx: np.ndarray, pad_to: int):
    """Gather + pad a per-shard slice of a bucket (pad roots are no-ops)."""
    take = idx[:pad_to] if len(idx) >= pad_to else idx
    pad = pad_to - len(take)
    a = bucket.a[take]
    p0 = bucket.p0[take]
    xr = bucket.x_rows[take]
    xa = bucket.x_alive0[take]
    rz = bucket.rsz0[take]
    if pad:
        w = bucket.a.shape[2]
        a = np.concatenate([a, np.zeros((pad,) + bucket.a.shape[1:], np.uint32)])
        p0 = np.concatenate([p0, np.zeros((pad, w), np.uint32)])  # empty P -> no-op
        xr = np.concatenate([xr, np.zeros((pad,) + bucket.x_rows.shape[1:], np.uint32)])
        xa = np.concatenate([xa, np.zeros((pad, bucket.x_rows.shape[1]), bool)])
        rz = np.concatenate([rz, np.ones(pad, np.int32)])
    return a, p0, xr, xa, rz


@partial(jax.jit, static_argnames=("cfg", "mesh", "axis"))
def _sharded_counts(a, p0, xr, xa, rz, cfg: EngineConfig, mesh: Mesh, axis):
    """Run a [n_shards, chunk, ...] batch under shard_map; psum counters.

    `axis` is a mesh axis name or a tuple of axis names (multi-pod: roots
    shard over the flattened ("pod", "data") product)."""

    def per_shard(a_s, p_s, xr_s, xa_s, rz_s):
        out = jax.vmap(lambda aa, pp, rr, ll, zz: run_root(aa, pp, rr, ll,
                                                           zz, cfg))(
            a_s[0], p_s[0], xr_s[0], xa_s[0], rz_s[0])
        sums = {k: jnp.sum(out[k]).astype(jnp.int32)[None] for k in COUNTER_KEYS}
        return sums

    specs_in = (P(axis), P(axis), P(axis), P(axis), P(axis))
    specs_out = {k: P(axis) for k in COUNTER_KEYS}
    fn = shard_map(per_shard, mesh=mesh, in_specs=specs_in,
                   out_specs=specs_out, check_vma=False)
    out = fn(a, p0, xr, xa, rz)
    return {k: jnp.sum(v) for k, v in out.items()}


@dataclasses.dataclass
class DriverCheckpoint:
    bucket: int = 0
    roots_done: int = 0            # cursor in canonical (cost-desc) order —
    counters: dict = dataclasses.field(  # shard-count independent (elastic)
        default_factory=lambda: {k: 0 for k in COUNTER_KEYS})

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(dataclasses.asdict(self), f)
        os.replace(tmp, path)  # atomic: a torn write never corrupts resume

    @staticmethod
    def load(path: str) -> "DriverCheckpoint":
        with open(path) as f:
            d = json.load(f)
        return DriverCheckpoint(bucket=d["bucket"],
                                roots_done=d["roots_done"],
                                counters=d["counters"])


class DistributedMCE:
    """Chunked, checkpointed, shard_map-parallel MCE over a device mesh."""

    def __init__(self, g: CSRGraph, *, mesh: Optional[Mesh] = None,
                 axis: str = "data", chunk: int = 1024,
                 ckpt_path: Optional[str] = None,
                 cfg: EngineConfig = EngineConfig(),
                 global_red: bool = True, x_red: bool = True,
                 bucket_sizes: Sequence[int] = (32, 64, 128, 256, 512, 1024),
                 split_threshold: Optional[int] = None):
        if mesh is None:
            # no axis_types kwarg: Auto is the default and the kwarg does
            # not exist on jax 0.4.x
            mesh = jax.make_mesh((len(jax.devices()),), ("data",))
            axis = "data"
        self.mesh = mesh
        self.axis = axis if isinstance(axis, (tuple, list)) else (axis,)
        self.axis = tuple(self.axis)
        self.n_shards = int(np.prod([mesh.shape[a] for a in self.axis]))
        self.chunk = chunk
        self.cfg = cfg
        self.ckpt_path = ckpt_path
        self.prep = prepare(g, global_red=global_red, x_red=x_red,
                            bucket_sizes=bucket_sizes,
                            split_threshold=split_threshold)
        # canonical cost-desc order per bucket: the elastic schedule. A chunk
        # step processes the next window of n_shards×chunk roots; shard s
        # takes window[s::n_shards] (cost-balanced: window is cost-sorted).
        self.order: List[np.ndarray] = [
            canonical_order(estimate_costs(bucket))
            for bucket in self.prep.buckets]

    def run(self, resume: bool = True) -> MCEResult:
        state = DriverCheckpoint()
        state.counters["cliques"] = len(self.prep.pre_reported)
        if resume and self.ckpt_path and os.path.exists(self.ckpt_path):
            state = DriverCheckpoint.load(self.ckpt_path)

        window = self.n_shards * self.chunk
        for b, bucket in enumerate(self.prep.buckets):
            if b < state.bucket:
                continue
            total = len(self.order[b])
            done = state.roots_done if b == state.bucket else 0
            while done < total:
                counts = self._run_chunk(b, done, min(done + window, total))
                done = min(done + window, total)
                for k in COUNTER_KEYS:
                    state.counters[k] += int(counts[k])
                state.bucket, state.roots_done = b, done
                if self.ckpt_path:
                    state.save(self.ckpt_path)
            state.roots_done = 0
        return MCEResult(cliques=state.counters["cliques"],
                         calls=state.counters["calls"],
                         branches=state.counters["branches"],
                         sum_px=state.counters["sum_px"],
                         pre_reported=len(self.prep.pre_reported))

    def _run_chunk(self, b: int, lo: int, hi: int):
        bucket = self.prep.buckets[b]
        window = self.order[b][lo:hi]
        slices = [window[s::self.n_shards] for s in range(self.n_shards)]
        pad_to = max(len(s) for s in slices)
        parts = [_shard_batch_slice(bucket, s, pad_to) for s in slices]
        n_pad = sum(pad_to - len(s) for s in slices)
        a = np.stack([p[0] for p in parts])
        p0 = np.stack([p[1] for p in parts])
        xr = np.stack([p[2] for p in parts])
        xa = np.stack([p[3] for p in parts])
        rz = np.stack([p[4] for p in parts])
        sharding = NamedSharding(self.mesh, P(self.axis))
        a, p0, xr, xa, rz = (jax.device_put(t, sharding)
                             for t in (a, p0, xr, xa, rz))
        out = _sharded_counts(a, p0, xr, xa, rz, self.cfg, self.mesh,
                              self.axis)
        out = jax.tree.map(lambda x: np.asarray(x), out)
        # padded no-op roots contribute exactly one call each; remove them so
        # distributed counters match the single-host run bit-for-bit
        out["calls"] = out["calls"] - n_pad
        return out


def _shard_batch_slice(bucket: RootBucket, idx: np.ndarray, pad_to: int):
    return _shard_batch(bucket, idx, pad_to)
