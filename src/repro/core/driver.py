"""Distributed MCE runtime: shard_map fan-out, load balancing, checkpointing.

Deployment model for 1000+ nodes (DESIGN.md §5–§6):

* Root subproblems are independent — MCE is data-parallel over roots. The
  production mesh's `pod` × `data` axes form the root-parallel dimension;
  `model` stays size-1 for MCE (a bitset subtree does not split further
  without work-stealing, which SPMD forbids; instead we over-decompose).
* **Streaming ingest**: the driver consumes `RootBucket`s from a
  `PrepStream` as the host packs them, and runs **double-buffered**: chunk
  *k* is dispatched asynchronously (device buffers donated), then the host
  packs and uploads chunk *k+1* while the device works, and only then
  blocks on chunk *k*'s counters. The host never sits between the device
  and its next batch; `stats` records how much packing was hidden.
* **Straggler mitigation** is static balancing: per bucket, roots are sorted
  by a cost estimate (|P|·2^{λ̂} proxy: universe² × mean row popcount) and
  dealt round-robin across shards, so each shard receives the same cost mass
  (LPT-style). Lockstep waste inside a vmap batch is bounded by chunking:
  each shard processes `chunk` roots per device step, so a pathological root
  stalls one chunk, not the epoch.
* **Fault tolerance**: after every chunk the accumulated counters + cursor
  are checkpointed host-side. The cursor counts roots completed in the
  *canonical cost-descending order* — a pure function of the prepared graph
  and the stream parameters, NOT of the device count — so an *elastic*
  restart with a different device count resumes at exactly the same root
  (tested in tests/test_distributed.py and tests/test_prep_stream.py).
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from functools import partial
from typing import Iterator, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.engine import (BACKENDS, EngineConfig, MCEResult,
                               PIVOT_BACKENDS, PreparedMCE, PrepStream,
                               RootBucket, choose_engine, estimate_costs,
                               root_cost_skew, run_bucket_persistent,
                               run_root)
from repro.graph.csr import CSRGraph
from repro.sharding.compat import shard_map

# "truncated" folds each chunk's iters-exhausted flags so a max_iters cutoff
# surfaces as MCEResult.iters_exhausted instead of silently partial counts.
# "live_iters"/"lane_iters" are the occupancy pair (useful lane-trips vs
# lane-trip capacity): occupancy = live/lane. The perroot engine's
# equivalent is Σ per-root iters over max(iters)·lanes — the lock-step vmap
# runs every lane until the slowest root finishes, which is exactly the
# idle time the persistent queue reclaims (surfaced per query through
# MCEService.stats). "steals"/"entry_terms"/"window_spills"/"window_hits"
# only move on the persistent engine (adopted branch-set halves, claims
# that finished inside their entry call, and windowed trips that stopped
# at a window boundary vs ran fully VMEM-resident); the perroot path
# zero-fills them so the counter schema — and every checkpoint written
# against it — is engine-independent. Checkpoints from before a key
# existed resume via `.get` in `_settle`.
COUNTER_KEYS = ("cliques", "calls", "branches", "sum_px", "truncated",
                "live_iters", "lane_iters", "steals", "entry_terms",
                "window_spills", "window_hits")


# ---------------------------------------------------------------------------
# Cost-balanced root scheduling (cost model lives in engine.prepare)
# ---------------------------------------------------------------------------


def canonical_order(costs: np.ndarray) -> np.ndarray:
    """Cost-descending stable order — the shard-count-INDEPENDENT schedule.

    Elasticity contract: the checkpoint cursor counts *roots completed in
    this order*; a restart with any device count resumes at the same root."""
    return np.argsort(-costs, kind="stable")


def deal_roots(costs: np.ndarray, n_shards: int) -> List[np.ndarray]:
    """Sort by cost desc, deal round-robin -> per-shard root index lists."""
    order = canonical_order(costs)
    return [order[s::n_shards] for s in range(n_shards)]


# ---------------------------------------------------------------------------
# Sharded bucket execution
# ---------------------------------------------------------------------------

def _graph_fingerprint(g: CSRGraph) -> List[int]:
    """Cheap O(m) identity of a CSR graph for the checkpoint schedule.

    The cursor indexes a bucket sequence that is a pure function of the
    graph too (DESIGN.md §6.4); a position-weighted xor fold of the
    adjacency catches resuming against a different graph, not just
    different stream parameters."""
    idx = g.indices.astype(np.uint64)
    weights = np.arange(1, len(idx) + 1, dtype=np.uint64)
    h = int(np.bitwise_xor.reduce(idx * weights)) if len(idx) else 0
    return [g.n, g.m, h]


def _shard_batch(bucket: RootBucket, idx: np.ndarray, pad_to: int):
    """Gather + pad a per-shard slice of a bucket (pad roots are no-ops)."""
    take = idx[:pad_to] if len(idx) >= pad_to else idx
    pad = pad_to - len(take)
    a = bucket.a[take]
    p0 = bucket.p0[take]
    xr = bucket.x_rows[take]
    xa = bucket.x_alive0[take]
    rz = bucket.rsz0[take]
    if pad:
        w = bucket.a.shape[2]
        a = np.concatenate([a, np.zeros((pad,) + bucket.a.shape[1:], np.uint32)])
        p0 = np.concatenate([p0, np.zeros((pad, w), np.uint32)])  # empty P -> no-op
        xr = np.concatenate([xr, np.zeros((pad,) + bucket.x_rows.shape[1:], np.uint32)])
        xa = np.concatenate([xa, np.zeros((pad, bucket.x_rows.shape[1]), bool)])
        rz = np.concatenate([rz, np.ones(pad, np.int32)])
    return a, p0, xr, xa, rz


def _sharded_counts_impl(a, p0, xr, xa, rz, cfg: EngineConfig, mesh: Mesh,
                         axis, engine: str = "perroot", lanes: int = 64):
    """Run a [n_shards, chunk, ...] batch under shard_map; psum counters.

    `axis` is a mesh axis name or a tuple of axis names (multi-pod: roots
    shard over the flattened ("pod", "data") product). `engine='persistent'`
    runs each shard's chunk through the lane-refill work queue — the
    chunk's cost-descending slice order IS the queue order — instead of
    one lock-step vmap lane per root."""

    def per_shard(a_s, p_s, xr_s, xa_s, rz_s):
        if engine == "persistent":
            L = min(lanes, a_s.shape[1])
            out = run_bucket_persistent(
                a_s[0], p_s[0], xr_s[0], xa_s[0], rz_s[0], cfg, lanes=L)
            # each windowed trip offers up to window_steps frame-steps
            # per lane, so the occupancy denominator scales with it
            spt = max(1, cfg.window_steps)
            out = dict(out, lane_iters=out["iters"] * L * spt)
        else:
            out = jax.vmap(lambda aa, pp, rr, ll, zz: run_root(
                aa, pp, rr, ll, zz, cfg))(
                a_s[0], p_s[0], xr_s[0], xa_s[0], rz_s[0])
            # lock-step equivalent of the queue's occupancy pair: every
            # vmap lane spins until the slowest root's DFS exhausts
            out = dict(out, live_iters=jnp.sum(out["iters"]),
                       lane_iters=jnp.max(out["iters"]) * a_s.shape[1],
                       steals=jnp.int32(0), entry_terms=jnp.int32(0),
                       window_spills=jnp.int32(0),
                       window_hits=jnp.int32(0))
        sums = {k: jnp.sum(out[k]).astype(jnp.int32)[None]
                for k in COUNTER_KEYS}
        return sums

    specs_in = (P(axis), P(axis), P(axis), P(axis), P(axis))
    specs_out = {k: P(axis) for k in COUNTER_KEYS}
    fn = shard_map(per_shard, mesh=mesh, in_specs=specs_in,
                   out_specs=specs_out, check_vma=False)
    out = fn(a, p0, xr, xa, rz)
    return {k: jnp.sum(v) for k, v in out.items()}


# Chunk buffers are fresh device_puts the driver never reuses, so on real
# accelerators they are donated: engine scratch aliases them instead of
# growing the footprint while the next chunk's upload is in flight (double
# buffering). Donation is a no-op on CPU (and warns per compile), and the
# backend must not be probed at import time (a 1000-node launcher calls
# jax.distributed.initialize() after importing this module) — so the
# variant is chosen lazily at the first call.
_sharded_counts_donated = partial(jax.jit,
                                  static_argnames=("cfg", "mesh", "axis",
                                                   "engine", "lanes"),
                                  donate_argnums=(0, 1, 2, 3, 4))(
    _sharded_counts_impl)
_sharded_counts_plain = partial(jax.jit,
                                static_argnames=("cfg", "mesh", "axis",
                                                 "engine", "lanes"))(
    _sharded_counts_impl)


def _sharded_counts(a, p0, xr, xa, rz, cfg: EngineConfig, mesh: Mesh, axis,
                    engine: str = "perroot", lanes: int = 64):
    fn = (_sharded_counts_plain if jax.default_backend() == "cpu"
          else _sharded_counts_donated)
    return fn(a, p0, xr, xa, rz, cfg=cfg, mesh=mesh, axis=axis,
              engine=engine, lanes=lanes)


@dataclasses.dataclass
class DriverCheckpoint:
    bucket: int = 0
    roots_done: int = 0            # cursor in canonical (cost-desc) order —
    counters: dict = dataclasses.field(  # shard-count independent (elastic)
        default_factory=lambda: {k: 0 for k in COUNTER_KEYS})
    schedule: dict = dataclasses.field(default_factory=dict)
    # ^ identity of the bucket sequence the cursor indexes (stream params or
    # materialized bucket shapes). The cursor is only meaningful against the
    # SAME sequence; run() refuses to resume against a different one.

    def save(self, path: str) -> None:
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            json.dump(dataclasses.asdict(self), f)
        os.replace(tmp, path)  # atomic: a torn write never corrupts resume

    @staticmethod
    def load(path: str) -> "DriverCheckpoint":
        with open(path) as f:
            d = json.load(f)
        return DriverCheckpoint(bucket=d["bucket"],
                                roots_done=d["roots_done"],
                                counters=d["counters"],
                                schedule=d.get("schedule", {}))


class DistributedMCE:
    """Chunked, checkpointed, shard_map-parallel MCE over a device mesh.

    Ingest is streaming by default: buckets arrive from a `PrepStream` and
    the run loop keeps one chunk in flight (see module docstring). Pass
    `streaming=False` for the legacy materialize-everything-first mode
    (exposed as `.prep`), or hand in an existing `PrepStream`/`PreparedMCE`
    via `prep=` to reuse packed buckets across runs (launch.mce_service).
    """

    def __init__(self, g: Optional[CSRGraph] = None, *,
                 mesh: Optional[Mesh] = None,
                 axis: str = "data", chunk: int = 1024,
                 ckpt_path: Optional[str] = None,
                 cfg: EngineConfig = EngineConfig(),
                 global_red: bool = True, x_red: bool = True,
                 bucket_sizes: Sequence[int] = (32, 64, 128, 256, 512, 1024),
                 max_x_rows: int = 8192,
                 split_threshold: Optional[int] = None,
                 streaming: bool = True, stream_roots: int = 1024,
                 prep: Union[PrepStream, PreparedMCE, None] = None,
                 engine: str = "perroot", lanes: int = 64):
        if engine not in ("perroot", "persistent", "auto"):
            raise ValueError(f"unknown engine {engine!r}")
        if cfg.backend not in BACKENDS:
            raise ValueError(f"unknown backend {cfg.backend!r} "
                             f"(expected one of {BACKENDS})")
        self.engine = engine
        self.lanes = lanes
        if mesh is None:
            # no axis_types kwarg: Auto is the default and the kwarg does
            # not exist on jax 0.4.x
            mesh = jax.make_mesh((len(jax.devices()),), ("data",))
            axis = "data"
        self.mesh = mesh
        self.axis = axis if isinstance(axis, (tuple, list)) else (axis,)
        self.axis = tuple(self.axis)
        self.n_shards = int(np.prod([mesh.shape[a] for a in self.axis]))
        self.chunk = chunk
        self.cfg = cfg
        self.ckpt_path = ckpt_path
        self.stats = {"host_pack_s": 0.0, "host_pack_overlap_s": 0.0,
                      "dispatch_s": 0.0, "device_wait_s": 0.0, "chunks": 0,
                      "engine_choices": {"perroot": 0, "persistent": 0}}
        self.last_counters: dict = {}   # COUNTER_KEYS of the last run()
        self.prep: Optional[PreparedMCE] = None
        self.stream: Optional[PrepStream] = None
        if prep is not None and g is not None:
            # a prepared stream fixes the graph and every prep-shaping
            # knob; accepting both would silently run against prep's graph
            raise ValueError("pass either a graph or prep=, not both")
        if isinstance(prep, PreparedMCE):
            self.prep = prep
        elif isinstance(prep, PrepStream):
            self.stream = prep
        else:
            if g is None:
                raise ValueError("need a graph or a prepared stream")
            # cache=False: a driver-owned stream is consumed once; caching
            # every packed bucket would recreate materialized-mode peak host
            # memory (pass a PrepStream(cache=True) for service-style reuse)
            stream = PrepStream(g, global_red=global_red, x_red=x_red,
                                bucket_sizes=bucket_sizes,
                                max_x_rows=max_x_rows,
                                split_threshold=split_threshold,
                                stream_roots=stream_roots if streaming else 0,
                                cache=not streaming)
            if streaming:
                self.stream = stream
            else:
                self.prep = stream.materialize()
        if self.stream is not None:
            st = self.stream
            self._schedule = dict(
                mode="stream", graph=_graph_fingerprint(st.g),
                stream_roots=st.stream_roots,
                bucket_sizes=list(st.bucket_sizes),
                split_threshold=st.split_threshold, global_red=st.global_red,
                x_red=st.x_red, max_x_rows=st.max_x_rows)
        else:
            self._schedule = dict(
                mode="materialized", n=self.prep.n,
                buckets=[[b.u_pad, b.num_roots] for b in self.prep.buckets])

    # ---- bucket source (streamed or materialized) ------------------------

    def _buckets(self) -> Iterator[RootBucket]:
        if self.stream is not None:
            return iter(self.stream)
        return iter(self.prep.buckets)

    def run(self, resume: bool = True) -> MCEResult:
        state = DriverCheckpoint()
        if self.stream is not None:
            self.stream.front()
            pre0 = len(self.stream.pre_reported)
        else:
            pre0 = len(self.prep.pre_reported)
        state.counters["cliques"] = pre0
        if resume and self.ckpt_path and os.path.exists(self.ckpt_path):
            state = DriverCheckpoint.load(self.ckpt_path)
            if state.schedule and state.schedule != self._schedule:
                raise ValueError(
                    "checkpoint schedule mismatch: the cursor was written "
                    f"against {state.schedule} but this driver runs "
                    f"{self._schedule}; resume with identical stream "
                    "parameters (device count may differ — that is the "
                    "elastic dimension)")
        state.schedule = self._schedule

        window = self.n_shards * self.chunk
        pending: Optional[Tuple[dict, int, int, int]] = None
        self._inflight_host = 0.0       # host work while `pending` flies
        src = self._buckets()
        b = -1
        while True:
            t0 = time.perf_counter()
            bucket = next(src, None)        # streaming: host packs here,
            dt = time.perf_counter() - t0   # overlapped with the device chunk
            self.stats["host_pack_s"] += dt
            if pending is not None:
                self._inflight_host += dt
            if bucket is None:
                break
            b += 1
            if b < state.bucket:
                continue                    # resume: replayed, not re-run
            # pad roots (remainder-flush pow2 padding) sit at the bucket's
            # tail; scheduling only the real prefix drops their no-op calls
            total = bucket.num_roots - bucket.n_pad
            if bucket.cost_order is None:   # memo: cached-bucket replays
                costs = estimate_costs(bucket)[:total]
                bucket.cost_order = canonical_order(costs)
                # same hardened skew as choose_engine's costs= path, so
                # memoized replays and fresh runs can't diverge (and an
                # all-zero/degenerate proxy can't explode to max/1e-12)
                bucket.cost_skew = (root_cost_skew(costs) if total else 1.0)
            order = bucket.cost_order
            eng_b, lanes_b = self.engine, self.lanes
            if self.engine == "auto":
                # the skew memo avoids re-deriving costs on cached replays;
                # the choice is a pure function of the bucket, so replays
                # and resumes land on the same engine
                eng_b, lanes_b = choose_engine(
                    skew=bucket.cost_skew, n_roots=total, lanes=self.lanes,
                    steal=bool(self.cfg.steal)
                    and self.cfg.backend in PIVOT_BACKENDS)
                self.stats["engine_choices"][eng_b] += 1
            done = state.roots_done if b == state.bucket else 0
            while done < total:
                hi = min(done + window, total)
                t0 = time.perf_counter()
                handle = self._run_chunk(bucket, order[done:hi],
                                         eng_b, lanes_b)
                dt = time.perf_counter() - t0   # gather/pad/upload: host work
                self.stats["dispatch_s"] += dt
                self.stats["host_pack_s"] += dt
                if pending is not None:
                    self._inflight_host += dt
                    self._settle(pending, state)
                pending = (*handle, b, hi)
                done = hi
        if pending is not None:
            self._settle(pending, state)

        late = len(self.stream.late_reported) if self.stream is not None else 0
        self.last_counters = dict(state.counters)
        return MCEResult(cliques=state.counters["cliques"] + late,
                         calls=state.counters["calls"],
                         branches=state.counters["branches"],
                         sum_px=state.counters["sum_px"],
                         pre_reported=pre0 + late,
                         iters_exhausted=state.counters.get("truncated", 0) > 0)

    # ---- chunk pipeline --------------------------------------------------

    def _run_chunk(self, bucket: RootBucket, window: np.ndarray,
                   engine: str, lanes: int):
        """Gather/pad + upload + *asynchronously* dispatch one chunk.

        `engine`/`lanes` are per-bucket: under engine="auto" the driver
        resolves them from the bucket's cost skew before each chunk.
        Returns (unrealized device counters, n_pad); the caller settles the
        previous chunk after dispatching this one, so host pack/upload of
        chunk k+1 overlaps device execution of chunk k."""
        slices = [window[s::self.n_shards] for s in range(self.n_shards)]
        pad_to = max(len(s) for s in slices)
        parts = [_shard_batch(bucket, s, pad_to) for s in slices]
        n_pad = sum(pad_to - len(s) for s in slices)
        stacked = (np.stack([p[i] for p in parts]) for i in range(5))
        sharding = NamedSharding(self.mesh, P(self.axis))
        a, p0, xr, xa, rz = (jax.device_put(t, sharding) for t in stacked)
        out = _sharded_counts(a, p0, xr, xa, rz, self.cfg, self.mesh,
                              self.axis, engine=engine, lanes=lanes)
        return out, n_pad

    def _settle(self, pending, state: DriverCheckpoint) -> None:
        """Block on a dispatched chunk, fold counters, checkpoint cursor."""
        out, n_pad, b, hi = pending
        t0 = time.perf_counter()
        out = jax.tree.map(lambda x: np.asarray(x), out)
        wait = time.perf_counter() - t0
        self.stats["device_wait_s"] += wait
        # credit in-flight host time as hidden only when the settle proves
        # the device was still busy; a zero wait means the device may have
        # finished early, so that host time gets no overlap credit (the
        # stat is a lower bound, never an optimistic one)
        if wait > 1e-4:
            self.stats["host_pack_overlap_s"] += self._inflight_host
        self._inflight_host = 0.0
        self.stats["chunks"] += 1
        # padded no-op roots contribute exactly one call each; remove them so
        # distributed counters match the single-host run bit-for-bit
        out["calls"] = out["calls"] - n_pad
        for k in COUNTER_KEYS:
            # .get: checkpoints written before a counter key existed resume
            # cleanly (the missing key starts from zero)
            state.counters[k] = state.counters.get(k, 0) + int(out[k])
        state.bucket, state.roots_done = b, hi
        if self.ckpt_path:
            state.save(self.ckpt_path)

    @property
    def overlap_fraction(self) -> float:
        """Share of host ingest time hidden behind device compute.

        Conservative: in-flight host time counts as hidden only for chunks
        whose settle still had to wait on the device (lower bound)."""
        total = self.stats["host_pack_s"]
        return self.stats["host_pack_overlap_s"] / total if total > 0 else 0.0
