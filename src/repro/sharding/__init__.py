from repro.sharding.lm import lm_sharding, LMSharding, opt_state_specs  # noqa: F401
from repro.sharding.gnn import gnn_sharding, GNNSharding  # noqa: F401
from repro.sharding.recsys import recsys_sharding, RecsysSharding  # noqa: F401
