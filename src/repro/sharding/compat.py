"""`shard_map` across jax versions.

Newer jax exposes `jax.shard_map(..., check_vma=...)`; older releases (like
this container's 0.4.x) only have `jax.experimental.shard_map.shard_map`
with the `check_rep` spelling of the same flag. Call sites import from here
so the rest of the codebase is version-agnostic.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
        return _shard_map(f, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_rep=check_vma)
