"""Sharding rules for the LM transformer stack (MaxText-style FSDP+TP+EP).

Mesh axes: optional "pod" (pure DP, gradient all-reduce crosses pods),
"data" (FSDP: weight storage sharded, gathered at use; batch parallel),
"model" (TP: heads / d_ff / vocab; EP for MoE experts when divisible).

Divisibility-driven choices per architecture:
  * attention heads sharded over "model" iff n_heads % model_size == 0
    (qwen3's 40 heads on a 16-way axis fall back to FSDP-only attention —
    batch-parallel compute, fully sharded storage);
  * kv projections: n_kv_heads (8 or 2) never divides 16 — stored
    FSDP-sharded on the D dim, replicated over "model" at use (GQA KV is
    small: D × kv × hd);
  * MoE experts sharded over "model" iff n_experts % model_size == 0
    (phi-3.5's 16 experts -> expert parallelism with all-to-all dispatch;
    mixtral's 8 experts -> per-expert tensor parallelism on d_ff);
  * vocab always sharded over "model" (all five vocabs divide 16).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.transformer import TransformerConfig


@dataclasses.dataclass
class LMSharding:
    mesh: Mesh
    dp: Tuple[str, ...]            # batch axes ("pod","data") or ("data",)
    fsdp: str                      # weight-storage axis
    tp: str                        # tensor/expert axis
    param_specs: dict
    batch_is_shardable: bool       # False for global_batch < dp size

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    def token_spec(self, batch: int) -> P:
        dp_size = 1
        for a in self.dp:
            dp_size *= self.mesh.shape[a]
        if batch % dp_size == 0:
            return P(self.dp, None)
        return P(None, None)

    def cache_spec(self, cfg: TransformerConfig, batch: int, cache_seq: int) -> dict:
        """KV cache (L, B, S, KV, dh) layout.

        Batch shards over dp AND the cache sequence dim over the tp axis
        when both divide (§Perf decode addendum: batch-only sharding left
        36-75 GiB/device caches on phi3.5/qwen3/command-r decode_32k —
        flash streaming over KV blocks is associative, so GSPMD partial
        reductions over the seq dim are exact). Falls back gracefully."""
        dp_size = 1
        for a in self.dp:
            dp_size *= self.mesh.shape[a]
        tp_size = self.mesh.shape[self.tp]
        if batch % dp_size == 0:
            if cache_seq % tp_size == 0:
                kv = P(None, self.dp, self.tp, None, None)
            else:
                kv = P(None, self.dp, None, None, None)
        elif cache_seq % tp_size == 0:
            # batch=1 long-context decode: shard the cache sequence dim
            kv = P(None, None, self.tp, None, None)
        else:
            kv = P(None, None, None, None, None)
        return dict(k=kv, v=kv, pos=P())


def lm_sharding(cfg: TransformerConfig, mesh: Mesh,
                dp_axes: Tuple[str, ...] = ("data",),
                fsdp_axis: str = "data", tp_axis: str = "model") -> LMSharding:
    tp_size = mesh.shape[tp_axis]
    fsdp = fsdp_axis
    tp = tp_axis

    heads_tp = cfg.n_heads % tp_size == 0
    experts_tp = cfg.is_moe and (cfg.n_experts % tp_size == 0)

    layer = dict(
        ln_attn=P(None, None),
        ln_ffn=P(None, None),
        wq=P(None, fsdp, tp, None) if heads_tp else P(None, fsdp, None, None),
        wk=P(None, fsdp, None, None),
        wv=P(None, fsdp, None, None),
        wo=P(None, tp, None, fsdp) if heads_tp else P(None, None, None, fsdp),
    )
    if cfg.qk_norm:
        layer["q_norm"] = P(None, None)
        layer["k_norm"] = P(None, None)
    if cfg.is_moe:
        layer.update(
            router=P(None, fsdp, None),
            w_in=(P(None, tp, fsdp, None) if experts_tp
                  else P(None, None, fsdp, tp)),
            w_gate=(P(None, tp, fsdp, None) if experts_tp
                    else P(None, None, fsdp, tp)),
            w_out=(P(None, tp, None, fsdp) if experts_tp
                   else P(None, None, tp, fsdp)),
        )
    else:
        layer.update(
            w_in=P(None, fsdp, tp),
            w_gate=P(None, fsdp, tp),
            w_out=P(None, tp, fsdp),
        )
    specs = dict(
        embed=P(tp, None),
        layers=layer,
        ln_final=P(None),
    )
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(fsdp, tp)
    return LMSharding(mesh=mesh, dp=dp_axes, fsdp=fsdp, tp=tp,
                      param_specs=specs, batch_is_shardable=True)


def opt_state_specs(sharding: LMSharding) -> dict:
    """AdamW moments inherit the param layout; step is replicated."""
    return dict(mu=sharding.param_specs, nu=sharding.param_specs, step=P())
