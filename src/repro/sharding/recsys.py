"""Sharding rules for the two-tower recsys stack.

Production layout (TorchRec/DLRM row-wise sharding, adapted to GSPMD):

* **Embedding tables row-shard over "model"** — the tables are the memory
  (user_id: 33.5M × 128 = 17GB fp32; item_id 8.6GB). Row sharding makes
  `jnp.take` lower to an all-to-all / gather exchange over the model axis
  — the recsys collective hot spot the roofline measures.
* **Batch shards over (pod, data)** — towers are data-parallel.
* **Tower MLPs replicate** (~2M params); the in-batch softmax logits
  matrix (B × B) shards rows over dp.
* ``retrieval_cand``: the 1M-candidate corpus shards over the data axes
  (each shard scores its slice, top-k is a tree reduce the compiler emits
  from lax.top_k over the sharded dim).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.recsys import TwoTowerConfig


@dataclasses.dataclass
class RecsysSharding:
    mesh: Mesh
    dp: Tuple[str, ...]
    table_axis: str
    param_specs: dict
    batch_specs: Dict[str, P]

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def recsys_sharding(cfg: TwoTowerConfig, mesh: Mesh, kind: str, meta: dict,
                    dp_axes: Tuple[str, ...] = ("data",),
                    table_axis: str = "model") -> RecsysSharding:
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]

    n_mlp = len(cfg.tower_mlp)
    mlp_spec = {f"w{i}": P(None, None) for i in range(n_mlp)} | \
               {f"b{i}": P(None) for i in range(n_mlp)}
    params = dict(
        user_id_table=P(table_axis, None),
        item_id_table=P(table_axis, None),
        geo_table=P(table_axis, None),
        tag_table=P(table_axis, None),
        user_mlp=mlp_spec,
        item_mlp=mlp_spec,
    )

    batch = meta.get("batch", 1)
    bspec = P(dp_axes) if batch % dp_size == 0 else P(None)
    row = bspec if batch % dp_size == 0 else P(None)
    specs = dict(
        user_id=row,
        user_geo=row,
        user_hist=P(*row, None),
        user_dense=P(*row, None),
    )
    if kind in ("train", "bulk"):
        specs |= dict(item_id=row, item_tags=P(*row, None))
    elif kind == "serve":
        specs |= dict(cand_emb=P(*row, None, None))
    elif kind == "retrieval":
        c = meta["n_candidates"]
        cspec = P(dp_axes) if c % dp_size == 0 else P(None)
        specs |= dict(cand_id=cspec, cand_tags=P(*cspec, None))
    return RecsysSharding(mesh=mesh, dp=dp_axes, table_axis=table_axis,
                          param_specs=params, batch_specs=specs)
