"""Sharding rules for the GNN stack (edge-parallel message passing).

GNN sharding regimes on the production mesh (DESIGN.md §5):

* **Edge parallelism** — the edge list (src, dst, edge_mask) and every
  edge-indexed tensor shard over the flattened data axes. `segment_sum`
  over sharded edges lowers to local scatter-add + all-reduce over the
  data axes (GSPMD emits the psum); this is the standard vertex-cut layout
  of large-graph systems (the all-reduce IS the aggregation boundary).
* **Node tensors** shard over data when the node count divides the axis
  (full-graph shapes), else replicate (tiny molecule graphs). Gathers
  h[src] from node-sharded h lower to all-gathers — the collective the
  roofline sees; molecule batches avoid it entirely by replication.
* **Params replicate** — every assigned GNN is < 10M params; FSDP would
  add latency for no memory win. (MACE state (N, 9, H) shards on N.)
* Triplet tensors (DimeNet) shard over data like edges.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class GNNSharding:
    mesh: Mesh
    dp: Tuple[str, ...]
    batch_specs: Dict[str, P]
    param_spec: P                    # uniform: replicated

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def gnn_sharding(mesh: Mesh, meta: dict,
                 dp_axes: Tuple[str, ...] = ("data",)) -> GNNSharding:
    dp_size = 1
    for a in dp_axes:
        dp_size *= mesh.shape[a]
    n_nodes = meta["n_nodes"]
    n_edges = meta["n_edges"]
    edge_spec = P(dp_axes) if n_edges % dp_size == 0 else P(None)
    node_spec = P(dp_axes) if n_nodes % dp_size == 0 else P(None)
    specs = dict(
        node_feat=P(*node_spec, None),
        positions=P(*node_spec, None),
        node_mask=node_spec,
        src=edge_spec,
        dst=edge_spec,
        edge_mask=edge_spec,
        graph_id=node_spec,
        targets=node_spec,
    )
    if meta.get("n_triplets"):
        t = meta["n_triplets"]
        trip_spec = P(dp_axes) if t % dp_size == 0 else P(None)
        specs["trip_kj"] = trip_spec
        specs["trip_ji"] = trip_spec
        specs["trip_mask"] = trip_spec
    return GNNSharding(mesh=mesh, dp=dp_axes, batch_specs=specs,
                       param_spec=P())
