from repro.kernels.bitset_ops import kernel, ops, ref  # noqa: F401
