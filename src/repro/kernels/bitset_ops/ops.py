"""Dispatching wrapper for bitset set algebra — the engine's ONLY entry point.

Layering contract (DESIGN.md §3): every module outside `kernels/bitset_ops`
that needs bitset algebra (AND+popcount sweeps, fused pivot-select, batched
X-subset tests) calls this module. Nothing outside this package may import
`ref` or `kernel` directly (enforced by tests/test_engine_layering.py), so
there is exactly one choke point to measure, swap, and accelerate.

On TPU the Pallas kernels are used for the 2-D shapes the engine's hot loop
emits; on CPU (this container) the pure-jnp ref is both the oracle and the
execution path (the Pallas kernels are validated in interpret mode by
tests). The engine's semantics never depend on the path taken.

Batching: the `ndim` guards below only catch *explicit* leading batch dims
(a caller handing in a 3-D array falls back to ref). They can NOT catch
`jax.vmap` — inside vmap the per-example tracer is 2-D, so the pallas path
is taken and jax's pallas batching rule prepends the batch axis to the
kernel grid. That IS the engine's real call pattern (`loop.run_bucket`
vmaps `run_root`), so the kernels are written batch-safe (no `program_id`
reads, no revisited output blocks — see kernel.py) and vmap parity is
tested per kernel in tests/test_bitset_ops_dispatch.py.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.bitset_ops import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def popcount_words(bits: jnp.ndarray) -> jnp.ndarray:
    """Total set-bit count over the trailing word axis: (..., W) -> (...)."""
    return jnp.sum(jax.lax.population_count(bits), axis=-1).astype(jnp.int32)


def and_popcount_rows(rows: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """popcount(rows & mask) per row; dispatches pallas on TPU, jnp elsewhere.

    Explicit leading batch dims take the ref path; under jax.vmap the
    tracer is 2-D so the pallas path is taken and the pallas_call itself
    is batched (see module docstring).
    """
    if _on_tpu() and rows.ndim == 2:
        return kernel.and_popcount_rows(rows, mask, interpret=False)
    return ref.and_popcount_rows(rows, mask)


def and_rows(rows: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """rows & mask broadcast over the row axis (materialised intersection)."""
    return ref.and_rows(rows, mask)


def and_popcount_argmax(rows: jnp.ndarray, mask: jnp.ndarray,
                        valid: Optional[jnp.ndarray] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused pivot-select: (first-argmax, max) of popcount(rows & mask) over
    `valid` rows; invalid rows score -1. On TPU the AND+popcount+masking
    fuse in one Pallas pass and the argmax runs in jnp on the scores."""
    if _on_tpu() and rows.ndim == 2 and valid is not None:
        return kernel.and_popcount_argmax(rows, mask, valid, interpret=False)
    return ref.and_popcount_argmax(rows, mask, valid)


def and_popcount_many(rows: jnp.ndarray, masks: jnp.ndarray) -> jnp.ndarray:
    """out[m, k] = popcount(rows[k] & masks[m]) — one row matrix against an
    (M, W) batch of masks (the X-subset maximality-test shape)."""
    if _on_tpu() and rows.ndim == 2 and masks.ndim == 2:
        return kernel.and_popcount_many(rows, masks, interpret=False)
    return ref.and_popcount_many(rows, masks)


def clique_counts(rows: jnp.ndarray, mask: jnp.ndarray, in_p: jnp.ndarray,
                  in_x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused early-termination census (hybrid backend): (n_full, n_dom).

    n_full = #{k : in_p[k] ∧ popcount(rows[k] & mask) == popcount(mask)−1},
    n_dom  = #{k : in_x[k] ∧ popcount(rows[k] & mask) == popcount(mask)}.
    With rows = adjacency ∪ X0 rows and mask = P: P induces a clique iff
    n_full == |P|, and some forbidden vertex dominates P iff n_dom > 0 —
    one row-vs-mask batch popcount decides emit-and-pop vs recurse."""
    if _on_tpu() and rows.ndim == 2:
        return kernel.clique_counts(rows, mask, in_p, in_x, interpret=False)
    return ref.clique_counts(rows, mask, in_p, in_x)


# VMEM stack-window geometry (DESIGN.md §2.6/§3): the fused dfs_step_window
# kernel keeps this many stack frames resident in VMEM scratch, whose
# literal scratch shapes bound the eligible problem size (words ≤ 128 ⇒
# U ≤ 4096 vertices, X0 rows ≤ 4096). Shapes outside the bounds — and every
# non-TPU backend — take the jnp ref path with the same contract.
WINDOW_FRAMES = 8
WINDOW_MAX_WORDS = 128
WINDOW_MAX_XROWS = 4096


def dfs_step_window(a: jnp.ndarray, x_rows: jnp.ndarray, eye: jnp.ndarray,
                    alive0: jnp.ndarray, winP: jnp.ndarray,
                    winB: jnp.ndarray, winXp: jnp.ndarray,
                    winRb: jnp.ndarray, winrsz: jnp.ndarray,
                    dloc: jnp.ndarray, steps: int):
    """Up to `steps` fused BK frame-steps over a resident T-frame stack
    window (pivot backend, dynamic reduction off, counting only).

    Returns the updated window plus ctl (8,) int32 = [dloc', calls,
    branches, sum_px, cliques, steps_done, 0, 0]; stops early on window
    underflow (dloc' == −1) or overflow (a branch step at the top slot).
    The engine's `run_root_windowed` owns the HBM stack and the
    spill/refill around each call — see ref.dfs_step_window for the full
    contract."""
    if (_on_tpu() and a.ndim == 2 and winP.shape[0] == WINDOW_FRAMES
            and a.shape[1] <= WINDOW_MAX_WORDS
            and x_rows.shape[0] <= WINDOW_MAX_XROWS):
        return kernel.dfs_step_window(a, x_rows, eye, alive0, winP, winB,
                                      winXp, winRb, winrsz, dloc,
                                      steps=steps, interpret=False)
    return ref.dfs_step_window(a, x_rows, eye, alive0, winP, winB, winXp,
                               winRb, winrsz, dloc, steps)


def dfs_step_window_lanes(a: jnp.ndarray, x_rows: jnp.ndarray,
                          eye: jnp.ndarray, alive0: jnp.ndarray,
                          winP: jnp.ndarray, winB: jnp.ndarray,
                          winXp: jnp.ndarray, winRb: jnp.ndarray,
                          winrsz: jnp.ndarray, dloc: jnp.ndarray,
                          steps: int):
    """Lane-batched window walk for the persistent engine: each of the L
    lanes runs up to `steps` fused BK frame-steps over its own resident
    T-frame stack window (pivot backend, dynamic reduction off, counting
    only — same eligibility as `dfs_step_window`).

    a: (L, U, W); x_rows: (L, XC, W); eye: (U, W) shared; alive0:
    (L, XC); windows (L, T, W); winrsz (L, T); dloc (L,) with dloc < 0
    marking a dead lane (no-op, zero deltas). Returns the updated
    windows plus ctl (L, 8) int32 = [dloc', calls, branches, sum_px,
    cliques, steps_done, 0, 0] per lane. On TPU this is one grid-over-
    lanes Pallas kernel (per-lane VMEM scratch window, per-lane scalars
    in 2-D SMEM); elsewhere a vmapped jnp window walk with the same
    contract."""
    if (_on_tpu() and a.ndim == 3 and winP.shape[1] == WINDOW_FRAMES
            and a.shape[2] <= WINDOW_MAX_WORDS
            and x_rows.shape[1] <= WINDOW_MAX_XROWS):
        return kernel.dfs_step_window_lanes(a, x_rows, eye, alive0, winP,
                                            winB, winXp, winRb, winrsz,
                                            dloc, steps=steps,
                                            interpret=False)
    return ref.dfs_step_window_lanes(a, x_rows, eye, alive0, winP, winB,
                                     winXp, winRb, winrsz, dloc, steps)


def frame_step(rows: jnp.ndarray, p: jnp.ndarray, xp: jnp.ndarray,
               wrow: jnp.ndarray):
    """Fused BK frame step: (childp, childxp, deg, partner).

    childp = p & wrow, childxp = xp & wrow, deg[k] = popcount(rows[k] &
    childp), partner[k] = the surviving bit index where deg[k] == 1 (the
    Lemma-7 partner; garbage elsewhere). One kernel pass replaces the
    engine's separate child-AND, degree-sweep, and partner-extraction
    passes over the (K, W) adjacency."""
    if _on_tpu() and rows.ndim == 2:
        return kernel.frame_step(rows, p, xp, wrow, interpret=False)
    return ref.frame_step(rows, p, xp, wrow)
