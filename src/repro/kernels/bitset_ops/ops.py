"""Dispatching wrapper for bitset ops.

On TPU the Pallas kernel is used; on CPU (this container) the pure-jnp ref is
both the oracle and the execution path (the Pallas kernel is validated in
interpret mode by tests). The engine's semantics never depend on the path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.bitset_ops import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def and_popcount_rows(rows: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """popcount(rows & mask) per row; dispatches pallas on TPU, jnp elsewhere.

    Supports leading batch dims via the ref path; the pallas path handles the
    2-D case that the engine's hot loop emits.
    """
    if _on_tpu() and rows.ndim == 2:
        return kernel.and_popcount_rows(rows, mask, interpret=False)
    return ref.and_popcount_rows(rows, mask)
