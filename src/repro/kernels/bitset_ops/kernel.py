"""Pallas TPU kernels: fused AND + popcount set algebra over bitset rows.

Three fused primitives back the MCE engine's inner loop (see DESIGN.md §3):

* `and_popcount_rows`  — out[k] = popcount(rows[k] & mask); the deg_P sweep.
* `and_popcount_argmax` — the pivot-select: AND + popcount + running argmax
  in one VMEM pass, so pivot scoring never materialises the (K,) score
  vector in HBM.
* `and_popcount_many`  — one row matrix against an (M, W) batch of masks;
  the X-subset maximality test shape.

All are tiled so each grid step keeps a (BK, W) row tile + the mask(s) in
VMEM. On TPU the AND+popcount pipeline runs on the VPU (8×128 lanes); W is
padded to the 128-lane boundary by the caller so loads are aligned.

These kernels exist because the ops execute once per BK tree node over the
whole row matrix — the paper's measurement that set intersections are 73.6%
of MCE time maps exactly onto this module.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_K = 256
DEFAULT_BLOCK_M = 256


def _and_popcount_kernel(rows_ref, mask_ref, out_ref):
    rows = rows_ref[...]                      # (BK, W) uint32
    mask = mask_ref[...]                      # (1, W) uint32
    anded = jnp.bitwise_and(rows, mask)
    out_ref[...] = jnp.sum(
        jax.lax.population_count(anded).astype(jnp.int32), axis=1, keepdims=True
    )


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def and_popcount_rows(rows: jnp.ndarray, mask: jnp.ndarray,
                      block_k: int = DEFAULT_BLOCK_K,
                      interpret: bool = True) -> jnp.ndarray:
    """Pallas path. rows: (K, W) uint32, mask: (W,) uint32 -> (K,) int32."""
    k, w = rows.shape
    bk = min(block_k, k)
    # pad K to a multiple of the block
    k_pad = -(-k // bk) * bk
    if k_pad != k:
        rows = jnp.pad(rows, ((0, k_pad - k), (0, 0)))
    grid = (k_pad // bk,)
    out = pl.pallas_call(
        _and_popcount_kernel,
        out_shape=jax.ShapeDtypeStruct((k_pad, 1), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, w), lambda i: (i, 0)),      # row tile in VMEM
            pl.BlockSpec((1, w), lambda i: (0, 0)),       # mask replicated
        ],
        out_specs=pl.BlockSpec((bk, 1), lambda i: (i, 0)),
        interpret=interpret,
    )(rows, mask[None, :])
    return out[:k, 0]


def _and_popcount_argmax_kernel(rows_ref, mask_ref, valid_ref,
                                best_ref, idx_ref, *, block_k: int):
    i = pl.program_id(0)
    rows = rows_ref[...]                      # (BK, W) uint32
    mask = mask_ref[...]                      # (1, W) uint32
    valid = valid_ref[...]                    # (BK, 1) int32 (0/1)
    counts = jnp.sum(
        jax.lax.population_count(jnp.bitwise_and(rows, mask)).astype(jnp.int32),
        axis=1, keepdims=True)                # (BK, 1)
    scores = jnp.where(valid != 0, counts, jnp.int32(-1))
    tile_best = jnp.max(scores)
    # first-max within the tile, matching jnp.argmax tie-breaking
    hit = scores[:, 0] == tile_best
    pos = jax.lax.broadcasted_iota(jnp.int32, scores.shape, 0)[:, 0]
    tile_arg = jnp.min(jnp.where(hit, pos, jnp.int32(block_k))) + i * block_k

    # grid steps are sequential on TPU: accumulate a running (best, argmax)
    # in the revisited (1, 1) output block; strict `>` keeps the first max.
    @pl.when(i == 0)
    def _init():
        best_ref[0, 0] = tile_best
        idx_ref[0, 0] = tile_arg

    @pl.when((i > 0) & (tile_best > best_ref[0, 0]))
    def _update():
        best_ref[0, 0] = tile_best
        idx_ref[0, 0] = tile_arg


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def and_popcount_argmax(rows: jnp.ndarray, mask: jnp.ndarray,
                        valid: jnp.ndarray,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = True):
    """Fused pivot-select. rows: (K, W) uint32, mask: (W,) uint32,
    valid: (K,) bool -> (idx int32, best int32) with invalid rows scoring -1.
    """
    k, w = rows.shape
    bk = min(block_k, k)
    k_pad = -(-k // bk) * bk
    valid_i = valid.astype(jnp.int32)
    if k_pad != k:
        rows = jnp.pad(rows, ((0, k_pad - k), (0, 0)))
        valid_i = jnp.pad(valid_i, (0, k_pad - k))   # pad rows are invalid
    grid = (k_pad // bk,)
    best, idx = pl.pallas_call(
        functools.partial(_and_popcount_argmax_kernel, block_k=bk),
        out_shape=(jax.ShapeDtypeStruct((1, 1), jnp.int32),
                   jax.ShapeDtypeStruct((1, 1), jnp.int32)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, w), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
            pl.BlockSpec((bk, 1), lambda i: (i, 0)),
        ],
        out_specs=(pl.BlockSpec((1, 1), lambda i: (0, 0)),
                   pl.BlockSpec((1, 1), lambda i: (0, 0))),
        interpret=interpret,
    )(rows, mask[None, :], valid_i[:, None])
    return idx[0, 0], best[0, 0]


def _and_popcount_many_kernel(rows_ref, masks_ref, out_ref):
    rows = rows_ref[...]                      # (BK, W) uint32
    masks = masks_ref[...]                    # (BM, W) uint32
    anded = jnp.bitwise_and(rows[None, :, :], masks[:, None, :])
    out_ref[...] = jnp.sum(
        jax.lax.population_count(anded).astype(jnp.int32), axis=2)


@functools.partial(jax.jit, static_argnames=("block_m", "block_k",
                                             "interpret"))
def and_popcount_many(rows: jnp.ndarray, masks: jnp.ndarray,
                      block_m: int = DEFAULT_BLOCK_M,
                      block_k: int = DEFAULT_BLOCK_K,
                      interpret: bool = True) -> jnp.ndarray:
    """Batched-mask path. rows: (K, W), masks: (M, W) -> (M, K) int32
    with out[m, k] = popcount(rows[k] & masks[m])."""
    k, w = rows.shape
    m, wm = masks.shape
    assert w == wm, f"word-width mismatch {w} vs {wm}"
    bk = min(block_k, k)
    bm = min(block_m, m)
    # VMEM budget: the kernel body materialises (BM, BK, W) uint32 + int32
    # intermediates (8 B/elem); cap the tile at ~4 MiB so wide-W buckets
    # (e.g. W=32 at 256×256 blocks) don't blow VMEM on the compiled path.
    max_elems = 1 << 19
    while bm * bk * w > max_elems and bk > 8:
        bk = -(-bk // 2)
    while bm * bk * w > max_elems and bm > 8:
        bm = -(-bm // 2)
    k_pad = -(-k // bk) * bk
    m_pad = -(-m // bm) * bm
    if k_pad != k:
        rows = jnp.pad(rows, ((0, k_pad - k), (0, 0)))
    if m_pad != m:
        masks = jnp.pad(masks, ((0, m_pad - m), (0, 0)))
    grid = (m_pad // bm, k_pad // bk)
    out = pl.pallas_call(
        _and_popcount_many_kernel,
        out_shape=jax.ShapeDtypeStruct((m_pad, k_pad), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, w), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, w), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        interpret=interpret,
    )(rows, masks)
    return out[:m, :k]
