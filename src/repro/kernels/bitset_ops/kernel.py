"""Pallas TPU kernel: fused AND + popcount over bitset rows.

Computes out[k] = popcount(rows[k] & mask) for a (K, W) uint32 row matrix and
a (W,) mask, tiled so each grid step keeps a (BK, W) row tile + the mask in
VMEM. On TPU the AND+popcount pipeline runs on the VPU (8×128 lanes); W is
padded to the 128-lane boundary by the caller so loads are aligned.

This is the engine's inner-loop op (`deg_P(u)` for all u, pivot scoring,
X-subset tests). The kernel exists because the op is executed once per BK
tree node over the whole row matrix — the paper's measurement that set
intersections are 73.6% of MCE time maps exactly onto this kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_K = 256


def _and_popcount_kernel(rows_ref, mask_ref, out_ref):
    rows = rows_ref[...]                      # (BK, W) uint32
    mask = mask_ref[...]                      # (1, W) uint32
    anded = jnp.bitwise_and(rows, mask)
    out_ref[...] = jnp.sum(
        jax.lax.population_count(anded).astype(jnp.int32), axis=1, keepdims=True
    )


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def and_popcount_rows(rows: jnp.ndarray, mask: jnp.ndarray,
                      block_k: int = DEFAULT_BLOCK_K,
                      interpret: bool = True) -> jnp.ndarray:
    """Pallas path. rows: (K, W) uint32, mask: (W,) uint32 -> (K,) int32."""
    k, w = rows.shape
    bk = min(block_k, k)
    # pad K to a multiple of the block
    k_pad = -(-k // bk) * bk
    if k_pad != k:
        rows = jnp.pad(rows, ((0, k_pad - k), (0, 0)))
    grid = (k_pad // bk,)
    out = pl.pallas_call(
        _and_popcount_kernel,
        out_shape=jax.ShapeDtypeStruct((k_pad, 1), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, w), lambda i: (i, 0)),      # row tile in VMEM
            pl.BlockSpec((1, w), lambda i: (0, 0)),       # mask replicated
        ],
        out_specs=pl.BlockSpec((bk, 1), lambda i: (i, 0)),
        interpret=interpret,
    )(rows, mask[None, :])
    return out[:k, 0]
