"""Pallas TPU kernels: fused AND + popcount set algebra over bitset rows.

Three fused primitives back the MCE engine's inner loop (see DESIGN.md §3):

* `and_popcount_rows`  — out[k] = popcount(rows[k] & mask); the deg_P sweep.
* `and_popcount_argmax` — the pivot-select: AND + popcount + validity
  masking fused in one VMEM pass over the row tile; the final (K,)→scalar
  argmax is a jnp reduction on the (K, 1) int32 scores (negligible traffic
  next to the (K, W) row load the kernel fuses away).
* `and_popcount_many`  — one row matrix against an (M, W) batch of masks;
  the X-subset maximality test shape.
* `frame_step`         — fused BK child-set + degree + Lemma-7 partner pass.
* `clique_counts`      — the hybrid backend's early-termination census:
  per-row AND+popcount against P plus the is-it-|P|/|P|−1 comparisons fused
  in one pass; the two scalar counts reduce in jnp outside.

All are tiled so each grid step keeps a (BK, W) row tile + the mask(s) in
VMEM. On TPU the AND+popcount pipeline runs on the VPU (8×128 lanes); W is
padded to the 128-lane boundary by the caller so loads are aligned.

Two structural rules keep the kernels correct and compilable beyond the
interpret-mode tests:

* **Batch-safety.** The engine reaches these kernels under `jax.vmap`
  (`loop.run_bucket` vmaps `run_root`; per-example tracers are 2-D so the
  ops dispatcher takes the pallas path and the pallas batching rule
  prepends the batch axis to the grid). Kernel bodies therefore must not
  read `pl.program_id` or accumulate across grid steps in revisited output
  blocks — under vmap `program_id(0)` becomes the batch index and such
  state goes wrong silently. Each grid step writes only its own block;
  cross-tile reductions happen in jnp outside the `pallas_call`.
  Enforced by the vmap parity tests in tests/test_bitset_ops_dispatch.py.
* **Mosaic-lowerable shapes/ops.** Word-axis popcount sums accumulate in
  float32 (Mosaic has no integer-axis reductions; exact for counts < 2^24,
  i.e. any W < 2^19) and every block keeps its last two dims (8, 128)-
  divisible or equal to the full array dims. Enforced without hardware by
  tests/test_kernels_tpu_lowering.py, which lowers every kernel (plain and
  vmapped) for a TPU target via jax.export.

These kernels exist because the ops execute once per BK tree node over the
whole row matrix — the paper's measurement that set intersections are 73.6%
of MCE time maps exactly onto this module.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK_K = 256
DEFAULT_BLOCK_M = 256


def _and_popcount_kernel(rows_ref, mask_ref, out_ref):
    rows = rows_ref[...]                      # (BK, W) uint32
    mask = mask_ref[...]                      # (1, W) uint32
    anded = jnp.bitwise_and(rows, mask)
    pc = jax.lax.population_count(anded).astype(jnp.float32)
    out_ref[...] = jnp.sum(pc, axis=1, keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def and_popcount_rows(rows: jnp.ndarray, mask: jnp.ndarray,
                      block_k: int = DEFAULT_BLOCK_K,
                      interpret: bool = True) -> jnp.ndarray:
    """Pallas path. rows: (K, W) uint32, mask: (W,) uint32 -> (K,) int32."""
    k, w = rows.shape
    bk = min(block_k, k)
    # pad K to a multiple of the block
    k_pad = -(-k // bk) * bk
    if k_pad != k:
        rows = jnp.pad(rows, ((0, k_pad - k), (0, 0)))
    grid = (k_pad // bk,)
    out = pl.pallas_call(
        _and_popcount_kernel,
        out_shape=jax.ShapeDtypeStruct((k_pad, 1), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, w), lambda i: (i, 0)),      # row tile in VMEM
            pl.BlockSpec((1, w), lambda i: (0, 0)),       # mask replicated
        ],
        out_specs=pl.BlockSpec((bk, 1), lambda i: (i, 0)),
        interpret=interpret,
    )(rows, mask[None, :])
    return out[:k, 0]


def _and_popcount_argmax_kernel(rows_ref, mask_ref, valid_ref, scores_ref):
    rows = rows_ref[...]                      # (BK, W) uint32
    mask = mask_ref[...]                      # (1, W) uint32
    valid = valid_ref[...]                    # (BK, 1) int32 (0/1)
    pc = jax.lax.population_count(jnp.bitwise_and(rows, mask))
    counts = jnp.sum(pc.astype(jnp.float32), axis=1,
                     keepdims=True).astype(jnp.int32)   # (BK, 1)
    scores_ref[...] = jnp.where(valid != 0, counts, jnp.int32(-1))


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def and_popcount_argmax(rows: jnp.ndarray, mask: jnp.ndarray,
                        valid: jnp.ndarray,
                        block_k: int = DEFAULT_BLOCK_K,
                        interpret: bool = True):
    """Fused pivot-select. rows: (K, W) uint32, mask: (W,) uint32,
    valid: (K,) bool -> (idx int32, best int32) with invalid rows scoring -1.

    The kernel fuses AND + popcount + validity masking per row tile; the
    argmax over the resulting (K,) scores runs in jnp outside the
    `pallas_call`. No grid step carries state (no `program_id`, no
    revisited output blocks), so vmap's batched-grid lowering — the
    engine's real call pattern — stays correct; jnp.argmax tie-breaking
    (first max wins, all-invalid -> (0, -1)) matches the ref by
    construction.
    """
    k, w = rows.shape
    bk = min(block_k, k)
    k_pad = -(-k // bk) * bk
    valid_i = valid.astype(jnp.int32)
    if k_pad != k:
        rows = jnp.pad(rows, ((0, k_pad - k), (0, 0)))
        valid_i = jnp.pad(valid_i, (0, k_pad - k))   # pad rows are invalid
    grid = (k_pad // bk,)
    scores = pl.pallas_call(
        _and_popcount_argmax_kernel,
        out_shape=jax.ShapeDtypeStruct((k_pad, 1), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, w), lambda i: (i, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
            pl.BlockSpec((bk, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bk, 1), lambda i: (i, 0)),
        interpret=interpret,
    )(rows, mask[None, :], valid_i[:, None])[:k, 0]
    return jnp.argmax(scores).astype(jnp.int32), jnp.max(scores)


def _frame_step_kernel(rows_ref, p_ref, xp_ref, wrow_ref,
                       childp_ref, childxp_ref, deg_ref, partner_ref):
    rows = rows_ref[...]                      # (BK, W) uint32
    p = p_ref[...]                            # (1, W) uint32
    xp = xp_ref[...]                          # (1, W) uint32
    wrow = wrow_ref[...]                      # (1, W) uint32
    childp = jnp.bitwise_and(p, wrow)
    # (1, W) output blocks are revisited by every grid step but each write
    # is the same full-block value (idempotent), so the batched-grid
    # lowering under vmap stays correct — no cross-step accumulation.
    childp_ref[...] = childp
    childxp_ref[...] = jnp.bitwise_and(xp, wrow)
    anded = jnp.bitwise_and(rows, childp)
    pc = jax.lax.population_count(anded).astype(jnp.float32)
    deg_ref[...] = jnp.sum(pc, axis=1, keepdims=True).astype(jnp.int32)
    # per-word lowest-set-bit position; summed contributions are exact when
    # exactly one bit survives (the Lemma-7 partner), garbage otherwise
    low = jnp.bitwise_and(anded, jnp.uint32(0) - anded)
    pos = jax.lax.population_count(low - jnp.uint32(1)).astype(jnp.float32)
    wi = jax.lax.broadcasted_iota(jnp.float32, anded.shape, 1) * 32.0
    contrib = jnp.where(anded != 0, wi + pos, 0.0)
    partner_ref[...] = jnp.sum(contrib, axis=1,
                               keepdims=True).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def frame_step(rows: jnp.ndarray, p: jnp.ndarray, xp: jnp.ndarray,
               wrow: jnp.ndarray, block_k: int = DEFAULT_BLOCK_K,
               interpret: bool = True):
    """Fused BK frame step (see ref.frame_step for the contract).

    rows: (K, W) uint32, p/xp/wrow: (W,) uint32 ->
    (childp (W,), childxp (W,), deg (K,) int32, partner (K,) int32).

    One VMEM pass per row tile fuses the child-set ANDs, the AND+popcount
    degree sweep, and the Lemma-7 partner extraction that the engine's hot
    loop previously issued as separate passes over A.
    """
    k, w = rows.shape
    bk = min(block_k, k)
    k_pad = -(-k // bk) * bk
    if k_pad != k:
        rows = jnp.pad(rows, ((0, k_pad - k), (0, 0)))
    grid = (k_pad // bk,)
    childp, childxp, deg, partner = pl.pallas_call(
        _frame_step_kernel,
        out_shape=(jax.ShapeDtypeStruct((1, w), jnp.uint32),
                   jax.ShapeDtypeStruct((1, w), jnp.uint32),
                   jax.ShapeDtypeStruct((k_pad, 1), jnp.int32),
                   jax.ShapeDtypeStruct((k_pad, 1), jnp.int32)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, w), lambda i: (i, 0)),      # row tile in VMEM
            pl.BlockSpec((1, w), lambda i: (0, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
            pl.BlockSpec((1, w), lambda i: (0, 0)),
        ],
        out_specs=(pl.BlockSpec((1, w), lambda i: (0, 0)),
                   pl.BlockSpec((1, w), lambda i: (0, 0)),
                   pl.BlockSpec((bk, 1), lambda i: (i, 0)),
                   pl.BlockSpec((bk, 1), lambda i: (i, 0))),
        interpret=interpret,
    )(rows, p[None, :], xp[None, :], wrow[None, :])
    return childp[0], childxp[0], deg[:k, 0], partner[:k, 0]


def _clique_counts_kernel(rows_ref, mask_ref, inp_ref, inx_ref,
                          full_ref, dom_ref):
    rows = rows_ref[...]                      # (BK, W) uint32
    mask = mask_ref[...]                      # (1, W) uint32
    inp = inp_ref[...]                        # (BK, 1) int32 (0/1)
    inx = inx_ref[...]                        # (BK, 1) int32 (0/1)
    anded = jnp.bitwise_and(rows, mask)
    pc = jnp.sum(jax.lax.population_count(anded).astype(jnp.float32),
                 axis=1, keepdims=True)       # (BK, 1) f32 (exact < 2^24)
    msize = jnp.sum(jax.lax.population_count(mask).astype(jnp.float32),
                    axis=1, keepdims=True)    # (1, 1)
    # per-row 0/1 flags; the two scalar counts reduce in jnp outside the
    # pallas_call (batch-safety: each grid step writes only its own block)
    full_ref[...] = ((inp != 0) & (pc == msize - 1.0)).astype(jnp.int32)
    dom_ref[...] = ((inx != 0) & (pc == msize)).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def clique_counts(rows: jnp.ndarray, mask: jnp.ndarray, in_p: jnp.ndarray,
                  in_x: jnp.ndarray, block_k: int = DEFAULT_BLOCK_K,
                  interpret: bool = True):
    """Fused early-termination census (see ref.clique_counts for the
    contract). rows: (K, W) uint32, mask: (W,) uint32, in_p/in_x: (K,) bool
    -> (n_full, n_dom) int32 scalars.

    One VMEM pass per row tile fuses the AND+popcount sweep against P with
    the ==|P| / ==|P|−1 comparisons; the kernel emits per-row 0/1 flags and
    the final counts are jnp sums over the (K,) flag vectors (negligible
    traffic next to the fused-away (K, W) row load, and keeps every grid
    step independent — vmap's batched-grid lowering stays correct)."""
    k, w = rows.shape
    bk = min(block_k, k)
    k_pad = -(-k // bk) * bk
    inp_i = in_p.astype(jnp.int32)
    inx_i = in_x.astype(jnp.int32)
    if k_pad != k:
        # pad rows are all-zero AND carry 0 selectors, so they never count
        rows = jnp.pad(rows, ((0, k_pad - k), (0, 0)))
        inp_i = jnp.pad(inp_i, (0, k_pad - k))
        inx_i = jnp.pad(inx_i, (0, k_pad - k))
    grid = (k_pad // bk,)
    full, dom = pl.pallas_call(
        _clique_counts_kernel,
        out_shape=(jax.ShapeDtypeStruct((k_pad, 1), jnp.int32),
                   jax.ShapeDtypeStruct((k_pad, 1), jnp.int32)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, w), lambda i: (i, 0)),      # row tile in VMEM
            pl.BlockSpec((1, w), lambda i: (0, 0)),       # mask replicated
            pl.BlockSpec((bk, 1), lambda i: (i, 0)),
            pl.BlockSpec((bk, 1), lambda i: (i, 0)),
        ],
        out_specs=(pl.BlockSpec((bk, 1), lambda i: (i, 0)),
                   pl.BlockSpec((bk, 1), lambda i: (i, 0))),
        interpret=interpret,
    )(rows, mask[None, :], inp_i[:, None], inx_i[:, None])
    return (jnp.sum(full[:k, 0]).astype(jnp.int32),
            jnp.sum(dom[:k, 0]).astype(jnp.int32))


def _and_popcount_many_kernel(rows_ref, masks_ref, out_ref):
    rows = rows_ref[...]                      # (BK, W) uint32
    masks = masks_ref[...]                    # (BM, W) uint32
    anded = jnp.bitwise_and(rows[None, :, :], masks[:, None, :])
    pc = jax.lax.population_count(anded).astype(jnp.float32)
    out_ref[...] = jnp.sum(pc, axis=2).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_m", "block_k",
                                             "interpret"))
def and_popcount_many(rows: jnp.ndarray, masks: jnp.ndarray,
                      block_m: int = DEFAULT_BLOCK_M,
                      block_k: int = DEFAULT_BLOCK_K,
                      interpret: bool = True) -> jnp.ndarray:
    """Batched-mask path. rows: (K, W), masks: (M, W) -> (M, K) int32
    with out[m, k] = popcount(rows[k] & masks[m])."""
    k, w = rows.shape
    m, wm = masks.shape
    assert w == wm, f"word-width mismatch {w} vs {wm}"
    bk = min(block_k, k)
    bm = min(block_m, m)
    # VMEM budget: the kernel body materialises (BM, BK, W) uint32 + f32
    # intermediates (8 B/elem); cap the tile at ~4 MiB so wide-W buckets
    # (e.g. W=32 at 256×256 blocks) don't blow VMEM on the compiled path.
    # Shrink bm first (Mosaic needs a shrunk second-minor block dim to stay
    # 8-divisible), then bk in 128-lane multiples (the out block's last dim
    # must be 128-divisible unless it equals the padded array dim) — shapes
    # that trip this clamp are covered by test_kernels_tpu_lowering.py.
    max_elems = 1 << 19
    while bm * bk * w > max_elems and bm > 8:
        bm = max(8, (bm // 2 + 7) & ~7)
    while bm * bk * w > max_elems and bk > 128:
        bk = max(128, (bk // 2 + 127) & ~127)
    k_pad = -(-k // bk) * bk
    m_pad = -(-m // bm) * bm
    if k_pad != k:
        rows = jnp.pad(rows, ((0, k_pad - k), (0, 0)))
    if m_pad != m:
        masks = jnp.pad(masks, ((0, m_pad - m), (0, 0)))
    grid = (m_pad // bm, k_pad // bk)
    out = pl.pallas_call(
        _and_popcount_many_kernel,
        out_shape=jax.ShapeDtypeStruct((m_pad, k_pad), jnp.int32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, w), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, w), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bk), lambda i, j: (i, j)),
        interpret=interpret,
    )(rows, masks)
    return out[:m, :k]


# ===========================================================================
# dfs_step_window — K fused BK frame-steps with the top-T stack frames in
# VMEM scratch (DESIGN.md §2.6/§3)
# ===========================================================================

# Literal VMEM scratch geometry for the stack window. The scratch shapes
# must be (8, 128)-aligned literals (mce_lint R3): 8 frames × 128 words
# bounds the eligible problem at U ≤ 4096 vertices per root universe.
WINDOW_FRAMES = 8
WINDOW_WORDS = 128


def _window_walk(a, xr, eye, alive0, read_a, read_x,
                 sp_ref, sb_ref, sxp_ref, srb_ref, srsz_ref,
                 t, w, u, xc, d0, steps):
    """Shared fori body of the window kernels: up to `steps` masked DFS
    frame-steps over the VMEM scratch window.

    `a`/`xr`/`eye`/`alive0` are the materialized per-invocation constants;
    `read_a(i)`/`read_x(i)` load one (1, W) row via a ref dynamic slice
    (the per-root and lane-batched kernels differ only in ref rank, which
    these closures absorb). Every reduction accumulates in f32 (Mosaic has
    no integer-axis reductions; counts < 2^24 are exact) and
    argmax/first-bit selections use the f32 min/max-of-masked-iota idiom
    so tie-breaking matches jnp.argmax (first occurrence wins)
    bit-for-bit. Returns the final (dloc, done, calls, branches, sum_px,
    cliques, steps_done) state."""
    big = jnp.float32(1e9)
    iw_f = jax.lax.broadcasted_iota(jnp.float32, (1, w), 1)
    iw_i = jax.lax.broadcasted_iota(jnp.int32, (1, w), 1)
    iu_f = jax.lax.broadcasted_iota(jnp.float32, (u, 1), 0)
    ix_f = jax.lax.broadcasted_iota(jnp.float32, (xc, 1), 0)

    def pcsum(x):
        return jnp.sum(jax.lax.population_count(x).astype(jnp.float32),
                       axis=1, keepdims=True)

    def step(_, s):
        dl, done, calls, branches, spx, clq, sdone = s
        d = jnp.clip(dl, 0, t - 1)
        fP = sp_ref[pl.ds(d, 1), :w]                       # (1, w)
        fB = sb_ref[pl.ds(d, 1), :w]
        fXp = sxp_ref[pl.ds(d, 1), :w]
        fRb = srb_ref[pl.ds(d, 1), :w]
        frsz = srsz_ref[d]
        has_branch = jnp.max(jnp.where(fB != 0, 1.0, 0.0)) > 0.5
        blocked = has_branch & (dl >= t - 1)
        act = (done == 0) & ~blocked & (dl >= 0)
        done = jnp.where(blocked | (dl < 0), jnp.int32(1), done)

        # first set bit of B: per-word low-bit position, f32 min over words
        low = jnp.bitwise_and(fB, jnp.uint32(0) - fB)
        pos = jax.lax.population_count(
            low - jnp.uint32(1)).astype(jnp.float32)
        cand = jnp.where(fB != 0, iw_f * 32.0 + pos, big)
        wv = jnp.clip(jnp.min(cand), 0.0,
                      jnp.float32(u - 1)).astype(jnp.int32)
        wbit = jnp.where(iw_i == wv // 32,
                         jnp.uint32(1) << (wv % 32).astype(jnp.uint32),
                         jnp.uint32(0))
        wrow = read_a(wv)
        childP = jnp.bitwise_and(fP, wrow)
        childXp = jnp.bitwise_and(fXp, wrow)
        childRb = jnp.bitwise_or(fRb, wbit)

        deg = pcsum(jnp.bitwise_and(a, childP))            # (u, 1)
        # gather-free P ∪ X membership: one-hot rows AND the member bitset
        inpool = pcsum(jnp.bitwise_and(
            eye, jnp.bitwise_or(childP, childXp))) > 0.5
        pcx = pcsum(jnp.bitwise_and(xr, childP))           # (xc, 1)
        # closed-form alive set from Rb (see ref.dfs_step_window); pcsum
        # of x&Rb never exceeds |Rb|, so >= |Rb|−0.5 is exactly ==
        pc_rb = jnp.sum(jax.lax.population_count(
            childRb).astype(jnp.float32))
        alive = jnp.where(
            (alive0 > 0.5) & (pcsum(jnp.bitwise_and(xr, childRb))
                              >= pc_rb - 0.5), 1.0, 0.0)

        # enter_call, restricted: counts + leaf report + pivot branch set
        en = act & has_branch
        en_i = en.astype(jnp.int32)
        branches = branches + en_i
        calls = calls + en_i
        pc_p = jnp.sum(jax.lax.population_count(
            childP).astype(jnp.float32))
        pc_x = jnp.sum(jax.lax.population_count(
            childXp).astype(jnp.float32))
        nal = jnp.sum(alive)
        spx = spx + (pc_p + pc_x + nal).astype(jnp.int32) * en_i
        p_empty = pc_p < 0.5
        x_empty = (nal < 0.5) & (pc_x < 0.5)
        crsz = frsz + 1
        clq = clq + (p_empty & x_empty & (crsz >= 2) & en).astype(jnp.int32)
        push = ~p_empty & en

        su_s = jnp.where(inpool, deg, -1.0)
        su = jnp.max(su_s)
        best_u = jnp.min(jnp.where(su_s == su, iu_f, big)).astype(jnp.int32)
        sx_s = jnp.where(alive > 0.5, pcx, -1.0)
        sx = jnp.max(sx_s)
        best_x = jnp.min(jnp.where(sx_s == sx, ix_f, big)).astype(jnp.int32)
        use_x = sx > su
        rowu = read_a(best_u)
        rowx = read_x(jnp.clip(best_x, 0, xc - 1))
        pivot_row = jnp.where(use_x, rowx, rowu)
        childB = jnp.bitwise_and(childP, jnp.bitwise_not(pivot_row))

        # current frame: P \ w, X ∪ w, B \ w (identity when not branching)
        nwbit = jnp.bitwise_not(wbit)
        sp_ref[pl.ds(d, 1), :w] = jnp.where(
            en, jnp.bitwise_and(fP, nwbit), fP)
        sxp_ref[pl.ds(d, 1), :w] = jnp.where(
            en, jnp.bitwise_or(fXp, wbit), fXp)
        sb_ref[pl.ds(d, 1), :w] = jnp.where(
            en, jnp.bitwise_and(fB, nwbit), fB)
        # child frame at d+1 (clamped; identity unless descended into)
        cd = jnp.clip(d + 1, 0, t - 1)
        sp_ref[pl.ds(cd, 1), :w] = jnp.where(
            push, childP, sp_ref[pl.ds(cd, 1), :w])
        sb_ref[pl.ds(cd, 1), :w] = jnp.where(
            push, childB, sb_ref[pl.ds(cd, 1), :w])
        sxp_ref[pl.ds(cd, 1), :w] = jnp.where(
            push, childXp, sxp_ref[pl.ds(cd, 1), :w])
        srb_ref[pl.ds(cd, 1), :w] = jnp.where(
            push, childRb, srb_ref[pl.ds(cd, 1), :w])
        srsz_ref[cd] = jnp.where(push, crsz, srsz_ref[cd])

        dl = jnp.where(act,
                       jnp.where(has_branch,
                                 jnp.where(push, dl + 1, dl), dl - 1), dl)
        sdone = sdone + act.astype(jnp.int32)
        return dl, done, calls, branches, spx, clq, sdone

    z = jnp.int32(0)
    return jax.lax.fori_loop(0, steps, step, (d0, z, z, z, z, z, z))


def _dfs_step_window_kernel(a_ref, xr_ref, eye_ref, alive_ref,
                            winp_ref, winb_ref, winxp_ref, winrb_ref,
                            winrsz_ref, dloc_ref,
                            outp_ref, outb_ref, outxp_ref, outrb_ref,
                            outrsz_ref, ctl_ref,
                            sp_ref, sb_ref, sxp_ref, srb_ref, srsz_ref,
                            *, steps):
    """One invocation = up to `steps` masked DFS frame-steps.

    The window frames live in VMEM scratch for the whole invocation (the
    per-frame |R| sizes and the control scalars ride in SMEM); the HBM
    stack is untouched until the engine wrapper writes the returned
    window back. The step loop itself is `_window_walk`, shared with the
    lane-batched variant below.
    """
    t, w = winp_ref.shape
    u = a_ref.shape[0]
    xc = xr_ref.shape[0]
    sp_ref[:, :w] = winp_ref[...]
    sb_ref[:, :w] = winb_ref[...]
    sxp_ref[:, :w] = winxp_ref[...]
    srb_ref[:, :w] = winrb_ref[...]
    for i in range(t):
        srsz_ref[i] = winrsz_ref[0, i]
    s = _window_walk(a_ref[...], xr_ref[...], eye_ref[...],
                     alive_ref[...].astype(jnp.float32),
                     lambda i: a_ref[pl.ds(i, 1), :],
                     lambda i: xr_ref[pl.ds(i, 1), :],
                     sp_ref, sb_ref, sxp_ref, srb_ref, srsz_ref,
                     t, w, u, xc, dloc_ref[0, 0], steps)
    z = jnp.int32(0)
    outp_ref[...] = sp_ref[:, :w]
    outb_ref[...] = sb_ref[:, :w]
    outxp_ref[...] = sxp_ref[:, :w]
    outrb_ref[...] = srb_ref[:, :w]
    for i in range(t):
        outrsz_ref[0, i] = srsz_ref[i]
    ctl_ref[0, 0] = s[0]
    ctl_ref[0, 1] = s[2]
    ctl_ref[0, 2] = s[3]
    ctl_ref[0, 3] = s[4]
    ctl_ref[0, 4] = s[5]
    ctl_ref[0, 5] = s[6]
    ctl_ref[0, 6] = z
    ctl_ref[0, 7] = z


def _dfs_step_window_lanes_kernel(a_ref, xr_ref, eye_ref, alive_ref,
                                  winp_ref, winb_ref, winxp_ref, winrb_ref,
                                  winrsz_ref, dloc_ref,
                                  outp_ref, outb_ref, outxp_ref, outrb_ref,
                                  outrsz_ref, ctl_ref,
                                  sp_ref, sb_ref, sxp_ref, srb_ref,
                                  srsz_ref, *, steps):
    """Lane-batched window walk: one grid step = one lane's K frame-steps.

    Every input/output block is that lane's plane of the (L, …) array —
    the (1, U, W) adjacency, (1, XC, W) X rows, (1, T, W) windows in
    VMEM, and the per-lane scalars (dloc in, rsz, ctl out) in
    (1, 1, ·) SMEM lane rows. The (8, 128) VMEM scratch window is
    re-initialized from
    the lane's own block at the top of every grid step and written back
    at the end — no state crosses grid steps (no `pl.program_id` reads,
    no revisited blocks), so the batched-grid lowering under `jax.vmap`
    stays correct and lanes never observe each other: a lane that stops
    on underflow/overflow simply burns the rest of its own grid step
    without stalling its neighbors.
    """
    t, w = winp_ref.shape[1], winp_ref.shape[2]
    u = a_ref.shape[1]
    xc = xr_ref.shape[1]
    sp_ref[:, :w] = winp_ref[0]
    sb_ref[:, :w] = winb_ref[0]
    sxp_ref[:, :w] = winxp_ref[0]
    srb_ref[:, :w] = winrb_ref[0]
    for i in range(t):
        srsz_ref[i] = winrsz_ref[0, 0, i]
    s = _window_walk(a_ref[0], xr_ref[0], eye_ref[...],
                     alive_ref[0].astype(jnp.float32),
                     lambda i: a_ref[0, pl.ds(i, 1), :],
                     lambda i: xr_ref[0, pl.ds(i, 1), :],
                     sp_ref, sb_ref, sxp_ref, srb_ref, srsz_ref,
                     t, w, u, xc, dloc_ref[0, 0, 0], steps)
    z = jnp.int32(0)
    outp_ref[0] = sp_ref[:, :w]
    outb_ref[0] = sb_ref[:, :w]
    outxp_ref[0] = sxp_ref[:, :w]
    outrb_ref[0] = srb_ref[:, :w]
    for i in range(t):
        outrsz_ref[0, 0, i] = srsz_ref[i]
    ctl_ref[0, 0, 0] = s[0]
    ctl_ref[0, 0, 1] = s[2]
    ctl_ref[0, 0, 2] = s[3]
    ctl_ref[0, 0, 3] = s[4]
    ctl_ref[0, 0, 4] = s[5]
    ctl_ref[0, 0, 5] = s[6]
    ctl_ref[0, 0, 6] = z
    ctl_ref[0, 0, 7] = z


@functools.partial(jax.jit, static_argnames=("steps", "interpret"))
def dfs_step_window(a: jnp.ndarray, x_rows: jnp.ndarray, eye: jnp.ndarray,
                    alive0: jnp.ndarray, winP: jnp.ndarray,
                    winB: jnp.ndarray, winXp: jnp.ndarray,
                    winRb: jnp.ndarray, winrsz: jnp.ndarray,
                    dloc: jnp.ndarray, steps: int = 16,
                    interpret: bool = True):
    """Pallas path for ref.dfs_step_window (same contract).

    The (T, W) window frames are copied into VMEM scratch once, mutated
    in place across up to `steps` frame-steps, and written back to the
    output refs at the end — the kernel's whole point is that the stack
    state does NOT round-trip HBM between steps. The adjacency, X rows,
    eye, and alive inputs stay resident in VMEM across the invocation;
    the |R| sizes and control scalars (dloc in, ctl out) ride in SMEM.
    """
    t, w = winP.shape
    assert t == WINDOW_FRAMES, f"window must have {WINDOW_FRAMES} frames"
    assert w <= WINDOW_WORDS, f"word width {w} exceeds {WINDOW_WORDS}"
    u = a.shape[0]
    xc = x_rows.shape[0]

    def vmem(shape):
        return pl.BlockSpec(shape, lambda: tuple(0 for _ in shape))

    smem = pl.BlockSpec(memory_space=pltpu.SMEM)
    outs = pl.pallas_call(
        functools.partial(_dfs_step_window_kernel, steps=steps),
        out_shape=(jax.ShapeDtypeStruct((t, w), jnp.uint32),
                   jax.ShapeDtypeStruct((t, w), jnp.uint32),
                   jax.ShapeDtypeStruct((t, w), jnp.uint32),
                   jax.ShapeDtypeStruct((t, w), jnp.uint32),
                   jax.ShapeDtypeStruct((1, t), jnp.int32),
                   jax.ShapeDtypeStruct((1, 8), jnp.int32)),
        in_specs=[vmem((u, w)), vmem((xc, w)), vmem((u, w)),
                  vmem((xc, 1)), vmem((t, w)), vmem((t, w)),
                  vmem((t, w)), vmem((t, w)), smem, smem],
        out_specs=(vmem((t, w)), vmem((t, w)), vmem((t, w)), vmem((t, w)),
                   smem, smem),
        scratch_shapes=[
            pltpu.VMEM((8, 128), jnp.uint32),
            pltpu.VMEM((8, 128), jnp.uint32),
            pltpu.VMEM((8, 128), jnp.uint32),
            pltpu.VMEM((8, 128), jnp.uint32),
            pltpu.SMEM((8,), jnp.int32),
        ],
        interpret=interpret,
    )(a, x_rows, eye, alive0.astype(jnp.int32)[:, None], winP, winB,
      winXp, winRb, winrsz.astype(jnp.int32)[None],
      jnp.asarray(dloc, jnp.int32)[None, None])
    outP, outB, outXp, outRb, outrsz, ctl = outs
    return outP, outB, outXp, outRb, outrsz[0], ctl[0]


@functools.partial(jax.jit, static_argnames=("steps", "interpret"))
def dfs_step_window_lanes(a: jnp.ndarray, x_rows: jnp.ndarray,
                          eye: jnp.ndarray, alive0: jnp.ndarray,
                          winP: jnp.ndarray, winB: jnp.ndarray,
                          winXp: jnp.ndarray, winRb: jnp.ndarray,
                          winrsz: jnp.ndarray, dloc: jnp.ndarray,
                          steps: int = 16, interpret: bool = True):
    """Pallas path for ref.dfs_step_window_lanes (same contract).

    The grid runs over lanes: each grid step walks one lane's window for
    up to `steps` frame-steps entirely in the shared (8, 128) VMEM
    scratch, touching only that lane's blocks of the (L, …) inputs and
    outputs. Per-lane scalars — the window-local depth in, the per-frame
    |R| sizes, and the ctl row out — ride in SMEM lane rows shaped
    (1, 1, T)/(1, 1, 1)/(1, 1, 8) over (L, 1, ·) arrays: Mosaic checks
    the LAST TWO dims of every block (even SMEM) against the array dims,
    so the lane axis is the mapped leading dim and the trailing (1, ·)
    matches the array exactly. a: (L, U, W); x_rows: (L, XC, W); eye:
    (U, W) shared; alive0: (L, XC); winP/winB/winXp/winRb: (L, T, W);
    winrsz: (L, T); dloc: (L,). Returns the updated lane windows plus
    ctl (L, 8).
    """
    l, t, w = winP.shape
    assert t == WINDOW_FRAMES, f"window must have {WINDOW_FRAMES} frames"
    assert w <= WINDOW_WORDS, f"word width {w} exceeds {WINDOW_WORDS}"
    u = a.shape[1]
    xc = x_rows.shape[1]

    def lane(shape):
        return pl.BlockSpec((1,) + shape,
                            lambda i: (i,) + (0,) * len(shape))

    def smem(cols):
        # (1, 1, cols) lane rows of an (L, 1, cols) array: Mosaic requires
        # the last TWO block dims to be 8/128-divisible or equal to the
        # array dims, so per-lane scalars carry a middle singleton — the
        # lane axis is Mapped, the trailing (1, cols) matches exactly.
        return pl.BlockSpec((1, 1, cols), lambda i: (i, 0, 0),
                            memory_space=pltpu.SMEM)

    outs = pl.pallas_call(
        functools.partial(_dfs_step_window_lanes_kernel, steps=steps),
        grid=(l,),
        out_shape=(jax.ShapeDtypeStruct((l, t, w), jnp.uint32),
                   jax.ShapeDtypeStruct((l, t, w), jnp.uint32),
                   jax.ShapeDtypeStruct((l, t, w), jnp.uint32),
                   jax.ShapeDtypeStruct((l, t, w), jnp.uint32),
                   jax.ShapeDtypeStruct((l, 1, t), jnp.int32),
                   jax.ShapeDtypeStruct((l, 1, 8), jnp.int32)),
        in_specs=[lane((u, w)), lane((xc, w)),
                  pl.BlockSpec((u, w), lambda i: (0, 0)),
                  lane((xc, 1)), lane((t, w)), lane((t, w)),
                  lane((t, w)), lane((t, w)), smem(t), smem(1)],
        out_specs=(lane((t, w)), lane((t, w)), lane((t, w)), lane((t, w)),
                   smem(t), smem(8)),
        scratch_shapes=[
            pltpu.VMEM((8, 128), jnp.uint32),
            pltpu.VMEM((8, 128), jnp.uint32),
            pltpu.VMEM((8, 128), jnp.uint32),
            pltpu.VMEM((8, 128), jnp.uint32),
            pltpu.SMEM((8,), jnp.int32),
        ],
        interpret=interpret,
    )(a, x_rows, eye, alive0.astype(jnp.int32)[..., None], winP, winB,
      winXp, winRb, winrsz.astype(jnp.int32)[:, None, :],
      jnp.asarray(dloc, jnp.int32)[:, None, None])
    outP, outB, outXp, outRb, outrsz, ctl = outs
    return outP, outB, outXp, outRb, outrsz[:, 0], ctl[:, 0]
