"""Pure-jnp oracle for the bitset AND+popcount kernels."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def and_popcount_rows(rows: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """popcount(rows & mask) reduced over the word axis.

    rows: (..., K, W) uint32, mask: (..., W) uint32 -> (..., K) int32.
    This is `|N(u) ∩ P|` for every u at once — the MCE set-intersection
    hot spot in bitset form.
    """
    anded = jnp.bitwise_and(rows, mask[..., None, :])
    return jnp.sum(jax.lax.population_count(anded), axis=-1).astype(jnp.int32)


def and_rows(rows: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """rows & mask broadcast over the row axis (materialised intersection)."""
    return jnp.bitwise_and(rows, mask[..., None, :])


def and_popcount_argmax(rows: jnp.ndarray, mask: jnp.ndarray,
                        valid: Optional[jnp.ndarray] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused pivot-select: argmax over popcount(rows & mask) scores.

    rows: (..., K, W) uint32, mask: (..., W) uint32, valid: (..., K) bool.
    Returns (idx, best): int32 index of the first best-scoring valid row and
    its score; invalid rows score -1 (so all-invalid -> best == -1, idx == 0).
    Matches jnp.argmax tie-breaking (first occurrence wins).
    """
    scores = and_popcount_rows(rows, mask)
    if valid is not None:
        scores = jnp.where(valid, scores, jnp.int32(-1))
    idx = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    best = jnp.take_along_axis(scores, idx[..., None], axis=-1)[..., 0]
    return idx, best


def frame_step(rows: jnp.ndarray, p: jnp.ndarray, xp: jnp.ndarray,
               wrow: jnp.ndarray):
    """Fused BK frame step: child-set construction + degree/partner sweep.

    rows: (..., K, W) uint32 adjacency, p/xp/wrow: (..., W) uint32.
    Returns (childp, childxp, deg, partner):
      childp  = p  & wrow                      (..., W)  child candidate set
      childxp = xp & wrow                      (..., W)  child forbidden set
      deg[k]  = popcount(rows[k] & childp)     (..., K)  child degree vector
      partner[k] = Σ_words (32·w + lowest-set-bit-pos) over nonzero words of
      rows[k] & childp — the exact bit index when deg[k] == 1 (the Lemma-7
      partner), deterministic garbage otherwise. Callers only read partner
      where deg == 1.
    """
    childp = jnp.bitwise_and(p, wrow)
    childxp = jnp.bitwise_and(xp, wrow)
    anded = jnp.bitwise_and(rows, childp[..., None, :])
    deg = jnp.sum(jax.lax.population_count(anded), axis=-1).astype(jnp.int32)
    low = jnp.bitwise_and(anded, jnp.uint32(0) - anded)
    pos = jax.lax.population_count(low - jnp.uint32(1)).astype(jnp.int32)
    wi = 32 * jnp.arange(anded.shape[-1], dtype=jnp.int32)
    contrib = jnp.where(anded != 0, wi + pos, jnp.int32(0))
    partner = jnp.sum(contrib, axis=-1).astype(jnp.int32)
    return childp, childxp, deg, partner


def clique_counts(rows: jnp.ndarray, mask: jnp.ndarray, in_p: jnp.ndarray,
                  in_x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused early-termination census for the hybrid backend.

    rows: (..., K, W) uint32, mask: (..., W) uint32 (the candidate set P),
    in_p/in_x: (..., K) bool row selectors -> (n_full, n_dom), both
    (...,) int32:
      n_full = |{k : in_p[k] ∧ popcount(rows[k] & mask) == popcount(mask)−1}|
      n_dom  = |{k : in_x[k] ∧ popcount(rows[k] & mask) == popcount(mask)}|
    With rows = adjacency ∪ X0 rows, in_p selecting P members and in_x the
    forbidden rows, P induces a clique iff n_full == |P| (each member is
    adjacent to the |P|−1 others; self-bits are absent from adjacency rows)
    and some forbidden vertex dominates P (P ⊆ N(x)) iff n_dom > 0.
    """
    pc = and_popcount_rows(rows, mask)
    msize = jnp.sum(jax.lax.population_count(mask),
                    axis=-1).astype(jnp.int32)
    full = in_p & (pc == (msize - 1)[..., None])
    dom = in_x & (pc == msize[..., None])
    return (jnp.sum(full.astype(jnp.int32), axis=-1),
            jnp.sum(dom.astype(jnp.int32), axis=-1))


def and_popcount_many(rows: jnp.ndarray, masks: jnp.ndarray) -> jnp.ndarray:
    """One row matrix against a batch of masks.

    rows: (..., K, W) uint32, masks: (..., M, W) uint32 -> (..., M, K) int32
    with out[m, k] = popcount(rows[k] & masks[m]). This is the X-subset
    maximality test shape: `P ⊆ N(x)` for every forbidden row x is
    `and_popcount_many(P[None, :], ~x_rows)[:, 0] == 0`.
    """
    anded = jnp.bitwise_and(rows[..., None, :, :], masks[..., :, None, :])
    return jnp.sum(jax.lax.population_count(anded), axis=-1).astype(jnp.int32)
