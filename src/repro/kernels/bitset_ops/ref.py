"""Pure-jnp oracle for the bitset AND+popcount kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def and_popcount_rows(rows: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """popcount(rows & mask) reduced over the word axis.

    rows: (..., K, W) uint32, mask: (..., W) uint32 -> (..., K) int32.
    This is `|N(u) ∩ P|` for every u at once — the MCE set-intersection
    hot spot in bitset form.
    """
    anded = jnp.bitwise_and(rows, mask[..., None, :])
    return jnp.sum(jax.lax.population_count(anded), axis=-1).astype(jnp.int32)


def and_rows(rows: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """rows & mask broadcast over the row axis (materialised intersection)."""
    return jnp.bitwise_and(rows, mask[..., None, :])
