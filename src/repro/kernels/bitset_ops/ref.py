"""Pure-jnp oracle for the bitset AND+popcount kernels."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def and_popcount_rows(rows: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """popcount(rows & mask) reduced over the word axis.

    rows: (..., K, W) uint32, mask: (..., W) uint32 -> (..., K) int32.
    This is `|N(u) ∩ P|` for every u at once — the MCE set-intersection
    hot spot in bitset form.
    """
    anded = jnp.bitwise_and(rows, mask[..., None, :])
    return jnp.sum(jax.lax.population_count(anded), axis=-1).astype(jnp.int32)


def and_rows(rows: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """rows & mask broadcast over the row axis (materialised intersection)."""
    return jnp.bitwise_and(rows, mask[..., None, :])


def and_popcount_argmax(rows: jnp.ndarray, mask: jnp.ndarray,
                        valid: Optional[jnp.ndarray] = None
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused pivot-select: argmax over popcount(rows & mask) scores.

    rows: (..., K, W) uint32, mask: (..., W) uint32, valid: (..., K) bool.
    Returns (idx, best): int32 index of the first best-scoring valid row and
    its score; invalid rows score -1 (so all-invalid -> best == -1, idx == 0).
    Matches jnp.argmax tie-breaking (first occurrence wins).
    """
    scores = and_popcount_rows(rows, mask)
    if valid is not None:
        scores = jnp.where(valid, scores, jnp.int32(-1))
    idx = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    best = jnp.take_along_axis(scores, idx[..., None], axis=-1)[..., 0]
    return idx, best


def frame_step(rows: jnp.ndarray, p: jnp.ndarray, xp: jnp.ndarray,
               wrow: jnp.ndarray):
    """Fused BK frame step: child-set construction + degree/partner sweep.

    rows: (..., K, W) uint32 adjacency, p/xp/wrow: (..., W) uint32.
    Returns (childp, childxp, deg, partner):
      childp  = p  & wrow                      (..., W)  child candidate set
      childxp = xp & wrow                      (..., W)  child forbidden set
      deg[k]  = popcount(rows[k] & childp)     (..., K)  child degree vector
      partner[k] = Σ_words (32·w + lowest-set-bit-pos) over nonzero words of
      rows[k] & childp — the exact bit index when deg[k] == 1 (the Lemma-7
      partner), deterministic garbage otherwise. Callers only read partner
      where deg == 1.
    """
    childp = jnp.bitwise_and(p, wrow)
    childxp = jnp.bitwise_and(xp, wrow)
    anded = jnp.bitwise_and(rows, childp[..., None, :])
    deg = jnp.sum(jax.lax.population_count(anded), axis=-1).astype(jnp.int32)
    low = jnp.bitwise_and(anded, jnp.uint32(0) - anded)
    pos = jax.lax.population_count(low - jnp.uint32(1)).astype(jnp.int32)
    wi = 32 * jnp.arange(anded.shape[-1], dtype=jnp.int32)
    contrib = jnp.where(anded != 0, wi + pos, jnp.int32(0))
    partner = jnp.sum(contrib, axis=-1).astype(jnp.int32)
    return childp, childxp, deg, partner


def clique_counts(rows: jnp.ndarray, mask: jnp.ndarray, in_p: jnp.ndarray,
                  in_x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused early-termination census for the hybrid backend.

    rows: (..., K, W) uint32, mask: (..., W) uint32 (the candidate set P),
    in_p/in_x: (..., K) bool row selectors -> (n_full, n_dom), both
    (...,) int32:
      n_full = |{k : in_p[k] ∧ popcount(rows[k] & mask) == popcount(mask)−1}|
      n_dom  = |{k : in_x[k] ∧ popcount(rows[k] & mask) == popcount(mask)}|
    With rows = adjacency ∪ X0 rows, in_p selecting P members and in_x the
    forbidden rows, P induces a clique iff n_full == |P| (each member is
    adjacent to the |P|−1 others; self-bits are absent from adjacency rows)
    and some forbidden vertex dominates P (P ⊆ N(x)) iff n_dom > 0.
    """
    pc = and_popcount_rows(rows, mask)
    msize = jnp.sum(jax.lax.population_count(mask),
                    axis=-1).astype(jnp.int32)
    full = in_p & (pc == (msize - 1)[..., None])
    dom = in_x & (pc == msize[..., None])
    return (jnp.sum(full.astype(jnp.int32), axis=-1),
            jnp.sum(dom.astype(jnp.int32), axis=-1))


def dfs_step_window(a: jnp.ndarray, x_rows: jnp.ndarray, eye: jnp.ndarray,
                    alive0: jnp.ndarray, winP: jnp.ndarray,
                    winB: jnp.ndarray, winXp: jnp.ndarray,
                    winRb: jnp.ndarray, winrsz: jnp.ndarray,
                    dloc: jnp.ndarray, steps: int):
    """K masked BK frame-steps over a T-frame stack window (counting only).

    The windowed DFS contract (DESIGN.md §2.6/§3): run up to `steps`
    straight-line frame-steps of the *pivot* backend with dynamic
    reduction off and no enumeration, touching only the T resident stack
    frames. The caller (engine `run_root_windowed`) owns the full HBM
    stack and re-slices a fresh window when this returns.

    a: (U, W) uint32 adjacency; x_rows: (XC, W) uint32; eye: (U, W)
    one-hot bitsets (fr.eye_bits — the gather-free membership test);
    alive0: (XC,) int32 0/1 root X0 alive mask. winP/winB/winXp/winRb:
    (T, W) uint32; winrsz: (T,) int32; dloc: () int32 window-local depth.

    The per-frame X0 alive set does NOT ride in the window: aliveness is
    a closed form of the frame's Rb — `alive[k] = alive0[k] ∧ Rb ⊆
    N(x_k)` (each branch vertex taken lands in Rb, and a row stays alive
    iff adjacent to every one) — recomputed per step with one
    AND+popcount sweep in the same (XC,) orientation it is consumed in.

    Returns (winP, winB, winXp, winRb, winrsz, ctl) with ctl (8,) int32
    = [dloc', calls, branches, sum_px, cliques, steps_done, 0, 0].
    Stops early when the walk pops below the window (dloc' == −1) or a
    branch step lands on the top slot (dloc' == T−1 with branches left —
    the push target would be outside the window); counter deltas are
    exact for the steps executed either way.
    """
    T, W = winP.shape
    U = a.shape[0]
    XC = x_rows.shape[0]
    iota_u = jnp.arange(U, dtype=jnp.int32)
    iota_w = jnp.arange(W, dtype=jnp.int32)
    big = jnp.int32(1 << 30)

    def first_bit(bits):
        low = jnp.bitwise_and(bits, jnp.uint32(0) - bits)
        pos = jax.lax.population_count(low - jnp.uint32(1)).astype(jnp.int32)
        cand = jnp.where(bits != 0, 32 * iota_w + pos, big)
        return jnp.min(cand)

    def unpack(bits):
        return ((bits[iota_u // 32] >> (iota_u % 32).astype(jnp.uint32))
                & jnp.uint32(1)) != 0

    def first_argmax(scores):
        m = jnp.max(scores)
        idx = jnp.min(jnp.where(
            scores == m, jnp.arange(scores.shape[0], dtype=jnp.int32), big))
        return idx.astype(jnp.int32), m

    def body(s):
        (wP, wB, wXp, wRb, wrsz, dl, done, it,
         calls, branches, spx, clq) = s
        d = jnp.clip(dl, 0, T - 1)
        fP, fB, fXp = wP[d], wB[d], wXp[d]
        fRb, frsz = wRb[d], wrsz[d]
        has_branch = jnp.any(fB != 0)
        blocked = has_branch & (dl >= T - 1)
        act = ~done & ~blocked & (dl >= 0)
        done = done | blocked | (dl < 0)

        w = jnp.clip(first_bit(fB), 0, U - 1)
        wbit = jnp.where(iota_w == w // 32,
                         jnp.uint32(1) << (w % 32).astype(jnp.uint32),
                         jnp.uint32(0))
        wrow = a[w]
        childP = fP & wrow
        childXp = fXp & wrow
        childRb = fRb | wbit
        deg = and_popcount_rows(a, childP)                    # (U,)
        pcx = and_popcount_rows(x_rows, childP)               # (XC,)
        # closed-form child alive set (see docstring)
        pc_rb = jnp.sum(jax.lax.population_count(childRb)).astype(jnp.int32)
        alive = alive0 * (and_popcount_rows(x_rows, childRb)
                          == pc_rb).astype(jnp.int32)

        # enter_call, restricted: counts + leaf report + pivot branch set
        en = act & has_branch
        en_i = en.astype(jnp.int32)
        branches = branches + en_i
        calls = calls + en_i
        pc_p = jnp.sum(jax.lax.population_count(childP)).astype(jnp.int32)
        pc_x = jnp.sum(jax.lax.population_count(childXp)).astype(jnp.int32)
        nal = jnp.sum(alive)
        spx = spx + (pc_p + pc_x + nal) * en_i
        p_empty = pc_p == 0
        x_empty = (nal == 0) & (pc_x == 0)
        crsz = frsz + 1
        clq = clq + (p_empty & x_empty & (crsz >= 2) & en).astype(jnp.int32)
        push = ~p_empty & en

        # pivot over P ∪ X (pivot.branch_set deg-vector path, exactly)
        pool = unpack(childP | childXp)
        best_u, su = first_argmax(jnp.where(pool, deg, jnp.int32(-1)))
        best_x, sx = first_argmax(jnp.where(alive > 0, pcx, jnp.int32(-1)))
        use_x = sx > su
        pivot_row = jnp.where(use_x, x_rows[jnp.clip(best_x, 0, XC - 1)],
                              a[best_u])
        childB = childP & ~pivot_row

        # current frame: P \ w, X ∪ w, B \ w (identity when not branching)
        wP = wP.at[d].set(jnp.where(en, fP & ~wbit, fP))
        wXp = wXp.at[d].set(jnp.where(en, fXp | wbit, fXp))
        wB = wB.at[d].set(jnp.where(en, fB & ~wbit, fB))
        # child frame at d+1, written only when descended into
        cd = jnp.clip(d + 1, 0, T - 1)
        wP = wP.at[cd].set(jnp.where(push, childP, wP[cd]))
        wB = wB.at[cd].set(jnp.where(push, childB, wB[cd]))
        wXp = wXp.at[cd].set(jnp.where(push, childXp, wXp[cd]))
        wRb = wRb.at[cd].set(jnp.where(push, childRb, wRb[cd]))
        wrsz = wrsz.at[cd].set(jnp.where(push, crsz, wrsz[cd]))

        dl = jnp.where(act,
                       jnp.where(has_branch,
                                 jnp.where(push, dl + 1, dl), dl - 1), dl)
        it = it + act.astype(jnp.int32)
        return (wP, wB, wXp, wRb, wrsz, dl, done, it,
                calls, branches, spx, clq)

    def cond(s):
        return (s[7] < steps) & ~s[6]

    z = jnp.int32(0)
    s = jax.lax.while_loop(cond, body, (
        winP, winB, winXp, winRb, winrsz, dloc.astype(jnp.int32),
        jnp.bool_(False), z, z, z, z, z))
    ctl = jnp.stack([s[5], s[8], s[9], s[10], s[11], s[7], z, z])
    return s[0], s[1], s[2], s[3], s[4], ctl


def dfs_step_window_lanes(a: jnp.ndarray, x_rows: jnp.ndarray,
                          eye: jnp.ndarray, alive0: jnp.ndarray,
                          winP: jnp.ndarray, winB: jnp.ndarray,
                          winXp: jnp.ndarray, winRb: jnp.ndarray,
                          winrsz: jnp.ndarray, dloc: jnp.ndarray,
                          steps: int):
    """Lane-batched `dfs_step_window`: one independent window walk per lane.

    a: (L, U, W) per-lane adjacency; x_rows: (L, XC, W); eye: (U, W)
    shared; alive0: (L, XC) int32 0/1 per-lane root alive masks;
    winP/winB/winXp/winRb: (L, T, W); winrsz: (L, T); dloc: (L,) int32.
    Returns the lane-batched windows plus ctl (L, 8) int32 rows of the
    single-lane contract. A dead lane (dloc < 0) no-ops: its first body
    evaluation marks it done, so it returns unchanged with zero counter
    deltas and steps_done 0. Lanes are independent — one lane stopping on
    underflow/overflow only masks its own updates (the vmapped while_loop
    keeps stepping the others), so a blocked lane never stalls its
    neighbors' progress.
    """
    return jax.vmap(
        lambda a_l, xr_l, al_l, wp, wb, wxp, wrb, wrz, dl: dfs_step_window(
            a_l, xr_l, eye, al_l, wp, wb, wxp, wrb, wrz, dl, steps)
    )(a, x_rows, alive0, winP, winB, winXp, winRb, winrsz, dloc)


def and_popcount_many(rows: jnp.ndarray, masks: jnp.ndarray) -> jnp.ndarray:
    """One row matrix against a batch of masks.

    rows: (..., K, W) uint32, masks: (..., M, W) uint32 -> (..., M, K) int32
    with out[m, k] = popcount(rows[k] & masks[m]). This is the X-subset
    maximality test shape: `P ⊆ N(x)` for every forbidden row x is
    `and_popcount_many(P[None, :], ~x_rows)[:, 0] == 0`.
    """
    anded = jnp.bitwise_and(rows[..., None, :, :], masks[..., :, None, :])
    return jnp.sum(jax.lax.population_count(anded), axis=-1).astype(jnp.int32)
