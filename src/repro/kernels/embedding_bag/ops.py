"""Dispatch: one-hot GEMM kernel for small vocab shards, XLA take otherwise."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.embedding_bag import kernel, ref

ONEHOT_VOCAB_LIMIT = 65536  # beyond this the one-hot GEMM wastes MXU flops


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  combiner: str = "sum") -> jnp.ndarray:
    if _on_tpu() and combiner == "sum" and table.shape[0] <= ONEHOT_VOCAB_LIMIT:
        return kernel.embedding_bag_sum(table, ids, interpret=False)
    return ref.embedding_bag(table, ids, combiner)
