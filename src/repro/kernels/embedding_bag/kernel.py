"""Pallas TPU kernel: EmbeddingBag as tiled one-hot GEMM (MXU path).

Hardware adaptation: TPUs have no fast data-dependent gather from HBM inside
a kernel; for small/medium vocab shards (the per-device shard of a
row-sharded table after the mod-sharding in repro/models/recsys.py), the
lookup is re-expressed as  onehot(ids) @ table — a (B_blk, V_blk)·(V_blk, D)
GEMM chain accumulated over vocab tiles on the MXU. Bags reduce over L inside
the tile. Giant tables use the XLA take-based path (ops.py dispatch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 128
DEFAULT_BLOCK_V = 512


def _bag_kernel(ids_ref, table_ref, out_ref, *, block_v):
    v_tile = pl.program_id(1)
    ids = ids_ref[...]                              # (BB, L)
    tbl = table_ref[...]                            # (BV, D)
    base = v_tile * block_v
    local = ids - base                              # (BB, L)
    valid = (ids >= 0) & (local >= 0) & (local < block_v)
    onehot = (
        (local[:, :, None] == jnp.arange(block_v)[None, None, :]) & valid[:, :, None]
    ).astype(jnp.float32)                           # (BB, L, BV)
    counts = onehot.sum(axis=1)                     # (BB, BV) multi-hot counts
    part = jax.lax.dot_general(
        counts, tbl, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)         # (BB, D)

    @pl.when(v_tile == 0)
    def _init():
        # mce-lint: disable=R2 -- vocab-tile accumulator over sequential grid axis 1; never vmapped (batch tiles ride grid axis 0, huge vocabs take the XLA path in ops.py)
        out_ref[...] = jnp.zeros_like(out_ref)

    # mce-lint: disable=R2 -- same sequential vocab-tile accumulation as _init above; grid axis 1 revisits this block in order, never under vmap
    out_ref[...] += part


@functools.partial(jax.jit, static_argnames=("block_b", "block_v", "interpret"))
def embedding_bag_sum(table: jnp.ndarray, ids: jnp.ndarray,
                      block_b: int = DEFAULT_BLOCK_B,
                      block_v: int = DEFAULT_BLOCK_V,
                      interpret: bool = True) -> jnp.ndarray:
    v, d = table.shape
    b, l = ids.shape
    bb = min(block_b, b)
    bv = min(block_v, v)
    b_pad = -(-b // bb) * bb
    v_pad = -(-v // bv) * bv
    if b_pad != b:
        ids = jnp.pad(ids, ((0, b_pad - b), (0, 0)), constant_values=-1)
    if v_pad != v:
        table = jnp.pad(table, ((0, v_pad - v), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_bag_kernel, block_v=bv),
        out_shape=jax.ShapeDtypeStruct((b_pad, d), jnp.float32),
        grid=(b_pad // bb, v_pad // bv),
        in_specs=[
            pl.BlockSpec((bb, l), lambda i, j: (i, 0)),
            pl.BlockSpec((bv, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bb, d), lambda i, j: (i, 0)),
        interpret=interpret,
    )(ids.astype(jnp.int32), table.astype(jnp.float32))
    return out[:b]
