"""Pure-jnp oracle: EmbeddingBag (multi-hot gather + reduce).

JAX has no native nn.EmbeddingBag; this take+mask+sum formulation IS the
recsys substrate (see system prompt: building it is part of the system).
ids are padded with -1 (masked out). combiner: 'sum' | 'mean'.
"""
from __future__ import annotations

import jax.numpy as jnp


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  combiner: str = "sum") -> jnp.ndarray:
    """table: (V, D); ids: (B, L) int32 padded with -1 -> (B, D)."""
    mask = ids >= 0
    safe = jnp.where(mask, ids, 0)
    rows = table[safe] * mask[..., None]
    out = rows.sum(axis=1)
    if combiner == "mean":
        denom = jnp.maximum(mask.sum(axis=1, keepdims=True), 1)
        out = out / denom
    return out
