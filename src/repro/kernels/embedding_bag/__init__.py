from repro.kernels.embedding_bag import kernel, ops, ref  # noqa: F401
