# Pallas TPU kernels for the framework's compute hot spots.
#
# Each kernel is a subpackage with:
#   kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target),
#   ref.py    — pure-jnp oracle (the semantics contract),
#   ops.py    — jit-friendly wrapper dispatching pallas / interpret / jnp.
#
# Kernels:
#   bitset_ops      — fused AND+popcount over bitset rows (the paper's
#                     set-intersection hot spot: 73.6% of MCE runtime per
#                     [Han et al. SIGMOD'18]; drives pivot selection and
#                     degree computation in the bitset BK engine).
#   common_neighbor — tiled common-neighbour existence over padded adjacency
#                     (global non-triangle edge reduction, paper §4.3).
#   segment_spmm    — gather-reduce sparse message passing (GNN substrate).
#   embedding_bag   — fused multi-hot gather + segment-sum (recsys substrate).
from repro.kernels.bitset_ops import ops as bitset_ops  # noqa: F401
from repro.kernels.common_neighbor import ops as common_neighbor  # noqa: F401
from repro.kernels.segment_spmm import ops as segment_spmm  # noqa: F401
from repro.kernels.embedding_bag import ops as embedding_bag  # noqa: F401
