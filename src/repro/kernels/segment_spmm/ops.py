"""Dispatch: segment_sum for sparse graphs, dense MXU kernel for molecules."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.segment_spmm import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def segment_spmm(x, src, dst, n_nodes, edge_weight=None):
    """Sparse path — always the segment_sum formulation (gather+scatter)."""
    return ref.segment_spmm(x, src, dst, n_nodes, edge_weight)


def dense_spmm(adj, x):
    """Batched-small-graph path — Pallas MXU kernel on TPU, jnp elsewhere."""
    if _on_tpu():
        return kernel.dense_spmm(adj, x, interpret=False)
    return ref.dense_spmm(adj, x)


def densify_edges(src, dst, n_nodes, graph_id, n_graphs, nodes_per_graph,
                  edge_weight=None):
    """Build (B, N, N) dense adjacency from a batched edge list.

    src/dst are global node indices (graph g owns [g*N, (g+1)*N)); rows are
    destinations, columns sources — matches ref.dense_spmm convention."""
    local_s = src - graph_id * nodes_per_graph
    local_d = dst - graph_id * nodes_per_graph
    w = jnp.ones_like(src, dtype=jnp.float32) if edge_weight is None else edge_weight
    adj = jnp.zeros((n_graphs, nodes_per_graph, nodes_per_graph), jnp.float32)
    return adj.at[graph_id, local_d, local_s].add(w)
