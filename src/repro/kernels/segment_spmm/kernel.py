"""Pallas TPU kernel: batched dense-adjacency message passing (MXU path).

Hardware adaptation (DESIGN.md §2): the molecule regime (30-node graphs,
batch 128) is the GNN hot loop of this framework's arch set. Scatter/gather
message passing wastes the MXU there; densifying each small graph's adjacency
turns aggregation into a batched (N×N)·(N×F) GEMM that the MXU executes at
full tilt. The kernel tiles (B_blk, N, N) × (B_blk, N, F) through VMEM.

Large sparse graphs keep the segment_sum path (ops.py dispatch) — densifying
them would be O(N²) memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_B = 8


def _spmm_kernel(adj_ref, x_ref, out_ref):
    adj = adj_ref[...]                     # (BB, N, N)
    x = x_ref[...]                         # (BB, N, F)
    out_ref[...] = jax.lax.dot_general(
        adj, x, (((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def dense_spmm(adj: jnp.ndarray, x: jnp.ndarray,
               block_b: int = DEFAULT_BLOCK_B,
               interpret: bool = True) -> jnp.ndarray:
    b, n, _ = adj.shape
    f = x.shape[-1]
    bb = min(block_b, b)
    b_pad = -(-b // bb) * bb
    if b_pad != b:
        adj = jnp.pad(adj, ((0, b_pad - b), (0, 0), (0, 0)))
        x = jnp.pad(x, ((0, b_pad - b), (0, 0), (0, 0)))
    out = pl.pallas_call(
        _spmm_kernel,
        out_shape=jax.ShapeDtypeStruct((b_pad, n, f), jnp.float32),
        grid=(b_pad // bb,),
        in_specs=[
            pl.BlockSpec((bb, n, n), lambda i: (i, 0, 0)),
            pl.BlockSpec((bb, n, f), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, n, f), lambda i: (i, 0, 0)),
        interpret=interpret,
    )(adj.astype(jnp.float32), x.astype(jnp.float32))
    return out[:b]
