"""Pure-jnp oracle for sparse message-passing aggregation (GNN substrate).

Two equivalent formulations:
  * `segment_spmm` — edge-list gather + segment_sum (the canonical JAX GNN
    primitive; JAX has no CSR SpMM, so this IS the sparse substrate).
  * `dense_spmm`   — batched dense adjacency matmul, equal on densifiable
    graphs; this is the MXU-friendly form the Pallas kernel implements for
    the batched-small-graph regime (molecule shape).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def segment_spmm(x: jnp.ndarray, src: jnp.ndarray, dst: jnp.ndarray,
                 n_nodes: int, edge_weight: jnp.ndarray | None = None) -> jnp.ndarray:
    """out[d] = Σ_{e: dst[e]=d} w[e] · x[src[e]].  x: (N, F)."""
    msgs = x[src]
    if edge_weight is not None:
        msgs = msgs * edge_weight[:, None]
    return jax.ops.segment_sum(msgs, dst, num_segments=n_nodes)


def dense_spmm(adj: jnp.ndarray, x: jnp.ndarray) -> jnp.ndarray:
    """adj: (B, N, N) weights (adj[b, d, s]); x: (B, N, F) -> (B, N, F)."""
    return jnp.einsum("bds,bsf->bdf", adj, x)
