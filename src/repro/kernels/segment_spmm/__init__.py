from repro.kernels.segment_spmm import kernel, ops, ref  # noqa: F401
