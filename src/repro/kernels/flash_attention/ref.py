"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True) -> jnp.ndarray:
    """q: (BH, Sq, D); k, v: (BH, Sk, D). Full-softmax reference."""
    scale = 1.0 / np.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    out = jnp.einsum("bqk,bkd->bqd", _softmax(s), v.astype(jnp.float32))
    return out.astype(q.dtype)


def _softmax(s):
    m = jnp.max(s, axis=-1, keepdims=True)
    e = jnp.exp(s - m)
    return e / jnp.sum(e, axis=-1, keepdims=True)
