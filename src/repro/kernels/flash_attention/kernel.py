"""Pallas TPU kernel: causal flash attention (forward).

Hardware adaptation (DESIGN.md §2): the XLA lowering of blockwise attention
round-trips the (B, H, Sq, KV) probability tensors through HBM at every
fusion boundary — ~20% of the HBM bytes of an LM train step (measured in
EXPERIMENTS.md §Perf/qwen3). This kernel keeps scores/probabilities in VMEM:
each grid step owns a (BQ, D) query tile and streams KV in (BK, D) tiles,
carrying the online-softmax (m, l, acc) in VMEM scratch. HBM traffic is
exactly q + k + v + out.

Tiling: BQ rows × D lanes with D padded to 128 (MXU alignment); BK chosen so
(BQ·BK scores + 2·BK·D kv tile) fits VMEM alongside the accumulator.
Grid = (batch·heads, Sq/BQ) — queries parallel, KV streamed innermost via
the contraction dim of the grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, block_q, block_k, seq_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (block_q, block_k), 1)

    run = True
    if causal:
        # whole tile above the diagonal: nothing to do
        run = (ki * block_k) <= (qi * block_q + block_q - 1)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # (BQ, D)
        k = k_ref[0].astype(jnp.float32)            # (BK, D)
        v = v_ref[0].astype(jnp.float32)            # (BK, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        mask = k_pos < seq_k
        if causal:
            mask &= q_pos >= k_pos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)                      # stays in VMEM
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        # mce-lint: disable=R2 -- epilogue on the sequential kv grid axis 2: one write per output block from VMEM scratch; batch*heads ride grid axis 0, kernel is never vmapped
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = True) -> jnp.ndarray:
    """q: (BH, Sq, D); k, v: (BH, Sk, D) — heads pre-flattened/expanded.
    Returns (BH, Sq, D) in q.dtype. Causal assumes Sq == Sk alignment."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    scale = 1.0 / np.sqrt(d)
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    sq_pad = -(-sq // bq) * bq
    sk_pad = -(-sk // bk) * bk
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0)))
    if sk_pad != sk:
        k = jnp.pad(k, ((0, 0), (0, sk_pad - sk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, sk_pad - sk), (0, 0)))
    grid = (bh, sq_pad // bq, sk_pad // bk)
    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          block_q=bq, block_k=bk, seq_k=sk),
        out_shape=jax.ShapeDtypeStruct((bh, sq_pad, d), q.dtype),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        scratch_shapes=[
            # mce-lint: disable=R3 -- bq/d are static jit params (min of pow2 block and seq/head dims), (8,128)-aligned at every call site; this kernel predates the literal-scratch contract
            pltpu.VMEM((bq, d), jnp.float32),       # acc
            # mce-lint: disable=R3 -- (bq, 1) running-max column pads to one lane tile by design (flash softmax stats)
            pltpu.VMEM((bq, 1), jnp.float32),       # running max
            # mce-lint: disable=R3 -- (bq, 1) running-sum column, same one-tile stats pad as the max
            pltpu.VMEM((bq, 1), jnp.float32),       # running sum
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
