"""Dispatch wrapper: Pallas flash attention on TPU, jnp blockwise elsewhere.

`mha` adapts the (B, S, H, D) layout of repro.models.layers to the kernel's
flattened (B·H, S, D) layout; GQA expansion happens before the call (the
kernel is head-agnostic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def flash_attention(q, k, v, *, causal: bool = True):
    if _on_tpu():
        return kernel.flash_attention(q, k, v, causal=causal,
                                      interpret=False)
    return ref.flash_attention(q, k, v, causal=causal)


def mha(q, k, v, *, causal: bool = True):
    """(B, S, H, D) attention via the flash kernel."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    qf = jnp.swapaxes(q, 1, 2).reshape(b * h, sq, d)
    kf = jnp.swapaxes(k, 1, 2).reshape(b * h, sk, d)
    vf = jnp.swapaxes(v, 1, 2).reshape(b * h, sk, d)
    out = flash_attention(qf, kf, vf, causal=causal)
    return jnp.swapaxes(out.reshape(b, h, sq, d), 1, 2)
