"""Wrapper: edge-parallel non-triangle test over a padded-CSR graph."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common_neighbor import kernel, ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def has_common_neighbor(adj_u: jnp.ndarray, adj_v: jnp.ndarray) -> jnp.ndarray:
    if _on_tpu():
        return kernel.has_common_neighbor(adj_u, adj_v, interpret=False)
    return ref.has_common_neighbor(adj_u, adj_v)


def edge_common_neighbor(padded_adj: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    """padded_adj: (N, D) int32 sorted neighbours padded with -1;
    edges: (E, 2) int32. Returns (E,) bool — does the edge close a triangle.

    The gathers run in XLA (HBM-friendly); the dense D×D compare tile is the
    kernel. Self-matches are impossible (simple graph: u ∉ N(u))."""
    adj_u = padded_adj[edges[:, 0]]
    adj_v = padded_adj[edges[:, 1]]
    return has_common_neighbor(adj_u, adj_v)


def pad_adjacency(indptr: np.ndarray, indices: np.ndarray, max_deg: int) -> np.ndarray:
    """Host helper: CSR -> (N, max_deg) int32 padded with -1."""
    n = len(indptr) - 1
    out = -np.ones((n, max_deg), dtype=np.int32)
    for v in range(n):
        row = indices[indptr[v]:indptr[v + 1]]
        out[v, :len(row)] = row
    return out
