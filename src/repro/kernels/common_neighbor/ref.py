"""Pure-jnp oracle: common-neighbour existence per edge.

Given pre-gathered padded adjacency rows for both endpoints of each edge
(`adj_u`, `adj_v`: (E, D) int32, padded with -1), decide whether the two
endpoints share any real common neighbour. This is the inner test of the
paper's non-triangle edge reduction (§4.3, Lemma 4): edges with no common
neighbour are maximal 2-cliques and are deleted.
"""
from __future__ import annotations

import jax.numpy as jnp


def has_common_neighbor(adj_u: jnp.ndarray, adj_v: jnp.ndarray) -> jnp.ndarray:
    """(E, D) x (E, D) -> (E,) bool. Padding entries must be -1."""
    eq = adj_u[:, :, None] == adj_v[:, None, :]
    valid = (adj_u[:, :, None] >= 0) & (adj_v[:, None, :] >= 0)
    return jnp.any(eq & valid, axis=(1, 2))
