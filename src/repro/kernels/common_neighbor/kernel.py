"""Pallas TPU kernel: tiled common-neighbour existence.

Each grid step loads a (BE, D) tile of both endpoint adjacency rows into VMEM
and evaluates the all-pairs equality reduce on the VPU. The D×D comparison is
dense and regular — the TPU-native replacement for the CPU paper's
merge-based sorted-list intersection (whose data-dependent control flow does
not map to the VPU). See DESIGN.md §2 (hardware adaptation).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_E = 128


def _cn_kernel(adj_u_ref, adj_v_ref, out_ref):
    au = adj_u_ref[...]                    # (BE, D)
    av = adj_v_ref[...]                    # (BE, D)
    eq = (au[:, :, None] == av[:, None, :])
    valid = (au[:, :, None] >= 0) & (av[:, None, :] >= 0)
    hit = jnp.any(eq & valid, axis=(1, 2))
    out_ref[...] = hit[:, None].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_e", "interpret"))
def has_common_neighbor(adj_u: jnp.ndarray, adj_v: jnp.ndarray,
                        block_e: int = DEFAULT_BLOCK_E,
                        interpret: bool = True) -> jnp.ndarray:
    e, d = adj_u.shape
    be = min(block_e, e)
    e_pad = -(-e // be) * be
    if e_pad != e:
        pad = ((0, e_pad - e), (0, 0))
        adj_u = jnp.pad(adj_u, pad, constant_values=-1)
        adj_v = jnp.pad(adj_v, pad, constant_values=-1)
    out = pl.pallas_call(
        _cn_kernel,
        out_shape=jax.ShapeDtypeStruct((e_pad, 1), jnp.int32),
        grid=(e_pad // be,),
        in_specs=[
            pl.BlockSpec((be, d), lambda i: (i, 0)),
            pl.BlockSpec((be, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((be, 1), lambda i: (i, 0)),
        interpret=interpret,
    )(adj_u, adj_v)
    return out[:e, 0] != 0
