from repro.kernels.common_neighbor import kernel, ops, ref  # noqa: F401
