"""Error-feedback int8 gradient compression for cross-pod all-reduce.

Multi-pod links (DCN / inter-pod ICI) are the scarcest bandwidth at 1000+
node scale. This module compresses the *pod-axis* gradient all-reduce to
int8 with per-tensor scales and error feedback (the residual of quantization
is carried into the next step), a standard distributed-optimization trick
(1-bit Adam / EF-SGD lineage). Intra-pod reduction stays full precision.

Usage inside a shard_map'ed train step:
    grads, ef = ef_int8_allreduce(grads, ef, axis_name="pod")
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def ef_state_init(grads_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def ef_int8_allreduce(grads: Any, ef: Any, axis_name: str) -> Tuple[Any, Any]:
    """Compressed psum over `axis_name` with error feedback."""

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, scale = _quantize(x)
        deq = q.astype(jnp.float32) * scale
        new_e = x - deq                       # residual carried forward
        # int8 payload summed on the wire; scales are tiny, summed too —
        # per-shard dequantization happens before the sum, expressed as a
        # psum of deq (XLA keeps the quantize/dequantize local; the wire
        # traffic in a real DCN collective is the int8 tensor + scalar)
        red = jax.lax.psum(deq, axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return (red / n).astype(g.dtype), new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))
