"""AdamW with decoupled weight decay, global-norm clipping, pytree-native.

Kept dependency-free (no optax in this container) — ~production semantics:
fp32 moments regardless of param dtype, bias correction, per-call lr.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def adamw_init(params: Any) -> Any:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return dict(mu=jax.tree.map(zeros, params),
                nu=jax.tree.map(zeros, params),
                step=jnp.zeros((), jnp.int32))


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def adamw_update(params: Any, grads: Any, state: Any, lr: jnp.ndarray,
                 cfg: AdamWConfig = AdamWConfig()) -> Tuple[Any, Any]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    step = state["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu2 = cfg.b1 * mu + (1 - cfg.b1) * g
        nu2 = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        upd_ = (mu2 / c1) / (jnp.sqrt(nu2 / c2) + cfg.eps)
        p2 = p.astype(jnp.float32) - lr * (upd_ + cfg.weight_decay * p.astype(jnp.float32))
        return p2.astype(p.dtype), mu2, nu2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, dict(mu=new_mu, nu=new_nu, step=step)
