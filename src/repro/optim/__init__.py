from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedule import cosine_warmup
from repro.optim.compress import ef_int8_allreduce, ef_state_init

__all__ = [
    "AdamWConfig", "adamw_init", "adamw_update", "cosine_warmup",
    "ef_int8_allreduce", "ef_state_init",
]
