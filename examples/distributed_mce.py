"""Distributed, fault-tolerant MCE: shard_map fan-out + checkpoint/restart.

    PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        python examples/distributed_mce.py

Runs the production driver over 8 (virtual) devices, kills it mid-run,
then resumes from the chunk checkpoint — the exact flow a preempted pod
follows. Works on any device count (elastic cursor).
"""
import os
import tempfile
import time

from repro.core.engine import EngineConfig
from repro.core.driver import DistributedMCE
from repro.graph import kronecker


def main():
    import jax
    g = kronecker(12, 8, seed=0)
    print(f"graph: n={g.n} m={g.m}; devices={len(jax.devices())}")

    ckpt = os.path.join(tempfile.mkdtemp(), "mce_ckpt.json")
    drv = DistributedMCE(g, chunk=64, ckpt_path=ckpt,
                         cfg=EngineConfig(backend="pivot"))
    print(f"shards={drv.n_shards} (buckets stream from the host packer, "
          f"double-buffered against device chunks)")

    # simulate a preemption after 2 chunks
    n = 0
    orig = drv._run_chunk

    def preempted(*args):
        nonlocal n
        if n >= 2:
            raise RuntimeError("node lost")
        n += 1
        return orig(*args)

    drv._run_chunk = preempted
    try:
        drv.run()
    except RuntimeError as e:
        print(f"!! {e} — restarting from checkpoint {ckpt}")

    drv2 = DistributedMCE(g, chunk=64, ckpt_path=ckpt,
                          cfg=EngineConfig(backend="pivot"))
    t0 = time.perf_counter()
    res = drv2.run(resume=True)
    print(f"resumed + finished in {time.perf_counter()-t0:.1f}s: "
          f"{res.cliques} maximal cliques ({res.calls} calls)")


if __name__ == "__main__":
    main()
