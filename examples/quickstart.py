"""Quickstart: enumerate maximal cliques with RMCE on a social-like graph.

    PYTHONPATH=src python examples/quickstart.py

Shows the public API end to end: generate a graph, run the paper-faithful
reduction pipeline + bitset BK engine, compare against the plain BK baseline,
and enumerate the actual cliques of a small subgraph.
"""
import time

from repro.core import engine as bitset_engine
from repro.core.global_reduction import global_reduce_host
from repro.graph import barabasi_albert, degeneracy_order


def main():
    g = barabasi_albert(3000, 6, seed=0)
    order, rank, lam = degeneracy_order(g)
    print(f"graph: n={g.n} m={g.m} degeneracy={lam}")

    # --- the paper's global reduction, §4 ---------------------------------
    red = global_reduce_host(g)
    print(f"global reduction: {red.num_deleted_vertices} vertices and "
          f"{red.num_deleted_edges} edges deleted, "
          f"{len(red.reported)} maximal cliques reported in advance")

    # --- full RMCE vs plain BK (same TPU-style bitset engine) -------------
    for label, kw in [("BKdegen  (baseline)",
                       dict(global_red=False, dynamic_red=False, x_red=False)),
                      ("RMCEdegen (paper)", {})]:
        bitset_engine.run(g, **kw)                      # warm jit
        t0 = time.perf_counter()
        res = bitset_engine.run(g, **kw)
        dt = time.perf_counter() - t0
        print(f"{label}: {res.cliques} maximal cliques, "
              f"{res.calls} BK calls, {dt*1e3:.0f} ms")

    # --- enumeration (bounded buffer) --------------------------------------
    small = barabasi_albert(120, 5, seed=1)
    res = bitset_engine.run(small, enumerate_cliques=True, out_cap=4096)
    print(f"\nenumerated {len(res.enumerated)} cliques of a 120-vertex graph;"
          f" largest: {sorted(max(res.enumerated, key=len))}")


if __name__ == "__main__":
    main()
