"""End-to-end driver: train a ~100M-param dense LM for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --steps 300

This is the (b) "end-to-end driver" deliverable: a real model (qwen3-family
block structure at ~100M scale), the real data pipeline (deterministic token
stream + background prefetch), AdamW + cosine schedule, async keep-k
checkpointing, and restart-on-failure — the same loop the production mesh
runs, on the host device.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import Prefetcher, TokenStream
from repro.models import transformer as T
from repro.models.lm_steps import make_train_step
from repro.optim import AdamWConfig, adamw_init, cosine_warmup


def config_100m() -> T.TransformerConfig:
    """~100M params: 12L, d=768, 12H (GQA kv=4), ffn 2048, vocab 32k."""
    return T.TransformerConfig(
        name="qwen3-100m", n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
        d_head=64, d_ff=2048, vocab=32000, qk_norm=True, remat="none",
        dtype="float32")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/lm100m_ckpt")
    args = ap.parse_args()

    cfg = config_100m()
    print(f"params: {cfg.param_count()/1e6:.1f}M")
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw_init(params)
    mgr = CheckpointManager(args.ckpt, keep=2)

    stream = TokenStream(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=0)
    from repro.optim import adamw_update

    @jax.jit
    def train_step(params, opt, tokens, targets, lr):
        loss, grads = jax.value_and_grad(
            lambda p: T.lm_loss(cfg, p, tokens, targets))(params)
        params, opt = adamw_update(params, grads, opt, lr,
                                   AdamWConfig(weight_decay=0.1))
        return params, opt, loss

    start = mgr.latest_step() or 0
    if start:
        (params, opt), start, _ = mgr.restore((params, opt))
        print(f"resumed at step {start}")

    pf = Prefetcher(lambda s: stream.batch(s), depth=2, start_step=start,
                    num_steps=args.steps - start)
    t0 = time.time()
    tokens_seen = 0
    for step, (toks, tgts) in pf:
        lr = cosine_warmup(step, peak_lr=3e-4, warmup=20, total=args.steps)
        params, opt, loss = train_step(params, opt, jnp.asarray(toks),
                                       jnp.asarray(tgts), lr)
        tokens_seen += toks.size
        if step % 20 == 0 or step == args.steps - 1:
            dt = time.time() - t0
            print(f"step {step:4d}  loss {float(loss):.4f}  "
                  f"lr {float(lr):.2e}  {tokens_seen/max(dt,1e-9):.0f} tok/s",
                  flush=True)
        if (step + 1) % 100 == 0:
            mgr.save_async(step + 1, (params, opt))
    mgr.wait()
    mgr.save(args.steps, (params, opt))
    print(f"done in {time.time()-t0:.0f}s; checkpoints in {args.ckpt}")


if __name__ == "__main__":
    main()
