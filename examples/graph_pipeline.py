"""RMCE as a data-pipeline stage: clique features for GNN training.

    PYTHONPATH=src python examples/graph_pipeline.py

The paper's reductions are graph-combinatorial preprocessing. This example
shows the substrate-level integration (DESIGN.md §Arch-applicability): the
reduction + MCE engine computes per-vertex clique statistics which become
input features for a GNN node-classification run — a production pattern
(clique counts are strong community features), and the reduced graph feeds
the sampler directly.
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import engine as bitset_engine
from repro.core.global_reduction import global_reduce_host
from repro.graph import caveman
from repro.models.gnn_steps import batch_from_graph, make_gnn_train_step
from repro.configs import get_arch
from repro.models.gnn_steps import FORWARD
from repro.optim import adamw_init


def clique_features(g, out_cap: int = 65536) -> np.ndarray:
    """(N, 3) features: [#maximal cliques at v, max clique size at v,
    deleted-by-reduction flag]."""
    res = bitset_engine.run(g, enumerate_cliques=True, out_cap=out_cap)
    assert not res.overflow, "raise out_cap for this graph"
    count = np.zeros(g.n)
    maxsz = np.zeros(g.n)
    for c in res.enumerated:
        for v in c:
            count[v] += 1
            maxsz[v] = max(maxsz[v], len(c))
    red = global_reduce_host(g)
    deleted = (red.graph.degrees() == 0).astype(np.float64)
    return np.stack([count, maxsz, deleted], axis=1).astype(np.float32)


def main():
    g = caveman(24, 7, rewire=0.15, seed=0)
    print(f"graph: n={g.n} m={g.m}")
    feats = clique_features(g)
    print(f"clique features: mean #cliques/vertex {feats[:,0].mean():.2f}, "
          f"max clique size {int(feats[:,1].max())}")

    # node task: predict each vertex's community density (max clique size)
    batch = batch_from_graph(g, d_feat=8, seed=1)
    batch["node_feat"] = np.concatenate(
        [batch["node_feat"][:, :5], feats], axis=1)   # inject clique features
    batch["targets"] = feats[:, 1] / max(feats[:, 1].max(), 1)

    cfg = get_arch("meshgraphnet").build_smoke()
    _, init, _, _ = FORWARD["meshgraphnet"]
    params = init(cfg, jax.random.PRNGKey(0), 8)
    opt = adamw_init(params)
    step = jax.jit(make_gnn_train_step("meshgraphnet", cfg, 1, lr=3e-3))
    b = {k: jnp.asarray(v) for k, v in batch.items()}
    for i in range(40):
        params, opt, loss = step(params, opt, b)
        if i % 10 == 0 or i == 39:
            print(f"step {i:3d} loss {float(loss):.4f}")
    print("clique-feature GNN pipeline: OK")


if __name__ == "__main__":
    main()
