"""§Perf hillclimb measurements for the MCE engine cells (paper's technique).

Measures the trip-count-weighted per-DFS-iteration roofline terms of the
shard_map'ed counting kernel on the production mesh, current engine vs the
flag-gated paper-faithful degree pass (reuse_degrees=False).

Iterations 2 (straight-line masked DFS, no lax.cond→select) and 3 (packed
bitset X-alive stacks) are structural rewrites; their before/after numbers
were measured during the hillclimb and are recorded in EXPERIMENTS.md §Perf.

  XLA_FLAGS=--xla_force_host_platform_device_count=512 \
      PYTHONPATH=src python -m benchmarks.perf_mce
"""
from __future__ import annotations

import json
import sys


def run(out_json: str | None = None):
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core.engine import EngineConfig
    from repro.core.driver import _sharded_counts
    from repro.launch.hlo_cost import analyze
    from repro.launch.mesh import data_axes, make_production_mesh

    mesh = make_production_mesh(multi_pod=False)
    dp = data_axes(mesh)
    n_shards = 1
    for a in dp:
        n_shards *= mesh.shape[a]

    cells = [("web_sparse", 1024, 64, 64), ("social_mid", 512, 256, 256),
             ("dense_core", 128, 1024, 1024), ("orkut_scale", 256, 512, 2048)]
    rows = []
    for name, r, u, xc in cells:
        w = u // 32
        sp = P(dp)

        def sds(shape, dt):
            return jax.ShapeDtypeStruct(
                shape, dt, sharding=NamedSharding(mesh, sp))

        args = (sds((n_shards, r, u, w), jnp.uint32),
                sds((n_shards, r, w), jnp.uint32),
                sds((n_shards, r, xc, w), jnp.uint32),
                sds((n_shards, r, xc), jnp.bool_),
                sds((n_shards, r), jnp.int32))
        for label, cfg in [
                ("paper-3sweep", EngineConfig(max_iters=1 << 20,
                                              reuse_degrees=False)),
                ("opt-reuse-deg", EngineConfig(max_iters=1 << 20,
                                               reuse_degrees=True))]:
            def fn(a_, p_, x_, l_, z_, cfg=cfg):
                return _sharded_counts(a_, p_, x_, l_, z_, cfg, mesh, dp)

            with mesh:
                c = jax.jit(fn).lower(*args).compile()
            wk = analyze(c.as_text())
            print(f"{name:12s} {label:14s} flops/iter={wk['flops']:.4e} "
                  f"bytes/iter={wk['bytes']:.4e} "
                  f"tm/iter={wk['bytes']/819e9*1e3:.3f}ms", flush=True)
            rows.append(dict(cell=name, variant=label, flops=wk["flops"],
                             bytes=wk["bytes"], link=wk["link"]))
    if out_json:
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    run(sys.argv[1] if len(sys.argv) > 1 else None)
