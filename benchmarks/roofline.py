"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by ``python -m repro.launch.dryrun
--all --out experiments/dryrun``) and emits, per (arch × shape × mesh):
three roofline terms in seconds, the dominant bottleneck, MODEL_FLOPS /
HLO_FLOPs utilisation, and a one-line lever on the dominant term.

Terms (TPU v5e): compute = flops/dev ÷ 197e12; memory = bytes/dev ÷ 819e9;
collective = link_bytes/dev ÷ 50e9. flops/bytes come from
``compiled.cost_analysis()`` of the partitioned per-device module;
link_bytes from parsing collective ops out of the optimized HLO.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

LEVERS = {
    "compute": "more chips / lower-precision matmuls / fewer recompute "
               "(remat policy) — compute-bound is the roofline target",
    "memory": "fuse elementwise chains, cast activations to bf16, raise "
              "arithmetic intensity (bigger per-chip tiles)",
    "collective": "shard to cut gather volume (FSDP prefetch overlap), "
                  "int8-compress cross-pod grads, overlap collectives "
                  "with compute (async collectives)",
}


def load_records(dirpath: str = "experiments/dryrun") -> List[Dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_row(r: Dict) -> str:
    if r["kind"] == "skip":
        return (f"{r['arch']:22s} {r['cell']:15s} {r['mesh']:8s} "
                f"SKIPPED ({r['note'][:60]})")
    if not r["ok"]:
        return (f"{r['arch']:22s} {r['cell']:15s} {r['mesh']:8s} FAILED")
    util = (r["model_flops"] / (r["flops_per_device"] * r["n_devices"])
            if r["flops_per_device"] else 0.0)
    dom = r["bottleneck"]
    t_dom = r[f"t_{dom}"]
    frac = t_dom / max(r["t_compute"] + 1e-30, 1e-30)
    return (f"{r['arch']:22s} {r['cell']:15s} {r['mesh']:8s} "
            f"tc={r['t_compute']:.3e} tm={r['t_memory']:.3e} "
            f"tx={r['t_collective']:.3e} dom={dom:10s} "
            f"useful/HLO={util:.2f} peak={r['peak_memory_per_device']/2**30:.1f}GiB")


def main(fast: bool = False) -> str:
    recs = load_records()
    if not recs:
        return ("# roofline: no dry-run records found — run\n"
                "#   python -m repro.launch.dryrun --all --multi-pod both "
                "--out experiments/dryrun\n")
    out = ["# roofline terms per (arch × shape × mesh), seconds per step",
           "# tc=compute tm=memory tx=collective; useful/HLO = "
           "MODEL_FLOPS/(HLO flops × devices)"]
    ok = [r for r in recs if r["ok"] and r["kind"] != "skip"]
    skip = [r for r in recs if r["kind"] == "skip"]
    fail = [r for r in recs if not r["ok"]]
    for r in sorted(ok, key=lambda r: (r["arch"], r["cell"], r["mesh"])):
        out.append(fmt_row(r))
    for r in sorted(skip, key=lambda r: (r["arch"], r["cell"], r["mesh"])):
        out.append(fmt_row(r))
    out.append(f"# {len(ok)} compiled, {len(skip)} skipped, "
               f"{len(fail)} failed")
    # bottleneck census + levers
    census: Dict[str, int] = {}
    for r in ok:
        census[r["bottleneck"]] = census.get(r["bottleneck"], 0) + 1
    for k, v in sorted(census.items()):
        out.append(f"# bottleneck {k}: {v} cells — lever: {LEVERS[k]}")
    return "\n".join(out) + "\n"


if __name__ == "__main__":
    print(main())
