"""§Perf hillclimb harness: named variants per target cell, measured via the
trip-count-weighted HLO walker (the dry-run 'profile').

MUST run with 512 virtual devices:
  XLA_FLAGS=--xla_force_host_platform_device_count=512 \
      PYTHONPATH=src python -m benchmarks.perf_iterations --cell qwen3_train

Each variant is (name, hypothesis, cfg_map). Results (three roofline terms +
deltas vs previous variant) print as the §Perf iteration log.
"""
from __future__ import annotations

import argparse
import dataclasses as dc
import sys


def _hints(heads_tp: bool, ctx: bool = False, ffn_tp: bool = True,
           seq_res: bool = False):
    return (("data",), "model", heads_tp, ctx, ffn_tp, seq_res)


def qwen3_variants():
    yield ("V0-baseline", "paper-faithful default GSPMD layout", None)
    yield ("V1-act-hints",
           "activations pinned batch-parallel (Megatron TP): GSPMD was "
           "replicating the batch because 40 heads % 16 != 0 forced a "
           "d_model-sharded fallback — expect ~16x less attention "
           "compute/bytes per device",
           lambda c: dc.replace(c, shard_hints=_hints(False)))
    yield ("V2-ctx-parallel",
           "attention still /16 only (40 heads don't divide 16): shard the "
           "QUERY seq dim over model (context parallelism) — attention "
           "dots drop another 16x to /256",
           lambda c: dc.replace(c, shard_hints=_hints(False, ctx=True)))
    yield ("V3-chunked-ce",
           "chunked cross-entropy (512): the (B,S,V) logits + log-softmax "
           "chain never materialises beyond one chunk — expect tm drop",
           lambda c: dc.replace(c, shard_hints=_hints(False, ctx=True),
                                loss_chunk=512))
    yield ("V4-remat-dots",
           "dots_saveable remat: keep (non-batch) matmul outputs, recompute "
           "elementwise only — trades bytes for flops; expect tc down",
           lambda c: dc.replace(c, shard_hints=_hints(False, ctx=True),
                                loss_chunk=512, remat="dots"))
    yield ("V5-flash-bwd",
           "remat the kv-block body: the scan saves (4,B,H,Sq,KV) "
           "probability stacks as bwd residuals (~10% of bytes); flash-style "
           "recompute drops them for ~1 extra block fwd of flops",
           lambda c: dc.replace(c, shard_hints=_hints(False, ctx=True),
                                loss_chunk=512, remat_blocks=True))
    yield ("V6-zero3-ffn",
           "Megatron FFN all-reduces move 2·(B,S,D) activations/layer but "
           "gathering the FFN weights is ~5x less volume at B·S=1M tokens: "
           "switch FFN to data-parallel + weight gather (ZeRO-3)",
           lambda c: dc.replace(c,
                                shard_hints=_hints(False, ctx=True,
                                                   ffn_tp=False),
                                loss_chunk=512, remat_blocks=True))
    yield ("V7-zero3+dots",
           "combine the winners: ZeRO-3 FFN + flash bwd + dots remat",
           lambda c: dc.replace(c,
                                shard_hints=_hints(False, ctx=True,
                                                   ffn_tp=False),
                                loss_chunk=512, remat_blocks=True,
                                remat="dots"))
    yield ("V9-seq-residual",
           "Megatron sequence parallelism: keep the residual stream "
           "sequence-sharded between blocks — activations stream at 1/16, "
           "and TP all-reduces should decompose into RS+AG pairs",
           lambda c: dc.replace(c,
                                shard_hints=_hints(False, ctx=True,
                                                   seq_res=True),
                                loss_chunk=512, remat="dots"))


def commandr_variants():
    yield ("V0-baseline", "paper-faithful default GSPMD layout", None)
    yield ("V1-act-hints",
           "96 heads % 16 == 0: full Megatron TP over heads + d_ff + vocab; "
           "pins batch parallelism, expect collective-volume drop from "
           "removed activation reshards",
           lambda c: dc.replace(c, shard_hints=_hints(True)))
    yield ("V2-chunked-ce",
           "vocab 256k: logits chain is 1M x 256k; chunked CE cuts its "
           "stored activations and the cross-shard softmax traffic",
           lambda c: dc.replace(c, shard_hints=_hints(True),
                                loss_chunk=512))
    yield ("V3-remat-dots",
           "cheaper recompute policy on top",
           lambda c: dc.replace(c, shard_hints=_hints(True), loss_chunk=512,
                                remat="dots"))
    yield ("V4-flash-bwd",
           "drop the saved per-block probability stacks "
           "(f32[4,16,6,4096,1024] = 6.9% of bytes) via flash-style "
           "block recompute",
           lambda c: dc.replace(c, shard_hints=_hints(True), loss_chunk=512,
                                remat="dots", remat_blocks=True))


def mixtral_variants():
    yield ("V0-baseline", "paper-faithful default GSPMD layout", None)
    yield ("V1-act-hints",
           "32 heads % 16 == 0: Megatron TP + EP; batch stays data-parallel",
           lambda c: dc.replace(c, shard_hints=_hints(True)))
    yield ("V2-chunked-ce", "chunked CE on top",
           lambda c: dc.replace(c, shard_hints=_hints(True), loss_chunk=512))


CELLS = {
    "qwen3_train": ("qwen3-14b", "train_4k", qwen3_variants),
    "commandr_train": ("command-r-plus-104b", "train_4k", commandr_variants),
    "mixtral_train": ("mixtral-8x7b", "train_4k", mixtral_variants),
}


def run(cell_key: str, out_json: str | None = None):
    from repro.launch.dryrun import run_cell
    arch, shape, variants = CELLS[cell_key]
    print(f"### §Perf hillclimb: {arch}/{shape} (single-pod 16x16)")
    prev = None
    rows = []
    for name, hypothesis, cfg_map in variants():
        rec = run_cell(arch, shape, multi_pod=False, cfg_map=cfg_map)
        if not rec.ok:
            print(f"{name}: FAILED\n{rec.error[-1500:]}")
            continue
        t = dict(tc=rec.t_compute, tm=rec.t_memory, tx=rec.t_collective)
        dom = max(t, key=t.get)
        line = (f"{name:15s} tc={t['tc']:.3f}s tm={t['tm']:.3f}s "
                f"tx={t['tx']:.3f}s dom={dom} "
                f"flops/dev={rec.flops_per_device:.3e} "
                f"bytes/dev={rec.bytes_per_device:.3e} "
                f"link/dev={rec.link_bytes_per_device:.3e}")
        if prev:
            dd = {k: (t[k] - prev[k]) / prev[k] * 100 if prev[k] else 0.0
                  for k in t}
            line += (f"  Δ(tc {dd['tc']:+.0f}%, tm {dd['tm']:+.0f}%, "
                     f"tx {dd['tx']:+.0f}%)")
        print("  hypothesis:", hypothesis)
        print("  " + line, flush=True)
        rows.append(dict(variant=name, hypothesis=hypothesis, **t,
                         flops=rec.flops_per_device,
                         bytes=rec.bytes_per_device,
                         link=rec.link_bytes_per_device,
                         collectives=rec.collectives))
        prev = t
    if out_json:
        import json
        with open(out_json, "w") as f:
            json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", required=True, choices=sorted(CELLS))
    ap.add_argument("--out", default=None)
    a = ap.parse_args()
    run(a.cell, a.out)
