"""Benchmark aggregator: one section per paper table/figure + roofline.

``python -m benchmarks.run [--fast]``
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller graph suite (CI)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset (fig7,fig8,...)")
    args = ap.parse_args()

    from benchmarks import (fig7_speedups, fig8_reduction, fig9_calls,
                            fig10_forbidden, fig11_visits, table3_ablation,
                            roofline)

    sections = [
        ("fig8", fig8_reduction.main),
        ("fig9", fig9_calls.main),
        ("fig10", fig10_forbidden.main),
        ("fig11", fig11_visits.main),
        ("fig7", fig7_speedups.main),
        ("table3", table3_ablation.main),
        ("roofline", roofline.main),
    ]
    only = set(args.only.split(",")) if args.only else None
    for name, fn in sections:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            out = fn(fast=args.fast)
        except Exception as e:  # keep the suite running; report the failure
            out = f"# {name} FAILED: {type(e).__name__}: {e}\n"
        sys.stdout.write(f"\n===== {name} ({time.time()-t0:.1f}s) =====\n")
        sys.stdout.write(out)
        sys.stdout.flush()


if __name__ == "__main__":
    main()
