"""Persistent lane-refill engine vs lock-step vmap on a skewed-root workload.

The lock-step comparator mirrors the driver's per-root path: cost-descending
chunks of `chunk` roots, one vmapped `run_bucket` per chunk — every lane in
a chunk spins (masked) until the chunk's slowest root finishes, so one
unsplit hub root stalls its whole chunk. The persistent engine walks the
same cost-descending queue with `lanes` resident DFS states; a lane whose
subtree exhausts claims the next root on device, so the hub monopolizes one
lane while the rest drain the queue.

Workload: a sparse BA graph with one planted dense blob (`--blob`,
`--blob-p`) packed into a SINGLE bucket size, so the hub root and the tail
share one queue. `split_threshold` is intentionally unset: the hub staying
unsplit is the lock-step worst case this engine exists for.

Emits BENCH_engine.json (last run at top level + full history under
"runs" — see benchmarks/bench_record.py):
  {graph, n, m, roots, iters_total, iters_hub,
   lockstep_s, persistent_s, speedup,
   lockstep_occupancy, persistent_occupancy, lanes, chunk,
   runs: [{commit, date, ...same metrics}, ...]}

  PYTHONPATH=src python -m benchmarks.perf_engine --out BENCH_engine.json
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np


def skewed_graph(n: int, m: int, blob: int, blob_p: float, seed: int = 7):
    from repro.graph import generators as gen
    from repro.graph.csr import from_edge_list

    g = gen.barabasi_albert(n, m, seed=seed)
    rng = np.random.default_rng(seed)
    extra = [(i, j) for i in range(blob) for j in range(i + 1, blob)
             if rng.random() < blob_p]
    e = np.concatenate([g.edges().astype(np.int64),
                        np.array(extra, np.int64)])
    key = e[:, 0] * n + e[:, 1]
    e = e[np.unique(key, return_index=True)[1]]
    return from_edge_list(n, e)


def run(n: int = 4000, m: int = 8, blob: int = 40, blob_p: float = 0.6,
        bucket: int = 64, chunk: int = 256, lanes: int = 16,
        out_json: str | None = "BENCH_engine.json"):
    from repro.core.driver import canonical_order, estimate_costs
    from repro.core.engine import (EngineConfig, prepare, run_bucket,
                                   run_bucket_persistent)

    g = skewed_graph(n, m, blob, blob_p)
    print(f"graph ba:n={n},m={m} + blob({blob},p={blob_p}): "
          f"n={g.n} m={g.m}", flush=True)
    prep = prepare(g, bucket_sizes=(bucket,))
    (bk,) = prep.buckets
    order = canonical_order(estimate_costs(bk))
    R = bk.num_roots
    cfg = EngineConfig()
    arrs = (bk.a[order], bk.p0[order], bk.x_rows[order],
            bk.x_alive0[order], bk.rsz0[order])

    # ---- lock-step comparator: cost-desc chunks, pad the last chunk ------
    def chunk_args(lo: int):
        hi = min(lo + chunk, R)
        pad = chunk - (hi - lo)
        parts = []
        for arr in arrs:
            sl = arr[lo:hi]
            if pad:
                fill = np.ones(pad, np.int32) if arr is arrs[-1] else \
                    np.zeros((pad,) + arr.shape[1:], arr.dtype)
                sl = np.concatenate([sl, fill])
            parts.append(jnp.asarray(sl))
        return parts, pad

    def lockstep():
        tot = {k: 0 for k in ("cliques", "calls", "branches", "sum_px")}
        live = spin = 0
        for lo in range(0, R, chunk):
            parts, pad = chunk_args(lo)
            out = run_bucket(*parts, cfg)
            iters = np.asarray(out["iters"])
            live += int(iters.sum())
            spin += chunk * int(iters.max())
            for k in tot:
                tot[k] += int(np.asarray(out[k]).sum())
            tot["calls"] -= pad        # empty pad roots: one call each
        return tot, live, spin

    def persistent():
        out = run_bucket_persistent(*(jnp.asarray(x) for x in arrs), cfg,
                                    lanes=lanes)
        tot = {k: int(np.asarray(out[k]).sum())
               for k in ("cliques", "calls", "branches", "sum_px")}
        live = int(out["live_iters"])
        spin = lanes * int(out["iters"])
        return tot, live, spin

    # warmup compiles both paths; second pass measures steady state
    t_lock, t_pers = [], []
    for it in range(2):
        t0 = time.perf_counter()
        lock_tot, lock_live, lock_spin = lockstep()
        t_lock.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        pers_tot, pers_live, pers_spin = persistent()
        t_pers.append(time.perf_counter() - t0)
        assert lock_tot == pers_tot, (lock_tot, pers_tot)

    # per-root iteration profile (skew evidence)
    iters = []
    for lo in range(0, R, chunk):
        parts, pad = chunk_args(lo)
        out = run_bucket(*parts, cfg)
        it_arr = np.asarray(out["iters"])
        iters.append(it_arr[:chunk - pad] if pad else it_arr)
    iters = np.concatenate(iters)

    lock_occ = lock_live / lock_spin
    pers_occ = pers_live / pers_spin
    speedup = t_lock[-1] / t_pers[-1]
    row = dict(graph=f"ba:n={n},m={m}+blob({blob},p={blob_p})",
               n=g.n, m=g.m, roots=R, bucket=bucket,
               chunk=chunk, lanes=lanes,
               iters_total=int(iters.sum()), iters_hub=int(iters.max()),
               lockstep_s=t_lock[-1], persistent_s=t_pers[-1],
               speedup=speedup,
               lockstep_occupancy=lock_occ,
               persistent_occupancy=pers_occ,
               cliques=lock_tot["cliques"])
    print(f"roots={R} iters: total={row['iters_total']} "
          f"hub={row['iters_hub']} "
          f"(hub is {row['iters_hub'] / row['iters_total']:.0%} of all work)",
          flush=True)
    print(f"lock-step  : {t_lock[-1]:.2f}s occupancy={lock_occ:.2f} "
          f"(chunk={chunk})", flush=True)
    print(f"persistent : {t_pers[-1]:.2f}s occupancy={pers_occ:.2f} "
          f"(lanes={lanes})", flush=True)
    print(f"speedup: {speedup:.2f}x", flush=True)
    if out_json:
        from benchmarks.bench_record import append_run
        append_run(out_json, row)   # appends to "runs", keeps top-level compat
    return row


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--blob", type=int, default=40)
    ap.add_argument("--blob-p", type=float, default=0.6)
    ap.add_argument("--bucket", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=256)
    ap.add_argument("--lanes", type=int, default=16)
    ap.add_argument("--out", default="BENCH_engine.json")
    a = ap.parse_args()
    run(a.n, a.m, a.blob, a.blob_p, a.bucket, a.chunk, a.lanes, a.out)
