"""Persistent lane-refill engine vs lock-step vmap on a skewed-root workload.

The lock-step comparator mirrors the driver's per-root path: cost-descending
chunks of `chunk` roots, one vmapped `run_bucket` per chunk — every lane in
a chunk spins (masked) until the chunk's slowest root finishes, so one
unsplit hub root stalls its whole chunk. The persistent engine walks the
same cost-descending queue with `lanes` resident DFS states; a lane whose
subtree exhausts claims the next root on device, so the hub monopolizes one
lane while the rest drain the queue.

Workload: a sparse BA graph with one planted dense blob (`--blob`,
`--blob-p`) packed into a SINGLE bucket size, so the hub root and the tail
share one queue. `split_threshold` is intentionally unset: the hub staying
unsplit is the lock-step worst case this engine exists for.

`--stream` switches to the multi-bucket workload: the same skewed root
population split into a cost-descending sequence of same-shape slabs (the
`PrepStream` bucket sequence shape). The per-bucket comparator drains the
persistent queue at every slab boundary — lanes idle behind the slab's
slowest subtree (the hub) while the next slab's roots wait on the host.
The bucket-spanning engine (`run_stream_persistent`) carries lane state
across the boundary, so claimed-out slabs hand refills straight to the
next slab's queue and idle lanes steal from the hub at the tail. Records
`boundary_stall` (the per-bucket path's idle lane-trip fraction — the
capacity the spanning engine reclaims), `steals`, and the end-to-end
`speedup`, and asserts exact clique-count AND enumerated-set parity
between the two paths before writing anything.

Emits BENCH_engine.json (last run at top level + full history under
"runs" — see benchmarks/bench_record.py):
  {graph, n, m, roots, iters_total, iters_hub,
   lockstep_s, persistent_s, speedup,
   lockstep_occupancy, persistent_occupancy, lanes, chunk,
   runs: [{commit, date, ...same metrics}, ...]}
and with --stream (speedup = windowed-over-spanning at the best K from
the window_steps sweep; spanning_speedup keeps the older
per-bucket-over-spanning ratio):
  {graph, n, m, roots, slabs, lanes, perbucket_s, stream_s, windowed_s,
   speedup, spanning_speedup, window_steps, window_spills, window_hits,
   window_sweep, boundary_stall, stream_occupancy, steals, cliques,
   enumerated, ...}

  PYTHONPATH=src python -m benchmarks.perf_engine --out BENCH_engine.json
  PYTHONPATH=src python -m benchmarks.perf_engine --stream
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np


def skewed_graph(n: int, m: int, blob: int, blob_p: float, seed: int = 7):
    from repro.graph import generators as gen
    from repro.graph.csr import from_edge_list

    g = gen.barabasi_albert(n, m, seed=seed)
    rng = np.random.default_rng(seed)
    extra = [(i, j) for i in range(blob) for j in range(i + 1, blob)
             if rng.random() < blob_p]
    e = np.concatenate([g.edges().astype(np.int64),
                        np.array(extra, np.int64)])
    key = e[:, 0] * n + e[:, 1]
    e = e[np.unique(key, return_index=True)[1]]
    return from_edge_list(n, e)


def run(n: int = 4000, m: int = 8, blob: int = 40, blob_p: float = 0.6,
        bucket: int = 64, chunk: int = 256, lanes: int = 16,
        out_json: str | None = "BENCH_engine.json"):
    from repro.core.driver import canonical_order, estimate_costs
    from repro.core.engine import (EngineConfig, prepare, run_bucket,
                                   run_bucket_persistent)

    g = skewed_graph(n, m, blob, blob_p)
    print(f"graph ba:n={n},m={m} + blob({blob},p={blob_p}): "
          f"n={g.n} m={g.m}", flush=True)
    prep = prepare(g, bucket_sizes=(bucket,))
    (bk,) = prep.buckets
    order = canonical_order(estimate_costs(bk))
    R = bk.num_roots
    cfg = EngineConfig()
    arrs = (bk.a[order], bk.p0[order], bk.x_rows[order],
            bk.x_alive0[order], bk.rsz0[order])

    # ---- lock-step comparator: cost-desc chunks, pad the last chunk ------
    def chunk_args(lo: int):
        hi = min(lo + chunk, R)
        pad = chunk - (hi - lo)
        parts = []
        for arr in arrs:
            sl = arr[lo:hi]
            if pad:
                fill = np.ones(pad, np.int32) if arr is arrs[-1] else \
                    np.zeros((pad,) + arr.shape[1:], arr.dtype)
                sl = np.concatenate([sl, fill])
            parts.append(jnp.asarray(sl))
        return parts, pad

    def lockstep():
        tot = {k: 0 for k in ("cliques", "calls", "branches", "sum_px")}
        live = spin = 0
        for lo in range(0, R, chunk):
            parts, pad = chunk_args(lo)
            out = run_bucket(*parts, cfg)
            iters = np.asarray(out["iters"])
            live += int(iters.sum())
            spin += chunk * int(iters.max())
            for k in tot:
                tot[k] += int(np.asarray(out[k]).sum())
            tot["calls"] -= pad        # empty pad roots: one call each
        return tot, live, spin

    def persistent():
        out = run_bucket_persistent(*(jnp.asarray(x) for x in arrs), cfg,
                                    lanes=lanes)
        tot = {k: int(np.asarray(out[k]).sum())
               for k in ("cliques", "calls", "branches", "sum_px")}
        live = int(out["live_iters"])
        spin = lanes * int(out["iters"])
        return tot, live, spin

    # warmup compiles both paths; second pass measures steady state
    t_lock, t_pers = [], []
    for it in range(2):
        t0 = time.perf_counter()
        lock_tot, lock_live, lock_spin = lockstep()
        t_lock.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        pers_tot, pers_live, pers_spin = persistent()
        t_pers.append(time.perf_counter() - t0)
        assert lock_tot == pers_tot, (lock_tot, pers_tot)

    # per-root iteration profile (skew evidence)
    iters = []
    for lo in range(0, R, chunk):
        parts, pad = chunk_args(lo)
        out = run_bucket(*parts, cfg)
        it_arr = np.asarray(out["iters"])
        iters.append(it_arr[:chunk - pad] if pad else it_arr)
    iters = np.concatenate(iters)

    lock_occ = lock_live / lock_spin
    pers_occ = pers_live / pers_spin
    speedup = t_lock[-1] / t_pers[-1]
    row = dict(graph=f"ba:n={n},m={m}+blob({blob},p={blob_p})",
               n=g.n, m=g.m, roots=R, bucket=bucket,
               chunk=chunk, lanes=lanes,
               iters_total=int(iters.sum()), iters_hub=int(iters.max()),
               lockstep_s=t_lock[-1], persistent_s=t_pers[-1],
               speedup=speedup,
               lockstep_occupancy=lock_occ,
               persistent_occupancy=pers_occ,
               cliques=lock_tot["cliques"])
    print(f"roots={R} iters: total={row['iters_total']} "
          f"hub={row['iters_hub']} "
          f"(hub is {row['iters_hub'] / row['iters_total']:.0%} of all work)",
          flush=True)
    print(f"lock-step  : {t_lock[-1]:.2f}s occupancy={lock_occ:.2f} "
          f"(chunk={chunk})", flush=True)
    print(f"persistent : {t_pers[-1]:.2f}s occupancy={pers_occ:.2f} "
          f"(lanes={lanes})", flush=True)
    print(f"speedup: {speedup:.2f}x", flush=True)
    if out_json:
        from benchmarks.bench_record import append_run
        append_run(out_json, row)   # appends to "runs", keeps top-level compat
    return row


def run_stream(n: int = 4000, m: int = 6, blob: int = 60,
               blob_p: float = 0.7, bucket: int = 64, slabs: int = 10,
               lanes: int = 32, out_cap: int = 4096,
               out_json: str | None = "BENCH_engine.json",
               window_sweep: tuple = (4, 8, 16, 32)):
    """Multi-bucket workload: bucket-spanning engine vs per-bucket drains.

    The baseline is the pre-spanning engine exactly as the driver ran it:
    one `run_bucket_persistent` launch per slab with stealing off — every
    slab boundary drains the queue, so the hub's subtree serializes one
    lane while the other `lanes-1` idle until the drain completes. The
    spanning path runs the same slab sequence through
    `run_stream_persistent` with stealing on. Both paths are asserted to
    exact clique-count AND enumerated-set parity before any metric is
    recorded (stealing and spanning are pure scheduling).

    The windowed sweep then re-runs the spanning path with
    `window_steps=K` for each K in `window_sweep` — lanes walk K
    frame-steps per stack round-trip over a resident stack window — and
    records the best K as `window_steps` with the headline `speedup` =
    unwindowed-spanning over best-windowed time (this PR's
    windowed-over-spanning claim; the older per-bucket-over-spanning
    ratio stays under `spanning_speedup`). The best-K config also runs
    the enumerated-set parity pass — windowing must neither drop nor
    reorder-beyond-scheduling any clique."""
    import dataclasses

    import jax

    from repro.core.driver import canonical_order
    from repro.core.engine import (EngineConfig, estimate_costs, prepare,
                                   run_bucket_persistent,
                                   run_stream_persistent)

    g = skewed_graph(n, m, blob, blob_p)
    print(f"graph ba:n={n},m={m} + blob({blob},p={blob_p}): "
          f"n={g.n} m={g.m}", flush=True)
    prep = prepare(g, bucket_sizes=(bucket,))
    (bk,) = prep.buckets
    total = bk.num_roots - bk.n_pad          # pad no-op roots: not scheduled
    # PrepStream flush semantics: slabs are ARRIVAL-order (degeneracy-order)
    # chunks of the root population, each sorted cost-descending internally
    # — the stream is never globally cost-sorted, so the hub lands deep in
    # one mid-stream slab and its subtree is that slab's entire drain
    costs = estimate_costs(bk)[:total]
    per = -(-total // slabs)
    arrs = (bk.a, bk.p0, bk.x_rows, bk.x_alive0, bk.rsz0)
    slab_list = []
    for lo in range(0, total, per):
        sub = lo + canonical_order(costs[lo:lo + per])
        slab_list.append(tuple(jnp.asarray(arr[sub]) for arr in arrs))
    bases = np.cumsum([0] + [s[0].shape[0] for s in slab_list])
    cfg_base = EngineConfig(steal=False)     # the pre-spanning engine
    cfg_span = EngineConfig(steal=True)

    def perbucket(cfg):
        tot = {k: 0 for k in ("cliques", "calls", "branches", "sum_px")}
        live = cap = 0
        for slab in slab_list:
            L = min(lanes, slab[0].shape[0])
            out = run_bucket_persistent(*slab, cfg, lanes=L)
            for k in tot:
                tot[k] += int(np.asarray(out[k]).sum())
            live += int(out["live_iters"])
            cap += L * int(out["iters"])
        return tot, live, cap

    def spanning(cfg):
        spt = max(1, cfg.window_steps)    # windowed trips walk K steps each
        outs, spans = run_stream_persistent(slab_list, cfg, lanes=lanes)
        tot = {k: sum(int(np.asarray(o[k]).sum()) for o in outs)
               for k in ("cliques", "calls", "branches", "sum_px")}
        live = sum(int(o["live_iters"]) for o in outs)
        cap = sum(int(o["iters"]) * int(np.asarray(o["calls"]).shape[0])
                  for o in outs) * spt
        steals = sum(int(o["steals"]) for o in outs)
        spills = sum(int(o.get("window_spills", 0)) for o in outs)
        hits = sum(int(o.get("window_hits", 0)) for o in outs)
        return tot, live, cap, steals, len(spans), spills, hits

    # warmup compiles both paths; second pass measures steady state
    t_pb, t_st = [], []
    for _ in range(2):
        t0 = time.perf_counter()
        pb_tot, pb_live, pb_cap = perbucket(cfg_base)
        t_pb.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        st_tot, st_live, st_cap, steals, n_spans, _, _ = spanning(cfg_span)
        t_st.append(time.perf_counter() - t0)
        assert pb_tot == st_tot, (pb_tot, st_tot)

    # ---- windowed-lane sweep: VMEM-resident stack windows inside the
    # spanning loop. Each K is a separate compile (the window phase is a
    # static inner loop), so warmup-then-measure per K; windowing is pure
    # scheduling, so every K must reproduce the unwindowed counters
    # exactly before its time counts.
    sweep = []
    for K in window_sweep:
        cfg_win = dataclasses.replace(cfg_span, window_steps=K)
        for _ in range(2):
            t0 = time.perf_counter()
            (w_tot, w_live, w_cap, w_steals,
             _, w_spills, w_hits) = spanning(cfg_win)
            t_w = time.perf_counter() - t0
        assert w_tot == st_tot, (K, w_tot, st_tot)
        sweep.append(dict(window_steps=K, windowed_s=t_w,
                          speedup=t_st[-1] / t_w,
                          window_spills=w_spills, window_hits=w_hits,
                          steals=w_steals,
                          occupancy=w_live / w_cap if w_cap else 0.0))
        print(f"window K={K:3d}: {t_w:.2f}s "
              f"speedup-over-spanning={sweep[-1]['speedup']:.2f}x "
              f"spills={w_spills} hits={w_hits} "
              f"occ={sweep[-1]['occupancy']:.2f}", flush=True)
    best = max(sweep, key=lambda r: r["speedup"])

    # enumerated-set parity (untimed): same roots, same cliques, lane and
    # boundary scheduling free — compare (stream-global root, members) sets
    def enum_sets():
        ecfg_b = EngineConfig(steal=False, out_cap=out_cap)
        ecfg_s = EngineConfig(steal=True, out_cap=out_cap)
        ecfg_w = dataclasses.replace(ecfg_s,
                                     window_steps=best["window_steps"])
        pb = set()
        for si, slab in enumerate(slab_list):
            L = min(lanes, slab[0].shape[0])
            out = run_bucket_persistent(*slab, ecfg_b, lanes=L)
            out = jax.tree.map(np.asarray, out)
            assert not out["overflow"].any(), "raise --out-cap"
            for l in range(out["out_n"].shape[0]):
                for k in range(int(out["out_n"][l])):
                    pb.add((int(bases[si]) + int(out["out_root"][l, k]),
                            out["out_rows"][l, k].tobytes()))
        stream_sets = []
        for ecfg in (ecfg_s, ecfg_w):
            st = set()
            outs, _ = run_stream_persistent(slab_list, ecfg, lanes=lanes)
            for out in outs:
                out = jax.tree.map(np.asarray, out)
                assert not out["overflow"].any(), "raise --out-cap"
                for l in range(out["out_n"].shape[0]):
                    for k in range(int(out["out_n"][l])):
                        st.add((int(out["out_root"][l, k]),
                                out["out_rows"][l, k].tobytes()))
            stream_sets.append(st)
        return pb, stream_sets[0], stream_sets[1]

    pb_set, st_set, win_set = enum_sets()
    assert pb_set == st_set, (
        f"enumerated-set divergence: {len(pb_set - st_set)} only-perbucket, "
        f"{len(st_set - pb_set)} only-stream")
    assert win_set == st_set, (
        f"windowed enumerated-set divergence at K={best['window_steps']}: "
        f"{len(st_set - win_set)} dropped, {len(win_set - st_set)} extra")
    assert len(pb_set) == pb_tot["cliques"]

    boundary_stall = 1.0 - pb_live / pb_cap
    stream_occ = st_live / st_cap
    spanning_speedup = t_pb[-1] / t_st[-1]
    row = dict(graph=f"ba:n={n},m={m}+blob({blob},p={blob_p})",
               n=g.n, m=g.m, roots=total, slabs=len(slab_list),
               lanes=lanes, bucket=bucket,
               perbucket_s=t_pb[-1], stream_s=t_st[-1],
               # headline: windowed-over-spanning at the best K; the
               # PR-9 per-bucket-over-spanning ratio keeps its own key
               speedup=best["speedup"],
               spanning_speedup=spanning_speedup,
               window_steps=best["window_steps"],
               windowed_s=best["windowed_s"],
               window_spills=best["window_spills"],
               window_hits=best["window_hits"],
               window_sweep=[dict(window_steps=r["window_steps"],
                                  windowed_s=r["windowed_s"],
                                  speedup=r["speedup"]) for r in sweep],
               boundary_stall=boundary_stall,
               stream_occupancy=stream_occ, steals=steals,
               spans=n_spans, cliques=pb_tot["cliques"],
               enumerated=len(pb_set))
    print(f"roots={total} slabs={len(slab_list)} spans={n_spans} "
          f"cliques={row['cliques']} (enumerated parity: {len(pb_set)} "
          f"sets equal, windowed included)", flush=True)
    print(f"per-bucket : {t_pb[-1]:.2f}s stall={boundary_stall:.2f} "
          f"(drains at every slab boundary, no stealing)", flush=True)
    print(f"spanning   : {t_st[-1]:.2f}s occupancy={stream_occ:.2f} "
          f"steals={steals} ({spanning_speedup:.2f}x over per-bucket)",
          flush=True)
    print(f"windowed   : {best['windowed_s']:.2f}s at "
          f"K={best['window_steps']} spills={best['window_spills']} "
          f"hits={best['window_hits']}", flush=True)
    print(f"speedup (windowed over spanning): {best['speedup']:.2f}x",
          flush=True)
    if out_json:
        from benchmarks.bench_record import append_run
        append_run(out_json, row)
    return row


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    # unset size knobs resolve per mode: the single-bucket workload keeps
    # its historical shape (trajectory comparability); --stream defaults a
    # bit smaller with a denser blob so the hub dominates a slab
    ap.add_argument("--n", type=int, default=None)
    ap.add_argument("--m", type=int, default=None)
    ap.add_argument("--blob", type=int, default=None)
    ap.add_argument("--blob-p", type=float, default=None)
    ap.add_argument("--bucket", type=int, default=64)
    ap.add_argument("--chunk", type=int, default=256)
    ap.add_argument("--lanes", type=int, default=None)
    ap.add_argument("--out", default="BENCH_engine.json")
    ap.add_argument("--stream", action="store_true",
                    help="multi-bucket workload: bucket-spanning engine "
                         "vs per-bucket persistent drains")
    ap.add_argument("--slabs", type=int, default=10)
    ap.add_argument("--out-cap", type=int, default=4096)
    ap.add_argument("--window-sweep", type=int, nargs="+",
                    default=(4, 8, 16, 32),
                    help="--stream: window_steps values to autotune over "
                         "(best K becomes the recorded window_steps)")
    a = ap.parse_args()
    if a.stream:
        run_stream(a.n or 4000, a.m or 6, a.blob or 60,
                   a.blob_p if a.blob_p is not None else 0.7,
                   a.bucket, a.slabs, a.lanes or 32, a.out_cap, a.out,
                   window_sweep=tuple(a.window_sweep))
    else:
        run(a.n or 4000, a.m or 8, a.blob or 40,
            a.blob_p if a.blob_p is not None else 0.6,
            a.bucket, a.chunk, a.lanes or 16, a.out)
