"""Paper Fig 11: vertex visits by degree — BKdegen vs RMCEdegen.

A visit is one appearance of a vertex in a P or X set at a recursion entry
(the paper's metric behind Fig 1/11). Reported per degree bucket.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import GRAPH_SUITE, Csv
from repro.core import oracle


def visit_by_degree(g, **kw):
    s = oracle.MCEStats()
    oracle.rmce(g, stats=s, collect=False, **kw)
    deg = g.degrees()
    buckets = {}
    for v, cnt in s.vertex_visits.items():
        buckets.setdefault(int(deg[v]), [0, 0])
        buckets[int(deg[v])][0] += cnt
        buckets[int(deg[v])][1] += 1
    return buckets, s


def main(fast: bool = False) -> str:
    csv = Csv(["graph", "degree_bucket", "visits_bk", "visits_rmce",
               "reduction"])
    names = ["ba_web", "kron_social", "caveman_comm", "rgg_delaunay"]
    suite = [x for x in GRAPH_SUITE if x[0] in names]
    for name, make, _ in suite:
        g = make()
        bk, s1 = visit_by_degree(g, global_red=False, dynamic_red=False,
                                 x_red=False)
        rm, s2 = visit_by_degree(g)
        assert s1.cliques == s2.cliques
        degs = sorted(set(bk) | set(rm))
        # log-spaced degree buckets like the paper's log-scaled axis
        edges = [1, 2, 3, 4, 6, 10, 16, 25, 40, 64, 100, 10**9]
        for lo, hi in zip(edges[:-1], edges[1:]):
            vb = sum(bk.get(d, [0, 0])[0] for d in degs if lo <= d < hi)
            vr = sum(rm.get(d, [0, 0])[0] for d in degs if lo <= d < hi)
            if vb == 0 and vr == 0:
                continue
            csv.add(name, f"[{lo},{hi})", vb, vr, 1.0 - vr / max(vb, 1))
    return csv.dump("fig11: vertex visits by degree (paper: up to 88% fewer "
                    "at low degree)")


if __name__ == "__main__":
    print(main())
