"""Paper Fig 10: forbidden-set (maximality-check) reduction ratios.

r_vertex     = Σ|X'| / Σ|X| over root subproblems (pruned mass),
r_subproblem = fraction of root subproblems with X' ⊂ X.
"""
from __future__ import annotations

from benchmarks.common import GRAPH_SUITE, Csv
from repro.core import oracle


def main(fast: bool = False) -> str:
    csv = Csv(["graph", "sum_x_before", "sum_x_after", "r_vertex_pruned",
               "r_subproblem"])
    suite = GRAPH_SUITE[:4] if fast else GRAPH_SUITE
    for name, make, _ in suite:
        g = make()
        s = oracle.MCEStats()
        oracle.rmce(g, stats=s, collect=False)
        pruned = (1.0 - s.sum_x_after / s.sum_x_before
                  if s.sum_x_before else 0.0)
        rsub = s.subproblems_with_x_reduction / max(s.root_subproblems, 1)
        csv.add(name, s.sum_x_before, s.sum_x_after, pruned, rsub)
    return csv.dump("fig10: forbidden-set reduction "
                    "(paper: r_vertex up to ~50%, r_subproblem up to ~40%)")


if __name__ == "__main__":
    print(main())
