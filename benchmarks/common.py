"""Shared benchmark infrastructure: graph suite + timing + CSV emit.

The paper evaluates on 18 SNAP/NetworkRepository graphs. This container is
offline, so the suite generates synthetic stand-ins from the same structural
regimes (see repro/graph/generators.py). Claim validation targets the
paper's *relative* behaviour (speedups > 1, call ratios ≪ 1, road graphs
fully reduced, delaunay-like untouched), not absolute wall-times of a C++
binary on different hardware.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

from repro.graph import generators as gen
from repro.graph.csr import CSRGraph

# name -> (constructor, paper-regime analogue)
GRAPH_SUITE: List[Tuple[str, Callable[[], CSRGraph], str]] = [
    ("road_grid", lambda: gen.grid_road(45, 0.1, seed=1),
     "inf-road-usa / roadNet-CA (degeneracy ≤ 2, fully reducible)"),
    ("rgg_delaunay", lambda: gen.random_geometric(3000, seed=2),
     "sc-delaunay_n23 (proximity, min degree > 2)"),
    ("ba_web", lambda: gen.barabasi_albert(3000, 5, seed=3),
     "web-Google / as-skitter (power law)"),
    ("ba_dense", lambda: gen.barabasi_albert(1500, 12, seed=4),
     "soc-pokec (denser power law)"),
    ("er_sparse", lambda: gen.erdos_renyi(2500, 0.004, seed=5),
     "email-EuAll (sparse uniform)"),
    ("kron_social", lambda: gen.kronecker(11, 8, seed=6),
     "com-youtube / com-orkut (RMAT heavy tail)"),
    ("caveman_comm", lambda: gen.caveman(60, 8, 0.12, seed=7),
     "com-dblp (community cliques)"),
    ("moon_moser_12", lambda: gen.moon_moser(12),
     "worst case 3^{n/3} cliques"),
]


def timed(fn: Callable, *args, repeat: int = 1, **kw) -> Tuple[float, object]:
    best = float("inf")
    out = None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return best, out


class Csv:
    def __init__(self, header: List[str]):
        self.header = header
        self.rows: List[List] = []

    def add(self, *row) -> None:
        self.rows.append(list(row))

    def dump(self, title: str) -> str:
        out = [f"# {title}", ",".join(self.header)]
        for r in self.rows:
            out.append(",".join(_fmt(x) for x in r))
        return "\n".join(out) + "\n"


def _fmt(x) -> str:
    if isinstance(x, float):
        return f"{x:.4g}"
    return str(x)
