"""Paper Fig 8: global reduction deletion ratios (vertices & edges)."""
from __future__ import annotations

from benchmarks.common import GRAPH_SUITE, Csv
from repro.core.global_reduction import global_reduce_host


def main(fast: bool = False) -> str:
    csv = Csv(["graph", "n", "m", "v_deleted_ratio", "e_deleted_ratio",
               "pre_reported_cliques", "regime"])
    for name, make, regime in GRAPH_SUITE:
        g = make()
        red = global_reduce_host(g)
        csv.add(name, g.n, g.m,
                red.num_deleted_vertices / max(g.n, 1),
                red.num_deleted_edges / max(g.m, 1),
                len(red.reported), regime.split("(")[0].strip())
    return csv.dump("fig8: global reduction ratios "
                    "(road≈1.0, delaunay-like≈0, social in between)")


if __name__ == "__main__":
    print(main())
