"""Paper Fig 7: speedups of RMCE-enhanced backends over plain BK backends.

Both sides run in the SAME bitset-engine harness (device path) so the ratio
isolates the paper's reductions, exactly as the paper's figure isolates them
on top of each recursion backend. Wall time excludes jit compilation
(jit warmup run first).
"""
from __future__ import annotations

from benchmarks.common import GRAPH_SUITE, Csv, timed
from repro.core import engine as bitset_engine

BACKENDS = ("pivot", "rcd", "revised")


def run_engine(g, backend, reductions: bool):
    return bitset_engine.run(
        g, backend=backend, global_red=reductions, dynamic_red=reductions,
        x_red=reductions, bucket_sizes=(32, 64, 128, 256))


def main(fast: bool = False) -> str:
    csv = Csv(["graph", "backend", "t_bk_s", "t_rmce_s", "speedup",
               "cliques_bk", "cliques_rmce"])
    suite = GRAPH_SUITE[:4] if fast else GRAPH_SUITE
    for name, make, _ in suite:
        g = make()
        for backend in BACKENDS:
            run_engine(g, backend, True)      # warm jit (both variants)
            run_engine(g, backend, False)
            t_rmce, r_rmce = timed(run_engine, g, backend, True, repeat=2)
            t_bk, r_bk = timed(run_engine, g, backend, False, repeat=2)
            assert r_bk.cliques == r_rmce.cliques, (name, backend)
            csv.add(name, backend, t_bk, t_rmce, t_bk / max(t_rmce, 1e-9),
                    r_bk.cliques, r_rmce.cliques)
    return csv.dump("fig7: RMCE speedup over plain BK (same engine harness)")


if __name__ == "__main__":
    print(main())
