"""Benchmark trajectory records: append, don't overwrite, BENCH_*.json.

The perf benchmarks used to `json.dump` a single snapshot, so every CI run
erased the previous one and the "trajectory" was always one point. Each
BENCH_<name>.json now keeps the latest run's metrics at top level (compat:
consumers keep reading e.g. doc["speedup"]) plus the full history under a
"runs" key — a list of {commit, date, **metrics} records, one appended per
benchmark invocation. The commit comes from the CI env (GITHUB_SHA) with a
`git rev-parse` fallback; pre-trajectory files (no "runs" key) are migrated
in place, their old top-level metrics becoming the first record.

Record dates resolve CI pipeline date -> the commit's own `git show`
date -> wall clock (re-runs outside CI used to stamp "unknown");
`--migrate-dates` backfills old "unknown" records in place.

Validate (exit 1 + reasons on stderr for malformed files):

  PYTHONPATH=src python -m benchmarks.bench_record --validate BENCH_*.json \
      [--require KEY ...] [--migrate-dates]

The mce-smoke CI job runs this over every emitted BENCH file, so a
benchmark that regresses to snapshot-overwriting fails the build;
`--require` additionally pins the metric fields a benchmark is
contracted to emit (e.g. the stream workload's boundary_stall/steals).
"""
from __future__ import annotations

import argparse
import datetime
import json
import os
import subprocess
import sys
from typing import List

RESERVED = ("runs", "commit", "date")


def _commit() -> str:
    for var in ("GITHUB_SHA", "CI_COMMIT_SHA"):
        sha = os.environ.get(var)
        if sha:
            return sha
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, check=True).stdout.strip()
    except Exception:
        return "unknown"


def _commit_date(sha: str) -> str:
    """Committer date (ISO 8601) of `sha`, or 'unknown' off-repo."""
    if not sha or sha == "unknown":
        return "unknown"
    try:
        out = subprocess.run(
            ["git", "show", "-s", "--format=%cI", sha],
            capture_output=True, text=True, timeout=10, check=True
        ).stdout.strip()
        return out or "unknown"
    except Exception:
        return "unknown"


def _date(commit: str) -> str:
    """Record timestamp: CI pipeline date, else the commit's own date,
    else wall clock. Benchmarks re-run against an old checkout used to
    stamp 'unknown' (the CI env vars were the only source); the commit
    date keeps the trajectory orderable everywhere git is available."""
    for var in ("BENCH_DATE", "CI_PIPELINE_CREATED_AT"):
        d = os.environ.get(var)
        if d:
            return d
    d = _commit_date(commit)
    if d != "unknown":
        return d
    return (datetime.datetime.now(datetime.timezone.utc)
            .isoformat(timespec="seconds"))


def migrate_dates(path: str) -> int:
    """Backfill 'unknown' run dates in place from each record's commit date.

    Returns how many records were fixed. Records whose commit is itself
    unknown (or unresolvable in this clone) are left as-is."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        return 0
    if not isinstance(doc, dict) or not isinstance(doc.get("runs"), list):
        return 0
    fixed = 0
    for rec in doc["runs"]:
        if isinstance(rec, dict) and rec.get("date") == "unknown":
            d = _commit_date(rec.get("commit", "unknown"))
            if d != "unknown":
                rec["date"] = d
                fixed += 1
    if fixed:
        with open(path, "w") as f:
            json.dump(doc, f, indent=1)
    return fixed


def append_run(path: str, metrics: dict) -> dict:
    """Append one run record to `path`; returns the document written.

    Document shape: {**metrics, "runs": [...older records, new record]}
    with record = {"commit": ..., "date": ..., **metrics}. An existing file
    in the legacy single-snapshot schema (no "runs") contributes its
    top-level metrics as the first record.
    """
    for k in RESERVED:
        if k in metrics:
            raise ValueError(f"metric name {k!r} is reserved")
    runs: List[dict] = []
    if os.path.exists(path):
        try:
            with open(path) as f:
                old = json.load(f)
        except (OSError, ValueError):
            old = None            # unreadable snapshot: start fresh
        if isinstance(old, dict):
            if isinstance(old.get("runs"), list):
                runs = old["runs"]
            elif old:             # legacy snapshot -> first record
                runs = [dict(old, commit="unknown", date="unknown")]
    commit = _commit()
    record = dict(commit=commit, date=_date(commit), **metrics)
    doc = dict(metrics)
    doc["runs"] = runs + [record]
    with open(path, "w") as f:
        json.dump(doc, f, indent=1)
    return doc


def validate(path: str) -> List[str]:
    """Schema check for one BENCH file; returns problems (empty = valid)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        return [f"{path}: unreadable ({e})"]
    if not isinstance(doc, dict):
        return [f"{path}: top level is not an object"]
    runs = doc.get("runs")
    if not isinstance(runs, list) or not runs:
        return [f"{path}: missing or empty 'runs' list "
                "(snapshot-overwrite regression?)"]
    problems = []
    for i, rec in enumerate(runs):
        if not isinstance(rec, dict):
            problems.append(f"{path}: runs[{i}] is not an object")
            continue
        for key in ("commit", "date"):
            if not isinstance(rec.get(key), str):
                problems.append(f"{path}: runs[{i}] missing string {key!r}")
    last = runs[-1]
    if isinstance(last, dict):
        for k, v in last.items():
            if k in ("commit", "date"):
                continue
            if k not in doc:
                problems.append(f"{path}: last-run metric {k!r} not "
                                "mirrored at top level")
            elif doc[k] != v:
                problems.append(f"{path}: top-level {k!r} differs from the "
                                "last run record")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--validate", nargs="+", metavar="FILE", required=True,
                    help="BENCH json files to schema-check")
    ap.add_argument("--require", nargs="*", metavar="KEY", default=[],
                    help="metric keys that must exist at top level of "
                         "every validated file (CI pins the fields a "
                         "benchmark is contracted to emit)")
    ap.add_argument("--migrate-dates", action="store_true",
                    help="backfill 'unknown' run dates in place from each "
                         "record's commit date before validating")
    args = ap.parse_args(argv)
    problems = []
    for path in args.validate:
        if args.migrate_dates:
            n = migrate_dates(path)
            if n:
                print(f"{path}: backfilled {n} run date(s)")
        problems += validate(path)
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, ValueError):
            doc = {}
        for key in args.require:
            if not isinstance(doc, dict) or key not in doc:
                problems.append(f"{path}: required metric {key!r} missing "
                                "at top level")
    for msg in problems:
        print(msg, file=sys.stderr)
    if not problems:
        print(f"ok: {len(args.validate)} BENCH file(s) valid")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
