"""Paper Fig 9: ratio of recursive calls (RMCE* / BK*) per backend.

Counters come from the oracle implementation — instrumentation-faithful to
Algorithm 4 (one count per `recursive` entry), matching the paper's metric.
"""
from __future__ import annotations

from benchmarks.common import GRAPH_SUITE, Csv
from repro.core import oracle

BACKENDS = ("pivot", "rcd", "revised")


def main(fast: bool = False) -> str:
    csv = Csv(["graph", "backend", "calls_bk", "calls_rmce", "ratio"])
    suite = GRAPH_SUITE[:4] if fast else GRAPH_SUITE
    for name, make, _ in suite:
        g = make()
        for backend in BACKENDS:
            s_bk = oracle.MCEStats()
            oracle.rmce(g, stats=s_bk, collect=False, backend=backend,
                        global_red=False, dynamic_red=False, x_red=False)
            s_r = oracle.MCEStats()
            oracle.rmce(g, stats=s_r, collect=False, backend=backend)
            assert s_bk.cliques == s_r.cliques
            csv.add(name, backend, s_bk.recursive_calls, s_r.recursive_calls,
                    s_r.recursive_calls / max(s_bk.recursive_calls, 1))
    return csv.dump("fig9: recursive-call ratio (paper: ≤0.285 for rcd, "
                    "≤0.176 for degen)")


if __name__ == "__main__":
    print(main())
