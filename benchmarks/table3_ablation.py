"""Paper Table 3: ablation — disable one reduction at a time — plus the
branch-policy ablation (ISSUE 8): backend='pivot' vs backend='hybrid'.

Reduction ablation (default, `main()`): Variant1 = no global reduction,
Variant2 = no dynamic reduction, Variant3 = no maximality-check reduction.
Times from the bitset engine (jit-warmed, best of 2).

Branch-policy ablation (`--branching`): pivot vs hybrid branching over the
er/ba/caveman members of the graph suite × dynamic reduction on/off,
recording the tree-size counters (calls / branches / sum_px) and
wall-clock. Exact clique-count parity is asserted per config; the result —
including the best calls reduction, the acceptance criterion — is appended
to BENCH_branching.json (see benchmarks/bench_record.py for the schema).

  PYTHONPATH=src python -m benchmarks.table3_ablation --branching \
      --out BENCH_branching.json
"""
from __future__ import annotations

import argparse

from benchmarks.common import GRAPH_SUITE, Csv, timed
from repro.core import engine as bitset_engine

VARIANTS = [
    ("RMCEdegen", dict(global_red=True, dynamic_red=True, x_red=True)),
    ("Variant1_noGlobal", dict(global_red=False, dynamic_red=True, x_red=True)),
    ("Variant2_noDynamic", dict(global_red=True, dynamic_red=False, x_red=True)),
    ("Variant3_noXred", dict(global_red=True, dynamic_red=True, x_red=False)),
]

# er/ba/caveman slice of the suite for the branch-policy ablation: the
# regimes where hybrid's two checks behave differently (sparse uniform —
# little to terminate early; power law — mixed; community cliques — the
# early-termination showcase).
BRANCH_GRAPHS = [(name, make) for name, make, _ in GRAPH_SUITE
                 if name in ("er_sparse", "ba_web", "caveman_comm")]


def main(fast: bool = False) -> str:
    csv = Csv(["graph"] + [v[0] + "_s" for v in VARIANTS] + ["cliques"])
    suite = GRAPH_SUITE[:4] if fast else GRAPH_SUITE
    for name, make, _ in suite:
        g = make()
        times = []
        counts = set()
        for _, kw in VARIANTS:
            bitset_engine.run(g, bucket_sizes=(32, 64, 128, 256), **kw)  # warm
            t, r = timed(bitset_engine.run, g,
                         bucket_sizes=(32, 64, 128, 256), repeat=2, **kw)
            times.append(t)
            counts.add(r.cliques)
        assert len(counts) == 1, f"variants disagree on {name}"
        csv.add(name, *times, counts.pop())
    return csv.dump("table3: ablation — one reduction disabled at a time")


def branching(out_json: str | None = "BENCH_branching.json") -> dict:
    """pivot vs hybrid: tree-size counters + wall-clock, parity asserted.

    With dynamic reduction ON, Lemma 8 already absorbs clique-P nodes, so
    hybrid's margin there comes from X-domination pruning alone; the
    dynamic_red=False rows isolate the full early-termination effect (on
    caveman a pivot walk strips a community clique one vertex per call,
    hybrid emits it in one)."""
    rows = []
    best = None
    for name, make in BRANCH_GRAPHS:
        g = make()
        for dyn in (True, False):
            per = {}
            for backend in ("pivot", "hybrid"):
                kw = dict(backend=backend, dynamic_red=dyn,
                          bucket_sizes=(32, 64, 128, 256))
                bitset_engine.run(g, **kw)                         # warm
                t, r = timed(bitset_engine.run, g, repeat=2, **kw)
                per[backend] = (t, r)
            (tp, rp), (th, rh) = per["pivot"], per["hybrid"]
            assert rp.cliques == rh.cliques, \
                f"clique parity broken on {name} dyn={dyn}: " \
                f"{rp.cliques} vs {rh.cliques}"
            # 0 pivot calls = the graph dissolved in reductions; nothing
            # for branching to reduce, so report 0, not a vacuous 100%.
            redn = 1.0 - rh.calls / rp.calls if rp.calls else 0.0
            row = dict(graph=name, dynamic_red=dyn, cliques=rp.cliques,
                       pivot_calls=rp.calls, hybrid_calls=rh.calls,
                       pivot_branches=rp.branches,
                       hybrid_branches=rh.branches,
                       pivot_sum_px=rp.sum_px, hybrid_sum_px=rh.sum_px,
                       pivot_s=tp, hybrid_s=th, calls_reduction=redn)
            rows.append(row)
            print(f"{name:14s} dyn={int(dyn)} calls {rp.calls:>6d} -> "
                  f"{rh.calls:>6d} ({redn:+.0%})  "
                  f"time {tp:.2f}s -> {th:.2f}s  cliques={rp.cliques}",
                  flush=True)
            if best is None or redn > best["calls_reduction"]:
                best = row
    doc = dict(best_graph=best["graph"],
               best_dynamic_red=best["dynamic_red"],
               best_calls_reduction=best["calls_reduction"],
               ablation=rows)
    print(f"best calls reduction: {doc['best_calls_reduction']:.0%} on "
          f"{doc['best_graph']} (dynamic_red={doc['best_dynamic_red']})")
    if out_json:
        from benchmarks.bench_record import append_run
        append_run(out_json, doc)
    return doc


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--branching", action="store_true",
                    help="run the pivot-vs-hybrid branch-policy ablation "
                         "instead of the reduction table")
    ap.add_argument("--fast", action="store_true",
                    help="reduction table only: first 4 suite graphs")
    ap.add_argument("--out", default="BENCH_branching.json",
                    help="--branching: BENCH json to append the run to")
    args = ap.parse_args()
    if args.branching:
        branching(args.out)
    else:
        print(main(args.fast))
