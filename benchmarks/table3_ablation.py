"""Paper Table 3: ablation — disable one reduction at a time.

Variant1 = no global reduction, Variant2 = no dynamic reduction,
Variant3 = no maximality-check reduction. Times from the bitset engine
(jit-warmed, best of 2).
"""
from __future__ import annotations

from benchmarks.common import GRAPH_SUITE, Csv, timed
from repro.core import engine as bitset_engine

VARIANTS = [
    ("RMCEdegen", dict(global_red=True, dynamic_red=True, x_red=True)),
    ("Variant1_noGlobal", dict(global_red=False, dynamic_red=True, x_red=True)),
    ("Variant2_noDynamic", dict(global_red=True, dynamic_red=False, x_red=True)),
    ("Variant3_noXred", dict(global_red=True, dynamic_red=True, x_red=False)),
]


def main(fast: bool = False) -> str:
    csv = Csv(["graph"] + [v[0] + "_s" for v in VARIANTS] + ["cliques"])
    suite = GRAPH_SUITE[:4] if fast else GRAPH_SUITE
    for name, make, _ in suite:
        g = make()
        times = []
        counts = set()
        for _, kw in VARIANTS:
            bitset_engine.run(g, bucket_sizes=(32, 64, 128, 256), **kw)  # warm
            t, r = timed(bitset_engine.run, g,
                         bucket_sizes=(32, 64, 128, 256), repeat=2, **kw)
            times.append(t)
            counts.add(r.cliques)
        assert len(counts) == 1, f"variants disagree on {name}"
        csv.add(name, *times, counts.pop())
    return csv.dump("table3: ablation — one reduction disabled at a time")


if __name__ == "__main__":
    print(main())
