"""Host ingest throughput: streaming vectorized prep vs the pre-refactor path.

Measures end-to-end host preparation (reduce + order + stage + pack) of
the streaming pipeline against a frozen copy of the pre-refactor
`prepare()` — the per-vertex `np.isin` row packer and the unmemoized
X-reduction, vendored below so the baseline cannot silently inherit
later optimizations. Also runs the double-buffered distributed driver
once to record the host/device overlap fraction.

Emits BENCH_prep.json:
  {graph, n, m, roots, legacy_prep_s, stream_prep_s, speedup,
   stage_timings, overlap_fraction, device_wait_s, host_pack_s}

  PYTHONPATH=src python -m benchmarks.perf_prep \
      [--graph ba:n=20000,m=8] [--overlap-graph ba:n=4000,m=6] \
      [--out BENCH_prep.json]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

WORD = 32


# ---------------------------------------------------------------------------
# Frozen pre-refactor baseline (PR 4 state) — do NOT modernize this code;
# it is the measurement yardstick. Shared helpers the old prepare() called
# (global_reduce_host, degeneracy_order, x_prune_roots) are vendored at
# their pre-refactor state too, so later optimizations to the live copies
# cannot silently inflate the baseline.
# ---------------------------------------------------------------------------

def _common_neighbor_exists_legacy(adj, u, v, exclude=-1):
    a, b = adj[u], adj[v]
    if len(a) > len(b):
        a, b = b, a
    for w in a:
        if w != exclude and w in b:
            return w
    return -1


def _global_reduce_host_legacy(g):
    """Pre-refactor global_reduce_host: full-range python cascade."""
    from repro.graph.csr import from_edge_list

    adj = {v: set(g.neighbors(v).tolist()) for v in range(g.n)}
    reported = []
    alive = np.ones(g.n, dtype=bool)

    def kill_edge(a, b):
        adj[a].discard(b)
        adj[b].discard(a)

    def kill_vertex(v):
        for u in list(adj[v]):
            adj[u].discard(v)
        adj[v].clear()
        alive[v] = False

    queue = [v for v in range(g.n) if len(adj[v]) <= 2]
    in_q = set(queue)
    qi = 0
    while qi < len(queue):
        v = queue[qi]
        qi += 1
        in_q.discard(v)
        if not alive[v]:
            continue
        d = len(adj[v])
        if d > 2:
            continue
        neighbors = list(adj[v])
        if d == 0:
            alive[v] = False
        elif d == 1:
            (u,) = neighbors
            reported.append(frozenset((v, u)))
            kill_vertex(v)
            if alive[u] and len(adj[u]) <= 2 and u not in in_q:
                queue.append(u); in_q.add(u)
        else:
            u, w = neighbors
            if w in adj[u]:
                reported.append(frozenset((v, u, w)))
                other = _common_neighbor_exists_legacy(adj, u, w, exclude=v)
                kill_vertex(v)
                if other < 0:
                    kill_edge(u, w)
            else:
                reported.append(frozenset((v, u)))
                reported.append(frozenset((v, w)))
                kill_vertex(v)
            for t in (u, w):
                if alive[t] and len(adj[t]) <= 2 and t not in in_q:
                    queue.append(t); in_q.add(t)

    visited = set()
    edge_stack = [(u, v) for u in range(g.n) if alive[u]
                  for v in adj[u] if u < v]
    for (u, v) in edge_stack:
        if v not in adj[u]:
            continue
        if (u, v) in visited:
            continue
        w = _common_neighbor_exists_legacy(adj, u, v)
        if w < 0:
            reported.append(frozenset((u, v)))
            kill_edge(u, v)
            sub_q = [t for t in (u, v) if alive[t] and len(adj[t]) <= 2]
            while sub_q:
                t = sub_q.pop()
                if not alive[t] or len(adj[t]) > 2:
                    continue
                nbs = list(adj[t])
                if len(nbs) == 0:
                    alive[t] = False
                elif len(nbs) == 1:
                    reported.append(frozenset((t, nbs[0])))
                    kill_vertex(t)
                    sub_q.extend(x for x in nbs
                                 if alive[x] and len(adj[x]) <= 2)
                else:
                    a, b = nbs
                    if b in adj[a]:
                        reported.append(frozenset((t, a, b)))
                        other = _common_neighbor_exists_legacy(adj, a, b,
                                                               exclude=t)
                        kill_vertex(t)
                        if other < 0:
                            kill_edge(a, b)
                    else:
                        reported.append(frozenset((t, a)))
                        reported.append(frozenset((t, b)))
                        kill_vertex(t)
                    sub_q.extend(x for x in nbs
                                 if alive[x] and len(adj[x]) <= 2)
        else:
            visited.add((min(u, v), max(u, v)))
            visited.add((min(u, w), max(u, w)))
            visited.add((min(v, w), max(v, w)))

    edges = [(u, v) for u in range(g.n) if alive[u] for v in adj[u] if u < v]
    g2 = from_edge_list(g.n, np.array(edges, dtype=np.int64)
                        if edges else np.zeros((0, 2), np.int64))
    return g2, reported


def _degeneracy_order_legacy(g):
    """Pre-refactor degeneracy_order: per-vertex numpy slice + tolist."""
    n = g.n
    if n == 0:
        z = np.zeros(0, dtype=np.int64)
        return z, z, 0
    deg = g.degrees().astype(np.int64).copy()
    max_deg = int(deg.max())
    bin_start = np.zeros(max_deg + 2, dtype=np.int64)
    np.add.at(bin_start, deg + 1, 1)
    bin_start = np.cumsum(bin_start)
    bin_cur = bin_start[:-1].copy()
    vert = np.empty(n, dtype=np.int64)
    pos = np.empty(n, dtype=np.int64)
    for v in range(n):
        p = bin_cur[deg[v]]
        vert[p] = v
        pos[v] = p
        bin_cur[deg[v]] += 1
    bin_ = bin_start[:-1].copy()
    dptr, dind = g.indptr, g.indices
    degeneracy = 0
    deg_list = deg.tolist()
    pos_list = pos.tolist()
    bin_list = bin_.tolist()
    vert_list = vert.tolist()
    for i in range(n):
        v = vert_list[i]
        dv = deg_list[v]
        if dv > degeneracy:
            degeneracy = dv
        for u in dind[dptr[v]:dptr[v + 1]].tolist():
            du = deg_list[u]
            if du > dv:
                pu = pos_list[u]
                pw = bin_list[du]
                w = vert_list[pw]
                if u != w:
                    vert_list[pu] = w
                    vert_list[pw] = u
                    pos_list[u] = pw
                    pos_list[w] = pu
                bin_list[du] = pw + 1
                deg_list[u] = du - 1
    order = np.asarray(vert_list, dtype=np.int64)
    rank = np.empty(n, dtype=np.int64)
    rank[order] = np.arange(n)
    return order, rank, degeneracy


def _pack_bits_legacy(ids, words):
    out = np.zeros(words, dtype=np.uint32)
    if len(ids):
        np.bitwise_or.at(out, ids // WORD,
                         np.uint32(1) << (ids % WORD).astype(np.uint32))
    return out


def _stage_subproblem_legacy(staged, bucket_sizes, base, p_set, x_set,
                             adj_sorted, rank):
    p_ids = np.array(sorted(p_set, key=lambda u: rank[u]), dtype=np.int64)
    u_size = len(p_ids)
    bucket = next((b for b in bucket_sizes if u_size <= b), None)
    if bucket is None:
        raise ValueError(f"universe {u_size} exceeds largest bucket")
    words = bucket // WORD
    a_rows = np.zeros((bucket, words), dtype=np.uint32)
    for j, u in enumerate(p_ids):
        mask = np.isin(p_ids, adj_sorted[int(u)], assume_unique=True)
        a_rows[j] = _pack_bits_legacy(np.nonzero(mask)[0].astype(np.int64),
                                      words)
    xr = []
    for x in sorted(x_set, key=lambda u: rank[u]):
        mask = np.isin(p_ids, adj_sorted[int(x)], assume_unique=True)
        if mask.any():
            xr.append(_pack_bits_legacy(np.nonzero(mask)[0].astype(np.int64),
                                        words))
    staged[bucket].append(dict(root=base[0], base=tuple(base),
                               p0=_pack_bits_legacy(np.arange(u_size), words),
                               a=a_rows, x_rows=xr, universe=p_ids))


def _x_prune_roots_legacy(adj, order, rank):
    """Pre-memoization x-reduction: nu_plus rebuilt per (root, u) pair."""
    from repro.core.xreduction import resolve_keeps

    n = len(adj)
    ignore_id = np.full(n, n, dtype=np.int64)
    ignore_wit = np.full(n, -1, dtype=np.int64)
    kept = []
    for i in range(n):
        v = int(order[i])
        P = {u for u in adj[v] if rank[u] > i}
        X_full = {u for u in adj[v] if rank[u] < i}
        kept.append(resolve_keeps(X_full, i, ignore_id, ignore_wit, rank))
        for u in P:
            nu_plus = {w for w in adj[u] if rank[w] > rank[u]}
            if (P - {u}) <= nu_plus:
                if rank[u] < ignore_id[v]:
                    ignore_id[v] = rank[u]
                    ignore_wit[v] = u
            elif nu_plus <= P:
                if i < ignore_id[u]:
                    ignore_id[u] = i
                    ignore_wit[u] = v
    return kept


def legacy_prepare(g, bucket_sizes=(32, 64, 128, 256, 512, 1024)):
    """The pre-refactor prepare(): serial host cascade + per-row packing."""
    g_work, _reported = _global_reduce_host_legacy(g)
    order, rank, _lam = _degeneracy_order_legacy(g_work)
    adj = [set(g_work.neighbors(v).tolist()) for v in range(g_work.n)]
    adj_sorted = [g_work.neighbors(v) for v in range(g_work.n)]
    kept_x = _x_prune_roots_legacy(adj, order, rank)
    staged = {b: [] for b in bucket_sizes}
    n_roots = 0
    for i in range(g_work.n):
        v = int(order[i])
        if not adj[v]:
            continue
        p_ids = np.array(sorted((u for u in adj[v] if rank[u] > i),
                                key=lambda u: rank[u]), dtype=np.int64)
        if len(p_ids) == 0:
            continue
        _stage_subproblem_legacy(staged, bucket_sizes, (v,),
                                 set(p_ids.tolist()), kept_x[i],
                                 adj_sorted, rank)
        n_roots += 1
    return staged, n_roots


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

def run(graph_desc: str = "ba:n=20000,m=8",
        overlap_graph: str = "caveman:c=400,k=8",
        out_json: str | None = "BENCH_prep.json"):
    from repro.core.driver import DistributedMCE
    from repro.core.engine import PrepStream
    from repro.launch.mce_run import parse_graph

    g = parse_graph(graph_desc)
    print(f"graph {graph_desc}: n={g.n} m={g.m}", flush=True)

    t0 = time.perf_counter()
    _, legacy_roots = legacy_prepare(g)
    legacy_s = time.perf_counter() - t0
    print(f"legacy prepare(): {legacy_s:.2f}s ({legacy_roots} roots)",
          flush=True)

    t0 = time.perf_counter()
    stream = PrepStream(g, stream_roots=1024, cache=False)
    n_roots = sum(b.num_roots for b in stream)
    stream_s = time.perf_counter() - t0
    print(f"streaming prep:   {stream_s:.2f}s ({n_roots} roots) "
          f"stages={ {k: round(v, 2) for k, v in stream.timings.items()} }",
          flush=True)
    speedup = legacy_s / stream_s

    og = parse_graph(overlap_graph)
    # warmup pass populates the jit cache; the measured pass re-packs a
    # fresh stream against warm executables = steady-state overlap
    DistributedMCE(og, chunk=128, stream_roots=256).run()
    drv = DistributedMCE(og, chunk=128, stream_roots=256)
    res = drv.run()
    print(f"overlap run {overlap_graph}: cliques={res.cliques} "
          f"overlap={drv.overlap_fraction:.2f} "
          f"host_pack={drv.stats['host_pack_s']:.2f}s "
          f"device_wait={drv.stats['device_wait_s']:.2f}s", flush=True)

    row = dict(graph=graph_desc, n=g.n, m=g.m, roots=n_roots,
               legacy_prep_s=legacy_s, stream_prep_s=stream_s,
               speedup=speedup,
               stage_timings=stream.timings,
               overlap_graph=overlap_graph,
               overlap_fraction=drv.overlap_fraction,
               host_pack_s=drv.stats["host_pack_s"],
               device_wait_s=drv.stats["device_wait_s"])
    print(f"host-prep speedup: {speedup:.1f}x", flush=True)
    if out_json:
        from benchmarks.bench_record import append_run
        append_run(out_json, row)   # appends to "runs", keeps top-level compat
    return row


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--graph", default="ba:n=20000,m=8")
    ap.add_argument("--overlap-graph", default="caveman:c=400,k=8")
    ap.add_argument("--out", default="BENCH_prep.json")
    args = ap.parse_args()
    run(args.graph, args.overlap_graph, args.out)
